//! Train a branching zoo model end to end on the pure-Rust backend.
//!
//!     cargo run --release --example train_zoo
//!
//! Lowers the ResNet50 topology to executable `[batch, width]` tensors,
//! plans it with the approximate DP at the minimal feasible budget, and
//! trains it under both vanilla and the planned schedule — printing the
//! executor's two verified invariants: the loss/gradients are
//! bit-identical across schedules, and the observed peak equals the
//! simulator's no-liveness prediction.

use recompute::anyhow::Result;
use recompute::coordinator::train::train_zoo_model;
use recompute::exec::TrainConfig;
use recompute::fmt_bytes;
use recompute::planner::Objective;

fn main() -> Result<()> {
    let cfg = TrainConfig { layers: 0, steps: 10, lr: 0.05, seed: 7, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(model, 8, 16, &cfg, None, Objective::MinOverhead, true)?;
        println!(
            "{:<24} k={:<3} recompute/step={:<4} peak vanilla {} → planned {} (sim {})",
            cmp.model,
            cmp.k,
            cmp.planned.recomputes_per_step,
            fmt_bytes(cmp.vanilla.observed_peak),
            fmt_bytes(cmp.planned.observed_peak),
            fmt_bytes(cmp.sim_peak),
        );
        println!(
            "  gradients bit-identical: {}   observed peak == sim prediction: {}   losses identical: {}",
            cmp.grads_match, cmp.peak_matches_sim, cmp.losses_identical
        );
        assert!(cmp.grads_match && cmp.peak_matches_sim && cmp.losses_identical);
    }
    Ok(())
}
