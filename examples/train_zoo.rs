//! Train a branching zoo model end to end on the pure-Rust backend.
//!
//!     cargo run --release --example train_zoo
//!
//! Lowers the ResNet50 topology to heterogeneous `[batch, width_v]`
//! tensors (per-node widths from the model's own `M_v` profile), plans
//! it with the approximate DP at the minimal feasible budget, and
//! trains it under both vanilla and the planned schedule — printing the
//! executor's verified invariants: the loss/gradients are bit-identical
//! across schedules, the observed peak equals the simulator's
//! liveness prediction (and stays below the no-liveness ablation), and
//! the per-node activation sizes really are non-uniform.

use recompute::anyhow::Result;
use recompute::coordinator::train::{train_zoo_model, BudgetSpec};
use recompute::exec::TrainConfig;
use recompute::fmt_bytes;
use recompute::planner::Objective;
use recompute::sim::SimMode;

fn main() -> Result<()> {
    let cfg = TrainConfig { layers: 0, steps: 10, lr: 0.05, seed: 7, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(
            model,
            8,
            16,
            &cfg,
            BudgetSpec::MinFeasible,
            Objective::MinOverhead,
            SimMode::Liveness,
            true,
        )?;
        println!(
            "{:<28} k={:<3} recompute/step={:<4} peak vanilla {} → planned {} (sim {})",
            cmp.model,
            cmp.k,
            cmp.planned.recomputes_per_step,
            fmt_bytes(cmp.vanilla.observed_peak),
            fmt_bytes(cmp.planned.observed_peak),
            fmt_bytes(cmp.sim_peak),
        );
        println!(
            "  sim {}: liveness peak {} ≤ no-liveness peak {}",
            cmp.mode.label(),
            fmt_bytes(cmp.sim_peak),
            fmt_bytes(cmp.sim_peak_strict),
        );
        println!(
            "  node activation sizes: {} distinct ({} … {})",
            cmp.distinct_act_bytes,
            fmt_bytes(cmp.act_bytes_range.0),
            fmt_bytes(cmp.act_bytes_range.1),
        );
        println!(
            "  gradients bit-identical: {}   observed peak == sim prediction: {}   losses identical: {}",
            cmp.grads_match, cmp.peak_matches_sim, cmp.losses_identical
        );
        assert!(cmp.grads_match && cmp.peak_matches_sim && cmp.losses_identical);
        assert!(cmp.distinct_act_bytes >= 2, "{model}: lowering must be heterogeneous");
    }
    Ok(())
}
