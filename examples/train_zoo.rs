//! Train a branching zoo model end to end on the pure-Rust backend.
//!
//!     cargo run --release --example train_zoo
//!
//! Lowers the ResNet50 / U-Net topologies to heterogeneous
//! `[batch, width_v]` tensors (per-node widths from the model's own
//! `M_v` profile), then drives the session API: one `PlanSession` per
//! model plans *both* objectives (time-centric and memory-centric) from
//! a single lower-set family, serves the repeated requests from the
//! compiled-plan cache, and trains vanilla plus both planned schedules —
//! printing the executor's verified invariants: loss/gradients
//! bit-identical across schedules, observed peak equal to the
//! simulator's liveness prediction (and below the no-liveness ablation),
//! and genuinely non-uniform per-node activation sizes.

use recompute::anyhow::Result;
use recompute::coordinator::train::{train_zoo_model, BudgetSpec};
use recompute::exec::TrainConfig;
use recompute::fmt_bytes;
use recompute::planner::Objective;
use recompute::sim::SimMode;

fn main() -> Result<()> {
    let cfg = TrainConfig { layers: 0, steps: 10, lr: 0.05, seed: 7, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(
            model,
            8,
            16,
            &cfg,
            BudgetSpec::MinFeasible,
            &[Objective::MinOverhead, Objective::MaxOverhead],
            SimMode::Liveness,
            true,
        )?;
        println!("{} (fingerprint {}):", cmp.model, cmp.fingerprint);
        for run in &cmp.runs {
            println!(
                "  {:<4} k={:<3} recompute/step={:<4} peak vanilla {} → planned {} (sim {})",
                run.objective.label(),
                run.k,
                run.report.recomputes_per_step,
                fmt_bytes(cmp.vanilla.observed_peak),
                fmt_bytes(run.report.observed_peak),
                fmt_bytes(run.sim_peak),
            );
            println!(
                "       sim {}: liveness peak {} ≤ no-liveness peak {}",
                cmp.mode.label(),
                fmt_bytes(run.sim_peak),
                fmt_bytes(run.sim_peak_strict),
            );
            println!(
                "       grads bit-identical: {}   observed peak == sim prediction: {}   \
                 losses identical: {}   plan served from cache: {}",
                run.grads_match, run.peak_matches_sim, run.losses_identical, run.cache_hit
            );
            assert!(run.grads_match && run.peak_matches_sim && run.losses_identical);
            assert!(run.cache_hit, "{model}: repeated request must hit the plan cache");
        }
        println!(
            "  node activation sizes: {} distinct ({} … {})",
            cmp.distinct_act_bytes,
            fmt_bytes(cmp.act_bytes_range.0),
            fmt_bytes(cmp.act_bytes_range.1),
        );
        println!(
            "  session: hits={} misses={} families_built={}",
            cmp.stats.hits, cmp.stats.misses, cmp.stats.families_built
        );
        assert_eq!(cmp.stats.families_built, 1, "{model}: one family for both objectives");
        assert!(cmp.distinct_act_bytes >= 2, "{model}: lowering must be heterogeneous");
    }
    Ok(())
}
