//! End-to-end driver (deliverable E6): REAL training through the full
//! three-layer stack — L3 plan → L2/L1 AOT artifacts → PJRT execution —
//! comparing vanilla, time-centric and memory-centric schedules on the
//! same initial parameters.
//!
//! Proves the layers compose: the loss trajectory is bitwise identical
//! across schedules (recomputation's defining property) while the
//! *measured* live activation bytes drop as planned.
//!
//! ```sh
//! make artifacts          # batch/width of the manifest
//! cargo run --release --example train_mlp -- [layers] [steps]
//! ```

use std::path::PathBuf;

use recompute::coordinator::report::{loss_summary, report_json};
use recompute::exec::{ChainSchedule, TowerTrainer, TrainConfig};
use recompute::fmt_bytes;
use recompute::models::mlp_tower;
use recompute::planner::{build_context, Family, Objective};
use recompute::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let artifacts = PathBuf::from("artifacts");
    let cfg = TrainConfig { layers, steps, lr: 0.05, seed: 17, log_every: steps / 10 + 1 };

    println!("== end-to-end training: {layers}-layer tower, {steps} steps ==");
    let mut reports = Vec::new();
    for mode in ["vanilla", "tc", "mc"] {
        let mut trainer = TowerTrainer::new(&artifacts, &cfg)?;
        let g = mlp_tower(layers as u32, trainer.width() as u32, trainer.batch() as u64);
        let sched = match mode {
            "vanilla" => ChainSchedule::vanilla(layers + 1),
            _ => {
                let ctx = build_context(&g, Family::Exact);
                let b = ctx.min_feasible_budget();
                let obj = if mode == "tc" {
                    Objective::MinOverhead
                } else {
                    Objective::MaxOverhead
                };
                ChainSchedule::from_chain(&g, &ctx.solve(b, obj).unwrap().chain)?
            }
        };
        eprintln!("-- {mode}: k={} segments", sched.segments.len());
        let r = trainer.train(&sched, &cfg)?;
        println!(
            "{mode:<8} k={:<3} peak_act={:<10} step={:>7.1}ms recompute/step={:<3} {}",
            r.k,
            fmt_bytes(r.peak_bytes),
            r.mean_step_ms,
            r.recomputes_per_step,
            loss_summary(&r)
        );
        reports.push((mode.to_string(), r));
    }

    // Invariant: identical loss trajectories.
    let v = &reports[0].1;
    for (mode, r) in &reports[1..] {
        let same = v
            .losses
            .iter()
            .zip(&r.losses)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0));
        println!(
            "{mode} trajectory vs vanilla: {}",
            if same { "IDENTICAL ✓" } else { "DIVERGED ✗" }
        );
        assert!(same, "recomputation must not alter the computation");
        println!(
            "{mode} peak: {} vs vanilla {} ({:.0}% reduction)",
            fmt_bytes(r.peak_bytes),
            fmt_bytes(v.peak_bytes),
            100.0 * (1.0 - r.peak_bytes as f64 / v.peak_bytes as f64)
        );
    }

    let arr: Vec<Json> = reports.iter().map(|(m, r)| report_json(m, r)).collect();
    std::fs::write("train_mlp_report.json", Json::Arr(arr).to_string_pretty())?;
    println!("wrote train_mlp_report.json");
    Ok(())
}
