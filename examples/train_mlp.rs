//! End-to-end driver: REAL training through the full stack — L3 plan →
//! backend kernels — comparing vanilla, time-centric and memory-centric
//! schedules on the same initial parameters.
//!
//! Runs on the pure-Rust `NativeBackend`: no Python, no artifacts, no
//! native libraries. (Build with `--features xla` and use
//! `repro train --backend pjrt` to drive the AOT/PJRT path instead.)
//!
//! Proves the layers compose: the loss trajectory is bitwise identical
//! across schedules (recomputation's defining property), the *measured*
//! live activation bytes drop as planned, and the loss decreases.
//!
//! ```sh
//! cargo run --release --example train_mlp -- [layers] [steps] [width] [batch]
//! ```

use recompute::anyhow::Result;
use recompute::coordinator::report::{
    loss_summary, report_json, session_json, session_summary, timing_summary,
};
use recompute::coordinator::train::{
    compare_schedules, trajectories_identical, BudgetSpec, ScheduleMode,
};
use recompute::exec::{TowerTrainer, TrainConfig};
use recompute::fmt_bytes;
use recompute::util::json::Json;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(12);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(80);
    let width: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let batch: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(32);
    let cfg = TrainConfig { layers, steps, lr: 0.1, seed: 7, log_every: steps / 10 + 1 };

    println!(
        "== end-to-end training: {layers}-layer tower (width {width}, batch {batch}), {steps} steps, native backend =="
    );
    let (reports, session_stats, session_timing) = compare_schedules(
        || TowerTrainer::native(batch, width, &cfg),
        &cfg,
        &[ScheduleMode::Vanilla, ScheduleMode::Tc, ScheduleMode::Mc],
        BudgetSpec::MinFeasible,
        false,
    )?;
    for (mode, r) in &reports {
        println!(
            "{:<8} k={:<3} peak_act={:<10} step={:>7.2}ms recompute/step={:<3} {}",
            mode.label(),
            r.k,
            fmt_bytes(r.peak_bytes),
            r.mean_step_ms,
            r.recomputes_per_step,
            loss_summary(r)
        );
    }

    // Invariant 1: identical loss trajectories across schedules.
    let v = &reports[0].1;
    for (mode, r) in &reports[1..] {
        let same = trajectories_identical(v, r);
        println!(
            "{} trajectory vs vanilla: {}",
            mode.label(),
            if same { "IDENTICAL ✓" } else { "DIVERGED ✗" }
        );
        assert!(same, "recomputation must not alter the computation");
        println!(
            "{} peak: {} vs vanilla {} ({:.0}% reduction)",
            mode.label(),
            fmt_bytes(r.peak_bytes),
            fmt_bytes(v.peak_bytes),
            100.0 * (1.0 - r.peak_bytes as f64 / v.peak_bytes as f64)
        );
    }

    // Invariant 2: the tower actually learns the synthetic task.
    let first = v.losses.first().copied().unwrap_or(f32::NAN);
    let last = v.losses.last().copied().unwrap_or(f32::NAN);
    println!("loss trajectory: {first:.4} → {last:.4}");
    assert!(last.is_finite() && last < first, "loss must decrease: {first} → {last}");

    // One session served both planned modes: the tower's lower-set
    // family and B* were solved once.
    println!("{}", session_summary(&session_stats));
    println!("{}", timing_summary(&session_timing));
    assert_eq!(session_stats.families_built, 1);

    let mut arr: Vec<Json> =
        reports.iter().map(|(m, r)| report_json(m.label(), r)).collect();
    arr.push(Json::obj().set("session", session_json(&session_stats)));
    std::fs::write("train_mlp_report.json", Json::Arr(arr).to_string_pretty())?;
    println!("wrote train_mlp_report.json");
    Ok(())
}
