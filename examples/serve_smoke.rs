//! Smoke driver for a running `repro serve` daemon: hammer it with
//! concurrent well-formed clients while a hostile corpus runs on a
//! parallel connection, then check the daemon's own `stats` agree.
//!
//! Start the daemon first (with default limits — the oversize probe
//! assumes the stock 1 MiB request cap), then point the driver at it:
//!
//! ```sh
//! repro serve --addr 127.0.0.1:7878 &
//! cargo run --release --example serve_smoke -- 127.0.0.1:7878 --shutdown
//! ```
//!
//! Checks (the process exits non-zero on any failure):
//!   * 8 concurrent clients upload isomorphic relabelings of one graph
//!     and plan it twice each — every fingerprint matches and every
//!     repeat plan is a cache hit;
//!   * hostile lines (broken JSON, 50k-deep nesting, an overflowing
//!     byte budget, invalid UTF-8) each draw a structured `ok:false`
//!     reply on a connection that stays up, and an over-cap request is
//!     answered before the server hangs up;
//!   * `stats` reflects the traffic: cache hits > 0, ordered latency
//!     percentiles, and at least the stats request itself in flight;
//!   * 100 warm plan requests against one fingerprint come back
//!     byte-identical (the zero-copy fast path serves stored summary
//!     bytes), an id-carrying request differs only by its spliced
//!     envelope, and the daemon's `fast_path_hits` / byte counters
//!     account for the traffic;
//!   * with `--shutdown`, the daemon acknowledges and stops.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use recompute::anyhow::{anyhow, bail, Result};
use recompute::serve::ServeConfig;
use recompute::testutil::{diamond, diamond_relabeled};
use recompute::util::json::Json;

const CLIENTS: usize = 8;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(Client { writer: stream.try_clone()?, reader: BufReader::new(stream) })
    }

    fn send(&mut self, line: &str) -> Result<Json> {
        self.send_bytes(line.as_bytes())
    }

    fn send_bytes(&mut self, line: &[u8]) -> Result<Json> {
        self.writer.write_all(line)?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        self.recv()
    }

    /// Send one line and return the raw reply line, newline included —
    /// for byte-level assertions about the zero-copy fast path.
    fn send_raw(&mut self, line: &str) -> Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply)? == 0 {
            bail!("server closed the connection");
        }
        Ok(reply)
    }

    fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection");
        }
        Json::parse(line.trim()).map_err(|e| anyhow!("unparseable reply {line:?}: {e}"))
    }

    /// True once the server has closed this connection.
    fn at_eof(&mut self) -> Result<bool> {
        let mut probe = String::new();
        Ok(self.reader.read_line(&mut probe)? == 0)
    }
}

fn expect_ok(reply: &Json, what: &str) -> Result<()> {
    if reply.get("ok").as_bool() != Some(true) {
        bail!("{what} failed: {}", reply.to_string());
    }
    Ok(())
}

fn expect_err(reply: &Json, want_code: &str, what: &str) -> Result<()> {
    if reply.get("ok").as_bool() != Some(false) {
        bail!("{what}: expected a structured error, got {}", reply.to_string());
    }
    if reply.get("error").get("code").as_str() != Some(want_code) {
        bail!("{what}: expected code {want_code}, got {}", reply.to_string());
    }
    Ok(())
}

/// Poll the daemon with pings until it answers (or ~10 s pass).
fn await_daemon(addr: &str) -> Result<()> {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let up = Client::connect(addr)
            .and_then(|mut c| c.send(r#"{"cmd":"ping"}"#))
            .map(|r| r.get("reply").as_str() == Some("pong"));
        match up {
            Ok(true) => return Ok(()),
            _ if Instant::now() >= deadline => bail!("no daemon answering at {addr} after 10s"),
            _ => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

/// Eight concurrent clients, two isomorphic relabelings of one graph:
/// everyone must see the same fingerprint and repeat plans must hit.
fn hammer_clients(addr: &str) -> Result<()> {
    let fps: Vec<String> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|i| {
                s.spawn(move || -> Result<String> {
                    let mut c = Client::connect(addr)?;
                    let g = if i % 2 == 0 { diamond() } else { diamond_relabeled() };
                    // Graph::to_json is pretty-printed; the protocol is
                    // one request per line, so compact it first.
                    let graph = Json::parse(&g.to_json())?;
                    let upload = Json::obj().set("cmd", "graph_upload".into()).set("graph", graph);
                    let up = c.send(&upload.to_string())?;
                    expect_ok(&up, "graph_upload")?;
                    let fp = up
                        .get("fingerprint")
                        .as_str()
                        .ok_or_else(|| anyhow!("upload reply without a fingerprint"))?
                        .to_string();
                    let plan =
                        format!(r#"{{"cmd":"plan","fingerprint":"{fp}","planner":"exact"}}"#);
                    expect_ok(&c.send(&plan)?, "first plan")?;
                    let second = c.send(&plan)?;
                    expect_ok(&second, "second plan")?;
                    if second.get("cache_hit").as_bool() != Some(true) {
                        bail!("repeat plan was not a cache hit: {}", second.to_string());
                    }
                    Ok(fp)
                })
            })
            .collect();
        workers
            .into_iter()
            .map(|w| w.join().map_err(|_| anyhow!("client thread panicked"))?)
            .collect::<Result<Vec<String>>>()
    })?;
    if fps.iter().any(|fp| *fp != fps[0]) {
        bail!("isomorphic graphs produced different fingerprints: {fps:?}");
    }
    println!("  {CLIENTS} clients agreed on fingerprint {} and repeat plans hit", fps[0]);
    Ok(())
}

/// Abuse one connection and verify every line draws a structured error
/// while the connection stays usable; then confirm the oversize path
/// replies before hanging up.
fn hostile_corpus(addr: &str) -> Result<()> {
    let mut c = Client::connect(addr)?;
    expect_err(&c.send("definitely not json")?, "bad-json", "broken JSON")?;
    expect_err(&c.send(&"[".repeat(50_000))?, "bad-json", "50k-deep nesting")?;
    expect_err(&c.send(r#"{"cmd":"warp"}"#)?, "unknown-cmd", "unknown command")?;
    expect_err(
        &c.send(r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#)?,
        "bad-request",
        "overflowing byte budget",
    )?;
    expect_err(&c.send_bytes(b"\"\xff\xfe\"")?, "bad-utf8", "invalid UTF-8")?;
    expect_ok(&c.send(r#"{"cmd":"ping"}"#)?, "ping after the abuse")?;

    let mut big = Client::connect(addr)?;
    let cap = ServeConfig::default().max_request_bytes;
    let reply = big.send(&"a".repeat(cap + 4096))?;
    expect_err(&reply, "request-too-large", "oversized request")?;
    if !big.at_eof()? {
        bail!("the connection must be closed after an over-cap request");
    }
    println!("  hostile corpus: structured errors throughout, oversize reply before close");
    Ok(())
}

/// Hammer one fingerprint with 100 warm plan requests and hold the
/// daemon to the fast-path contract: identical requests draw
/// byte-identical reply lines (stored summary bytes, spliced envelope),
/// an id only changes the envelope, and the `fast_path_hits` counter
/// accounts for every warm hit.
fn warm_fast_path(addr: &str) -> Result<()> {
    const WARM: usize = 100;
    let mut c = Client::connect(addr)?;
    let graph = Json::parse(&diamond().to_json())?;
    let upload = Json::obj().set("cmd", "graph_upload".into()).set("graph", graph);
    let up = c.send(&upload.to_string())?;
    expect_ok(&up, "graph_upload")?;
    let fp = up
        .get("fingerprint")
        .as_str()
        .ok_or_else(|| anyhow!("upload reply without a fingerprint"))?
        .to_string();
    let plan = format!(r#"{{"cmd":"plan","fingerprint":"{fp}"}}"#);

    // The first request may compile (cache_hit:false); every line after
    // it is a warm hit and must be byte-for-byte the same reply.
    let _first = c.send_raw(&plan)?;
    let baseline = c.send_raw(&plan)?;
    if Json::parse(baseline.trim())?.get("cache_hit").as_bool() != Some(true) {
        bail!("second identical plan request must be a cache hit: {baseline:?}");
    }
    for i in 0..WARM - 1 {
        let reply = c.send_raw(&plan)?;
        if reply != baseline {
            bail!("warm reply {i} diverged:\n  {baseline:?}\nvs\n  {reply:?}");
        }
    }
    // An id-carrying request is the same stored bytes with the id
    // spliced into the envelope — removing it restores the baseline.
    let with_id = c.send_raw(&format!(r#"{{"cmd":"plan","fingerprint":"{fp}","id":"smoke"}}"#))?;
    if with_id.replace(r#""id":"smoke","#, "") != baseline {
        bail!("id must only change the envelope:\n  {baseline:?}\nvs\n  {with_id:?}");
    }

    let stats = c.send(r#"{"cmd":"stats"}"#)?;
    expect_ok(&stats, "stats")?;
    let fast_hits = stats.get("fast_path_hits").as_u64().unwrap_or(0);
    if fast_hits < WARM as u64 {
        bail!("expected ≥{WARM} fast-path hits, daemon counted {fast_hits}");
    }
    let (bin, bout) = (
        stats.get("bytes_in").as_u64().unwrap_or(0),
        stats.get("bytes_out").as_u64().unwrap_or(0),
    );
    if bin == 0 || bout == 0 {
        bail!("byte counters must move: bytes_in={bin} bytes_out={bout}");
    }
    println!(
        "  warm fast path: {WARM} byte-identical replies, {fast_hits} fast-path hits, \
         {bin}B in / {bout}B out"
    );
    Ok(())
}

/// The daemon's own accounting must reflect what we just did to it.
fn check_stats(addr: &str) -> Result<()> {
    let mut c = Client::connect(addr)?;
    let stats = c.send(r#"{"cmd":"stats"}"#)?;
    expect_ok(&stats, "stats")?;
    let hits = stats.get("cache").get("hits").as_u64().unwrap_or(0);
    if hits == 0 {
        bail!("expected cache hits after the hammering: {}", stats.to_string());
    }
    if stats.get("errors").as_u64().unwrap_or(0) < 5 {
        bail!("the hostile corpus should be counted: {}", stats.to_string());
    }
    if stats.get("inflight").as_u64().unwrap_or(0) < 1 {
        bail!("the stats request itself holds an admission slot: {}", stats.to_string());
    }
    let lat = stats.get("latency_us");
    let count = lat.get("count").as_u64().unwrap_or(0);
    let p50 = lat.get("p50_us").as_u64().unwrap_or(u64::MAX);
    let p90 = lat.get("p90_us").as_u64().unwrap_or(0);
    let p99 = lat.get("p99_us").as_u64().unwrap_or(0);
    let max = lat.get("max_us").as_u64().unwrap_or(0);
    if count == 0 || p50 > p90 || p90 > p99 || p99 > max {
        bail!("latency percentiles must be populated and ordered: {}", stats.to_string());
    }
    println!(
        "  stats: {} requests, {hits} cache hits, latency p50={p50}us p90={p90}us p99={p99}us",
        stats.get("requests").as_u64().unwrap_or(0)
    );
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Some(bad) = args.iter().find(|a| a.starts_with("--") && a.as_str() != "--shutdown") {
        bail!("unknown flag {bad}; usage: serve_smoke <host:port> [--shutdown]");
    }
    let addr = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .ok_or_else(|| anyhow!("usage: serve_smoke <host:port> [--shutdown]"))?;

    await_daemon(&addr)?;
    println!("daemon up at {addr}");
    // Hostile traffic runs concurrently with the well-formed clients:
    // abuse on one connection must not perturb its neighbours.
    std::thread::scope(|s| -> Result<()> {
        let hostile = s.spawn(|| hostile_corpus(&addr));
        hammer_clients(&addr)?;
        hostile.join().map_err(|_| anyhow!("hostile-corpus thread panicked"))?
    })?;
    warm_fast_path(&addr)?;
    check_stats(&addr)?;
    if args.iter().any(|a| a == "--shutdown") {
        let bye = Client::connect(&addr)?.send(r#"{"cmd":"shutdown"}"#)?;
        expect_ok(&bye, "shutdown")?;
        println!("  daemon acknowledged shutdown");
    }
    println!("serve smoke ok");
    Ok(())
}
