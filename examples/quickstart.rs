//! Quickstart: plan recomputation for ResNet-50, inspect the tradeoff,
//! then actually train a small tower under a plan — all in pure Rust,
//! with no Python, artifacts, or native libraries.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use recompute::anyhow::Result;
use recompute::coordinator::train::{schedule_for_mode, BudgetSpec, ScheduleMode};
use recompute::exec::{ChainSchedule, TowerTrainer, TrainConfig};
use recompute::fmt_bytes;
use recompute::models::zoo;
use recompute::planner::{build_context, Family, Objective};
use recompute::sim::{simulate, simulate_vanilla, SimOptions};

fn main() -> Result<()> {
    // 1. Build the computation graph of ResNet-50 at batch 32, 224×224.
    let g = zoo::resnet50(32, 224);
    println!(
        "ResNet-50 @ batch 32: #V={} activations={} params={}",
        g.len(),
        fmt_bytes(g.total_mem()),
        fmt_bytes(g.total_param_bytes())
    );

    // 2. Baseline: vanilla training memory.
    let vanilla = simulate_vanilla(&g, SimOptions::default());
    println!("vanilla peak: {}", fmt_bytes(vanilla.peak_total));

    // 3. Plan at the minimal feasible budget (the paper's Table-1 setup).
    let ctx = build_context(&g, Family::Approx);
    let budget = ctx.min_feasible_budget();
    println!("minimal feasible budget B* = {}", fmt_bytes(budget));

    // 4. Time-centric vs memory-centric strategies.
    for (label, obj) in
        [("time-centric", Objective::MinOverhead), ("memory-centric", Objective::MaxOverhead)]
    {
        let sol = ctx.solve(budget, obj).expect("B* is feasible by construction");
        let measured = simulate(&g, &sol.chain, SimOptions::default());
        println!(
            "{label:<14} k={:<3} overhead=+{:.0}% of fwd  peak={} (-{:.0}% vs vanilla)",
            sol.chain.k(),
            100.0 * sol.overhead as f64 / g.total_time() as f64,
            fmt_bytes(measured.peak_total),
            100.0 * (1.0 - measured.peak_total as f64 / vanilla.peak_total as f64)
        );
    }

    // 5. Plans execute, not just simulate: train an 8-layer tower for a few
    //    steps on the native backend, under a real recomputation schedule,
    //    and watch the measured peak drop while losses match bitwise.
    let (batch, width) = (16usize, 32usize);
    let cfg = TrainConfig { layers: 8, steps: 5, lr: 0.05, seed: 7, log_every: 0 };
    let tc =
        schedule_for_mode(ScheduleMode::Tc, cfg.layers, width, batch, BudgetSpec::MinFeasible)?;
    let mut trainer = TowerTrainer::native(batch, width, &cfg)?;
    let planned = trainer.train(&tc, &cfg)?;
    let mut vanilla_t = TowerTrainer::native(batch, width, &cfg)?;
    let baseline = vanilla_t.train(&ChainSchedule::vanilla(cfg.layers + 1), &cfg)?;
    println!(
        "executed on {}: vanilla peak {} → planned (k={}) peak {}, losses identical: {}",
        planned.backend,
        fmt_bytes(baseline.peak_bytes),
        planned.k,
        fmt_bytes(planned.peak_bytes),
        planned
            .losses
            .iter()
            .zip(&baseline.losses)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0)),
    );
    Ok(())
}
