//! Plan every Table-1 network with the fast planner and print a
//! Table-1-shaped summary (ApproxDP MC/TC vs Chen vs vanilla).
//!
//! ```sh
//! cargo run --release --example plan_zoo
//! ```

use recompute::bench::tables;
use recompute::fmt_bytes;
use recompute::models::zoo::TABLE1;
use recompute::planner::{build_context, chen_plan, Family, Objective};
use recompute::sim::{simulate, simulate_vanilla, SimOptions};
use recompute::util::table::Table;

fn main() -> recompute::anyhow::Result<()> {
    let mut t =
        Table::new(&["Network", "ApproxDP+MC", "ApproxDP+TC", "Chen's", "Vanilla", "paper MC"])
            .numeric();
    for e in TABLE1 {
        let g = e.build_paper();
        let opts = SimOptions::default();
        let vanilla = simulate_vanilla(&g, opts).peak_total;
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let cell = |obj| {
            let sol = ctx.solve(b, obj).unwrap();
            let p = simulate(&g, &sol.chain, opts).peak_total;
            format!("{} (-{:.0}%)", fmt_bytes(p), 100.0 * (1.0 - p as f64 / vanilla as f64))
        };
        let chen = {
            let plan = chen_plan(&g, |c| simulate(&g, c, opts).peak_total).unwrap();
            let p = simulate(&g, &plan.chain, opts).peak_total;
            format!("{} (-{:.0}%)", fmt_bytes(p), 100.0 * (1.0 - p as f64 / vanilla as f64))
        };
        t.row(vec![
            e.name.to_string(),
            cell(Objective::MaxOverhead),
            cell(Objective::MinOverhead),
            chen,
            fmt_bytes(vanilla),
            format!("{} GB (-{:.0}%)", e.paper.approx_mc_gb,
                100.0 * (1.0 - e.paper.approx_mc_gb / e.paper.vanilla_gb)),
        ]);
    }
    println!("{}", t.render());
    println!("(device reference: {})", fmt_bytes(tables::DEVICE_BYTES));
    Ok(())
}
