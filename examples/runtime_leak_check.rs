//! Regression check for the PJRT input-buffer leak (EXPERIMENTS §Perf-L3-2).
//!
//! The published `xla` crate's `execute` C shim leaks every input buffer
//! (`BufferFromHostLiteral(..).release()` with no matching free). The
//! runtime works around it with caller-owned buffers + `execute_b`; this
//! example hammers an artifact for 300 iterations and asserts RSS stays
//! flat.
//!
//! ```sh
//! make artifacts && cargo run --release --example runtime_leak_check
//! ```

use recompute::runtime::{literal_f32, ArtifactSet};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() {
    let arts = ArtifactSet::load(std::path::Path::new("artifacts")).unwrap();
    let w = arts.width;
    let wm = vec![1.0f32; w * w];
    let gm = vec![0.1f32; w * w];
    let baseline = {
        // Warm up allocator + executable caches first.
        let mut cur = literal_f32(&wm, &[w, w]).unwrap();
        for _ in 0..20 {
            let g = literal_f32(&gm, &[w, w]).unwrap();
            let lr = literal_f32(&[0.01], &[]).unwrap();
            cur = arts.run("sgd_mat", &[cur, g, lr]).unwrap().pop().unwrap();
        }
        rss_mb()
    };
    let mut cur = literal_f32(&wm, &[w, w]).unwrap();
    for i in 0..300 {
        let g = literal_f32(&gm, &[w, w]).unwrap();
        let lr = literal_f32(&[0.01], &[]).unwrap();
        cur = arts.run("sgd_mat", &[cur, g, lr]).unwrap().pop().unwrap();
        if i % 100 == 0 {
            println!("iter {i:>3}  rss {:.1} MB", rss_mb());
        }
    }
    drop(cur);
    let end = rss_mb();
    println!("baseline {baseline:.1} MB → end {end:.1} MB");
    let mat_mb = (w * w * 4) as f64 / 1e6;
    assert!(
        end - baseline < 40.0 * mat_mb.max(1.0),
        "RSS grew by {:.1} MB over 300 iters — input buffers are leaking again",
        end - baseline
    );
    println!("runtime_leak_check OK");
}
