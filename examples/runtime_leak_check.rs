//! Regression check: hammering the backend's hot kernel must keep RSS
//! flat — no per-call buffer leaks.
//!
//! History: the published `xla` crate's `execute` C shim leaked every
//! input buffer (`BufferFromHostLiteral(..).release()` with no matching
//! free; EXPERIMENTS §Perf-L3-2), which this example was written to
//! catch. The same harness now guards the default `NativeBackend`: its
//! `Rc`-shared tensors would show up here just the same if a reference
//! cycle or an unbounded stats structure ever kept buffers alive.
//!
//! ```sh
//! cargo run --release --example runtime_leak_check
//! ```

use recompute::runtime::{Backend, NativeBackend};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() {
    let w = 256usize;
    let be = NativeBackend::new(32, w);
    let wm = vec![1.0f32; w * w];
    let gm = vec![0.1f32; w * w];
    let baseline = {
        // Warm up allocator caches first.
        let mut cur = be.upload(&wm, &[w, w]).unwrap();
        for _ in 0..20 {
            let g = be.upload(&gm, &[w, w]).unwrap();
            let lr = be.upload(&[0.01], &[]).unwrap();
            cur = be.run("sgd_mat", &[cur, g, lr]).unwrap().pop().unwrap();
        }
        rss_mb()
    };
    let mut cur = be.upload(&wm, &[w, w]).unwrap();
    for i in 0..300 {
        let g = be.upload(&gm, &[w, w]).unwrap();
        let lr = be.upload(&[0.01], &[]).unwrap();
        cur = be.run("sgd_mat", &[cur, g, lr]).unwrap().pop().unwrap();
        if i % 100 == 0 {
            println!("iter {i:>3}  rss {:.1} MB", rss_mb());
        }
    }
    drop(cur);
    let end = rss_mb();
    println!("baseline {baseline:.1} MB → end {end:.1} MB");
    let mat_mb = (w * w * 4) as f64 / 1e6;
    assert!(
        end - baseline < 40.0 * mat_mb.max(1.0),
        "RSS grew by {:.1} MB over 300 iters — kernel buffers are leaking",
        end - baseline
    );
    let stats = be.stats();
    let sgd = stats.iter().find(|s| s.kernel == "sgd_mat").unwrap();
    assert_eq!(sgd.calls, 320, "stats must count every call");
    println!("runtime_leak_check OK ({} sgd_mat calls tracked)", sgd.calls);
}
