//! The budget ↔ overhead tradeoff frontier: sweep the memory budget from
//! B* to vanilla scale and plot (textually) the minimal recomputation
//! overhead at each point — the tradeoff the general recomputation
//! problem (§3) formalizes.
//!
//! ```sh
//! cargo run --release --example memory_frontier -- [network]
//! ```

use recompute::fmt_bytes;
use recompute::models::zoo;
use recompute::planner::{build_context, Family, Objective};

fn main() -> recompute::anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "ResNet50".into());
    let e = zoo::find(&name)
        .ok_or_else(|| recompute::anyhow::Error::msg(format!("unknown network {name}")))?;
    let g = e.build_paper();
    let ctx = build_context(&g, Family::Approx);
    let b_star = ctx.min_feasible_budget();
    let fwd = g.total_time() as f64;
    println!("== {} — overhead vs budget frontier (B* = {}) ==", e.name, fmt_bytes(b_star));
    println!("{:>12} {:>10} {:>8}  bar", "budget", "overhead", "+fwd%");
    for pct in [100u64, 110, 125, 150, 200, 300, 400, 600, 800] {
        let budget = b_star * pct / 100;
        let sol = ctx.solve(budget, Objective::MinOverhead).unwrap();
        let frac = sol.overhead as f64 / fwd;
        let bar = "#".repeat((frac * 50.0) as usize);
        println!(
            "{:>12} {:>10} {:>7.0}%  {bar}",
            fmt_bytes(budget),
            sol.overhead,
            frac * 100.0
        );
    }
    Ok(())
}
