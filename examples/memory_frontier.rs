//! The budget ↔ overhead tradeoff frontier: sweep the memory budget from
//! B* to vanilla scale and plot (textually) the minimal recomputation
//! overhead at each point — the tradeoff the general recomputation
//! problem (§3) formalizes.
//!
//! The sweep runs through [`recompute::planner::DpContext::solve_frontier`]:
//! every budget row is an independent DP solve, sharded across the
//! worker pool (`REPRO_THREADS` controls the width; the rows are
//! bit-identical at any thread count).
//!
//! ```sh
//! cargo run --release --example memory_frontier -- [network]
//! ```

use recompute::fmt_bytes;
use recompute::models::zoo;
use recompute::planner::{build_context, Family, Objective};
use recompute::util::pool;

fn main() -> recompute::anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "ResNet50".into());
    let e = zoo::find(&name)
        .ok_or_else(|| recompute::anyhow::Error::msg(format!("unknown network {name}")))?;
    let g = e.build_paper();
    let ctx = build_context(&g, Family::Approx);
    let b_star = ctx.min_feasible_budget();
    let fwd = g.total_time() as f64;
    let pool = pool::global();
    println!(
        "== {} — overhead vs budget frontier (B* = {}, {} threads) ==",
        e.name,
        fmt_bytes(b_star),
        pool.threads()
    );
    println!("{:>12} {:>10} {:>8}  bar", "budget", "overhead", "+fwd%");
    let pcts = [100u64, 110, 125, 150, 200, 300, 400, 600, 800];
    let budgets: Vec<u64> = pcts.iter().map(|pct| b_star * pct / 100).collect();
    let rows = ctx.solve_frontier(&budgets, Objective::MinOverhead, &pool);
    for (budget, sol) in budgets.iter().zip(rows) {
        let sol = sol.expect("budgets ≥ B* are feasible");
        let frac = sol.overhead as f64 / fwd;
        let bar = "#".repeat((frac * 50.0) as usize);
        println!(
            "{:>12} {:>10} {:>7.0}%  {bar}",
            fmt_bytes(*budget),
            sol.overhead,
            frac * 100.0
        );
    }
    Ok(())
}
