//! Guard against silent bench-schema drift: compare a committed
//! `BENCH_*.json` against a freshly generated one (usually from a
//! `BENCH_QUICK=1` run in CI) and fail if the fresh file introduces
//! result names or keys the committed file does not carry.
//!
//! Rules (quick mode trims iteration counts, never renames):
//!   * both files must describe the same `suite`;
//!   * every fresh result name must exist in the committed file — a new
//!     or renamed benchmark means the committed JSON is stale;
//!   * every result (both files) must carry exactly the canonical keys
//!     `{name, iters, min_ms, median_ms, mean_ms, max_ms}` with positive
//!     finite timings and `iters ≥ 1`;
//!   * the `planner` suite must keep at least one `decomposed_*` result
//!     — the divide-and-conquer section must not silently drop out —
//!     and at least one `audit_*` result — the static-auditor overhead
//!     guard must not silently drop out;
//!   * the `runtime` suite must keep at least one `serve_*` result —
//!     the daemon-dispatch section (lazy fast path vs eager pipeline)
//!     must not silently drop out.
//!
//! ```sh
//! cargo run --example bench_schema_check -- committed.json fresh.json
//! ```

use recompute::anyhow::{anyhow, bail, Result};
use recompute::util::json::Json;

const KEYS: [&str; 6] = ["name", "iters", "min_ms", "median_ms", "mean_ms", "max_ms"];

/// Parse one bench report, validate every result row, and return
/// `(suite, result names)` in file order.
fn load(path: &str) -> Result<(String, Vec<String>)> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path}: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    let suite = doc
        .get("suite")
        .as_str()
        .ok_or_else(|| anyhow!("{path}: missing string field 'suite'"))?
        .to_string();
    let results =
        doc.get("results").as_arr().ok_or_else(|| anyhow!("{path}: missing 'results' array"))?;
    let mut names = Vec::with_capacity(results.len());
    for (i, r) in results.iter().enumerate() {
        let obj = r.as_obj().ok_or_else(|| anyhow!("{path}: results[{i}] is not an object"))?;
        let keys: Vec<&str> = obj.keys().map(String::as_str).collect();
        let mut want = KEYS.to_vec();
        want.sort_unstable();
        if keys != want {
            bail!("{path}: results[{i}] keys {keys:?} differ from the schema {want:?}");
        }
        let name = r
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("{path}: results[{i}].name is not a string"))?;
        if r.get("iters").as_u64().unwrap_or(0) < 1 {
            bail!("{path}: {name}: iters must be ≥ 1");
        }
        for key in ["min_ms", "median_ms", "mean_ms", "max_ms"] {
            let v = r.get(key).as_f64().unwrap_or(f64::NAN);
            if !v.is_finite() || v <= 0.0 {
                bail!("{path}: {name}: {key} must be positive and finite, got {v}");
            }
        }
        names.push(name.to_string());
    }
    Ok((suite, names))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let [committed, fresh] = args.as_slice() else {
        bail!("usage: bench_schema_check <committed.json> <fresh.json>");
    };
    let (committed_suite, committed_names) = load(committed)?;
    let (fresh_suite, fresh_names) = load(fresh)?;
    if committed_suite != fresh_suite {
        bail!("suite mismatch: committed '{committed_suite}' vs fresh '{fresh_suite}'");
    }
    let missing: Vec<&String> =
        fresh_names.iter().filter(|n| !committed_names.contains(*n)).collect();
    if !missing.is_empty() {
        bail!(
            "fresh results not present in {committed}: {missing:?} — \
             re-run the full bench and commit the refreshed JSON"
        );
    }
    // The name-subset rule above would pass trivially if a refactor
    // dropped a whole section; pin the one this repo's perf story
    // depends on.
    if fresh_suite == "planner" && !fresh_names.iter().any(|n| n.starts_with("decomposed_")) {
        bail!("planner suite lost its decomposed_* results — keep the divide-and-conquer section");
    }
    if fresh_suite == "planner" && !fresh_names.iter().any(|n| n.starts_with("audit_")) {
        bail!("planner suite lost its audit_* results — keep the static-auditor overhead guard");
    }
    if fresh_suite == "runtime" && !fresh_names.iter().any(|n| n.starts_with("serve_")) {
        bail!("runtime suite lost its serve_* results — keep the daemon-dispatch section");
    }
    println!(
        "schema ok: suite '{committed_suite}', {}/{} fresh results covered by the committed file",
        fresh_names.len(),
        committed_names.len(),
    );
    Ok(())
}
