"""AOT pipeline tests: lowering succeeds, manifest is sane, HLO text is
parseable."""

import json
import os
import subprocess
import sys
import tempfile

from compile import aot, model


def test_artifact_specs_cover_all_training_ops():
    specs = aot.artifact_specs(4, 16)
    assert set(specs) == {
        "layer_fwd",
        "layer_bwd",
        "loss_head",
        "loss_head_bwd",
        "sgd_mat",
        "sgd_vec",
    }


def test_lowering_produces_hlo_text():
    text = aot.to_hlo_text(model.sgd_vec, aot.f32(8), aot.f32(8), aot.f32())
    assert "HloModule" in text
    assert "f32[8]" in text


def test_cli_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as d:
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", d, "--batch", "4",
             "--width", "16"],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        assert manifest["batch"] == 4 and manifest["width"] == 16
        for name, meta in manifest["artifacts"].items():
            path = os.path.join(d, meta["file"])
            assert os.path.exists(path), name
            head = open(path).read(200)
            assert "HloModule" in head


def test_pallas_lowering_is_inlined_not_custom_call():
    """interpret=True must lower to plain HLO (no Mosaic custom-call) so
    the CPU PJRT client can run it."""
    text = aot.to_hlo_text(
        model.layer_fwd, aot.f32(8, 16), aot.f32(16, 16), aot.f32(16)
    )
    assert "custom-call" not in text or "Mosaic" not in text
