"""L2 model tests: loss head, SGD, and the whole-step reference."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), jnp.float32)


def test_loss_head_bwd_consistent_with_fwd():
    h, w, b, y = rand((16, 32), 0), rand((32, 32), 1), rand((32,), 2), rand((16, 32), 3)
    (loss_fwd,) = model.loss_head(h, w, b, y)
    loss_bwd, gh, gw, gb = model.loss_head_bwd(h, w, b, y)
    np.testing.assert_allclose(loss_fwd, loss_bwd, rtol=1e-6)
    _, rgh, rgw, rgb = ref.loss_bwd_ref(h, w, b, y)
    np.testing.assert_allclose(gh, rgh, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gw, rgw, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gb, rgb, rtol=1e-5, atol=1e-6)


def test_sgd_updates():
    w, gw = rand((8, 8), 0), rand((8, 8), 1)
    (w2,) = model.sgd_mat(w, gw, jnp.float32(0.1))
    np.testing.assert_allclose(w2, w - 0.1 * gw, rtol=1e-6)
    b, gb = rand((8,), 2), rand((8,), 3)
    (b2,) = model.sgd_vec(b, gb, jnp.float32(0.01))
    np.testing.assert_allclose(b2, b - 0.01 * gb, rtol=1e-6)


def test_manual_layerwise_backprop_matches_autodiff():
    """The exact sequence the Rust executor runs (fwd layers, loss bwd,
    layer bwds, SGD) must equal monolithic jax value_and_grad."""
    layers, width, batch, lr = 3, 16, 8, 0.05
    params = model.init_tower(jax.random.PRNGKey(0), layers, width)
    x, y = rand((batch, width), 10), rand((batch, width), 11)

    ref_loss, ref_params = model.tower_reference_step(params, x, y, jnp.float32(lr))

    acts = [x]
    h = x
    for (w, b) in params[:-1]:
        (h,) = model.layer_fwd(h, w, b)
        acts.append(h)
    w_out, b_out = params[-1]
    loss, gh, gw_out, gb_out = model.loss_head_bwd(h, w_out, b_out, y)
    new_params = [None] * len(params)
    new_params[-1] = (w_out - lr * gw_out, b_out - lr * gb_out)
    for i in reversed(range(layers)):
        w, b = params[i]
        gx, gw, gb = model.layer_bwd(acts[i], w, b, gh)
        new_params[i] = (w - lr * gw, b - lr * gb)
        gh = gx

    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)
    for (got_w, got_b), (want_w, want_b) in zip(new_params, ref_params):
        np.testing.assert_allclose(got_w, want_w, rtol=3e-4, atol=3e-5)
        np.testing.assert_allclose(got_b, want_b, rtol=3e-4, atol=3e-5)


def test_init_tower_shapes():
    params = model.init_tower(jax.random.PRNGKey(1), 4, 32)
    assert len(params) == 5
    for w, b in params:
        assert w.shape == (32, 32) and b.shape == (32,)
