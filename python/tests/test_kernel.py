"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes; every sweep asserts the Pallas kernels
(interpret mode) match the pure-jnp/autodiff oracles to float32
tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense as k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), jnp.float32)


dims = st.sampled_from([1, 2, 3, 4, 8, 16, 31, 64, 128])


@settings(max_examples=25, deadline=None)
@given(m=dims, kdim=dims, n=dims, seed=st.integers(0, 2**16))
def test_fwd_matches_ref(m, kdim, n, seed):
    x = rand((m, kdim), seed)
    w = rand((kdim, n), seed + 1)
    b = rand((n,), seed + 2)
    got = k.fused_dense_fwd(x, w, b)
    want = ref.dense_fwd_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(m=dims, kdim=dims, n=dims, seed=st.integers(0, 2**16))
def test_bwd_matches_autodiff(m, kdim, n, seed):
    x = rand((m, kdim), seed)
    w = rand((kdim, n), seed + 1)
    b = rand((n,), seed + 2)
    gh = rand((m, n), seed + 3)
    gx, gw, gb = k.fused_dense_bwd(x, w, b, gh)
    rgx, rgw, rgb = ref.dense_bwd_ref(x, w, b, gh)
    np.testing.assert_allclose(gx, rgx, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gw, rgw, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(gb, rgb, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n", [(128, 128), (256, 128), (128, 256), (384, 384)])
def test_fwd_tiled_grid_matches_single_block(m, n):
    """Tiling must be value-invariant: 128-blocks vs one big block."""
    kdim = 64
    x = rand((m, kdim), 7)
    w = rand((kdim, n), 8)
    b = rand((n,), 9)
    tiled = k.fused_dense_fwd(x, w, b, block_m=128, block_n=128)
    single = k.fused_dense_fwd(x, w, b, block_m=m, block_n=n)
    np.testing.assert_allclose(tiled, single, rtol=1e-6, atol=1e-6)


def test_gelu_derivative_formula():
    """The hand-derived dgelu in the bwd kernel vs autodiff of jax.nn.gelu."""
    x = rand((64,), 3)
    got = jax.vmap(jax.grad(lambda t: jax.nn.gelu(t, approximate=True)))(x)
    c = jnp.sqrt(2.0 / jnp.pi)
    t = jnp.tanh(c * (x + 0.044715 * x**3))
    dgelu = 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * x**2)
    np.testing.assert_allclose(dgelu, got, rtol=1e-5, atol=1e-6)


def test_non_divisible_shapes_fall_back_to_single_block():
    x = rand((100, 30), 1)
    w = rand((30, 70), 2)
    b = rand((70,), 3)
    got = k.fused_dense_fwd(x, w, b)
    np.testing.assert_allclose(got, ref.dense_fwd_ref(x, w, b), rtol=1e-5, atol=1e-5)
