"""Layer-1 Pallas kernels: fused dense layer (matmul + bias + GELU).

The execution engine's compute hot-spot is the per-layer forward and
backward of the MLP/transformer towers it trains. Both directions are
written as Pallas kernels so the whole layer is one fused kernel instead
of a matmul + bias-add + activation chain.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):

- the forward kernel is tiled for the 128x128 MXU systolic array: the
  grid walks (batch/bm, width/bn) output tiles with the full contraction
  dimension resident in VMEM; block sizes are clamped to the actual array
  sizes so small problems still lower;
- VMEM footprint per grid cell is (bm*K + K*bn + bm*bn + bn) * 4 bytes,
  kept under the ~16 MiB VMEM budget by the default bm = bn = 128 and the
  K <= 8192 widths this repo trains;
- `interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls, so kernels run through the Pallas interpreter (bitwise
  the same math), and real-TPU efficiency is estimated statically in
  EXPERIMENTS.md §Perf.

Correctness is pinned against the pure-jnp oracle in `ref.py` by
`python/tests/test_kernel.py` (hypothesis sweeps shapes and dtypes).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    # tanh-approximation GELU, matching jax.nn.gelu's default.
    return jax.nn.gelu(x, approximate=True)


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref):
    """One (bm, bn) output tile: o = gelu(x @ w + b)."""
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = _gelu(acc + b[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def fused_dense_fwd(x, w, b, *, block_m: int = 128, block_n: int = 128):
    """Forward: ``gelu(x @ w + b)`` with an MXU-tiled Pallas kernel.

    Args:
      x: ``[B, K]`` activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
    Returns:
      ``[B, N]`` activations.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,)
    bm = min(block_m, m)
    bn = min(block_n, n)
    # Pad-free tiling only: fall back to one block when not divisible.
    if m % bm or n % bn:
        bm, bn = m, n
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _fwd_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, b)


def _bwd_kernel(x_ref, w_ref, b_ref, gh_ref, gx_ref, gw_ref, gb_ref):
    """Full backward of the fused layer in one kernel.

    Recomputes the pre-activation (cheap vs caching it — this is the
    paper's recomputation idea applied *inside* the layer), then produces
    all three gradients. Runs as a single grid cell: the towers trained
    here keep B, K, N <= 2048 so all operands fit VMEM on a real TPU; a
    production multi-tile variant would privatize gw/gb per tile and
    reduce, which does not change the math checked against the oracle.
    """
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    gh = gh_ref[...]
    pre = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    # d/dpre gelu(pre), tanh approximation (matches jax.nn.gelu).
    c = jnp.sqrt(2.0 / jnp.pi).astype(jnp.float32)
    t = jnp.tanh(c * (pre + 0.044715 * pre**3))
    dgelu = 0.5 * (1.0 + t) + 0.5 * pre * (1.0 - t**2) * c * (1.0 + 3 * 0.044715 * pre**2)
    dpre = gh * dgelu.astype(gh.dtype)
    gx_ref[...] = jnp.dot(dpre, w.T, preferred_element_type=jnp.float32).astype(gx_ref.dtype)
    gw_ref[...] = jnp.dot(x.T, dpre, preferred_element_type=jnp.float32).astype(gw_ref.dtype)
    gb_ref[...] = jnp.sum(dpre, axis=0).astype(gb_ref.dtype)


@jax.jit
def fused_dense_bwd(x, w, b, gh):
    """Backward: gradients of ``gelu(x @ w + b)`` w.r.t. x, w, b.

    Args:
      x: ``[B, K]`` layer input (cached or recomputed by the L3 plan).
      w: ``[K, N]`` weights, b: ``[N]`` bias.
      gh: ``[B, N]`` gradient w.r.t. the layer output.
    Returns:
      ``(gx [B,K], gw [K,N], gb [N])``.
    """
    m, k = x.shape
    _, n = w.shape
    return pl.pallas_call(
        _bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m, k), x.dtype),
            jax.ShapeDtypeStruct((k, n), w.dtype),
            jax.ShapeDtypeStruct((n,), b.dtype),
        ),
        interpret=True,
    )(x, w, b, gh)
