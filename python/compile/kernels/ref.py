"""Pure-jnp oracles for the Pallas kernels.

These are the single source of mathematical truth: the kernels in
`fused_dense.py` must match them to float tolerance for every shape the
tests sweep, and the L2 model composes *these* in its own unit tests so a
kernel bug cannot hide behind a model bug.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def dense_fwd_ref(x, w, b):
    """gelu(x @ w + b)."""
    return gelu(x @ w + b[None, :])


def dense_bwd_ref(x, w, b, gh):
    """Gradients of dense_fwd_ref via jax autodiff (the gold standard)."""
    _, vjp = jax.vjp(lambda x_, w_, b_: dense_fwd_ref(x_, w_, b_), x, w, b)
    return vjp(gh)


def loss_fwd_ref(h, w, b, y):
    """MSE regression head: mean((h @ w + b - y)^2)."""
    pred = h @ w + b[None, :]
    return jnp.mean((pred - y) ** 2)


def loss_bwd_ref(h, w, b, y):
    """(loss, gh, gw, gb) of the regression head."""
    loss, vjp = jax.vjp(lambda h_, w_, b_: loss_fwd_ref(h_, w_, b_, y), h, w, b)
    gh, gw, gb = vjp(jnp.ones_like(loss))
    return loss, gh, gw, gb
