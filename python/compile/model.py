"""Layer-2 JAX model: the compute graphs the Rust executor trains.

Each function here is one AOT artifact: the L3 coordinator sequences them
according to a recomputation plan, so the *unit of caching/recomputation*
(one tower layer) is exactly the unit of compilation. Layer forward /
backward call the Layer-1 Pallas kernels; the loss head and SGD updates
are small pure-jnp graphs.

Python never runs at training time — `aot.py` lowers everything in this
file to HLO text once, and the Rust side loads the artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels import fused_dense as kernels
from .kernels import ref


def layer_fwd(x, w, b):
    """One fused dense layer: gelu(x @ w + b) (Pallas kernel)."""
    return (kernels.fused_dense_fwd(x, w, b),)


def layer_bwd(x, w, b, gh):
    """Backward of one layer: (gx, gw, gb) (Pallas kernel)."""
    return kernels.fused_dense_bwd(x, w, b, gh)


def loss_head(h, w, b, y):
    """Forward of the MSE regression head: scalar loss."""
    return (ref.loss_fwd_ref(h, w, b, y),)


def loss_head_bwd(h, w, b, y):
    """Loss + gradients of the head in one artifact: (loss, gh, gw, gb).

    Fusing the loss value into the backward artifact means the training
    loop gets its loss curve for free — no extra forward execution.
    """
    return ref.loss_bwd_ref(h, w, b, y)


def sgd_mat(w, gw, lr):
    """SGD update for a weight matrix; lr is a scalar operand so one
    artifact serves any schedule."""
    return (w - lr * gw,)


def sgd_vec(b, gb, lr):
    """SGD update for a bias vector."""
    return (b - lr * gb,)


def tower_reference_step(params, x, y, lr):
    """Whole-step reference: full forward + backward + SGD for an
    n-layer tower, in one jax graph (no recomputation).

    Not exported as an artifact — used by tests to verify that the Rust
    executor's layer-by-layer orchestration computes the same loss and
    the same updated parameters as monolithic JAX autodiff.
    """

    def loss_fn(ps):
        # ref.dense_fwd_ref is the verified twin of the Pallas kernel
        # (pallas_call is not differentiable; the kernel-vs-ref tests pin
        # them to float tolerance, so autodiff through the ref is exact).
        h = x
        for (w, b) in ps[:-1]:
            h = ref.dense_fwd_ref(h, w, b)
        w_out, b_out = ps[-1]
        return ref.loss_fwd_ref(h, w_out, b_out, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params = [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, grads)]
    return loss, new_params


def init_tower(key, layers: int, width: int):
    """He-initialized tower parameters: `layers` hidden + 1 head."""
    params = []
    for _ in range(layers + 1):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (width, width), jnp.float32) * jnp.sqrt(2.0 / width)
        b = jnp.zeros((width,), jnp.float32)
        params.append((w, b))
    return params
