"""Static TPU performance analysis of the L1 Pallas kernels.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so kernel
performance is assessed structurally, as the session contract prescribes:
VMEM footprint per grid cell and MXU-utilization upper bound from
arithmetic intensity, across candidate block shapes.

Usage:  python -m compile.perf_analysis [--batch 64] [--width 768]
Output feeds EXPERIMENTS.md §Perf.
"""

import argparse

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, TPUv4-class
MXU_FLOPS = 275e12             # bf16 peak, TPUv4-class
HBM_BW = 1.2e12                # bytes/s


def fwd_block_stats(m, k, n, bm, bn, dtype_bytes=4):
    """One (bm, bn) output tile of gelu(x@w + b) with full-K residency."""
    vmem = (bm * k + k * bn + bm * bn + bn) * dtype_bytes
    flops = 2 * bm * k * bn            # MAC = 2 flops
    hbm = (bm * k + k * bn + bm * bn + bn) * dtype_bytes  # cold tile traffic
    intensity = flops / hbm
    # Roofline: compute-bound iff intensity > MXU/BW ridge.
    ridge = MXU_FLOPS / HBM_BW
    bound = "compute" if intensity >= ridge else "memory"
    util_bound = min(1.0, intensity / ridge)
    # MXU tiling efficiency: fraction of the 128x128 systolic array busy.
    mxu_fill = (min(bm, 128) / 128) * (min(bn, 128) / 128)
    return {
        "vmem": vmem,
        "fits": vmem <= VMEM_BYTES,
        "intensity": intensity,
        "bound": bound,
        "util_bound": util_bound * mxu_fill,
        "mxu_fill": mxu_fill,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--width", type=int, default=768)
    args = ap.parse_args()
    m, k, n = args.batch, args.width, args.width
    print(f"fused_dense_fwd gelu(x@w+b): x[{m},{k}] w[{k},{n}]")
    print(f"{'bm':>5} {'bn':>5} {'VMEM':>10} {'fits':>5} {'FLOP/B':>7} "
          f"{'bound':>8} {'MXUfill':>8} {'util≤':>6}")
    best = None
    for bm in [32, 64, 128, 256]:
        for bn in [64, 128, 256, 512]:
            if bm > m or bn > n:
                continue
            s = fwd_block_stats(m, k, n, bm, bn)
            print(f"{bm:>5} {bn:>5} {s['vmem']:>10,} {str(s['fits']):>5} "
                  f"{s['intensity']:>7.1f} {s['bound']:>8} "
                  f"{s['mxu_fill']:>8.2f} {s['util_bound']:>6.2f}")
            if s["fits"] and (best is None or s["util_bound"] > best[2]):
                best = (bm, bn, s["util_bound"])
    if best:
        print(f"\nchosen default block (128,128): matches MXU tile; "
              f"best feasible here bm={best[0]} bn={best[1]} util≤{best[2]:.2f}")
    ridge = MXU_FLOPS / HBM_BW
    print(f"roofline ridge: {ridge:.0f} FLOP/B — at width {k} the fused layer's "
          f"intensity is k-limited; batch≥{int(ridge)} rows per tile would be "
          f"needed to saturate the MXU, so the kernel is HBM-bound at this "
          f"scale (as is the paper's K40c workload at batch 2 on PSPNet).")


if __name__ == "__main__":
    main()
