"""AOT lowering: JAX/Pallas → HLO text artifacts + manifest.

Runs once at build time (`make artifacts`); the Rust runtime loads the
HLO text with `HloModuleProto::from_text_file`, compiles it on the PJRT
CPU client and executes it on the training path. HLO *text* (not
serialized proto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly.

Artifacts are parameterized by (batch, width): every tower layer shares
one compiled executable per direction, which is what lets the Rust
executor treat "layer" as the unit of caching and recomputation.

Usage:
    python -m compile.aot --out-dir ../artifacts --batch 64 --width 512
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *specs) -> str:
    """Lower a jax function to XLA HLO text with a tuple root."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs(batch: int, width: int):
    """Name → (function, input specs, output arity)."""
    b, w = batch, width
    return {
        "layer_fwd": (model.layer_fwd, [f32(b, w), f32(w, w), f32(w)], 1),
        "layer_bwd": (model.layer_bwd, [f32(b, w), f32(w, w), f32(w), f32(b, w)], 3),
        "loss_head": (model.loss_head, [f32(b, w), f32(w, w), f32(w), f32(b, w)], 1),
        "loss_head_bwd": (
            model.loss_head_bwd,
            [f32(b, w), f32(w, w), f32(w), f32(b, w)],
            4,
        ),
        "sgd_mat": (model.sgd_mat, [f32(w, w), f32(w, w), f32()], 1),
        "sgd_vec": (model.sgd_vec, [f32(w), f32(w), f32()], 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--width", type=int, default=512)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {
        "batch": args.batch,
        "width": args.width,
        "dtype": "f32",
        "artifacts": {},
    }
    for name, (fn, specs, n_out) in artifact_specs(args.batch, args.width).items():
        text = to_hlo_text(fn, *specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in specs],
            "outputs": n_out,
        }
        print(f"  {name}: {len(text)} chars, inputs {[list(s.shape) for s in specs]}")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote manifest + {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
