//! Session-API integration suite: determinism, caching, fingerprinting.
//!
//! The contract under test (ISSUE 5 acceptance):
//! - the same `PlanRequest` twice returns bit-identical plans with
//!   `hits == 1` (and in fact the *same* `Arc`);
//! - different budgets miss separately while sharing one family;
//! - the graph fingerprint changes when an edge is added and collides
//!   for isomorphic relabelings of the diamond fixture.

use std::sync::Arc;

use recompute::graph::EnumerationLimit;
use recompute::planner::{
    min_feasible_budget, BudgetSpec, Family, Objective, PlanRequest, PlannerId,
};
use recompute::session::{PlanCache, PlanSession, SessionStats};
use recompute::sim::SimMode;
use recompute::testutil::{diamond, diamond_relabeled, diamond_with_mems, diamond_with_skip};

fn exact_req(budget: BudgetSpec) -> PlanRequest {
    PlanRequest { budget, ..PlanRequest::new(PlannerId::ExactDp, Objective::MinOverhead) }
}

#[test]
fn same_request_twice_is_one_hit_and_bit_identical() {
    let session = PlanSession::new(diamond());
    let req = exact_req(BudgetSpec::MinFeasible);
    let first = session.plan(&req).unwrap();
    let second = session.plan(&req).unwrap();
    assert!(Arc::ptr_eq(&first, &second), "a cache hit returns the same compiled plan");
    assert_eq!(
        session.stats(),
        SessionStats { hits: 1, misses: 1, families_built: 1, ..SessionStats::default() }
    );

    // Determinism across *sessions*: an independent session over an
    // identically built graph produces bit-identical artifacts.
    let other = PlanSession::new(diamond());
    let third = other.plan(&req).unwrap();
    assert_eq!(first.fingerprint, third.fingerprint);
    assert_eq!(first.plan.chain.lower_sets(), third.plan.chain.lower_sets());
    assert_eq!(first.plan.overhead, third.plan.overhead);
    assert_eq!(first.plan.peak_eq2, third.plan.peak_eq2);
    assert_eq!(first.program.steps, third.program.steps);
    assert_eq!(first.program.predicted_live, third.program.predicted_live);
    assert_eq!(first.report.peak_bytes, third.report.peak_bytes);
}

#[test]
fn different_budgets_miss_while_sharing_one_family() {
    let session = PlanSession::new(diamond());
    let b_star = session.min_feasible_budget(Family::Exact);
    let a = session.plan(&exact_req(BudgetSpec::Bytes(b_star))).unwrap();
    let b = session.plan(&exact_req(BudgetSpec::Bytes(b_star + 16))).unwrap();
    assert!(!Arc::ptr_eq(&a, &b));
    let stats = session.stats();
    assert_eq!(stats.hits, 0);
    assert_eq!(stats.misses, 2, "distinct budgets are distinct cache keys");
    assert_eq!(stats.families_built, 1, "…but the family is solved once");
    // Request shape matters too: a different objective misses again.
    let req_mc = PlanRequest {
        budget: BudgetSpec::Bytes(b_star),
        ..PlanRequest::new(PlannerId::ExactDp, Objective::MaxOverhead)
    };
    session.plan(&req_mc).unwrap();
    assert_eq!(session.stats().misses, 3);
    assert_eq!(session.stats().families_built, 1);
}

#[test]
fn min_feasible_budget_is_memoized_and_agrees_with_the_free_function() {
    let session = PlanSession::new(diamond());
    let b = session.min_feasible_budget(Family::Exact);
    assert_eq!(b, session.min_feasible_budget(Family::Exact));
    assert_eq!(b, min_feasible_budget(&diamond(), Family::Exact));
    assert_eq!(session.stats().families_built, 1);
    // The approx family is a second (and last) family build.
    let ba = session.min_feasible_budget(Family::Approx);
    assert!(ba >= b, "exact family ⊇ approx family ⇒ B*_exact ≤ B*_approx");
    assert_eq!(session.stats().families_built, 2);
}

#[test]
fn fingerprint_changes_when_an_edge_is_added() {
    assert_ne!(diamond().fingerprint(), diamond_with_skip().fingerprint());
}

#[test]
fn fingerprint_collides_for_isomorphic_relabelings_of_the_diamond() {
    // The relabeled fixture stores the two branch nodes in the opposite
    // index order and renames everything: the same graph up to node
    // numbering.
    assert_eq!(diamond().fingerprint(), diamond_relabeled().fingerprint());
    // Sanity: it is not an everything-collides hash.
    assert_ne!(
        diamond().fingerprint(),
        diamond_with_mems([10, 20, 30, 41]).fingerprint()
    );
}

#[test]
fn compiled_plans_verify_against_their_own_reports() {
    // The CompiledPlan bundle is internally consistent: the program's
    // predicted peak is the simulator's activation peak, under both
    // sim modes.
    for mode in [SimMode::Liveness, SimMode::Strict] {
        let session = PlanSession::new(diamond());
        let req = PlanRequest {
            sim_mode: mode,
            ..PlanRequest::new(PlannerId::ExactDp, Objective::MinOverhead)
        };
        let cp = session.plan(&req).unwrap();
        assert_eq!(cp.program.predicted_peak(), cp.report.peak_bytes, "{mode:?}");
        assert!(cp.report.peak_bytes <= cp.peak_strict, "liveness ≤ strict ({mode:?})");
        assert_eq!(cp.plan.overhead, cp.report.overhead_time, "{mode:?}");
    }
}

#[test]
fn shared_cache_serves_repeated_traces_across_sessions() {
    let cache = PlanCache::shared(8);
    let s1 =
        PlanSession::with_cache(diamond(), EnumerationLimit::default(), cache.clone());
    let req = exact_req(BudgetSpec::MinFeasible);
    let a = s1.plan(&req).unwrap();
    assert_eq!(cache.len(), 1);

    // A second session over a re-trace of the same model (same node
    // numbering, different names): same fingerprint, so the shared
    // cache serves it without building any family. (Sharing across
    // *renumbered* labelings is unsound for execution — see the session
    // module docs — which is why the default cache is per-session.)
    let retrace = diamond_with_mems([10, 20, 30, 40]);
    let s2 = PlanSession::with_cache(retrace, EnumerationLimit::default(), cache.clone());
    let b = s2.plan(&req).unwrap();
    assert!(Arc::ptr_eq(&a, &b));
    assert_eq!(
        s2.stats(),
        SessionStats { hits: 1, misses: 0, families_built: 0, ..SessionStats::default() }
    );
}

#[test]
fn plan_cache_is_lru_bounded() {
    let cache = PlanCache::shared(2);
    let session =
        PlanSession::with_cache(diamond(), EnumerationLimit::default(), cache.clone());
    let b_star = session.min_feasible_budget(Family::Exact);
    let r1 = exact_req(BudgetSpec::Bytes(b_star));
    let r2 = exact_req(BudgetSpec::Bytes(b_star + 8));
    let r3 = exact_req(BudgetSpec::Bytes(b_star + 16));
    session.plan(&r1).unwrap();
    session.plan(&r2).unwrap();
    // Touch r1 so r2 becomes the LRU entry, then insert r3.
    session.plan(&r1).unwrap();
    session.plan(&r3).unwrap();
    assert_eq!(cache.len(), 2, "capacity bound holds");
    // r1 survived (recently used); r2 was evicted and must recompile.
    let before = session.stats();
    session.plan(&r1).unwrap();
    assert_eq!(session.stats().hits, before.hits + 1, "r1 still cached");
    session.plan(&r2).unwrap();
    assert_eq!(session.stats().misses, before.misses + 1, "r2 was evicted");
}
