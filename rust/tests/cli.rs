//! CLI integration: drive the `repro` binary end-to-end (no artifacts
//! needed for these subcommands).

use std::process::Command;

use recompute::util::json::Json;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

#[test]
fn help_lists_subcommands() {
    let out = repro().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["table1", "table2", "figure3", "plan", "train", "export", "serve"] {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn serve_daemon_answers_over_tcp_and_shuts_down_cleanly() {
    use std::io::{BufRead, BufReader, Write};

    let mut child = repro()
        .args(["serve", "--addr", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    // The daemon prints one parseable line naming the bound port.
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    let mut banner = String::new();
    stdout.read_line(&mut banner).unwrap();
    let addr = banner.trim().rsplit(' ').next().unwrap().to_string();
    assert!(banner.contains("listening on"), "{banner}");

    let stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut roundtrip = |line: &str| -> recompute::util::json::Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim()).unwrap_or_else(|e| panic!("bad reply {reply:?}: {e}"))
    };

    let pong = roundtrip(r#"{"cmd":"ping"}"#);
    assert_eq!(pong.get("reply").as_str(), Some("pong"));
    // Hostile input over the real socket: structured error, no crash.
    let err = roundtrip("certainly not json");
    assert_eq!(err.get("ok").as_bool(), Some(false));
    assert_eq!(err.get("error").get("code").as_str(), Some("bad-json"));
    // A `shutdown` command must end the process with exit code 0.
    let bye = roundtrip(r#"{"cmd":"shutdown"}"#);
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    let status = child.wait().unwrap();
    assert!(status.success(), "daemon must exit cleanly after shutdown: {status:?}");
}

#[test]
fn serve_rejects_bad_flags_without_binding() {
    let out = repro().args(["serve", "--max-inflight", "zero"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("bad value"), "actionable flag error");
    let out = repro().args(["serve", "--help"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("--addr"), "{text}");
    assert!(text.contains("graph_upload"), "{text}");
}

#[test]
fn unknown_subcommand_fails() {
    let out = repro().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn export_then_plan_graph_roundtrip() {
    let dir = std::env::temp_dir().join(format!("repro_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("vgg19.json");
    let out = repro()
        .args(["export", "--network", "VGG19", "--batch", "2", "--out"])
        .arg(&path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(path.exists());

    let out = repro().args(["plan", "--graph"]).arg(&path).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("vanilla peak"), "{text}");
    assert!(text.contains("ApproxDP plan"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn plan_named_network_with_explicit_budget() {
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--budget", "1.0"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("peak:"));
}

#[test]
fn plan_chen_mode() {
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--chen"])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("chen: k="));
}

#[test]
fn infeasible_budget_reports_error_naming_the_minimum() {
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "64", "--budget", "0.001"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("infeasible"), "{err}");
    assert!(err.contains("min_feasible_budget"), "{err}");
}

#[test]
fn plan_accepts_human_readable_budget() {
    // 8GiB is comfortably feasible for VGG19 at batch 4.
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--budget", "8GiB"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("peak:"));
    // And a nonsense unit is a parse error, not a planner error.
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--budget", "12parsecs"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("byte unit"));
}

#[test]
fn sim_strict_flag_reproduces_the_no_liveness_ablation() {
    // `--sim strict` must run the zoo executor under strategy-mandated
    // frees only (paper Table 2) and still hold the observed == predicted
    // equality.
    let out = repro()
        .args([
            "train", "--model", "unet", "--batch", "2", "--width", "8", "--steps", "1",
            "--quiet", "--sim", "strict",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("sim strict"), "{text}");
    assert!(text.contains("EQUAL ✓"), "{text}");

    // The planner CLI honors it too…
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--sim", "strict"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("measured(strict)"));

    // …and rejects unknown modes with an actionable message.
    let out = repro()
        .args(["plan", "--network", "VGG19", "--sim", "eager"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("liveness|strict"));
}

#[test]
fn train_mode_all_builds_the_family_once_and_serves_repeats_from_cache() {
    // ISSUE 5 acceptance: `repro train --mode all --model resnet` must
    // solve the lower-set family exactly once per (graph, limit) even
    // though two objectives (tc + mc) are planned, and each objective's
    // repeated PlanRequest (verify step, then training run) must be a
    // cache hit — all observable through the --stats session counters.
    let out = repro()
        .args([
            "train", "--model", "resnet", "--batch", "2", "--width", "8", "--steps", "1",
            "--mode", "all", "--quiet", "--stats",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("planned[tc]"), "{text}");
    assert!(text.contains("planned[mc]"), "{text}");
    assert!(text.contains("families_built=1"), "{text}");
    assert!(text.contains("hits=2"), "{text}");
    assert!(text.contains("misses=2"), "{text}");
    // Both planned runs passed the executor's invariants (the binary
    // exits nonzero otherwise; the markers make it legible here).
    assert_eq!(text.matches("EQUAL ✓").count(), 2, "{text}");
    assert_eq!(text.matches("BIT-IDENTICAL ✓").count(), 2, "{text}");
    // --stats also reports kernel throughput and the planner wall-time.
    assert!(text.contains("GFLOP/s"), "{text}");
    assert!(text.contains("planner: family_build="), "{text}");
    assert!(text.contains("compile="), "{text}");
}

#[test]
fn plan_stats_reports_planner_wall_time_and_thread_count() {
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--stats", "--threads", "2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("session: hits="), "{text}");
    assert!(text.contains("planner: family_build="), "{text}");
    assert!(text.contains("compile="), "{text}");
    assert!(text.contains("threads: 2"), "{text}");
}

#[test]
fn plan_json_is_byte_identical_across_thread_counts() {
    // The threaded planner's core guarantee: the same request must
    // produce the same plan — byte for byte — at any worker-pool width.
    let run = |threads: &str| {
        let out = repro()
            .args(["plan", "--network", "ResNet50", "--batch", "8", "--json"])
            .env("REPRO_THREADS", threads)
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        out.stdout
    };
    let serial = run("1");
    let wide = run("4");
    assert_eq!(
        serial,
        wide,
        "plan --json diverged between REPRO_THREADS=1 and 4:\n{}\nvs\n{}",
        String::from_utf8_lossy(&serial),
        String::from_utf8_lossy(&wide)
    );
}

#[test]
fn train_accepts_threads_flag_with_identical_outputs() {
    // A planned training run through `--threads 1` and `--threads 4`
    // must print identical results (same plan, bit-exact execution).
    // Wall-clock tokens (`step=…ms`) are stripped before comparing —
    // they are the only nondeterministic part of the output.
    let run = |threads: &str| -> String {
        let out = repro()
            .args([
                "train", "--model", "unet", "--batch", "2", "--width", "8", "--steps", "1",
                "--mode", "tc", "--quiet", "--threads", threads,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout)
            .lines()
            .map(|l| {
                l.split_whitespace()
                    .filter(|tok| !tok.starts_with("step="))
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect::<Vec<_>>()
            .join("\n")
    };
    let serial = run("1");
    assert!(serial.contains("BIT-IDENTICAL ✓"), "{serial}");
    assert_eq!(serial, run("4"), "train output diverged across thread counts");
}

#[test]
fn plan_json_emits_a_machine_consumable_compiled_plan_summary() {
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("bad JSON: {e}\n{text}"));
    assert_eq!(j.get("planner").as_str(), Some("ApproxDP"));
    assert_eq!(j.get("objective").as_str(), Some("tc"));
    assert_eq!(j.get("sim").as_str(), Some("liveness"));
    assert!(j.get("budget_bytes").as_u64().unwrap() > 0);
    assert!(j.get("k_segments").as_u64().unwrap() >= 1);
    assert!(j.get("peak_eq2").as_u64().unwrap() > 0);
    assert!(j.get("predicted_peak").as_u64().unwrap() > 0);
    assert!(j.get("vanilla_peak").as_u64().unwrap() > 0);
    assert!(!j.get("fingerprint").as_str().unwrap().is_empty());
    assert_eq!(j.get("cache_hit").as_bool(), Some(false), "fresh session, first request");
    assert_eq!(j.get("session").get("families_built").as_u64(), Some(1));
    // The chen planner emits the same machine-readable shape.
    let out = repro()
        .args(["plan", "--network", "VGG19", "--batch", "4", "--chen", "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap();
    assert_eq!(j.get("planner").as_str(), Some("Chen's"));
    assert_eq!(j.get("session").get("families_built").as_u64(), Some(0));
}

#[test]
fn train_accepts_human_readable_budget_and_names_minimum_when_infeasible() {
    // An absurdly small absolute budget must fail actionably…
    let out = repro()
        .args([
            "train", "--model", "unet", "--batch", "2", "--width", "8", "--steps", "1",
            "--quiet", "--budget", "16B",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("min_feasible_budget"), "{err}");
    // …while a generous human-readable budget trains end to end.
    let out = repro()
        .args([
            "train", "--model", "unet", "--batch", "2", "--width", "8", "--steps", "1",
            "--quiet", "--budget", "1MiB",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("HETEROGENEOUS ✓"), "{text}");
}
