//! Integration tests across runtime + executor + planner.
//!
//! These run on the pure-Rust `NativeBackend` by default — no artifacts,
//! no Python, no native libraries. The PJRT artifact cases live in the
//! feature-gated `pjrt` module at the bottom (`--features xla`, plus real
//! PJRT libraries and `make artifacts`; they are `#[ignore]`d so a stub
//! build's test run stays green).

use recompute::exec::{ChainSchedule, TowerTrainer, TrainConfig};
use recompute::models::mlp_tower;
use recompute::planner::{build_context, Family, Objective};
use recompute::runtime::{Backend, NativeBackend, TOWER_KERNELS};

const BATCH: usize = 32;
const WIDTH: usize = 64;

fn quiet_cfg(layers: usize, steps: usize) -> TrainConfig {
    TrainConfig { layers, steps, lr: 0.05, seed: 7, log_every: 0 }
}

fn native_trainer(cfg: &TrainConfig) -> TowerTrainer<NativeBackend> {
    TowerTrainer::native(BATCH, WIDTH, cfg).unwrap()
}

/// Host-side GELU (tanh approximation) — independent re-implementation
/// for cross-checking the backend kernel.
fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

#[test]
fn layer_fwd_kernel_matches_host_math() {
    let be = NativeBackend::new();
    let (b, w) = (BATCH, WIDTH);
    // x = small ramp, w = identity, bias = 0.5 ⇒ out = gelu(x + 0.5).
    let x: Vec<f32> = (0..b * w).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
    let mut wmat = vec![0f32; w * w];
    for i in 0..w {
        wmat[i * w + i] = 1.0;
    }
    let bias = vec![0.5f32; w];
    let out = be
        .run(
            "layer_fwd",
            &[
                be.upload(&x, &[b, w]).unwrap(),
                be.upload(&wmat, &[w, w]).unwrap(),
                be.upload(&bias, &[w]).unwrap(),
            ],
        )
        .unwrap();
    let got = be.download(&out[0]).unwrap();
    for (i, (&g, &xi)) in got.iter().zip(&x).enumerate() {
        let want = gelu(xi + 0.5);
        assert!((g - want).abs() < 1e-5, "elem {i}: got {g} want {want}");
    }
}

#[test]
fn sgd_kernels_update_parameters() {
    let be = NativeBackend::new();
    let w = WIDTH;
    let wmat = vec![1.0f32; w * w];
    let gmat = vec![2.0f32; w * w];
    let out = be
        .run(
            "sgd_mat",
            &[
                be.upload(&wmat, &[w, w]).unwrap(),
                be.upload(&gmat, &[w, w]).unwrap(),
                be.upload(&[0.25], &[]).unwrap(),
            ],
        )
        .unwrap();
    let got = be.download(&out[0]).unwrap();
    assert!(got.iter().all(|&v| (v - 0.5).abs() < 1e-6));
}

#[test]
fn recomputation_does_not_alter_training_trajectory() {
    // The defining property of recomputation (§1): identical outputs.
    let layers = 10;
    let cfg = quiet_cfg(layers, 4);

    let mut vanilla = native_trainer(&cfg);
    let v_report = vanilla.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();

    let mut recomp = native_trainer(&cfg);
    let g = mlp_tower(layers as u32, recomp.width() as u32, recomp.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MinOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    assert!(sched.segments.len() > 1, "plan must actually cut");
    let r_report = recomp.train(&sched, &cfg).unwrap();

    assert_eq!(v_report.losses.len(), r_report.losses.len());
    for (i, (a, b)) in v_report.losses.iter().zip(&r_report.losses).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "step {i}: vanilla {a} vs recompute {b}"
        );
    }
    assert!(
        r_report.peak_bytes < v_report.peak_bytes,
        "recompute {} must beat vanilla {}",
        r_report.peak_bytes,
        v_report.peak_bytes
    );
    assert!(r_report.recomputes_per_step > 0);
    assert_eq!(v_report.recomputes_per_step, 0, "vanilla never recomputes");
}

#[test]
fn executor_peak_matches_schedule_prediction() {
    // Peak layer-activation count under a k-segment schedule on a chain:
    // checkpoints + the running segment's activations. Verify the measured
    // byte counter against structural bounds for the actual schedule.
    let layers = 12;
    let cfg = quiet_cfg(layers, 2);
    let mut t = native_trainer(&cfg);
    let act = (t.batch() * t.width() * 4) as u64;
    let g = mlp_tower(layers as u32, t.width() as u32, t.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MinOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    let report = t.train(&sched, &cfg).unwrap();
    // Loose structural bounds: at least max-segment activations, at most
    // vanilla's (n+1 live activations + gradient).
    let n = sched.n_layers as u64;
    let max_seg = sched.segments.iter().map(|s| (s.end - s.start) as u64).max().unwrap();
    assert!(report.peak_bytes >= max_seg * act, "peak {} too small", report.peak_bytes);
    assert!(report.peak_bytes <= (n + 2) * act, "peak {} too large", report.peak_bytes);
}

#[test]
fn mc_schedule_runs_and_matches_losses_too() {
    let layers = 8;
    let cfg = quiet_cfg(layers, 3);
    let mut mc = native_trainer(&cfg);
    let g = mlp_tower(layers as u32, mc.width() as u32, mc.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MaxOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    let mc_report = mc.train(&sched, &cfg).unwrap();

    let mut v = native_trainer(&cfg);
    let v_report = v.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();
    for (a, b) in v_report.losses.iter().zip(&mc_report.losses) {
        assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0));
    }
}

#[test]
fn loss_decreases_on_synthetic_task() {
    let layers = 6;
    let cfg = TrainConfig { layers, steps: 30, lr: 0.1, seed: 3, log_every: 0 };
    let mut t = native_trainer(&cfg);
    let report = t.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(last < first * 0.8, "loss must drop: {first} → {last}");
    assert!(last.is_finite());
}

#[test]
fn backend_reports_per_kernel_stats() {
    let layers = 4;
    let cfg = quiet_cfg(layers, 2);
    let mut t = native_trainer(&cfg);
    let report = t.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();
    assert_eq!(report.backend, "native");
    // Every training kernel except the standalone loss forward ran.
    let ran: Vec<&str> = report.kernel_stats.iter().map(|s| s.kernel.as_str()).collect();
    for k in ["layer_fwd", "layer_bwd", "loss_head_bwd", "sgd_mat", "sgd_vec"] {
        assert!(ran.contains(&k), "missing stats for {k}, have {ran:?}");
        assert!(TOWER_KERNELS.contains(&k));
    }
    let fwd = report.kernel_stats.iter().find(|s| s.kernel == "layer_fwd").unwrap();
    // 2 steps × `layers` forward calls, no recomputation under vanilla.
    assert_eq!(fwd.calls, 2 * layers as u64);
    assert!(fwd.bytes_in > 0 && fwd.bytes_out > 0);
}

/// PJRT artifact cases — require `--features xla` **with the real `xla`
/// crate linked** (see `runtime::backend::xla_stub`) and `make artifacts`.
/// `#[ignore]`d so stub builds stay green; run with `--ignored` on a
/// PJRT-capable machine.
#[cfg(feature = "xla")]
mod pjrt {
    use super::*;
    use recompute::runtime::{ArtifactSet, literal_f32, to_vec_f32};
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    #[ignore = "needs real PJRT libraries and `make artifacts`"]
    fn layer_fwd_artifact_matches_host_math() {
        let arts = ArtifactSet::load(&artifacts_dir()).expect("run `make artifacts` first");
        let (b, w) = (arts.batch, arts.width);
        let x: Vec<f32> = (0..b * w).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
        let mut wmat = vec![0f32; w * w];
        for i in 0..w {
            wmat[i * w + i] = 1.0;
        }
        let bias = vec![0.5f32; w];
        let out = arts
            .run(
                "layer_fwd",
                &[
                    literal_f32(&x, &[b, w]).unwrap(),
                    literal_f32(&wmat, &[w, w]).unwrap(),
                    literal_f32(&bias, &[w]).unwrap(),
                ],
            )
            .unwrap();
        let got = to_vec_f32(&out[0]).unwrap();
        for (i, (&g, &xi)) in got.iter().zip(&x).enumerate() {
            let want = gelu(xi + 0.5);
            assert!((g - want).abs() < 1e-5, "elem {i}: got {g} want {want}");
        }
    }

    #[test]
    #[ignore = "needs real PJRT libraries and `make artifacts`"]
    fn pjrt_trainer_matches_native_trajectory() {
        // The same plan must produce the same physics on both backends
        // (up to f32 kernel-order noise): loss decreasing, peak equal.
        let layers = 6;
        let cfg = quiet_cfg(layers, 3);
        let mut pjrt = TowerTrainer::from_artifacts(&artifacts_dir(), &cfg).unwrap();
        let sched = ChainSchedule::vanilla(layers + 1);
        let p_report = pjrt.train(&sched, &cfg).unwrap();
        assert_eq!(p_report.backend, "pjrt");
        assert!(p_report.losses.iter().all(|l| l.is_finite()));
    }
}
