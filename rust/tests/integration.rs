//! Integration tests across runtime + executor + planner.
//!
//! These need `artifacts/` (run `make artifacts` first); the Makefile's
//! `test` target guarantees that ordering.

use std::path::PathBuf;

use recompute::exec::{ChainSchedule, TowerTrainer, TrainConfig};
use recompute::models::mlp_tower;
use recompute::planner::{build_context, Family, Objective};
use recompute::runtime::{literal_f32, to_vec_f32, ArtifactSet};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn quiet_cfg(layers: usize, steps: usize) -> TrainConfig {
    TrainConfig { layers, steps, lr: 0.05, seed: 7, log_every: 0 }
}

/// Host-side GELU (tanh approximation) — independent re-implementation
/// for cross-checking the compiled artifact.
fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

#[test]
fn layer_fwd_artifact_matches_host_math() {
    let arts = ArtifactSet::load(&artifacts_dir()).expect("run `make artifacts` first");
    let (b, w) = (arts.batch, arts.width);
    // x = small ramp, w = identity, bias = 0.5 ⇒ out = gelu(x + 0.5).
    let x: Vec<f32> = (0..b * w).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
    let mut wmat = vec![0f32; w * w];
    for i in 0..w {
        wmat[i * w + i] = 1.0;
    }
    let bias = vec![0.5f32; w];
    let out = arts
        .run(
            "layer_fwd",
            &[
                literal_f32(&x, &[b, w]).unwrap(),
                literal_f32(&wmat, &[w, w]).unwrap(),
                literal_f32(&bias, &[w]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    for (i, (&g, &xi)) in got.iter().zip(&x).enumerate() {
        let want = gelu(xi + 0.5);
        assert!((g - want).abs() < 1e-5, "elem {i}: got {g} want {want}");
    }
}

#[test]
fn sgd_artifacts_update_parameters() {
    let arts = ArtifactSet::load(&artifacts_dir()).unwrap();
    let w = arts.width;
    let wmat = vec![1.0f32; w * w];
    let gmat = vec![2.0f32; w * w];
    let out = arts
        .run(
            "sgd_mat",
            &[
                literal_f32(&wmat, &[w, w]).unwrap(),
                literal_f32(&gmat, &[w, w]).unwrap(),
                literal_f32(&[0.25], &[]).unwrap(),
            ],
        )
        .unwrap();
    let got = to_vec_f32(&out[0]).unwrap();
    assert!(got.iter().all(|&v| (v - 0.5).abs() < 1e-6));
}

#[test]
fn recomputation_does_not_alter_training_trajectory() {
    // The defining property of recomputation (§1): identical outputs.
    let layers = 10;
    let cfg = quiet_cfg(layers, 4);
    let g = mlp_tower(layers as u32, 0, 1); // width/batch irrelevant for plan shape
    let _ = g;

    let mut vanilla = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let v_report = vanilla.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();

    let mut recomp = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let g = mlp_tower(layers as u32, recomp.width() as u32, recomp.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MinOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    assert!(sched.segments.len() > 1, "plan must actually cut");
    let r_report = recomp.train(&sched, &cfg).unwrap();

    assert_eq!(v_report.losses.len(), r_report.losses.len());
    for (i, (a, b)) in v_report.losses.iter().zip(&r_report.losses).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * a.abs().max(1.0),
            "step {i}: vanilla {a} vs recompute {b}"
        );
    }
    assert!(
        r_report.peak_bytes < v_report.peak_bytes,
        "recompute {} must beat vanilla {}",
        r_report.peak_bytes,
        v_report.peak_bytes
    );
    assert!(r_report.recomputes_per_step > 0);
    assert_eq!(v_report.recomputes_per_step, 0, "vanilla never recomputes");
}

#[test]
fn executor_peak_matches_schedule_prediction() {
    // Peak layer-activation count under a k-segment schedule on a chain:
    // checkpoints + the running segment's activations. Verify the measured
    // byte counter against the closed-form for the actual schedule.
    let layers = 12;
    let cfg = quiet_cfg(layers, 2);
    let mut t = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let act = (t.batch() * t.width() * 4) as u64;
    let g = mlp_tower(layers as u32, t.width() as u32, t.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MinOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    let report = t.train(&sched, &cfg).unwrap();
    // Loose structural bounds: at least max-segment activations, at most
    // vanilla's (n+1 live activations + gradient).
    let n = sched.n_layers as u64;
    let max_seg = sched.segments.iter().map(|s| (s.end - s.start) as u64).max().unwrap();
    assert!(report.peak_bytes >= max_seg * act, "peak {} too small", report.peak_bytes);
    assert!(report.peak_bytes <= (n + 2) * act, "peak {} too large", report.peak_bytes);
}

#[test]
fn mc_schedule_runs_and_matches_losses_too() {
    let layers = 8;
    let cfg = quiet_cfg(layers, 3);
    let mut mc = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let g = mlp_tower(layers as u32, mc.width() as u32, mc.batch() as u64);
    let ctx = build_context(&g, Family::Exact);
    let sol = ctx.solve(ctx.min_feasible_budget(), Objective::MaxOverhead).unwrap();
    let sched = ChainSchedule::from_chain(&g, &sol.chain).unwrap();
    let mc_report = mc.train(&sched, &cfg).unwrap();

    let mut v = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let v_report = v.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();
    for (a, b) in v_report.losses.iter().zip(&mc_report.losses) {
        assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0));
    }
}

#[test]
fn loss_decreases_on_synthetic_task() {
    let layers = 6;
    let cfg = TrainConfig { layers, steps: 30, lr: 0.1, seed: 3, log_every: 0 };
    let mut t = TowerTrainer::new(&artifacts_dir(), &cfg).unwrap();
    let report = t.train(&ChainSchedule::vanilla(layers + 1), &cfg).unwrap();
    let first = report.losses[0];
    let last = *report.losses.last().unwrap();
    assert!(last < first * 0.8, "loss must drop: {first} → {last}");
    assert!(last.is_finite());
}
