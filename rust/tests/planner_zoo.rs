//! Planner integration over the real network zoo (no artifacts needed).
//!
//! These assert the *shape* of the paper's results end-to-end through the
//! public API: reductions in the 36–81% band, method ordering, Chen
//! weakest on skip-heavy graphs — the qualitative content of Table 1.

use recompute::models::zoo;
use recompute::planner::{build_context, chen_plan, Family, Objective};
use recompute::sim::{simulate, simulate_vanilla, SimOptions};

fn reduction(peak: u64, vanilla: u64) -> f64 {
    100.0 * (1.0 - peak as f64 / vanilla as f64)
}

#[test]
fn approx_dp_reductions_land_in_paper_band() {
    // Run the fast planner on every zoo network at the paper's batch
    // sizes; reductions (incl. params) must land in a generous band
    // around the paper's 36–81%.
    for e in zoo::TABLE1 {
        let g = e.build_paper();
        let opts = SimOptions::default();
        let vanilla = simulate_vanilla(&g, opts).peak_total;
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let mc = ctx.solve(b, Objective::MaxOverhead).unwrap();
        let peak = simulate(&g, &mc.chain, opts).peak_total;
        let red = reduction(peak, vanilla);
        assert!(
            (30.0..=92.0).contains(&red),
            "{}: ApproxDP+MC reduction {red:.0}% out of band (peak {peak}, vanilla {vanilla})",
            e.name
        );
    }
}

#[test]
fn ours_beats_chen_on_skip_heavy_networks() {
    // The paper's headline qualitative claim (§5.1): PSPNet, U-Net and
    // GoogLeNet favor lower-set planning over Chen's segmentation.
    for name in ["U-Net", "GoogLeNet"] {
        let e = zoo::find(name).unwrap();
        let g = e.build_paper();
        let opts = SimOptions::default();
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let ours = simulate(&g, &ctx.solve(b, Objective::MaxOverhead).unwrap().chain, opts)
            .peak_total;
        let chen = chen_plan(&g, |c| simulate(&g, c, opts).peak_total).unwrap();
        let chen_peak = simulate(&g, &chen.chain, opts).peak_total;
        assert!(
            ours <= chen_peak,
            "{name}: ours {ours} should beat Chen {chen_peak}"
        );
    }
}

#[test]
fn mc_overhead_bounded_by_forward_pass() {
    // §4.4: memory-centric overhead ≤ one forward computation.
    for e in zoo::TABLE1 {
        let g = e.build_batch(1);
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let mc = ctx.solve(b, Objective::MaxOverhead).unwrap();
        assert!(mc.overhead <= g.total_time(), "{}", e.name);
    }
}

#[test]
fn tc_overhead_leq_mc_overhead_at_min_budget() {
    for e in zoo::TABLE1 {
        let g = e.build_batch(1);
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let tc = ctx.solve(b, Objective::MinOverhead).unwrap();
        let mc = ctx.solve(b, Objective::MaxOverhead).unwrap();
        assert!(tc.overhead <= mc.overhead, "{}", e.name);
    }
}

#[test]
fn bigger_budget_means_less_overhead_across_zoo() {
    for e in zoo::TABLE1 {
        let g = e.build_batch(1);
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let tight = ctx.solve(b, Objective::MinOverhead).unwrap().overhead;
        let loose = ctx.solve(b * 2, Objective::MinOverhead).unwrap().overhead;
        assert!(loose <= tight, "{}", e.name);
    }
}
