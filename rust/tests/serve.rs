//! Serve daemon integration: an in-process [`Server`] on an ephemeral
//! port, driven by real TCP clients — concurrent isomorphic uploads
//! sharing one plan cache, hostile lines answered with structured
//! errors on a surviving connection, stats shape, admission control,
//! idle timeout and shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread::JoinHandle;
use std::time::Duration;

use recompute::anyhow::Result;
use recompute::serve::{ServeConfig, Server, ServerHandle};
use recompute::testutil::{diamond, diamond_relabeled};
use recompute::util::json::Json;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client { writer: stream.try_clone().unwrap(), reader: BufReader::new(stream) }
    }

    fn send(&mut self, line: &str) -> Json {
        self.send_bytes(line.as_bytes())
    }

    fn send_bytes(&mut self, line: &[u8]) -> Json {
        self.writer.write_all(line).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        self.recv()
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad reply {line:?}: {e}"))
    }

    /// True once the server has closed this connection.
    fn at_eof(&mut self) -> bool {
        let mut line = String::new();
        matches!(self.reader.read_line(&mut line), Ok(0))
    }
}

fn start(cfg: ServeConfig) -> (ServerHandle, JoinHandle<Result<()>>) {
    let server = Server::bind(cfg).unwrap();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run());
    (handle, join)
}

fn cfg_on_free_port() -> ServeConfig {
    ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() }
}

/// Compact (single-line) upload command for a graph.
fn upload_line(graph_json: &str) -> String {
    Json::obj()
        .set("cmd", "graph_upload".into())
        .set("graph", Json::parse(graph_json).unwrap())
        .to_string()
}

fn err_code(reply: &Json) -> &str {
    assert_eq!(reply.get("ok").as_bool(), Some(false), "expected error: {}", reply.to_string());
    reply.get("error").get("code").as_str().unwrap()
}

#[test]
fn concurrent_isomorphic_clients_share_one_plan_cache() {
    const CLIENTS: usize = 8;
    let (handle, join) = start(cfg_on_free_port());
    let addr = handle.addr();

    let results: Vec<(String, bool, bool)> = std::thread::scope(|s| {
        let workers: Vec<_> = (0..CLIENTS)
            .map(|i| {
                s.spawn(move || {
                    let mut c = Client::connect(addr);
                    // Even clients upload the diamond, odd ones an
                    // isomorphic relabeling — same fingerprint, so all
                    // traffic lands on one shared session.
                    let g = if i % 2 == 0 { diamond() } else { diamond_relabeled() };
                    let up = c.send(&upload_line(&g.to_json()));
                    assert_eq!(up.get("ok").as_bool(), Some(true), "{}", up.to_string());
                    let fp = up.get("fingerprint").as_str().unwrap().to_string();
                    let plan =
                        format!(r#"{{"cmd":"plan","fingerprint":"{fp}","planner":"exact"}}"#);
                    let first = c.send(&plan);
                    assert_eq!(first.get("ok").as_bool(), Some(true), "{}", first.to_string());
                    let second = c.send(&plan);
                    assert_eq!(second.get("ok").as_bool(), Some(true));
                    (
                        fp,
                        first.get("cache_hit").as_bool().unwrap(),
                        second.get("cache_hit").as_bool().unwrap(),
                    )
                })
            })
            .collect();
        workers.into_iter().map(|w| w.join().unwrap()).collect()
    });

    // Relabeling-invariant fingerprints: every client saw the same one.
    let fp0 = &results[0].0;
    assert!(results.iter().all(|(fp, _, _)| fp == fp0), "fingerprints diverged: {results:?}");
    // A client's repeated request is always a hit, whoever compiled it.
    assert!(results.iter().all(|&(_, _, second)| second), "second plan must be a cache hit");

    let mut c = Client::connect(addr);
    let stats = c.send(r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("ok").as_bool(), Some(true));
    assert_eq!(stats.get("sessions").as_u64(), Some(1), "one session for both relabelings");
    let cache = stats.get("cache");
    assert!(cache.get("hits").as_u64().unwrap() >= CLIENTS as u64, "{}", stats.to_string());
    assert_eq!(cache.get("entries").as_u64(), Some(1), "one compiled plan serves everyone");
    assert!(cache.get("hit_rate").as_f64().unwrap() > 0.0);
    // 3 requests per client have been recorded by the time stats runs.
    assert!(stats.get("requests").as_u64().unwrap() >= (3 * CLIENTS) as u64);
    assert_eq!(stats.get("errors").as_u64(), Some(0));
    // The stats request itself occupies an admission slot.
    assert!(stats.get("inflight").as_u64().unwrap() >= 1);
    assert!(stats.get("connections_total").as_u64().unwrap() >= (CLIENTS + 1) as u64);
    let lat = stats.get("latency_us");
    assert!(lat.get("count").as_u64().unwrap() >= (3 * CLIENTS) as u64, "{}", stats.to_string());
    let (p50, p90, p99) = (
        lat.get("p50_us").as_u64().unwrap(),
        lat.get("p90_us").as_u64().unwrap(),
        lat.get("p99_us").as_u64().unwrap(),
    );
    assert!(p50 <= p90 && p90 <= p99 && p99 <= lat.get("max_us").as_u64().unwrap());
    // Byte counters move in both directions, and every client's second
    // plan request was a warm hit served by the zero-copy fast path.
    assert!(stats.get("bytes_in").as_u64().unwrap() > 0, "{}", stats.to_string());
    assert!(stats.get("bytes_out").as_u64().unwrap() > 0);
    assert!(
        stats.get("fast_path_hits").as_u64().unwrap() >= CLIENTS as u64,
        "{}",
        stats.to_string()
    );

    handle.shutdown();
    join.join().unwrap().unwrap();
}

#[test]
fn hostile_lines_get_structured_errors_and_the_connection_survives() {
    let (handle, join) = start(cfg_on_free_port());
    let mut c = Client::connect(handle.addr());

    assert_eq!(err_code(&c.send("not json")), "bad-json");
    assert_eq!(err_code(&c.send(&"[".repeat(50_000))), "bad-json");
    assert_eq!(err_code(&c.send(r#"{"cmd":"warp"}"#)), "unknown-cmd");
    assert_eq!(err_code(&c.send(r#"{"cmd":"plan"}"#)), "bad-request");
    assert_eq!(err_code(&c.send(r#"{"cmd":"plan","fingerprint":"feed"}"#)), "unknown-fingerprint");
    assert_eq!(
        err_code(&c.send(r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#)),
        "bad-request"
    );
    // Invalid UTF-8 bytes get a structured reply, not a reset.
    assert_eq!(err_code(&c.send_bytes(b"\"\xff\xfe\"")), "bad-utf8");
    // Blank lines are skipped silently; the connection still works.
    c.writer.write_all(b"\r\n\n").unwrap();
    let pong = c.send(r#"{"cmd":"ping"}"#);
    assert_eq!(pong.get("reply").as_str(), Some("pong"), "connection must survive the abuse");

    let stats = c.send(r#"{"cmd":"stats"}"#);
    assert!(stats.get("errors").as_u64().unwrap() >= 7, "{}", stats.to_string());

    handle.shutdown();
    join.join().unwrap().unwrap();
}

#[test]
fn oversize_requests_are_refused_and_the_connection_closed() {
    let cfg = ServeConfig { max_request_bytes: 4096, ..cfg_on_free_port() };
    let (handle, join) = start(cfg);
    let mut c = Client::connect(handle.addr());
    let reply = c.send(&"a".repeat(10_000));
    assert_eq!(err_code(&reply), "request-too-large");
    assert!(c.at_eof(), "framing can't be trusted past the cap: server must close");
    handle.shutdown();
    join.join().unwrap().unwrap();
}

#[test]
fn connection_cap_refuses_with_busy() {
    let cfg = ServeConfig { max_connections: 1, ..cfg_on_free_port() };
    let (handle, join) = start(cfg);
    let mut first = Client::connect(handle.addr());
    // Ensure the first connection's worker is up before the second dials.
    assert_eq!(first.send(r#"{"cmd":"ping"}"#).get("ok").as_bool(), Some(true));
    let mut second = Client::connect(handle.addr());
    assert_eq!(err_code(&second.recv()), "busy");
    assert!(second.at_eof());
    // The admitted connection keeps working.
    assert_eq!(first.send(r#"{"cmd":"ping"}"#).get("ok").as_bool(), Some(true));
    handle.shutdown();
    join.join().unwrap().unwrap();
}

#[test]
fn idle_connections_time_out_with_a_structured_reply() {
    let cfg = ServeConfig { read_timeout: Duration::from_millis(200), ..cfg_on_free_port() };
    let (handle, join) = start(cfg);
    let mut c = Client::connect(handle.addr());
    // Send nothing: the server must speak first, naming the timeout.
    assert_eq!(err_code(&c.recv()), "idle-timeout");
    assert!(c.at_eof());
    handle.shutdown();
    join.join().unwrap().unwrap();
}

#[test]
fn shutdown_command_stops_the_daemon() {
    let (handle, join) = start(cfg_on_free_port());
    let mut c = Client::connect(handle.addr());
    let bye = c.send(r#"{"cmd":"shutdown"}"#);
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    join.join().unwrap().unwrap();
    assert!(handle.is_shutdown());
}

#[test]
fn train_request_verifies_and_repeats_hit_the_shared_session() {
    let (handle, join) = start(cfg_on_free_port());
    let mut c = Client::connect(handle.addr());
    let line = r#"{"cmd":"train","network":"unet","batch":2,"width":8,"steps":1}"#;
    let reply = c.send(line);
    assert_eq!(reply.get("ok").as_bool(), Some(true), "{}", reply.to_string());
    assert_eq!(reply.get("all_verified").as_bool(), Some(true));
    let runs = reply.get("runs").as_arr().unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].get("grads_match").as_bool(), Some(true));
    assert_eq!(runs[0].get("losses_identical").as_bool(), Some(true));
    assert!(
        runs[0].get("peak").as_u64().unwrap() < reply.get("vanilla_peak").as_u64().unwrap(),
        "planned peak must undercut vanilla"
    );
    let fp = reply.get("fingerprint").as_str().unwrap().to_string();

    // A repeated train request reuses the registered session: its plan
    // requests are cache hits, visible in the session totals.
    let again = c.send(line);
    assert_eq!(again.get("ok").as_bool(), Some(true));
    assert_eq!(again.get("fingerprint").as_str(), Some(fp.as_str()));
    let stats = c.send(r#"{"cmd":"stats"}"#);
    assert_eq!(stats.get("sessions").as_u64(), Some(1));
    let totals = stats.get("session_totals");
    assert!(totals.get("hits").as_u64().unwrap() > totals.get("misses").as_u64().unwrap());

    // The training graph is addressable for direct plan requests too.
    let plan = c.send(&format!(r#"{{"cmd":"plan","fingerprint":"{fp}"}}"#));
    assert_eq!(plan.get("ok").as_bool(), Some(true), "{}", plan.to_string());
    assert_eq!(plan.get("cache_hit").as_bool(), Some(true), "train already compiled this plan");

    handle.shutdown();
    join.join().unwrap().unwrap();
}
