//! Seeded property harness for the trace-driven general-DAG executor.
//!
//! Three end-to-end claims, each over seeded random graphs so failures
//! reproduce exactly:
//!
//! 1. **Schedules don't change numerics.** For random DAGs × every
//!    planner family (exact DP, approx DP, Chen's baseline, the DFS
//!    oracle), executing the compiled recomputation program yields the
//!    same forward loss and the same parameter gradients as vanilla
//!    execution — *bit-exactly* (compared via `f32::to_bits`).
//! 2. **Observed memory is predicted memory.** On executable-lowered
//!    chains and random DAGs, the executor's per-step live-byte counter
//!    equals the program's model prediction, and its peak equals
//!    `sim::SimReport::peak_bytes` with liveness off — as an equality.
//!    Divergence reports the first differing step, rendered.
//! 3. **The zoo runs.** ResNet50 and U-Net (and friends) train end to end
//!    on the native backend under a planner-chosen budget with both
//!    invariants holding.

use recompute::coordinator::train::{bits_equal, grad_maps_equal, train_zoo_model};
use recompute::exec::{DagTrainer, GradMap, OpProgram, StepReport, TrainConfig};
use recompute::models::executable::recost;
use recompute::planner::{
    chen_plan, exhaustive_search, plan_at_min_budget, Family, LowerSetChain, Objective,
};
use recompute::runtime::{Backend, HostTensor, NativeBackend};
use recompute::sim::{canonical_trace, measure, SimOptions};
use recompute::testutil::{chain_graph, diamond, random_dag};
use recompute::util::rng::Pcg32;
use recompute::Graph;

const BATCH: usize = 4;
const WIDTH: usize = 8;
const LR: f32 = 0.05;
const SEED: u64 = 7;

/// Fresh trainer + one recorded step of `prog` on the shared batch.
fn run_one(g: &Graph, prog: &OpProgram, x: &HostTensor, y: &HostTensor) -> StepReport {
    let mut t = DagTrainer::new(NativeBackend::new(BATCH, WIDTH), g, SEED).unwrap();
    t.run_step(prog, x, y, LR, true).unwrap()
}

/// Shared random batch for one graph's comparisons.
fn batch_xy(rng: &mut Pcg32) -> (HostTensor, HostTensor) {
    let be = NativeBackend::new(BATCH, WIDTH);
    let n = BATCH * WIDTH;
    let xv: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let yv: Vec<f32> = (0..n).map(|_| (1.7 * rng.normal() as f32).sin()).collect();
    (be.upload(&xv, &[BATCH, WIDTH]).unwrap(), be.upload(&yv, &[BATCH, WIDTH]).unwrap())
}

fn assert_grads_bitwise(label: &str, case: u32, vanilla: &GradMap, got: &GradMap) {
    if grad_maps_equal(vanilla, got) {
        return;
    }
    assert_eq!(vanilla.len(), got.len(), "[{label} case {case}] gradient node sets differ");
    for (node, (w0, b0)) in vanilla {
        let (w1, b1) = &got[node];
        assert!(
            bits_equal(w0, w1) && bits_equal(b0, b1),
            "[{label} case {case}] gradient of node {node} diverged from vanilla"
        );
    }
    panic!("[{label} case {case}] gradient maps diverged");
}

#[test]
fn every_planner_matches_vanilla_bit_exactly_on_random_dags() {
    let mut rng = Pcg32::seeded(0xda6);
    for case in 0..10u32 {
        let n = rng.range(4, 10);
        let g = random_dag(&mut rng, n);
        let (x, y) = batch_xy(&mut rng);

        let vanilla = OpProgram::vanilla(&g).unwrap();
        let base = run_one(&g, &vanilla, &x, &y);
        let base_grads = base.grads.as_ref().unwrap();

        let mut plans: Vec<(&str, LowerSetChain)> = Vec::new();
        let exact = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let exact_budget = exact.budget;
        plans.push(("exact-dp", exact.chain));
        plans.push((
            "approx-dp",
            plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap().chain,
        ));
        plans.push((
            "exact-dp-mc",
            plan_at_min_budget(&g, Family::Exact, Objective::MaxOverhead).unwrap().chain,
        ));
        plans.push(("chen", chen_plan(&g, |c| c.peak_mem(&g)).unwrap().chain));
        if n <= 8 {
            plans.push((
                "dfs-oracle",
                exhaustive_search(&g, exact_budget, Objective::MinOverhead)
                    .expect("oracle feasible at the exact min budget"),
            ));
        }

        for (label, chain) in plans {
            let prog = OpProgram::from_chain(&g, &chain)
                .unwrap_or_else(|e| panic!("[{label} case {case}] compile: {e}"));
            let r = run_one(&g, &prog, &x, &y);
            assert_eq!(
                base.loss.to_bits(),
                r.loss.to_bits(),
                "[{label} case {case}] loss diverged: vanilla {} vs {}",
                base.loss,
                r.loss
            );
            assert_grads_bitwise(label, case, base_grads, r.grads.as_ref().unwrap());
        }
    }
}

/// On failure, name the first step whose observed live bytes differ from
/// the model prediction — the debuggability contract of the harness.
fn assert_trajectory_matches(label: &str, g: &Graph, prog: &OpProgram, r: &StepReport) {
    assert_eq!(r.live_trajectory.len(), prog.predicted_live.len(), "[{label}] step counts");
    if let Some(i) =
        (0..prog.steps.len()).find(|&i| r.live_trajectory[i] != prog.predicted_live[i])
    {
        panic!(
            "[{label}] live-byte divergence at step {i} ({}): observed {} vs predicted {}",
            prog.steps[i].describe(g),
            r.live_trajectory[i],
            prog.predicted_live[i]
        );
    }
}

#[test]
fn observed_peak_equals_simulator_prediction_on_chains_and_dags() {
    let mut rng = Pcg32::seeded(0x9ea);
    // Chains of several lengths plus random DAG topologies, all lowered
    // to the executable cost model (M_v = real tensor bytes).
    let mut graphs: Vec<Graph> = vec![
        recost(&chain_graph(&[1; 6]), BATCH, WIDTH),
        recost(&chain_graph(&[1; 13]), BATCH, WIDTH),
        recost(&diamond(), BATCH, WIDTH),
    ];
    for _ in 0..8 {
        let n = rng.range(4, 12);
        graphs.push(recost(&random_dag(&mut rng, n), BATCH, WIDTH));
    }
    for (gi, g) in graphs.iter().enumerate() {
        let (x, y) = batch_xy(&mut rng);
        for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
            let plan = plan_at_min_budget(g, Family::Exact, obj).unwrap();
            let tr = canonical_trace(g, &plan.chain);
            let prog = OpProgram::compile(g, &tr).unwrap();
            let sim = measure(g, &tr, SimOptions { liveness: false, include_params: false });
            let label = format!("graph {gi} {:?}", obj);
            let r = run_one(g, &prog, &x, &y);
            assert_trajectory_matches(&label, g, &prog, &r);
            assert_eq!(
                r.observed_peak,
                sim.peak_bytes,
                "[{label}] observed peak (at step {}: {}) vs SimReport::peak_bytes \
                 (predicted peak at step {}: {})",
                r.peak_step,
                prog.steps[r.peak_step].describe(g),
                prog.predicted_peak_step(),
                prog.steps[prog.predicted_peak_step()].describe(g),
            );
        }
        // Vanilla execution obeys the same equality.
        let prog = OpProgram::vanilla(g).unwrap();
        let r = run_one(g, &prog, &x, &y);
        assert_trajectory_matches(&format!("graph {gi} vanilla"), g, &prog, &r);
    }
}

#[test]
fn diamond_fixture_runs_under_every_schedule() {
    // The shared fan-in/fan-out fixture (also used by the graph and exec
    // unit suites) through the integration path: vanilla, the exact plan,
    // and the maximally-coarse whole-graph strategy all agree bitwise.
    let g = recost(&diamond(), BATCH, WIDTH);
    let mut rng = Pcg32::seeded(0xd1a);
    let (x, y) = batch_xy(&mut rng);
    let vanilla = run_one(&g, &OpProgram::vanilla(&g).unwrap(), &x, &y);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    for chain in [plan.chain, recompute::planner::whole_graph_chain(&g)] {
        let prog = OpProgram::from_chain(&g, &chain).unwrap();
        let r = run_one(&g, &prog, &x, &y);
        assert_eq!(vanilla.loss.to_bits(), r.loss.to_bits());
        let (gv, gr) = (vanilla.grads.as_ref().unwrap(), r.grads.as_ref().unwrap());
        assert_grads_bitwise("diamond", 0, gv, gr);
    }
}

#[test]
fn zoo_resnet_and_unet_train_end_to_end_with_invariants() {
    let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(model, 2, 4, &cfg, None, Objective::MinOverhead, true)
            .unwrap_or_else(|e| panic!("{model}: {e}"));
        assert!(cmp.grads_match, "{model}: planned gradients must match vanilla bit-exactly");
        assert!(cmp.peak_matches_sim, "{model}: observed peak must equal sim prediction");
        assert!(cmp.losses_identical, "{model}: loss trajectories must be bit-identical");
        assert!(
            cmp.planned.observed_peak < cmp.vanilla.observed_peak,
            "{model}: recomputation must reduce the measured peak"
        );
        assert!(cmp.planned.losses.iter().all(|l| l.is_finite()), "{model}: finite losses");
        assert!(cmp.planned.recomputes_per_step > 0, "{model}: plan actually recomputes");
    }
}

#[test]
fn chain_schedule_error_is_actionable_for_zoo_graphs() {
    // Regression (integration-level): planning a branching zoo model and
    // feeding it to the chain fast path must produce an error naming the
    // offending node, not a generic rejection.
    use recompute::exec::ChainSchedule;
    let g = recost(&recompute::models::zoo::find("unet").unwrap().build_batch(1), 2, 4);
    let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
    let msg = ChainSchedule::from_chain(&g, &plan.chain).unwrap_err().to_string();
    assert!(msg.contains("fan-in"), "degree in message: {msg}");
    assert!(msg.contains("DAG executor"), "remediation in message: {msg}");
}
