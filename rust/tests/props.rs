//! Seeded property harness for the trace-driven general-DAG executor.
//!
//! Four end-to-end claims, each over seeded random graphs so failures
//! reproduce exactly:
//!
//! 1. **Schedules don't change numerics.** For random DAGs × every
//!    planner family (exact DP, approx DP, Chen's baseline, the DFS
//!    oracle — planned against the raw graphs' *non-uniform* `M_v`
//!    costs), executing the compiled recomputation program yields the
//!    same forward loss and the same parameter gradients as vanilla
//!    execution — *bit-exactly* (compared via `f32::to_bits`).
//! 2. **Observed memory is predicted memory.** On executable-lowered
//!    chains and random DAGs, the executor's per-step live-byte counter
//!    equals the program's model prediction, and its peak equals
//!    `sim::SimReport::peak_bytes` with liveness off — as an equality.
//!    Divergence reports the first differing step, rendered.
//! 3. **Heterogeneous shapes preserve every invariant.** Random DAGs
//!    lowered with *per-node* widths from their own `M_v` profile
//!    (`recost_profiled`) still match vanilla bit-exactly under every
//!    planner family, with observed peak == predicted peak ≤ vanilla
//!    peak.
//! 4. **The zoo runs.** ResNet50 and U-Net (and friends) train end to end
//!    on the native backend under a planner-chosen budget with the
//!    invariants holding — and with genuinely non-uniform per-node
//!    activation bytes.
//! 5. **The liveness invariant chain.** Programs compiled in liveness
//!    mode observe exactly the liveness-predicted peak (an equality),
//!    which never exceeds the no-liveness peak, which never exceeds the
//!    vanilla peak — for every planner family × random DAGs — while the
//!    gradients stay bit-identical to vanilla.
//! 6. **Decomposition is invisible to correctness.** Stitched
//!    decomposed plans on random block–cut DAGs behave like any other
//!    planner's output (bit-exact gradients, observed == predicted ≤
//!    vanilla peak), match whole-graph exact DP where the lattice is
//!    small enough to cross-check, and come out identical — chains,
//!    decomposition reports, and session counters — at any worker
//!    thread count.

use std::collections::BTreeMap;
use std::sync::Arc;

use recompute::coordinator::train::{bits_equal, grad_maps_equal, train_zoo_model, BudgetSpec};
use recompute::exec::{DagTask, DagTrainer, GradMap, OpProgram, StepReport, TrainConfig};
use recompute::graph::{EnumerationLimit, GraphBuilder, NodeId, OpKind};
use recompute::models::executable::{distinct_act_sizes, recost, recost_profiled};
use recompute::planner::{
    chen_plan, exact_dp, exhaustive_search, plan_at_min_budget, Family, LowerSetChain, Objective,
    PlanRequest, PlannerId,
};
use recompute::runtime::{Backend, HostTensor, NativeBackend};
use recompute::session::{PlanCache, PlanSession};
use recompute::sim::{canonical_trace, measure, SimMode, SimOptions};
use recompute::testutil::{chain_graph, diamond, random_dag};
use recompute::util::pool::WorkerPool;
use recompute::util::rng::Pcg32;
use recompute::Graph;

const BATCH: usize = 4;
const WIDTH: usize = 8;
const LR: f32 = 0.05;
const SEED: u64 = 7;

/// Fresh trainer + one recorded step of `prog` on the shared batch.
fn run_one(
    g: &Graph,
    prog: &OpProgram,
    x: &HostTensor,
    targets: &BTreeMap<u32, HostTensor>,
) -> StepReport {
    let mut t = DagTrainer::new(NativeBackend::new(), g, BATCH, SEED).unwrap();
    t.run_step(prog, x, targets, LR, true).unwrap()
}

/// Shared random batch (input + per-sink targets) for one executable
/// lowering's comparisons; shapes are read off the task's vectors.
fn batch_xy(g: &Graph, rng: &mut Pcg32) -> (HostTensor, BTreeMap<u32, HostTensor>) {
    let be = NativeBackend::new();
    let mut task = DagTask::for_graph(g, BATCH, rng.next_u64());
    let (xv, ys) = task.next_batch();
    let x = be.upload(&xv, &[BATCH, xv.len() / BATCH]).unwrap();
    let targets = ys
        .into_iter()
        .map(|(id, y)| {
            let w = y.len() / BATCH;
            (id, be.upload(&y, &[BATCH, w]).unwrap())
        })
        .collect();
    (x, targets)
}

fn assert_grads_bitwise(label: &str, case: u32, vanilla: &GradMap, got: &GradMap) {
    if grad_maps_equal(vanilla, got) {
        return;
    }
    assert_eq!(vanilla.len(), got.len(), "[{label} case {case}] gradient node sets differ");
    for (node, (w0, b0)) in vanilla {
        let (w1, b1) = &got[node];
        assert!(
            bits_equal(w0, w1) && bits_equal(b0, b1),
            "[{label} case {case}] gradient of node {node} diverged from vanilla"
        );
    }
    panic!("[{label} case {case}] gradient maps diverged");
}

#[test]
fn every_planner_matches_vanilla_bit_exactly_on_random_dags() {
    let mut rng = Pcg32::seeded(0xda6);
    for case in 0..10u32 {
        let n = rng.range(4, 10);
        // Plan against the raw graph's non-uniform M_v costs; execute the
        // same chains on the uniform lowering (same node ids/topology).
        let base = random_dag(&mut rng, n);
        let g = recost(&base, BATCH, WIDTH);
        let (x, targets) = batch_xy(&g, &mut rng);

        let vanilla = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let base_report = run_one(&g, &vanilla, &x, &targets);
        let base_grads = base_report.grads.as_ref().unwrap();

        let mut plans: Vec<(&str, LowerSetChain)> = Vec::new();
        let exact = plan_at_min_budget(&base, Family::Exact, Objective::MinOverhead).unwrap();
        let exact_budget = exact.budget;
        plans.push(("exact-dp", exact.chain));
        plans.push((
            "approx-dp",
            plan_at_min_budget(&base, Family::Approx, Objective::MinOverhead).unwrap().chain,
        ));
        plans.push((
            "exact-dp-mc",
            plan_at_min_budget(&base, Family::Exact, Objective::MaxOverhead).unwrap().chain,
        ));
        plans.push(("chen", chen_plan(&base, |c| c.peak_mem(&base)).unwrap().chain));
        if n <= 8 {
            plans.push((
                "dfs-oracle",
                exhaustive_search(&base, exact_budget, Objective::MinOverhead)
                    .expect("oracle feasible at the exact min budget"),
            ));
        }

        for (label, chain) in plans {
            let prog = OpProgram::from_chain(&g, &chain, SimMode::Strict)
                .unwrap_or_else(|e| panic!("[{label} case {case}] compile: {e}"));
            let r = run_one(&g, &prog, &x, &targets);
            assert_eq!(
                base_report.loss.to_bits(),
                r.loss.to_bits(),
                "[{label} case {case}] loss diverged: vanilla {} vs {}",
                base_report.loss,
                r.loss
            );
            assert_grads_bitwise(label, case, base_grads, r.grads.as_ref().unwrap());
        }
    }
}

/// On failure, name the first step whose observed live bytes differ from
/// the model prediction — the debuggability contract of the harness.
fn assert_trajectory_matches(label: &str, g: &Graph, prog: &OpProgram, r: &StepReport) {
    assert_eq!(r.live_trajectory.len(), prog.predicted_live.len(), "[{label}] step counts");
    if let Some(i) =
        (0..prog.steps.len()).find(|&i| r.live_trajectory[i] != prog.predicted_live[i])
    {
        panic!(
            "[{label}] live-byte divergence at step {i} ({}): observed {} vs predicted {}",
            prog.steps[i].describe(g),
            r.live_trajectory[i],
            prog.predicted_live[i]
        );
    }
}

#[test]
fn observed_peak_equals_simulator_prediction_on_chains_and_dags() {
    let mut rng = Pcg32::seeded(0x9ea);
    // Chains of several lengths plus random DAG topologies, all lowered
    // to the executable cost model (M_v = real tensor bytes).
    let mut graphs: Vec<Graph> = vec![
        recost(&chain_graph(&[1; 6]), BATCH, WIDTH),
        recost(&chain_graph(&[1; 13]), BATCH, WIDTH),
        recost(&diamond(), BATCH, WIDTH),
    ];
    for _ in 0..8 {
        let n = rng.range(4, 12);
        graphs.push(recost(&random_dag(&mut rng, n), BATCH, WIDTH));
    }
    for (gi, g) in graphs.iter().enumerate() {
        let (x, targets) = batch_xy(g, &mut rng);
        for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
            let plan = plan_at_min_budget(g, Family::Exact, obj).unwrap();
            let tr = canonical_trace(g, &plan.chain);
            let prog = OpProgram::compile(g, &tr).unwrap();
            let sim = measure(g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let label = format!("graph {gi} {:?}", obj);
            let r = run_one(g, &prog, &x, &targets);
            assert_trajectory_matches(&label, g, &prog, &r);
            assert_eq!(
                r.observed_peak,
                sim.peak_bytes,
                "[{label}] observed peak (at step {}: {}) vs SimReport::peak_bytes \
                 (predicted peak at step {}: {})",
                r.peak_step,
                prog.steps[r.peak_step].describe(g),
                prog.predicted_peak_step(),
                prog.steps[prog.predicted_peak_step()].describe(g),
            );
        }
        // Vanilla execution obeys the same equality.
        let prog = OpProgram::vanilla(g, SimMode::Strict).unwrap();
        let r = run_one(g, &prog, &x, &targets);
        assert_trajectory_matches(&format!("graph {gi} vanilla"), g, &prog, &r);
    }
}

#[test]
fn heterogeneous_lowerings_hold_invariants_across_planners() {
    // The tentpole claim: per-node widths from the graph's own M_v
    // profile — so nodes hold differently-sized tensors — and still:
    // bit-exact gradients vs vanilla under every planner family, and
    // observed peak == predicted peak ≤ vanilla peak.
    let mut rng = Pcg32::seeded(0x8e7e40);
    let mut hetero_cases = 0u32;
    for case in 0..8u32 {
        let n = rng.range(5, 11);
        let base = random_dag(&mut rng, n);
        let g = recost_profiled(&base, BATCH, 12);
        if distinct_act_sizes(&g).len() >= 2 {
            hetero_cases += 1;
        }
        let (x, targets) = batch_xy(&g, &mut rng);

        let vanilla_prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let rv = run_one(&g, &vanilla_prog, &x, &targets);
        assert_trajectory_matches(&format!("het vanilla case {case}"), &g, &vanilla_prog, &rv);
        let base_grads = rv.grads.as_ref().unwrap();

        for (name, family, obj) in [
            ("exact-tc", Family::Exact, Objective::MinOverhead),
            ("exact-mc", Family::Exact, Objective::MaxOverhead),
            ("approx-tc", Family::Approx, Objective::MinOverhead),
        ] {
            let label = format!("het {name} case {case}");
            let plan = plan_at_min_budget(&g, family, obj).unwrap();
            let tr = canonical_trace(&g, &plan.chain);
            let prog = OpProgram::compile(&g, &tr).unwrap();
            let sim = measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let r = run_one(&g, &prog, &x, &targets);
            assert_trajectory_matches(&label, &g, &prog, &r);
            assert_eq!(r.observed_peak, sim.peak_bytes, "[{label}] observed == predicted");
            assert!(
                r.observed_peak <= rv.observed_peak,
                "[{label}] planned peak {} must not exceed vanilla {}",
                r.observed_peak,
                rv.observed_peak
            );
            assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[{label}] loss diverged");
            assert_grads_bitwise(&label, case, base_grads, r.grads.as_ref().unwrap());
        }

        // Chen's baseline executes heterogeneous shapes bit-exactly too.
        let chen = chen_plan(&g, |c| c.peak_mem(&g)).unwrap();
        let prog = OpProgram::from_chain(&g, &chen.chain, SimMode::Strict).unwrap();
        let r = run_one(&g, &prog, &x, &targets);
        assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[het chen case {case}] loss");
        assert_grads_bitwise("het chen", case, base_grads, r.grads.as_ref().unwrap());
    }
    assert!(
        hetero_cases > 0,
        "profiled lowering never produced heterogeneous widths across the suite"
    );
}

#[test]
fn diamond_fixture_runs_under_every_schedule() {
    // The shared fan-in/fan-out fixture (also used by the graph and exec
    // unit suites) through the integration path: vanilla, the exact plan,
    // and the maximally-coarse whole-graph strategy all agree bitwise.
    let g = recost(&diamond(), BATCH, WIDTH);
    let mut rng = Pcg32::seeded(0xd1a);
    let (x, targets) = batch_xy(&g, &mut rng);
    let vanilla = run_one(&g, &OpProgram::vanilla(&g, SimMode::Strict).unwrap(), &x, &targets);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    for chain in [plan.chain, recompute::planner::whole_graph_chain(&g)] {
        let prog = OpProgram::from_chain(&g, &chain, SimMode::Strict).unwrap();
        let r = run_one(&g, &prog, &x, &targets);
        assert_eq!(vanilla.loss.to_bits(), r.loss.to_bits());
        let (gv, gr) = (vanilla.grads.as_ref().unwrap(), r.grads.as_ref().unwrap());
        assert_grads_bitwise("diamond", 0, gv, gr);
    }
}

#[test]
fn zoo_resnet_and_unet_train_end_to_end_with_invariants() {
    let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(
            model,
            2,
            8,
            &cfg,
            BudgetSpec::MinFeasible,
            &[Objective::MinOverhead],
            SimMode::Liveness,
            true,
        )
        .unwrap_or_else(|e| panic!("{model}: {e}"));
        assert_eq!(cmp.runs.len(), 1);
        let run = &cmp.runs[0];
        assert!(run.grads_match, "{model}: planned gradients must match vanilla bit-exactly");
        assert!(run.peak_matches_sim, "{model}: observed peak must equal sim prediction");
        assert!(
            run.sim_peak <= run.sim_peak_strict,
            "{model}: liveness peak must not exceed the no-liveness peak"
        );
        assert!(run.losses_identical, "{model}: loss trajectories must be bit-identical");
        assert!(
            run.report.observed_peak < cmp.vanilla.observed_peak,
            "{model}: recomputation must reduce the measured peak"
        );
        assert!(run.report.losses.iter().all(|l| l.is_finite()), "{model}: finite losses");
        assert!(run.report.recomputes_per_step > 0, "{model}: plan actually recomputes");
        assert!(
            cmp.distinct_act_bytes >= 2,
            "{model}: heterogeneous lowering must yield ≥ 2 distinct node byte-sizes"
        );
        // The session amortized: one family built, and the training run's
        // repeated request was a cache hit.
        assert_eq!(cmp.stats.families_built, 1, "{model}");
        assert!(run.cache_hit, "{model}: repeated PlanRequest must be cached");
    }
}

#[test]
fn liveness_invariant_chain_holds_across_planners_on_random_dags() {
    // The tentpole claim, end to end: executing the liveness-compiled
    // program of every planner family observes *exactly* the
    // liveness-predicted peak, which is ≤ the no-liveness peak of the
    // same plan, which is ≤ the vanilla peak — and none of it perturbs
    // the numerics (gradients bit-identical to vanilla execution).
    let mut rng = Pcg32::seeded(0x11fe);
    for case in 0..6u32 {
        let n = rng.range(5, 11);
        let base = random_dag(&mut rng, n);
        // Heterogeneous lowering: the liveness schedule must hold on
        // non-uniform per-node byte sizes, not just uniform shapes.
        let g = recost_profiled(&base, BATCH, 12);
        let (x, targets) = batch_xy(&g, &mut rng);

        // Vanilla baseline, strict mode: keeps every buffer until the
        // step ends — the ceiling of the whole chain.
        let vanilla_prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let rv = run_one(&g, &vanilla_prog, &x, &targets);
        let vanilla_peak = rv.observed_peak;
        let base_grads = rv.grads.as_ref().unwrap();

        let mut plans: Vec<(&str, LowerSetChain)> = vec![
            (
                "exact-tc",
                plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap().chain,
            ),
            (
                "exact-mc",
                plan_at_min_budget(&g, Family::Exact, Objective::MaxOverhead).unwrap().chain,
            ),
            (
                "approx-tc",
                plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap().chain,
            ),
            ("chen", chen_plan(&g, |c| c.peak_mem(&g)).unwrap().chain),
        ];
        for (label, chain) in plans.drain(..) {
            let label = format!("liveness {label} case {case}");
            let tr = canonical_trace(&g, &chain);
            let prog = OpProgram::from_trace(&g, &tr, SimMode::Liveness)
                .unwrap_or_else(|e| panic!("[{label}] compile: {e}"));
            let live_sim =
                measure(&g, &tr, SimOptions { mode: SimMode::Liveness, include_params: false });
            let strict_sim =
                measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let r = run_one(&g, &prog, &x, &targets);
            assert_trajectory_matches(&label, &g, &prog, &r);
            assert_eq!(
                r.observed_peak, live_sim.peak_bytes,
                "[{label}] observed == liveness-predicted must be an equality"
            );
            assert!(
                live_sim.peak_bytes <= strict_sim.peak_bytes,
                "[{label}] liveness {} must not exceed no-liveness {}",
                live_sim.peak_bytes,
                strict_sim.peak_bytes
            );
            assert!(
                strict_sim.peak_bytes <= vanilla_peak,
                "[{label}] no-liveness {} must not exceed vanilla {}",
                strict_sim.peak_bytes,
                vanilla_peak
            );
            assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[{label}] loss diverged");
            assert_grads_bitwise(&label, case, base_grads, r.grads.as_ref().unwrap());
        }
    }
}

#[test]
fn chain_schedule_error_is_actionable_for_zoo_graphs() {
    // Regression (integration-level): planning a branching zoo model and
    // feeding it to the chain fast path must produce an error naming the
    // offending node, not a generic rejection.
    use recompute::exec::ChainSchedule;
    let g = recost(&recompute::models::zoo::find("unet").unwrap().build_batch(1), 2, 4);
    let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
    let msg = ChainSchedule::from_chain(&g, &plan.chain).unwrap_err().to_string();
    assert!(msg.contains("fan-in"), "degree in message: {msg}");
    assert!(msg.contains("DAG executor"), "remediation in message: {msg}");
}

/// Random block–cut DAG: `blocks` stacked units, each fanning a random
/// number of parallel chains out of the previous merge and joining them
/// at a fresh merge node. Every merge is an articulation-point gate, so
/// the decomposed planner gets real components to split and stitch.
fn random_block_dag(rng: &mut Pcg32, blocks: u32) -> Graph {
    let mut b = GraphBuilder::new("blockcut", 1);
    let mut prev = b.add_raw("in", OpKind::Other, u64::from(rng.range(1, 8)), 1, &[]);
    for blk in 0..blocks {
        let branches = rng.range(2, 4);
        let len = rng.range(3, 6);
        let mut tails: Vec<NodeId> = Vec::new();
        for br in 0..branches {
            let mut cur = prev;
            for i in 0..len {
                let name = format!("b{blk}/c{br}/n{i}");
                cur = b.add_raw(name, OpKind::Other, u64::from(rng.range(1, 16)), 1, &[cur]);
            }
            tails.push(cur);
        }
        let merge_mem = u64::from(rng.range(1, 8));
        prev = b.add_raw(format!("b{blk}/merge"), OpKind::Other, merge_mem, 1, &tails);
    }
    b.build()
}

#[test]
fn decomposed_plans_hold_invariants_on_random_block_cut_dags() {
    // The decomposed planner is a *planner*, not a new executor: its
    // stitched chains must satisfy every invariant the other families
    // do. The generated graphs exceed the 32-node coalescing target, so
    // every plan here really is stitched across ≥ 2 components.
    let mut rng = Pcg32::seeded(0xb10c);
    for case in 0..4u32 {
        let blocks = rng.range(5, 8);
        let base = random_block_dag(&mut rng, blocks);
        let g = recost(&base, BATCH, WIDTH);
        let (x, targets) = batch_xy(&g, &mut rng);

        let vanilla_prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let rv = run_one(&g, &vanilla_prog, &x, &targets);
        let base_grads = rv.grads.as_ref().unwrap();

        let session = PlanSession::new(g.clone());
        let cp = session
            .plan(&PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead))
            .unwrap();
        let info = cp.plan.decomposition.as_ref().unwrap();
        assert!(info.components >= 2, "case {case}: {} nodes must split: {info:?}", g.len());

        let label = format!("decomposed case {case}");
        let r = run_one(&g, &cp.program, &x, &targets);
        assert_trajectory_matches(&label, &g, &cp.program, &r);
        assert_eq!(r.observed_peak, cp.report.peak_bytes, "[{label}] observed == predicted");
        assert!(
            r.observed_peak <= rv.observed_peak,
            "[{label}] stitched peak {} must not exceed vanilla {}",
            r.observed_peak,
            rv.observed_peak
        );
        assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[{label}] loss diverged");
        assert_grads_bitwise(&label, case, base_grads, r.grads.as_ref().unwrap());
    }
}

#[test]
fn decomposed_matches_whole_graph_exact_dp_where_crosscheckable() {
    let mut rng = Pcg32::seeded(0xdec0);
    // (a) Below the coalescing target the planner collapses to a single
    // exact-DP component — the whole-graph optimum, bit for bit, at the
    // same minimal feasible budget and for both objectives.
    for case in 0..6u32 {
        let n = rng.range(6, 12);
        let base = random_dag(&mut rng, n);
        let session = PlanSession::new(base.clone());
        for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
            let cp = session.plan(&PlanRequest::new(PlannerId::Decomposed, obj)).unwrap();
            let info = cp.plan.decomposition.as_ref().unwrap();
            assert_eq!(info.components, 1, "case {case}: {n} nodes stay one unit");
            let exact = plan_at_min_budget(&base, Family::Exact, obj).unwrap();
            assert_eq!(cp.plan.overhead, exact.overhead, "case {case} {obj:?}: overhead");
            assert_eq!(cp.plan.budget, exact.budget, "case {case} {obj:?}: budget");
        }
    }
    // (b) Multi-component chains: at a generous budget the stitched
    // plan reaches the whole-graph optimum, and at its own realized
    // min-feasible budget exact DP can only do as well or better.
    for case in 0..4u32 {
        let len = rng.range(40, 72);
        let mems: Vec<u64> = (0..len).map(|_| u64::from(rng.range(1, 20))).collect();
        let g = chain_graph(&mems);
        let session = PlanSession::new(g.clone());
        let generous = g.total_mem() * 4;
        let req = PlanRequest {
            planner: PlannerId::Decomposed,
            budget: BudgetSpec::Bytes(generous),
            objective: Objective::MinOverhead,
            sim_mode: SimMode::Liveness,
        };
        let cp = session.plan(&req).unwrap();
        let info = cp.plan.decomposition.as_ref().unwrap();
        assert!(info.components >= 2, "case {case}: {len} nodes must split: {info:?}");
        let exact = exact_dp(&g, generous, Objective::MinOverhead).unwrap();
        assert_eq!(cp.plan.overhead, exact.overhead, "case {case}: generous-budget optimum");

        let tight = session
            .plan(&PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead))
            .unwrap();
        let lb = exact_dp(&g, tight.plan.budget, Objective::MinOverhead).unwrap();
        assert!(
            lb.overhead <= tight.plan.overhead,
            "case {case}: exact optimum {} must lower-bound stitched {}",
            lb.overhead,
            tight.plan.overhead
        );
    }
}

#[test]
fn decomposed_planning_is_identical_at_any_thread_count() {
    // REPRO_THREADS must not leak into plans or accounting: the same
    // workload on 1-thread and 4-thread pools yields identical chains,
    // decomposition reports, and session counters — including the
    // component-cache hit/miss split, which is why the solver probes
    // its cache sequentially before fanning out.
    let mut rng = Pcg32::seeded(0x7d5);
    let base = random_block_dag(&mut rng, 6);
    let session_for = |threads: usize| {
        PlanSession::with_pool(
            base.clone(),
            EnumerationLimit::default(),
            PlanCache::shared(64),
            Arc::new(WorkerPool::with_threads(threads)),
        )
    };
    let (one, four) = (session_for(1), session_for(4));
    let mut frac = PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead);
    frac.budget = BudgetSpec::Frac(0.5);
    for req in [
        PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead),
        PlanRequest::new(PlannerId::Decomposed, Objective::MaxOverhead),
        frac,
    ] {
        let a = one.plan(&req).unwrap();
        let b = four.plan(&req).unwrap();
        assert_eq!(a.plan.chain.lower_sets(), b.plan.chain.lower_sets(), "{req:?}");
        assert_eq!(a.plan.overhead, b.plan.overhead, "{req:?}");
        assert_eq!(a.plan.peak_eq2, b.plan.peak_eq2, "{req:?}");
        assert_eq!(a.plan.decomposition, b.plan.decomposition, "{req:?}");
    }
    assert_eq!(one.stats(), four.stats(), "session counters must be thread-count invariant");
}
