//! Seeded property harness for the trace-driven general-DAG executor.
//!
//! Four end-to-end claims, each over seeded random graphs so failures
//! reproduce exactly:
//!
//! 1. **Schedules don't change numerics.** For random DAGs × every
//!    planner family (exact DP, approx DP, Chen's baseline, the DFS
//!    oracle — planned against the raw graphs' *non-uniform* `M_v`
//!    costs), executing the compiled recomputation program yields the
//!    same forward loss and the same parameter gradients as vanilla
//!    execution — *bit-exactly* (compared via `f32::to_bits`).
//! 2. **Observed memory is predicted memory.** On executable-lowered
//!    chains and random DAGs, the executor's per-step live-byte counter
//!    equals the program's model prediction, and its peak equals
//!    `sim::SimReport::peak_bytes` with liveness off — as an equality.
//!    Divergence reports the first differing step, rendered.
//! 3. **Heterogeneous shapes preserve every invariant.** Random DAGs
//!    lowered with *per-node* widths from their own `M_v` profile
//!    (`recost_profiled`) still match vanilla bit-exactly under every
//!    planner family, with observed peak == predicted peak ≤ vanilla
//!    peak.
//! 4. **The zoo runs.** ResNet50 and U-Net (and friends) train end to end
//!    on the native backend under a planner-chosen budget with the
//!    invariants holding — and with genuinely non-uniform per-node
//!    activation bytes.
//! 5. **The liveness invariant chain.** Programs compiled in liveness
//!    mode observe exactly the liveness-predicted peak (an equality),
//!    which never exceeds the no-liveness peak, which never exceeds the
//!    vanilla peak — for every planner family × random DAGs — while the
//!    gradients stay bit-identical to vanilla.

use std::collections::BTreeMap;

use recompute::coordinator::train::{bits_equal, grad_maps_equal, train_zoo_model, BudgetSpec};
use recompute::exec::{DagTask, DagTrainer, GradMap, OpProgram, StepReport, TrainConfig};
use recompute::models::executable::{distinct_act_sizes, recost, recost_profiled};
use recompute::planner::{
    chen_plan, exhaustive_search, plan_at_min_budget, Family, LowerSetChain, Objective,
};
use recompute::runtime::{Backend, HostTensor, NativeBackend};
use recompute::sim::{canonical_trace, measure, SimMode, SimOptions};
use recompute::testutil::{chain_graph, diamond, random_dag};
use recompute::util::rng::Pcg32;
use recompute::Graph;

const BATCH: usize = 4;
const WIDTH: usize = 8;
const LR: f32 = 0.05;
const SEED: u64 = 7;

/// Fresh trainer + one recorded step of `prog` on the shared batch.
fn run_one(
    g: &Graph,
    prog: &OpProgram,
    x: &HostTensor,
    targets: &BTreeMap<u32, HostTensor>,
) -> StepReport {
    let mut t = DagTrainer::new(NativeBackend::new(), g, BATCH, SEED).unwrap();
    t.run_step(prog, x, targets, LR, true).unwrap()
}

/// Shared random batch (input + per-sink targets) for one executable
/// lowering's comparisons; shapes are read off the task's vectors.
fn batch_xy(g: &Graph, rng: &mut Pcg32) -> (HostTensor, BTreeMap<u32, HostTensor>) {
    let be = NativeBackend::new();
    let mut task = DagTask::for_graph(g, BATCH, rng.next_u64());
    let (xv, ys) = task.next_batch();
    let x = be.upload(&xv, &[BATCH, xv.len() / BATCH]).unwrap();
    let targets = ys
        .into_iter()
        .map(|(id, y)| {
            let w = y.len() / BATCH;
            (id, be.upload(&y, &[BATCH, w]).unwrap())
        })
        .collect();
    (x, targets)
}

fn assert_grads_bitwise(label: &str, case: u32, vanilla: &GradMap, got: &GradMap) {
    if grad_maps_equal(vanilla, got) {
        return;
    }
    assert_eq!(vanilla.len(), got.len(), "[{label} case {case}] gradient node sets differ");
    for (node, (w0, b0)) in vanilla {
        let (w1, b1) = &got[node];
        assert!(
            bits_equal(w0, w1) && bits_equal(b0, b1),
            "[{label} case {case}] gradient of node {node} diverged from vanilla"
        );
    }
    panic!("[{label} case {case}] gradient maps diverged");
}

#[test]
fn every_planner_matches_vanilla_bit_exactly_on_random_dags() {
    let mut rng = Pcg32::seeded(0xda6);
    for case in 0..10u32 {
        let n = rng.range(4, 10);
        // Plan against the raw graph's non-uniform M_v costs; execute the
        // same chains on the uniform lowering (same node ids/topology).
        let base = random_dag(&mut rng, n);
        let g = recost(&base, BATCH, WIDTH);
        let (x, targets) = batch_xy(&g, &mut rng);

        let vanilla = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let base_report = run_one(&g, &vanilla, &x, &targets);
        let base_grads = base_report.grads.as_ref().unwrap();

        let mut plans: Vec<(&str, LowerSetChain)> = Vec::new();
        let exact = plan_at_min_budget(&base, Family::Exact, Objective::MinOverhead).unwrap();
        let exact_budget = exact.budget;
        plans.push(("exact-dp", exact.chain));
        plans.push((
            "approx-dp",
            plan_at_min_budget(&base, Family::Approx, Objective::MinOverhead).unwrap().chain,
        ));
        plans.push((
            "exact-dp-mc",
            plan_at_min_budget(&base, Family::Exact, Objective::MaxOverhead).unwrap().chain,
        ));
        plans.push(("chen", chen_plan(&base, |c| c.peak_mem(&base)).unwrap().chain));
        if n <= 8 {
            plans.push((
                "dfs-oracle",
                exhaustive_search(&base, exact_budget, Objective::MinOverhead)
                    .expect("oracle feasible at the exact min budget"),
            ));
        }

        for (label, chain) in plans {
            let prog = OpProgram::from_chain(&g, &chain, SimMode::Strict)
                .unwrap_or_else(|e| panic!("[{label} case {case}] compile: {e}"));
            let r = run_one(&g, &prog, &x, &targets);
            assert_eq!(
                base_report.loss.to_bits(),
                r.loss.to_bits(),
                "[{label} case {case}] loss diverged: vanilla {} vs {}",
                base_report.loss,
                r.loss
            );
            assert_grads_bitwise(label, case, base_grads, r.grads.as_ref().unwrap());
        }
    }
}

/// On failure, name the first step whose observed live bytes differ from
/// the model prediction — the debuggability contract of the harness.
fn assert_trajectory_matches(label: &str, g: &Graph, prog: &OpProgram, r: &StepReport) {
    assert_eq!(r.live_trajectory.len(), prog.predicted_live.len(), "[{label}] step counts");
    if let Some(i) =
        (0..prog.steps.len()).find(|&i| r.live_trajectory[i] != prog.predicted_live[i])
    {
        panic!(
            "[{label}] live-byte divergence at step {i} ({}): observed {} vs predicted {}",
            prog.steps[i].describe(g),
            r.live_trajectory[i],
            prog.predicted_live[i]
        );
    }
}

#[test]
fn observed_peak_equals_simulator_prediction_on_chains_and_dags() {
    let mut rng = Pcg32::seeded(0x9ea);
    // Chains of several lengths plus random DAG topologies, all lowered
    // to the executable cost model (M_v = real tensor bytes).
    let mut graphs: Vec<Graph> = vec![
        recost(&chain_graph(&[1; 6]), BATCH, WIDTH),
        recost(&chain_graph(&[1; 13]), BATCH, WIDTH),
        recost(&diamond(), BATCH, WIDTH),
    ];
    for _ in 0..8 {
        let n = rng.range(4, 12);
        graphs.push(recost(&random_dag(&mut rng, n), BATCH, WIDTH));
    }
    for (gi, g) in graphs.iter().enumerate() {
        let (x, targets) = batch_xy(g, &mut rng);
        for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
            let plan = plan_at_min_budget(g, Family::Exact, obj).unwrap();
            let tr = canonical_trace(g, &plan.chain);
            let prog = OpProgram::compile(g, &tr).unwrap();
            let sim = measure(g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let label = format!("graph {gi} {:?}", obj);
            let r = run_one(g, &prog, &x, &targets);
            assert_trajectory_matches(&label, g, &prog, &r);
            assert_eq!(
                r.observed_peak,
                sim.peak_bytes,
                "[{label}] observed peak (at step {}: {}) vs SimReport::peak_bytes \
                 (predicted peak at step {}: {})",
                r.peak_step,
                prog.steps[r.peak_step].describe(g),
                prog.predicted_peak_step(),
                prog.steps[prog.predicted_peak_step()].describe(g),
            );
        }
        // Vanilla execution obeys the same equality.
        let prog = OpProgram::vanilla(g, SimMode::Strict).unwrap();
        let r = run_one(g, &prog, &x, &targets);
        assert_trajectory_matches(&format!("graph {gi} vanilla"), g, &prog, &r);
    }
}

#[test]
fn heterogeneous_lowerings_hold_invariants_across_planners() {
    // The tentpole claim: per-node widths from the graph's own M_v
    // profile — so nodes hold differently-sized tensors — and still:
    // bit-exact gradients vs vanilla under every planner family, and
    // observed peak == predicted peak ≤ vanilla peak.
    let mut rng = Pcg32::seeded(0x8e7e40);
    let mut hetero_cases = 0u32;
    for case in 0..8u32 {
        let n = rng.range(5, 11);
        let base = random_dag(&mut rng, n);
        let g = recost_profiled(&base, BATCH, 12);
        if distinct_act_sizes(&g).len() >= 2 {
            hetero_cases += 1;
        }
        let (x, targets) = batch_xy(&g, &mut rng);

        let vanilla_prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let rv = run_one(&g, &vanilla_prog, &x, &targets);
        assert_trajectory_matches(&format!("het vanilla case {case}"), &g, &vanilla_prog, &rv);
        let base_grads = rv.grads.as_ref().unwrap();

        for (name, family, obj) in [
            ("exact-tc", Family::Exact, Objective::MinOverhead),
            ("exact-mc", Family::Exact, Objective::MaxOverhead),
            ("approx-tc", Family::Approx, Objective::MinOverhead),
        ] {
            let label = format!("het {name} case {case}");
            let plan = plan_at_min_budget(&g, family, obj).unwrap();
            let tr = canonical_trace(&g, &plan.chain);
            let prog = OpProgram::compile(&g, &tr).unwrap();
            let sim = measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let r = run_one(&g, &prog, &x, &targets);
            assert_trajectory_matches(&label, &g, &prog, &r);
            assert_eq!(r.observed_peak, sim.peak_bytes, "[{label}] observed == predicted");
            assert!(
                r.observed_peak <= rv.observed_peak,
                "[{label}] planned peak {} must not exceed vanilla {}",
                r.observed_peak,
                rv.observed_peak
            );
            assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[{label}] loss diverged");
            assert_grads_bitwise(&label, case, base_grads, r.grads.as_ref().unwrap());
        }

        // Chen's baseline executes heterogeneous shapes bit-exactly too.
        let chen = chen_plan(&g, |c| c.peak_mem(&g)).unwrap();
        let prog = OpProgram::from_chain(&g, &chen.chain, SimMode::Strict).unwrap();
        let r = run_one(&g, &prog, &x, &targets);
        assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[het chen case {case}] loss");
        assert_grads_bitwise("het chen", case, base_grads, r.grads.as_ref().unwrap());
    }
    assert!(
        hetero_cases > 0,
        "profiled lowering never produced heterogeneous widths across the suite"
    );
}

#[test]
fn diamond_fixture_runs_under_every_schedule() {
    // The shared fan-in/fan-out fixture (also used by the graph and exec
    // unit suites) through the integration path: vanilla, the exact plan,
    // and the maximally-coarse whole-graph strategy all agree bitwise.
    let g = recost(&diamond(), BATCH, WIDTH);
    let mut rng = Pcg32::seeded(0xd1a);
    let (x, targets) = batch_xy(&g, &mut rng);
    let vanilla = run_one(&g, &OpProgram::vanilla(&g, SimMode::Strict).unwrap(), &x, &targets);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    for chain in [plan.chain, recompute::planner::whole_graph_chain(&g)] {
        let prog = OpProgram::from_chain(&g, &chain, SimMode::Strict).unwrap();
        let r = run_one(&g, &prog, &x, &targets);
        assert_eq!(vanilla.loss.to_bits(), r.loss.to_bits());
        let (gv, gr) = (vanilla.grads.as_ref().unwrap(), r.grads.as_ref().unwrap());
        assert_grads_bitwise("diamond", 0, gv, gr);
    }
}

#[test]
fn zoo_resnet_and_unet_train_end_to_end_with_invariants() {
    let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
    for model in ["resnet", "unet"] {
        let cmp = train_zoo_model(
            model,
            2,
            8,
            &cfg,
            BudgetSpec::MinFeasible,
            &[Objective::MinOverhead],
            SimMode::Liveness,
            true,
        )
        .unwrap_or_else(|e| panic!("{model}: {e}"));
        assert_eq!(cmp.runs.len(), 1);
        let run = &cmp.runs[0];
        assert!(run.grads_match, "{model}: planned gradients must match vanilla bit-exactly");
        assert!(run.peak_matches_sim, "{model}: observed peak must equal sim prediction");
        assert!(
            run.sim_peak <= run.sim_peak_strict,
            "{model}: liveness peak must not exceed the no-liveness peak"
        );
        assert!(run.losses_identical, "{model}: loss trajectories must be bit-identical");
        assert!(
            run.report.observed_peak < cmp.vanilla.observed_peak,
            "{model}: recomputation must reduce the measured peak"
        );
        assert!(run.report.losses.iter().all(|l| l.is_finite()), "{model}: finite losses");
        assert!(run.report.recomputes_per_step > 0, "{model}: plan actually recomputes");
        assert!(
            cmp.distinct_act_bytes >= 2,
            "{model}: heterogeneous lowering must yield ≥ 2 distinct node byte-sizes"
        );
        // The session amortized: one family built, and the training run's
        // repeated request was a cache hit.
        assert_eq!(cmp.stats.families_built, 1, "{model}");
        assert!(run.cache_hit, "{model}: repeated PlanRequest must be cached");
    }
}

#[test]
fn liveness_invariant_chain_holds_across_planners_on_random_dags() {
    // The tentpole claim, end to end: executing the liveness-compiled
    // program of every planner family observes *exactly* the
    // liveness-predicted peak, which is ≤ the no-liveness peak of the
    // same plan, which is ≤ the vanilla peak — and none of it perturbs
    // the numerics (gradients bit-identical to vanilla execution).
    let mut rng = Pcg32::seeded(0x11fe);
    for case in 0..6u32 {
        let n = rng.range(5, 11);
        let base = random_dag(&mut rng, n);
        // Heterogeneous lowering: the liveness schedule must hold on
        // non-uniform per-node byte sizes, not just uniform shapes.
        let g = recost_profiled(&base, BATCH, 12);
        let (x, targets) = batch_xy(&g, &mut rng);

        // Vanilla baseline, strict mode: keeps every buffer until the
        // step ends — the ceiling of the whole chain.
        let vanilla_prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let rv = run_one(&g, &vanilla_prog, &x, &targets);
        let vanilla_peak = rv.observed_peak;
        let base_grads = rv.grads.as_ref().unwrap();

        let mut plans: Vec<(&str, LowerSetChain)> = vec![
            (
                "exact-tc",
                plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap().chain,
            ),
            (
                "exact-mc",
                plan_at_min_budget(&g, Family::Exact, Objective::MaxOverhead).unwrap().chain,
            ),
            (
                "approx-tc",
                plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap().chain,
            ),
            ("chen", chen_plan(&g, |c| c.peak_mem(&g)).unwrap().chain),
        ];
        for (label, chain) in plans.drain(..) {
            let label = format!("liveness {label} case {case}");
            let tr = canonical_trace(&g, &chain);
            let prog = OpProgram::from_trace(&g, &tr, SimMode::Liveness)
                .unwrap_or_else(|e| panic!("[{label}] compile: {e}"));
            let live_sim =
                measure(&g, &tr, SimOptions { mode: SimMode::Liveness, include_params: false });
            let strict_sim =
                measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            let r = run_one(&g, &prog, &x, &targets);
            assert_trajectory_matches(&label, &g, &prog, &r);
            assert_eq!(
                r.observed_peak, live_sim.peak_bytes,
                "[{label}] observed == liveness-predicted must be an equality"
            );
            assert!(
                live_sim.peak_bytes <= strict_sim.peak_bytes,
                "[{label}] liveness {} must not exceed no-liveness {}",
                live_sim.peak_bytes,
                strict_sim.peak_bytes
            );
            assert!(
                strict_sim.peak_bytes <= vanilla_peak,
                "[{label}] no-liveness {} must not exceed vanilla {}",
                strict_sim.peak_bytes,
                vanilla_peak
            );
            assert_eq!(rv.loss.to_bits(), r.loss.to_bits(), "[{label}] loss diverged");
            assert_grads_bitwise(&label, case, base_grads, r.grads.as_ref().unwrap());
        }
    }
}

#[test]
fn chain_schedule_error_is_actionable_for_zoo_graphs() {
    // Regression (integration-level): planning a branching zoo model and
    // feeding it to the chain fast path must produce an error naming the
    // offending node, not a generic rejection.
    use recompute::exec::ChainSchedule;
    let g = recost(&recompute::models::zoo::find("unet").unwrap().build_batch(1), 2, 4);
    let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
    let msg = ChainSchedule::from_chain(&g, &plan.chain).unwrap_err().to_string();
    assert!(msg.contains("fan-in"), "degree in message: {msg}");
    assert!(msg.contains("DAG executor"), "remediation in message: {msg}");
}
