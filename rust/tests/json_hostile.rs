//! Fuzz-style hostile-input corpus against the two byte-facing surfaces:
//! the hardened JSON parser (`util::json`) and the serve request router.
//!
//! A seeded generator mutates valid seed documents — truncation, byte
//! flips (mangled UTF-8 included, fed through lossy replacement since
//! both surfaces take `&str`), noise insertion, slice duplication — plus
//! hand-picked pathologies (deep nesting, over-long inputs, NUL bytes,
//! lone surrogates). The invariants under test:
//!
//! - `Json::parse` never panics: every input returns `Ok` or a
//!   positioned `JsonError`;
//! - `Router::route_line` is total: every input produces exactly one
//!   reply object with an `"ok"` bool, and error replies carry a
//!   structured `{"code", "msg"}`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use recompute::serve::{Router, RouterConfig, ServeMetrics};
use recompute::session::{PlanCache, SessionRegistry};
use recompute::testutil::diamond;
use recompute::util::json::Json;
use recompute::util::rng::Pcg32;

fn router() -> Router {
    Router::new(
        SessionRegistry::new(4, PlanCache::shared(32)),
        Arc::new(ServeMetrics::new()),
        RouterConfig::default(),
    )
}

/// Valid seed documents the mutator starts from — a graph export, real
/// serve commands, and a value exercising every JSON type.
fn seeds() -> Vec<String> {
    vec![
        diamond().to_json(),
        r#"{"cmd":"ping"}"#.to_string(),
        format!(r#"{{"cmd":"graph_upload","graph":{}}}"#, diamond().to_json()),
        r#"{"cmd":"plan","network":"unet","budget":"512KiB","objective":"tc"}"#.to_string(),
        r#"{"cmd":"stats"}"#.to_string(),
        r#"[1,2.5,-3e7,true,false,null,"café \"quoted\"",{"k":[{}]}]"#.to_string(),
    ]
}

/// One seeded mutation: truncate, flip bytes, insert noise, or duplicate
/// a slice. Byte flips routinely produce invalid UTF-8; the lossy
/// conversion models what the connection layer admits to `&str` surfaces.
fn mutate(rng: &mut Pcg32, s: &str) -> String {
    let mut b = s.as_bytes().to_vec();
    match rng.below(4) {
        0 => {
            if !b.is_empty() {
                let cut = rng.below(b.len() as u32) as usize;
                b.truncate(cut);
            }
        }
        1 => {
            for _ in 0..=rng.below(8) {
                if b.is_empty() {
                    break;
                }
                let i = rng.below(b.len() as u32) as usize;
                b[i] = (rng.next_u32() & 0xff) as u8;
            }
        }
        2 => {
            let i = rng.below(b.len() as u32 + 1) as usize;
            let n = rng.below(16) + 1;
            let noise: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xff) as u8).collect();
            b.splice(i..i, noise);
        }
        _ => {
            if b.len() >= 2 {
                let i = rng.below(b.len() as u32 - 1) as usize;
                let j = i + 1 + rng.below((b.len() - i - 1) as u32) as usize;
                let chunk: Vec<u8> = b[i..j].to_vec();
                b.extend_from_slice(&chunk);
            }
        }
    }
    String::from_utf8_lossy(&b).into_owned()
}

#[test]
fn corpus_generator_is_deterministic() {
    let (mut a, mut b) = (Pcg32::seeded(99), Pcg32::seeded(99));
    let seed = &seeds()[0];
    for _ in 0..50 {
        assert_eq!(mutate(&mut a, seed), mutate(&mut b, seed));
    }
}

#[test]
fn mutated_corpus_never_panics_the_json_parser() {
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x4a50);
    for round in 0..600 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        let input = mutate(&mut rng, seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| Json::parse(&input).map(drop)));
        let result = outcome.unwrap_or_else(|_| panic!("round {round} panicked on {input:?}"));
        // Whatever parses must re-serialize and re-parse cleanly.
        if result.is_ok() {
            let v = Json::parse(&input).unwrap();
            assert!(Json::parse(&v.to_string()).is_ok(), "round {round}: unstable roundtrip");
        }
    }
}

#[test]
fn mutated_corpus_gets_structured_replies_from_the_router() {
    let rt = router();
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x5e17);
    for round in 0..300 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        let line = mutate(&mut rng, seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| rt.route_line(&line)));
        let routed = outcome.unwrap_or_else(|_| panic!("round {round} panicked on {line:?}"));
        let ok = routed.reply.get("ok").as_bool();
        assert!(ok.is_some(), "round {round}: reply without 'ok': {}", routed.reply.to_string());
        assert_eq!(ok == Some(false), routed.is_error);
        if routed.is_error {
            let code = routed.reply.get("error").get("code").as_str().unwrap_or("");
            assert!(!code.is_empty(), "round {round}: error reply without a code");
        }
        assert!(!routed.shutdown, "mutations never form a shutdown command");
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    let rt = router();
    let arrays = "[".repeat(100_000);
    let objects = format!("{}1", r#"{"a":"#.repeat(50_000));
    let mixed = format!("{}0", r#"[{"x":"#.repeat(40_000));
    let closed = format!("{}{}", "[".repeat(10_000), "]".repeat(10_000));
    for hostile in [&arrays, &objects, &mixed, &closed] {
        assert!(Json::parse(hostile).is_err(), "depth limit must reject {} bytes", hostile.len());
        let routed = rt.route_line(hostile);
        assert!(routed.is_error);
        assert_eq!(routed.reply.get("error").get("code").as_str(), Some("bad-json"));
    }
}

#[test]
fn overlong_and_malformed_inputs_never_panic() {
    let rt = router();
    let cases = [
        "a".repeat(2 << 20),
        format!(r#"{{"cmd":"{}"}}"#, "x".repeat(1 << 20)),
        format!("[{}1]", "1,".repeat(200_000)),
        "\u{0}\u{0}\u{0}".to_string(),
        "{\"k\":\u{fffd}\u{fffd}}".to_string(),
        r#""\ud800""#.to_string(),
        r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#.to_string(),
        r#"{"cmd":"plan","network":"unet","batch":1e999}"#.to_string(),
        r#"{"cmd":123}"#.to_string(),
        r#"{"cmd":"graph_upload","graph":{"nodes":"nope","edges":[]}}"#.to_string(),
        r#"{"cmd":"graph_upload","graph":{"nodes":[],"edges":[]}}"#.to_string(),
        r#"{"cmd":"train","network":"unet","steps":100000}"#.to_string(),
    ];
    for input in &cases {
        let parse = catch_unwind(AssertUnwindSafe(|| Json::parse(input).map(drop)));
        assert!(parse.is_ok(), "parser panicked on {} bytes", input.len());
        let routed = catch_unwind(AssertUnwindSafe(|| rt.route_line(input)))
            .unwrap_or_else(|_| panic!("router panicked on {} bytes", input.len()));
        assert!(routed.reply.get("ok").as_bool().is_some());
    }
}
