//! Fuzz-style hostile-input corpus against the three byte-facing
//! surfaces: the hardened JSON parser (`util::json`), the lazy field
//! scanner (`util::json_lazy`) that fronts it in the serve daemon, and
//! the serve request router.
//!
//! A seeded generator mutates valid seed documents — truncation, byte
//! flips (mangled UTF-8 included, fed through lossy replacement since
//! all three surfaces take `&str`), noise insertion, slice duplication —
//! plus hand-picked pathologies (deep nesting, over-long inputs, NUL
//! bytes, lone surrogates). The invariants under test:
//!
//! - `Json::parse` never panics: every input returns `Ok` or a
//!   positioned `JsonError`;
//! - `scan_fields` agrees with `Json::parse` on **every** input —
//!   same accept/reject decision, same extracted field values, no
//!   panics, and error positions that never point past the input;
//! - `Router::route_line` is total: every input produces exactly one
//!   reply object with an `"ok"` bool, and error replies carry a
//!   structured `{"code", "msg"}` — and the lazy dispatch agrees with
//!   the eager pipeline reply-for-reply.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use recompute::serve::{Router, RouterConfig, ServeMetrics};
use recompute::session::{PlanCache, SessionRegistry};
use recompute::testutil::diamond;
use recompute::util::json::Json;
use recompute::util::json_lazy::scan_fields;
use recompute::util::rng::Pcg32;

fn router() -> Router {
    Router::new(
        SessionRegistry::new(4, PlanCache::shared(32)),
        Arc::new(ServeMetrics::new()),
        RouterConfig::default(),
    )
}

/// Valid seed documents the mutator starts from — a graph export, real
/// serve commands, and a value exercising every JSON type.
fn seeds() -> Vec<String> {
    vec![
        diamond().to_json(),
        r#"{"cmd":"ping"}"#.to_string(),
        format!(r#"{{"cmd":"graph_upload","graph":{}}}"#, diamond().to_json()),
        r#"{"cmd":"plan","network":"unet","budget":"512KiB","objective":"tc"}"#.to_string(),
        r#"{"cmd":"stats"}"#.to_string(),
        r#"[1,2.5,-3e7,true,false,null,"café \"quoted\"",{"k":[{}]}]"#.to_string(),
    ]
}

/// Hand-picked pathologies: deep nesting, NUL bytes, lone surrogates,
/// escaped keys, duplicate keys, huge strings.
fn pathologies() -> Vec<String> {
    vec![
        "[".repeat(100_000),
        format!("{}1", r#"{"a":"#.repeat(50_000)),
        format!("{}{}", "[".repeat(10_000), "]".repeat(10_000)),
        "\u{0}\u{0}\u{0}".to_string(),
        "{\"cmd\":\"\u{0}embedded nul\u{0}\"}".to_string(),
        r#"{"cmd":"\ud800"}"#.to_string(),
        r#"{"cmd":"𐀀","id":"\udfff"}"#.to_string(),
        r#"{"cmd":"ping","id":1e308,"x":[{"cmd":"nested"}]}"#.to_string(),
        r#"{"cmd":"plan","cmd":null,"cmd":"ping"}"#.to_string(),
        format!(r#"{{"cmd":"{}"}}"#, "x".repeat(1 << 20)),
        r#""trunc \u00"#.to_string(),
        r#"{"cmd" :  "ping" , "id":"A\t"}  "#.to_string(),
    ]
}

/// One seeded mutation: truncate, flip bytes, insert noise, or duplicate
/// a slice. Byte flips routinely produce invalid UTF-8; the lossy
/// conversion models what the connection layer admits to `&str` surfaces.
fn mutate(rng: &mut Pcg32, s: &str) -> String {
    let mut b = s.as_bytes().to_vec();
    match rng.below(4) {
        0 => {
            if !b.is_empty() {
                let cut = rng.below(b.len() as u32) as usize;
                b.truncate(cut);
            }
        }
        1 => {
            for _ in 0..=rng.below(8) {
                if b.is_empty() {
                    break;
                }
                let i = rng.below(b.len() as u32) as usize;
                b[i] = (rng.next_u32() & 0xff) as u8;
            }
        }
        2 => {
            let i = rng.below(b.len() as u32 + 1) as usize;
            let n = rng.below(16) + 1;
            let noise: Vec<u8> = (0..n).map(|_| (rng.next_u32() & 0xff) as u8).collect();
            b.splice(i..i, noise);
        }
        _ => {
            if b.len() >= 2 {
                let i = rng.below(b.len() as u32 - 1) as usize;
                let j = i + 1 + rng.below((b.len() - i - 1) as u32) as usize;
                let chunk: Vec<u8> = b[i..j].to_vec();
                b.extend_from_slice(&chunk);
            }
        }
    }
    String::from_utf8_lossy(&b).into_owned()
}

/// The serve router's scan surface, as seen by the differential check.
const PROTO_KEYS: [&str; 6] = ["cmd", "id", "fingerprint", "network", "budget", "graph"];

/// Feed one input to both the eager parser and the lazy scanner and
/// hold them to full agreement: same accept/reject, same extracted
/// field values, in-bounds error positions, no panics.
fn assert_parsers_agree(input: &str) {
    let eager = catch_unwind(AssertUnwindSafe(|| Json::parse(input)))
        .unwrap_or_else(|_| panic!("eager parser panicked on {} bytes", input.len()));
    let lazy = catch_unwind(AssertUnwindSafe(|| scan_fields(input, &PROTO_KEYS)))
        .unwrap_or_else(|_| panic!("lazy scanner panicked on {} bytes", input.len()));
    match (eager, lazy) {
        (Ok(tree), Ok(fields)) => {
            for (key, lv) in PROTO_KEYS.iter().zip(fields.iter()) {
                let want = tree.get(key);
                match lv {
                    // Scanner slot empty: absent key or non-object top
                    // level — both read as Null through `Json::get`.
                    None => assert_eq!(want, &Json::Null, "key {key} on {input:?}"),
                    Some(v) => assert_eq!(&v.to_json(), want, "key {key} on {input:?}"),
                }
            }
        }
        (Err(e), Err(l)) => {
            // Positioned errors must stay inside the input — neither
            // parser ever claims to have read past what it was given.
            assert!(e.pos <= input.len(), "eager pos {} past {} bytes", e.pos, input.len());
            assert!(l.pos <= input.len(), "lazy pos {} past {} bytes", l.pos, input.len());
        }
        (eager, lazy) => panic!(
            "accept/reject disagreement on {:?}…: eager_ok={} lazy_ok={}",
            input.chars().take(120).collect::<String>(),
            eager.is_ok(),
            lazy.is_ok()
        ),
    }
}

#[test]
fn corpus_generator_is_deterministic() {
    let (mut a, mut b) = (Pcg32::seeded(99), Pcg32::seeded(99));
    let seed = &seeds()[0];
    for _ in 0..50 {
        assert_eq!(mutate(&mut a, seed), mutate(&mut b, seed));
    }
}

#[test]
fn mutated_corpus_never_panics_the_json_parser() {
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x4a50);
    for round in 0..600 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        let input = mutate(&mut rng, seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| Json::parse(&input).map(drop)));
        let result = outcome.unwrap_or_else(|_| panic!("round {round} panicked on {input:?}"));
        // Whatever parses must re-serialize and re-parse cleanly.
        if result.is_ok() {
            let v = Json::parse(&input).unwrap();
            assert!(Json::parse(&v.to_string()).is_ok(), "round {round}: unstable roundtrip");
        }
    }
}

#[test]
fn lazy_scanner_agrees_with_the_eager_parser_on_the_whole_corpus() {
    // Every seed line verbatim…
    for s in seeds() {
        assert_parsers_agree(&s);
    }
    // …every hand-picked pathology…
    for p in pathologies() {
        assert_parsers_agree(&p);
    }
    // …and a fresh seeded mutation stream.
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x1a27);
    for _ in 0..600 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        assert_parsers_agree(&mutate(&mut rng, seed));
    }
}

#[test]
fn mutated_corpus_gets_structured_replies_from_the_router() {
    let rt = router();
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x5e17);
    for round in 0..300 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        let line = mutate(&mut rng, seed);
        let outcome = catch_unwind(AssertUnwindSafe(|| rt.route_line(&line)));
        let routed = outcome.unwrap_or_else(|_| panic!("round {round} panicked on {line:?}"));
        let reply = routed.reply_json();
        let ok = reply.get("ok").as_bool();
        assert!(ok.is_some(), "round {round}: reply without 'ok': {}", reply.to_string());
        assert_eq!(ok == Some(false), routed.is_error);
        if routed.is_error {
            let code = reply.get("error").get("code").as_str().unwrap_or("");
            assert!(!code.is_empty(), "round {round}: error reply without a code");
        }
        assert!(!routed.shutdown, "mutations never form a shutdown command");
    }
}

/// Strip the fields that legitimately differ between two router
/// instances answering the same request stream (wall-clock uptime; the
/// fast-path counter only the lazy pipeline increments).
fn scrub(mut j: Json) -> Json {
    if let Json::Obj(ref mut o) = j {
        o.remove("uptime_ms");
        o.remove("fast_path_hits");
    }
    j
}

#[test]
fn lazy_and_eager_router_pipelines_agree_on_the_mutated_corpus() {
    // Two routers fed the identical line sequence — one through the
    // lazy dispatch, one through the eager tree pipeline — must produce
    // the same replies, including on hostile input.
    let (lazy_rt, eager_rt) = (router(), router());
    let seeds = seeds();
    let mut rng = Pcg32::seeded(0x0dd5);
    for round in 0..300 {
        let seed = &seeds[rng.below(seeds.len() as u32) as usize];
        let line = mutate(&mut rng, seed);
        let a = lazy_rt.route_line(&line);
        let b = eager_rt.route_line_eager(&line);
        assert_eq!(
            scrub(a.reply_json()),
            scrub(b.reply_json()),
            "round {round} disagrees on {line:?}"
        );
        assert_eq!(a.is_error, b.is_error, "round {round}");
    }
    // The pathologies too (all rejected or answered identically).
    for line in pathologies() {
        let a = lazy_rt.route_line(&line);
        let b = eager_rt.route_line_eager(&line);
        assert_eq!(scrub(a.reply_json()), scrub(b.reply_json()), "{} bytes", line.len());
    }
}

#[test]
fn deep_nesting_is_rejected_not_overflowed() {
    let rt = router();
    let arrays = "[".repeat(100_000);
    let objects = format!("{}1", r#"{"a":"#.repeat(50_000));
    let mixed = format!("{}0", r#"[{"x":"#.repeat(40_000));
    let closed = format!("{}{}", "[".repeat(10_000), "]".repeat(10_000));
    for hostile in [&arrays, &objects, &mixed, &closed] {
        assert!(Json::parse(hostile).is_err(), "depth limit must reject {} bytes", hostile.len());
        assert!(scan_fields(hostile, &["cmd"]).is_err(), "scanner must also reject");
        let routed = rt.route_line(hostile);
        assert!(routed.is_error);
        assert_eq!(routed.reply_json().get("error").get("code").as_str(), Some("bad-json"));
    }
}

#[test]
fn overlong_and_malformed_inputs_never_panic() {
    let rt = router();
    let cases = [
        "a".repeat(2 << 20),
        format!(r#"{{"cmd":"{}"}}"#, "x".repeat(1 << 20)),
        format!("[{}1]", "1,".repeat(200_000)),
        "\u{0}\u{0}\u{0}".to_string(),
        "{\"k\":\u{fffd}\u{fffd}}".to_string(),
        r#""\ud800""#.to_string(),
        r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#.to_string(),
        r#"{"cmd":"plan","network":"unet","batch":1e999}"#.to_string(),
        r#"{"cmd":123}"#.to_string(),
        r#"{"cmd":"graph_upload","graph":{"nodes":"nope","edges":[]}}"#.to_string(),
        r#"{"cmd":"graph_upload","graph":{"nodes":[],"edges":[]}}"#.to_string(),
        r#"{"cmd":"train","network":"unet","steps":100000}"#.to_string(),
    ];
    for input in &cases {
        let parse = catch_unwind(AssertUnwindSafe(|| Json::parse(input).map(drop)));
        assert!(parse.is_ok(), "parser panicked on {} bytes", input.len());
        assert_parsers_agree(input);
        let routed = catch_unwind(AssertUnwindSafe(|| rt.route_line(input)))
            .unwrap_or_else(|_| panic!("router panicked on {} bytes", input.len()));
        assert!(routed.reply_json().get("ok").as_bool().is_some());
    }
}
