//! Static schedule auditor integration suite (ISSUE 10 acceptance).
//!
//! Two halves:
//!
//! - **Silence on health**: every zoo network × planner × sim mode
//!   compiles to a plan whose audit report is completely clean — the
//!   auditor never cries wolf on schedules the planners actually emit.
//! - **Mutation kill-list**: seeded corruptions of a known-good trace or
//!   chain (dropped free, duplicated free, use hoisted above its alloc,
//!   shrunken checkpoint set, inflated peak prediction, impossible
//!   budget) are each caught with their exact stable rule code, and a
//!   corrupted decomposed stitch is rejected end to end — session error,
//!   serve `audit-failed` reply, CLI exit — never a panic or a silent
//!   success.

use std::sync::Arc;

use recompute::analysis::{
    audit_chain, audit_plan, audit_trace, AuditReport, PlanAudit, Rule, AUDIT_FAILED_PREFIX,
    FAULT_INJECT_GRAPH,
};
use recompute::models::zoo;
use recompute::planner::{
    plan_at_min_budget, Family, Objective, PlanRequest, PlannerId,
};
use recompute::serve::{Router, RouterConfig, ServeMetrics};
use recompute::session::{PlanCache, PlanSession, SessionRegistry};
use recompute::sim::{apply_liveness, canonical_trace, Event, SimMode, Trace};
use recompute::testutil::chain_graph;
use recompute::util::json::Json;
use recompute::util::rng::Pcg32;
use recompute::Graph;

/// Codes of every diagnostic in a report.
fn codes(rep: &AuditReport) -> Vec<&'static str> {
    rep.diagnostics.iter().map(|d| d.rule.code()).collect()
}

/// A known-good plan + liveness trace over a seeded DAG, the substrate
/// every mutation below corrupts.
fn healthy_fixture() -> (Graph, Trace) {
    let mut rng = Pcg32::seeded(0x5eed_a0d1);
    let g = recompute::testutil::random_dag(&mut rng, 24);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    let tr = apply_liveness(&canonical_trace(&g, &plan.chain));
    let rep = audit_trace(&g, &tr, SimMode::Liveness);
    assert!(rep.is_clean(), "fixture must start healthy: {:?}", codes(&rep));
    (g, tr)
}

// ---------------------------------------------------------------------
// Silence on health: full zoo × planner × mode.
// ---------------------------------------------------------------------

#[test]
fn every_zoo_planner_mode_combination_audits_clean() {
    for e in zoo::TABLE1 {
        // Batch 1 keeps byte values small; planning difficulty (and the
        // audited event stream's shape) depends only on the structure.
        let session = PlanSession::new(e.build_batch(1));
        for planner in
            [PlannerId::ExactDp, PlannerId::ApproxDp, PlannerId::Chen, PlannerId::Decomposed]
        {
            for mode in [SimMode::Liveness, SimMode::Strict] {
                let req = PlanRequest {
                    sim_mode: mode,
                    ..PlanRequest::new(planner, Objective::MinOverhead)
                };
                let cp = session
                    .plan(&req)
                    .unwrap_or_else(|err| panic!("{} {planner:?} {mode:?}: {err}", e.name));
                assert!(
                    cp.audit.is_clean(),
                    "{} {planner:?} {mode:?}: {:?}",
                    e.name,
                    codes(&cp.audit)
                );
                assert!(cp.audit.events > 0, "audit must have swept the trace");
            }
        }
    }
}

#[test]
fn deny_audit_mode_still_admits_clean_plans() {
    let session = PlanSession::new(zoo::find("U-Net").unwrap().build_batch(1));
    session.set_deny_audit(true);
    assert!(session.deny_audit());
    let cp = session
        .plan(&PlanRequest::new(PlannerId::ApproxDp, Objective::MaxOverhead))
        .expect("a clean plan passes even with warnings escalated");
    assert_eq!(cp.audit.verdict(), "clean");
}

// ---------------------------------------------------------------------
// Mutation kill-list: every seeded corruption caught, exact rule codes.
// ---------------------------------------------------------------------

#[test]
fn dropping_a_free_is_reported_as_a_leak() {
    let (g, mut tr) = healthy_fixture();
    let i = tr.events.iter().position(|e| matches!(e, Event::Free { .. })).unwrap();
    tr.events.remove(i);
    tr.op_of.remove(i);
    let rep = audit_trace(&g, &tr, SimMode::Liveness);
    assert!(codes(&rep).contains(&"A004"), "dropped free must leak: {:?}", codes(&rep));
}

#[test]
fn duplicating_a_free_is_reported_as_a_double_free() {
    let (g, mut tr) = healthy_fixture();
    let i = tr.events.iter().position(|e| matches!(e, Event::Free { .. })).unwrap();
    let (ev, op) = (tr.events[i], tr.op_of[i]);
    tr.events.insert(i + 1, ev);
    tr.op_of.insert(i + 1, op);
    let rep = audit_trace(&g, &tr, SimMode::Liveness);
    assert!(codes(&rep).contains(&"A002"), "{:?}", codes(&rep));
}

#[test]
fn hoisting_a_use_above_its_alloc_is_reported() {
    let (g, mut tr) = healthy_fixture();
    // Swap the first Alloc with the first Use of the same buffer (the
    // op_of entries travel with their events): the read now precedes
    // the materialization in program order.
    let ia = tr.events.iter().position(|e| matches!(e, Event::Alloc { .. })).unwrap();
    let Event::Alloc { buffer, .. } = tr.events[ia] else { unreachable!() };
    let iu = tr
        .events
        .iter()
        .position(|e| matches!(e, Event::Use { buffer: b } if *b == buffer))
        .expect("the allocated buffer is read somewhere");
    assert!(iu > ia);
    tr.events.swap(ia, iu);
    tr.op_of.swap(ia, iu);
    let rep = audit_trace(&g, &tr, SimMode::Liveness);
    assert!(codes(&rep).contains(&"A006"), "{:?}", codes(&rep));
}

#[test]
fn shrinking_a_checkpoint_set_breaks_the_chain_rules() {
    let mut rng = Pcg32::seeded(0xc0ffee);
    let g = recompute::testutil::random_dag(&mut rng, 24);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    let good = plan.chain.lower_sets();
    assert!(audit_chain(&g, good).is_empty(), "healthy chain must be silent");
    assert!(good.len() >= 2, "need an interior set to corrupt");

    let mut bad = good.to_vec();
    let victim = bad[0].iter().next().unwrap();
    for l in bad.iter_mut().take(good.len() - 1) {
        l.remove(victim);
    }
    let diags = audit_chain(&g, &bad);
    assert!(!diags.is_empty(), "shrunken checkpoint set must be flagged");
    assert!(
        diags.iter().all(|d| matches!(d.rule, Rule::ChainInvariant | Rule::CheckpointCoverage)),
        "only chain rules may fire: {:?}",
        diags.iter().map(|d| d.rule.code()).collect::<Vec<_>>()
    );
}

#[test]
fn inflated_peak_prediction_and_tight_budget_are_cross_checked() {
    let mut rng = Pcg32::seeded(0xfeed);
    let g = recompute::testutil::random_dag(&mut rng, 20);
    let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
    let tr = apply_liveness(&canonical_trace(&g, &plan.chain));
    let truth = audit_trace(&g, &tr, SimMode::Liveness).static_peak;

    // An inflated simulator prediction is a peak mismatch…
    let rep = audit_plan(&PlanAudit {
        graph: &g,
        chain: &plan.chain,
        trace: &tr,
        mode: SimMode::Liveness,
        budget: None,
        predicted_peak: Some(truth + 1),
        program_peak: Some(truth),
    });
    assert_eq!(codes(&rep), vec!["A011"]);

    // …and a budget below the analytic peak is a budget violation.
    let eq2 = plan.chain.peak_mem(&g);
    let rep = audit_plan(&PlanAudit {
        graph: &g,
        chain: &plan.chain,
        trace: &tr,
        mode: SimMode::Liveness,
        budget: Some(eq2 - 1),
        predicted_peak: Some(truth),
        program_peak: Some(truth),
    });
    assert_eq!(codes(&rep), vec!["A012"]);
}

// ---------------------------------------------------------------------
// End-to-end rejection of a corrupted stitched chain.
// ---------------------------------------------------------------------

/// A chain long enough that the decomposed planner stitches several
/// global sets — the fault hook needs at least two.
fn fault_graph() -> Graph {
    let mut g = chain_graph(&[64; 24]);
    g.name = FAULT_INJECT_GRAPH.to_string();
    g
}

#[test]
fn corrupted_stitch_is_rejected_by_the_session_with_a_rule_code() {
    let session = PlanSession::new(fault_graph());
    let err = session
        .plan(&PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead))
        .unwrap_err()
        .to_string();
    assert!(err.starts_with(AUDIT_FAILED_PREFIX), "{err}");
    assert!(err.contains("A0"), "must cite a stable rule code: {err}");

    // The same graph planned whole (no stitching) stays admissible:
    // the corruption hook lives in the decomposed stitcher only.
    let cp = session
        .plan(&PlanRequest::new(PlannerId::ExactDp, Objective::MinOverhead))
        .expect("whole-graph planning of the fault graph is clean");
    assert!(cp.audit.is_clean());
}

#[test]
fn serve_rejects_a_corrupted_stitch_with_audit_failed() {
    let rt = Router::new(
        SessionRegistry::new(4, PlanCache::shared(16)),
        Arc::new(ServeMetrics::new()),
        RouterConfig::default(),
    );
    let up = Json::obj()
        .set("cmd", "graph_upload".into())
        .set("graph", Json::parse(&fault_graph().to_json()).unwrap())
        .to_string();
    let r = rt.route_line(&up);
    let j = r.reply_json();
    assert_eq!(j.get("ok").as_bool(), Some(true), "{}", j.to_string());
    let fp = j.get("fingerprint").as_str().unwrap().to_string();

    for eager in [false, true] {
        let line = format!(r#"{{"cmd":"plan","fingerprint":"{fp}","planner":"decomposed"}}"#);
        let r = if eager { rt.route_line_eager(&line) } else { rt.route_line(&line) };
        let j = r.reply_json();
        assert!(r.is_error, "corrupted stitch must be refused: {}", j.to_string());
        assert_eq!(j.get("error").get("code").as_str(), Some("audit-failed"));
        let msg = j.get("error").get("msg").as_str().unwrap_or_default();
        assert!(msg.contains("A0"), "reply must carry the rule code: {msg}");
    }

    // The rejection is visible in `stats`.
    let s = rt.route_line(r#"{"cmd":"stats"}"#).reply_json();
    assert_eq!(s.get("audit_failed").as_u64(), Some(2));

    // A healthy plan on the same router still succeeds afterwards.
    let okp = rt.route_line(r#"{"cmd":"plan","network":"unet","planner":"decomposed"}"#);
    assert!(!okp.is_error, "{}", okp.reply_json().to_string());
}

// ---------------------------------------------------------------------
// CLI surface: `repro audit`.
// ---------------------------------------------------------------------

#[test]
fn cli_audit_reports_clean_and_supports_json() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["audit", "--network", "unet", "--planner", "decomposed"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("clean"), "{text}");
    assert!(text.contains("static peak"), "{text}");

    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["audit", "--network", "unet", "--json", "--deny-audit"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let j = Json::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(j.get("clean").as_bool(), Some(true));
    assert_eq!(j.get("errors").as_u64(), Some(0));
    assert!(j.get("static_peak").as_u64().unwrap() > 0);
    assert_eq!(j.get("network").as_str(), Some("unet"));
}
