//! Leak regression guard, promoted from `examples/runtime_leak_check.rs`.
//!
//! The native backend counts every byte of every tensor it produces
//! (uploads and kernel outputs) and decrements the counter when the
//! buffer drops, so `Backend::live_bytes` is an *exact* census — far
//! stronger than the RSS heuristic the old example used. Two guards:
//!
//! - hammering the hot SGD kernel keeps the census flat (the historical
//!   PJRT `execute` leak this harness was born to catch would show up
//!   here as monotone growth);
//! - after `DagTrainer::train` on a zoo model, live bytes return
//!   *exactly* to the post-init baseline (parameters + merge
//!   normalizers) — no activation, gradient or optimizer buffer
//!   survives the run;
//! - under a *liveness* schedule the same census guarantee holds while
//!   the buffer pool reports nonzero reuse — freed storage is recycled
//!   into later allocations, never counted as live, never leaked.

use recompute::exec::{DagTrainer, OpProgram, TrainConfig};
use recompute::models::executable::recost_profiled;
use recompute::models::zoo;
use recompute::planner::{plan_at_min_budget, Family, Objective};
use recompute::runtime::{Backend, NativeBackend};
use recompute::sim::SimMode;

#[test]
fn sgd_kernel_hammer_keeps_live_bytes_flat() {
    let w = 64usize;
    let be = NativeBackend::new();
    let wm = vec![1.0f32; w * w];
    let gm = vec![0.1f32; w * w];
    let mut cur = be.upload(&wm, &[w, w]).unwrap();
    let baseline = be.live_bytes().expect("native backend tracks allocations");
    assert_eq!(baseline, (w * w * 4) as u64, "only `cur` is live");
    for _ in 0..300 {
        let g = be.upload(&gm, &[w, w]).unwrap();
        let lr = be.upload(&[0.01], &[]).unwrap();
        cur = be.run("sgd_mat", &[cur, g, lr]).unwrap().pop().unwrap();
    }
    // Every iteration's gradient, lr scalar and replaced parameter died.
    assert_eq!(be.live_bytes(), Some(baseline), "kernel buffers are leaking");
    drop(cur);
    assert_eq!(be.live_bytes(), Some(0), "census returns to zero");
    let stats = be.stats();
    let sgd = stats.iter().find(|s| s.kernel == "sgd_mat").unwrap();
    assert_eq!(sgd.calls, 300, "stats must count every call");
}

#[test]
fn dag_training_returns_live_bytes_to_post_init_baseline() {
    let g = recost_profiled(&zoo::find("resnet").unwrap().build_batch(1), 2, 8);
    let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
    let prog = OpProgram::from_chain(&g, &plan.chain, SimMode::Strict).unwrap();

    let mut t = DagTrainer::new(NativeBackend::new(), &g, 2, 7).unwrap();
    let baseline = t.backend().live_bytes().expect("native backend tracks allocations");
    assert!(
        baseline >= t.param_bytes(),
        "baseline {} must cover the {} parameter bytes",
        baseline,
        t.param_bytes()
    );

    let cfg = TrainConfig { layers: 0, steps: 3, lr: 0.02, seed: 11, log_every: 0 };
    t.train(&prog, &cfg).unwrap();
    let after = t.backend().live_bytes().unwrap();
    assert_eq!(
        after, baseline,
        "live bytes must return exactly to the post-init baseline after training"
    );
    // Parameters were updated in place (old buffers replaced 1:1), so the
    // census still covers exactly the parameter set.
    assert!(after >= t.param_bytes());
}

#[test]
fn liveness_training_returns_census_to_baseline_and_recycles_buffers() {
    // The liveness schedule frees and recomputes far more often than the
    // strict one — the very churn the buffer pool exists for. Two
    // guarantees after a multi-step run: the exact live-byte census is
    // back at the post-init baseline (no activation, gradient or
    // optimizer buffer survives, pooled storage is *not* live), and the
    // pool actually recycled (reuse count > 0, so the churn cost no
    // allocator traffic).
    let g = recost_profiled(&zoo::find("unet").unwrap().build_batch(1), 2, 8);
    let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
    let prog = OpProgram::from_chain(&g, &plan.chain, SimMode::Liveness).unwrap();

    let mut t = DagTrainer::new(NativeBackend::new(), &g, 2, 7).unwrap();
    let baseline = t.backend().live_bytes().expect("native backend tracks allocations");

    let cfg = TrainConfig { layers: 0, steps: 3, lr: 0.02, seed: 11, log_every: 0 };
    t.train(&prog, &cfg).unwrap();
    assert_eq!(
        t.backend().live_bytes().unwrap(),
        baseline,
        "live bytes must return exactly to the post-init baseline after liveness training"
    );
    let pool = t.backend().pool_stats().expect("native backend pools");
    assert!(pool.reuses > 0, "the pool must have recycled buffers: {pool:?}");
    assert!(pool.allocs > 0, "warm-up allocations must be counted: {pool:?}");
    assert!(
        pool.high_water_bytes >= pool.parked_bytes,
        "high-water covers everything the pool ever administered: {pool:?}"
    );
}
