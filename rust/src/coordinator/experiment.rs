//! Declarative experiment runner: a JSON config describes a set of
//! planner comparisons, the runner executes them and emits both the
//! human-readable table and machine-readable CSV — the workflow a team
//! would use to evaluate recomputation before enabling it in production.
//!
//! Config format:
//! ```json
//! {
//!   "name": "ablation-chains",
//!   "device_gb": 11.4,
//!   "liveness": true,
//!   "runs": [
//!     {"network": "ResNet18", "batch": 128, "methods": ["approx_tc", "approx_mc", "chen", "vanilla"]},
//!     {"network": "MobileNetV1", "methods": ["approx_mc", "chen", "vanilla"]}
//!   ]
//! }
//! ```
//! Omitted fields default (batch = zoo default, methods = all).

use crate::anyhow::{anyhow, bail, Context, Result};

use crate::fmt_bytes;
use crate::graph::Graph;
use crate::models::zoo;
use crate::planner::{Objective, PlanRequest, PlannerId};
use crate::session::PlanSession;
use crate::sim::{simulate_vanilla, SimMode, SimOptions};
use crate::util::json::Json;
use crate::util::table::Table;

/// One requested run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub network: String,
    pub batch: Option<u64>,
    pub methods: Vec<Method>,
}

/// Planner method selector.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    ApproxTc,
    ApproxMc,
    ExactTc,
    ExactMc,
    Chen,
    Vanilla,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "approx_tc" => Method::ApproxTc,
            "approx_mc" => Method::ApproxMc,
            "exact_tc" => Method::ExactTc,
            "exact_mc" => Method::ExactMc,
            "chen" => Method::Chen,
            "vanilla" => Method::Vanilla,
            other => bail!("unknown method '{other}'"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Method::ApproxTc => "ApproxDP+TC",
            Method::ApproxMc => "ApproxDP+MC",
            Method::ExactTc => "ExactDP+TC",
            Method::ExactMc => "ExactDP+MC",
            Method::Chen => "Chen's",
            Method::Vanilla => "Vanilla",
        }
    }

    pub const ALL: [Method; 6] = [
        Method::ApproxTc,
        Method::ApproxMc,
        Method::ExactTc,
        Method::ExactMc,
        Method::Chen,
        Method::Vanilla,
    ];
}

/// Whole experiment definition.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub liveness: bool,
    pub runs: Vec<RunSpec>,
}

impl Experiment {
    /// Parse the JSON config format documented at module level.
    pub fn from_json(text: &str) -> Result<Experiment> {
        let v = Json::parse(text).context("parsing experiment config")?;
        let name = v.get("name").as_str().unwrap_or("experiment").to_string();
        let liveness = v.get("liveness").as_bool().unwrap_or(true);
        let runs_json = v.get("runs").as_arr().context("config: missing 'runs' array")?;
        let mut runs = Vec::new();
        for (i, rj) in runs_json.iter().enumerate() {
            let network = rj
                .get("network")
                .as_str()
                .with_context(|| format!("run {i}: missing network"))?
                .to_string();
            if zoo::find(&network).is_none() {
                bail!("run {i}: unknown network '{network}'");
            }
            let batch = rj.get("batch").as_u64();
            let methods = match rj.get("methods").as_arr() {
                None => Method::ALL.to_vec(),
                Some(ms) => ms
                    .iter()
                    .map(|m| {
                        m.as_str()
                            .ok_or_else(|| anyhow!("run {i}: method must be a string"))
                            .and_then(Method::parse)
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            runs.push(RunSpec { network, batch, methods });
        }
        Ok(Experiment { name, liveness, runs })
    }
}

/// One measured result row.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub network: String,
    pub batch: u64,
    pub method: Method,
    pub peak_total: u64,
    pub overhead: u64,
    pub k: usize,
    pub reduction_pct: f64,
}

/// Execute the experiment; returns all rows. One [`PlanSession`] per run
/// spec serves every method: families are built lazily once per family,
/// `B*` is memoized, and repeated methods hit the compiled-plan cache.
pub fn run_experiment(exp: &Experiment) -> Result<Vec<RunResult>> {
    let mut out = Vec::new();
    for spec in &exp.runs {
        let entry = zoo::find(&spec.network).expect("validated at parse");
        let batch = spec.batch.unwrap_or(entry.batch);
        let g: Graph = entry.build_batch(batch);
        let sim_mode = SimMode::from_liveness(exp.liveness);
        let vanilla_peak =
            simulate_vanilla(&g, SimOptions { mode: SimMode::Liveness, include_params: true })
                .peak_total;
        let session = PlanSession::new(g);

        for &method in &spec.methods {
            let (peak, overhead, k) = match method {
                Method::Vanilla => {
                    // Vanilla keeps its framework-native eager freeing
                    // regardless of the liveness toggle (Appendix C).
                    (vanilla_peak, 0u64, session.graph().len() as usize)
                }
                Method::Chen => {
                    let req = PlanRequest {
                        sim_mode,
                        ..PlanRequest::new(PlannerId::Chen, Objective::MinOverhead)
                    };
                    let cp = session.plan(&req)?;
                    (cp.report.peak_total, cp.report.overhead_time, cp.plan.chain.k())
                }
                m => {
                    let (planner, obj) = match m {
                        Method::ApproxTc => (PlannerId::ApproxDp, Objective::MinOverhead),
                        Method::ApproxMc => (PlannerId::ApproxDp, Objective::MaxOverhead),
                        Method::ExactTc => (PlannerId::ExactDp, Objective::MinOverhead),
                        Method::ExactMc => (PlannerId::ExactDp, Objective::MaxOverhead),
                        _ => unreachable!(),
                    };
                    let req = PlanRequest { sim_mode, ..PlanRequest::new(planner, obj) };
                    let cp = session
                        .plan(&req)
                        .map_err(|e| anyhow!("{}: {e}", spec.network))?;
                    (cp.report.peak_total, cp.plan.overhead, cp.plan.chain.k())
                }
            };
            out.push(RunResult {
                network: spec.network.clone(),
                batch,
                method,
                peak_total: peak,
                overhead,
                k,
                reduction_pct: 100.0 * (1.0 - peak as f64 / vanilla_peak as f64),
            });
        }
    }
    Ok(out)
}

/// Render results as a text table.
pub fn render(results: &[RunResult]) -> String {
    let mut t =
        Table::new(&["Network", "Batch", "Method", "Peak", "Reduction", "Overhead", "k"]).numeric();
    for r in results {
        t.row(vec![
            r.network.clone(),
            r.batch.to_string(),
            r.method.label().to_string(),
            fmt_bytes(r.peak_total),
            format!("{:.0}%", -r.reduction_pct),
            r.overhead.to_string(),
            r.k.to_string(),
        ]);
    }
    t.render()
}

/// Render results as CSV (for plotting).
pub fn to_csv(results: &[RunResult]) -> String {
    let mut s = String::from("network,batch,method,peak_bytes,reduction_pct,overhead,k\n");
    for r in results {
        s.push_str(&format!(
            "{},{},{},{},{:.2},{},{}\n",
            r.network,
            r.batch,
            r.method.label(),
            r.peak_total,
            r.reduction_pct,
            r.overhead,
            r.k
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const CFG: &str = r#"{
        "name": "mini",
        "liveness": true,
        "runs": [
            {"network": "VGG19", "batch": 4,
             "methods": ["approx_tc", "approx_mc", "chen", "vanilla"]}
        ]
    }"#;

    #[test]
    fn parse_and_run() {
        let exp = Experiment::from_json(CFG).unwrap();
        assert_eq!(exp.name, "mini");
        assert_eq!(exp.runs.len(), 1);
        let results = run_experiment(&exp).unwrap();
        assert_eq!(results.len(), 4);
        let vanilla = results.iter().find(|r| r.method == Method::Vanilla).unwrap();
        let mc = results.iter().find(|r| r.method == Method::ApproxMc).unwrap();
        assert!(mc.peak_total < vanilla.peak_total);
        assert!(mc.reduction_pct > 0.0);
        // Render paths.
        assert!(render(&results).contains("ApproxDP+MC"));
        let csv = to_csv(&results);
        assert_eq!(csv.lines().count(), 5);
        assert!(csv.starts_with("network,batch"));
    }

    #[test]
    fn rejects_unknown_network_and_method() {
        assert!(Experiment::from_json(
            r#"{"runs": [{"network": "NopeNet"}]}"#
        )
        .is_err());
        assert!(Experiment::from_json(
            r#"{"runs": [{"network": "VGG19", "methods": ["magic"]}]}"#
        )
        .is_err());
    }

    #[test]
    fn defaults_apply() {
        let exp =
            Experiment::from_json(r#"{"runs": [{"network": "ResNet18"}]}"#).unwrap();
        assert_eq!(exp.runs[0].methods.len(), 6);
        assert!(exp.liveness);
        assert!(exp.runs[0].batch.is_none());
    }
}
