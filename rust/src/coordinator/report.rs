//! JSON run reports (loss curve, measured peaks, timings, pool counters,
//! session amortization counters).

use crate::exec::TrainReport;
use crate::fmt_bytes;
use crate::planner::DecompositionInfo;
use crate::runtime::PoolStats;
use crate::session::{SessionStats, SessionTiming};
use crate::util::json::Json;

/// Serialize a training report for EXPERIMENTS.md / plotting.
pub fn report_json(label: &str, r: &TrainReport) -> Json {
    let kernels: Vec<Json> = r
        .kernel_stats
        .iter()
        .map(|s| {
            Json::obj()
                .set("kernel", s.kernel.as_str().into())
                .set("calls", s.calls.into())
                .set("total_ms", (s.total.as_secs_f64() * 1000.0).into())
                .set("bytes_in", s.bytes_in.into())
                .set("bytes_out", s.bytes_out.into())
                .set("flops", s.flops.into())
                .set("gflops", s.gflops().into())
        })
        .collect();
    let mut out = Json::obj()
        .set("label", label.into())
        .set("backend", r.backend.into())
        .set("k_segments", (r.k as u64).into())
        .set("peak_bytes", r.peak_bytes.into())
        .set("param_bytes", r.param_bytes.into())
        .set("mean_step_ms", r.mean_step_ms.into())
        .set("recomputes_per_step", (r.recomputes_per_step as u64).into())
        .set(
            "losses",
            Json::Arr(r.losses.iter().map(|&l| Json::Num(l as f64)).collect()),
        )
        .set("kernel_stats", Json::Arr(kernels));
    if let Some(p) = &r.pool {
        out = out.set("pool", pool_json(p));
    }
    out
}

/// Serialize buffer-pool counters.
pub fn pool_json(p: &PoolStats) -> Json {
    Json::obj()
        .set("allocs", p.allocs.into())
        .set("reuses", p.reuses.into())
        .set("parked_bytes", p.parked_bytes.into())
        .set("high_water_bytes", p.high_water_bytes.into())
}

/// One-line rendering of the pool counters — printed alongside the
/// observed peak by `repro train` (`--stats` for tower runs, always for
/// zoo runs).
pub fn pool_summary(p: &PoolStats) -> String {
    format!(
        "pool: allocs={} reuses={} ({:.0}% recycled) high-water={}",
        p.allocs,
        p.reuses,
        100.0 * p.reuse_ratio(),
        fmt_bytes(p.high_water_bytes),
    )
}

/// Machine-readable rendering of a decomposed plan's full per-component
/// statistics (`plan --json`; the serve protocol carries the compact
/// 3-field variant from [`crate::session::CompiledPlan::summary_json`]).
pub fn decomposition_json(info: &DecompositionInfo) -> Json {
    Json::obj()
        .set("components", info.components.into())
        .set("cut_vertices", info.cut_vertices.into())
        .set("cache_hits", info.cache_hits.into())
        .set("sizes", Json::Arr(info.sizes.iter().map(|&s| Json::from(s)).collect()))
        .set(
            "family_sizes",
            Json::Arr(info.family_sizes.iter().map(|&s| Json::from(s)).collect()),
        )
        .set("kinds", Json::Arr(info.kinds.iter().map(|k| Json::from(k.label())).collect()))
}

/// Serialize the plan-session amortization counters.
pub fn session_json(s: &SessionStats) -> Json {
    Json::obj()
        .set("hits", s.hits.into())
        .set("misses", s.misses.into())
        .set("families_built", s.families_built.into())
        .set("components", s.components.into())
        .set("component_cache_hits", s.component_cache_hits.into())
}

/// One-line rendering of the session counters — printed next to the pool
/// counters by `repro train --stats`. The component counters only render
/// when the decomposed planner actually ran (they would be noise for the
/// whole-graph planners).
pub fn session_summary(s: &SessionStats) -> String {
    let mut line = format!(
        "session: hits={} misses={} families_built={}",
        s.hits, s.misses, s.families_built
    );
    if s.components > 0 {
        line.push_str(&format!(
            " components={} component_cache_hits={}",
            s.components, s.component_cache_hits
        ));
    }
    line
}

/// One-line rendering of the planner wall-time counters — printed next
/// to the session counters by `--stats` (`repro plan` and `repro train`).
pub fn timing_summary(t: &SessionTiming) -> String {
    format!(
        "planner: family_build={:.2?} compile={:.2?}",
        t.family_build, t.compile
    )
}

/// First/last loss summary line.
pub fn loss_summary(r: &TrainReport) -> String {
    let first = r.losses.first().copied().unwrap_or(f32::NAN);
    let last = r.losses.last().copied().unwrap_or(f32::NAN);
    format!("loss {first:.4} → {last:.4} over {} steps", r.losses.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{KernelStat, PoolStats};

    #[test]
    fn report_roundtrips() {
        let r = TrainReport {
            backend: "native",
            losses: vec![1.0, 0.5],
            peak_bytes: 1234,
            param_bytes: 99,
            mean_step_ms: 1.5,
            recomputes_per_step: 7,
            k: 3,
            kernel_stats: vec![KernelStat {
                kernel: "layer_fwd".into(),
                calls: 12,
                ..KernelStat::default()
            }],
            pool: Some(PoolStats {
                allocs: 10,
                reuses: 30,
                parked_bytes: 256,
                high_water_bytes: 4096,
            }),
        };
        let j = report_json("tc", &r);
        assert_eq!(j.get("peak_bytes").as_u64(), Some(1234));
        assert_eq!(j.get("backend").as_str(), Some("native"));
        assert_eq!(j.get("losses").as_arr().unwrap().len(), 2);
        let ks = j.get("kernel_stats").as_arr().unwrap();
        assert_eq!(ks[0].get("kernel").as_str(), Some("layer_fwd"));
        assert_eq!(ks[0].get("calls").as_u64(), Some(12));
        assert_eq!(ks[0].get("flops").as_u64(), Some(0));
        assert_eq!(ks[0].get("gflops").as_f64(), Some(0.0));
        assert_eq!(j.get("pool").get("reuses").as_u64(), Some(30));
        assert_eq!(j.get("pool").get("high_water_bytes").as_u64(), Some(4096));
        assert!(loss_summary(&r).contains("1.0000 → 0.5000"));
        // serialize → parse round-trip through the util::json module.
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("mean_step_ms").as_f64(), Some(1.5));

        let line = pool_summary(r.pool.as_ref().unwrap());
        assert!(line.contains("allocs=10"), "{line}");
        assert!(line.contains("75% recycled"), "{line}");
        assert!(line.contains("4.0KiB") || line.contains("4096"), "{line}");
    }

    #[test]
    fn timing_summary_renders_both_counters() {
        let t = SessionTiming {
            family_build: std::time::Duration::from_millis(12),
            compile: std::time::Duration::from_micros(340),
        };
        let line = timing_summary(&t);
        assert!(line.contains("planner:"), "{line}");
        assert!(line.contains("family_build="), "{line}");
        assert!(line.contains("compile="), "{line}");
    }

    #[test]
    fn session_counters_serialize_and_summarize() {
        let s = SessionStats {
            hits: 3,
            misses: 2,
            families_built: 1,
            components: 0,
            component_cache_hits: 0,
        };
        let j = session_json(&s);
        assert_eq!(j.get("hits").as_u64(), Some(3));
        assert_eq!(j.get("misses").as_u64(), Some(2));
        assert_eq!(j.get("families_built").as_u64(), Some(1));
        assert_eq!(j.get("components").as_u64(), Some(0));
        let line = session_summary(&s);
        assert!(line.contains("hits=3"), "{line}");
        assert!(line.contains("families_built=1"), "{line}");
        assert!(!line.contains("components="), "quiet without decomposed runs: {line}");

        let d = SessionStats { components: 7, component_cache_hits: 4, ..s };
        let dline = session_summary(&d);
        assert!(dline.contains("components=7"), "{dline}");
        assert!(dline.contains("component_cache_hits=4"), "{dline}");
    }
}
