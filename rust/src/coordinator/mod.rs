//! Training coordinator: configuration, launcher CLI, and run reports for
//! the backend-generic training executor.
//!
//! The coordinator is deliberately thin — the paper's contribution is the
//! planner (L3 `planner`) and the plan-following executor (`exec`); this
//! module wires them to a command line, compares schedules side by side
//! on whatever [`crate::runtime::Backend`] is selected, and emits
//! machine-readable reports for EXPERIMENTS.md.

pub mod cli;
pub mod experiment;
pub mod report;
pub mod train;
