//! `repro train` — CLI front-end of the training coordinator.
//!
//! Runs the real PJRT executor on the MLP tower under one or more
//! schedules and prints the measured peak / step-time / loss evidence.
//!
//! Flags:
//!   --artifacts DIR   artifact directory (default: artifacts)
//!   --layers N        hidden layers (default 16)
//!   --steps N         training steps (default 50)
//!   --lr F            learning rate (default 0.05)
//!   --mode M          vanilla | tc | mc | all (default all)
//!   --budget-frac F   activation budget as a fraction of vanilla (tc/mc
//!                     default: minimal feasible)
//!   --report FILE     write a JSON report
//!   --quiet           suppress per-step loss logging

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::exec::{ChainSchedule, TowerTrainer, TrainConfig};
use crate::fmt_bytes;
use crate::models::mlp_tower;
use crate::planner::{build_context, Family, Objective};
use crate::util::json::Json;

use super::report::{loss_summary, report_json};

struct TrainArgs {
    artifacts: PathBuf,
    layers: usize,
    steps: usize,
    lr: f32,
    mode: String,
    budget_frac: Option<f64>,
    report: Option<PathBuf>,
    quiet: bool,
}

fn parse_args(args: &[String]) -> Result<TrainArgs> {
    let mut out = TrainArgs {
        artifacts: PathBuf::from("artifacts"),
        layers: 16,
        steps: 50,
        lr: 0.05,
        mode: "all".into(),
        budget_frac: None,
        report: None,
        quiet: false,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut val = || it.next().ok_or_else(|| anyhow!("missing value for {a}"));
        match a.as_str() {
            "--artifacts" => out.artifacts = PathBuf::from(val()?),
            "--layers" => out.layers = val()?.parse()?,
            "--steps" => out.steps = val()?.parse()?,
            "--lr" => out.lr = val()?.parse()?,
            "--mode" => out.mode = val()?.clone(),
            "--budget-frac" => out.budget_frac = Some(val()?.parse()?),
            "--report" => out.report = Some(PathBuf::from(val()?)),
            "--quiet" => out.quiet = true,
            "--help" | "-h" => {
                bail!("see module docs: repro train [--artifacts DIR] [--layers N] [--steps N] [--lr F] [--mode vanilla|tc|mc|all] [--budget-frac F] [--report FILE] [--quiet]")
            }
            other => bail!("unknown train flag {other}"),
        }
    }
    Ok(out)
}

/// Entry point for `repro train`.
pub fn cmd_train(args: &[String]) -> Result<()> {
    let a = parse_args(args)?;
    let cfg = TrainConfig {
        layers: a.layers,
        steps: a.steps,
        lr: a.lr,
        seed: 17,
        log_every: if a.quiet { 0 } else { (a.steps / 5).max(1) },
    };

    // One trainer per schedule: training mutates parameters, and the
    // schedules must see identical initial conditions for the bitwise
    // loss comparison.
    let mut results: Vec<(String, crate::exec::TrainReport)> = Vec::new();
    let modes: Vec<&str> = match a.mode.as_str() {
        "all" => vec!["vanilla", "tc", "mc"],
        m @ ("vanilla" | "tc" | "mc") => vec![m],
        m => bail!("bad --mode {m}"),
    };

    for mode in modes {
        let mut trainer = TowerTrainer::new(&a.artifacts, &cfg)?;
        let batch = trainer.batch() as u64;
        let width = trainer.width() as u32;
        let g = mlp_tower(a.layers as u32, width, batch);
        let sched = match mode {
            "vanilla" => ChainSchedule::vanilla(a.layers + 1),
            tc_or_mc => {
                let ctx = build_context(&g, Family::Exact);
                let min_b = ctx.min_feasible_budget();
                let budget = match a.budget_frac {
                    Some(f) => {
                        let vanilla_acts = g.total_mem();
                        ((vanilla_acts as f64 * f) as u64).max(min_b)
                    }
                    None => min_b,
                };
                let obj = if tc_or_mc == "tc" {
                    Objective::MinOverhead
                } else {
                    Objective::MaxOverhead
                };
                let sol = ctx
                    .solve(budget, obj)
                    .ok_or_else(|| anyhow!("budget {} infeasible", fmt_bytes(budget)))?;
                ChainSchedule::from_chain(&g, &sol.chain)?
            }
        };
        if !a.quiet {
            eprintln!("== mode {mode}: k={} segments ==", sched.segments.len());
        }
        let report = trainer.train(&sched, &cfg)?;
        println!(
            "{mode:<8} k={:<3} peak_act={:<10} (+params {:<9}) step={:.1}ms recompute/step={} {}",
            report.k,
            fmt_bytes(report.peak_bytes),
            fmt_bytes(report.param_bytes),
            report.mean_step_ms,
            report.recomputes_per_step,
            loss_summary(&report),
        );
        results.push((mode.to_string(), report));
    }

    // Cross-schedule invariants worth asserting out loud.
    if results.len() > 1 {
        let v = results.iter().find(|(m, _)| m == "vanilla");
        let tc = results.iter().find(|(m, _)| m == "tc");
        if let (Some((_, v)), Some((_, t))) = (v, tc) {
            let same = v
                .losses
                .iter()
                .zip(&t.losses)
                .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1.0));
            println!(
                "loss trajectory vanilla vs tc: {} (recomputation must not alter outputs)",
                if same { "IDENTICAL ✓" } else { "DIVERGED ✗" }
            );
            println!(
                "peak activation memory: vanilla {} → tc {} ({:.0}% reduction)",
                fmt_bytes(v.peak_bytes),
                fmt_bytes(t.peak_bytes),
                100.0 * (1.0 - t.peak_bytes as f64 / v.peak_bytes as f64)
            );
            if !same {
                bail!("recomputation changed the training trajectory");
            }
        }
    }

    if let Some(path) = a.report {
        let arr: Vec<Json> = results.iter().map(|(m, r)| report_json(m, r)).collect();
        std::fs::write(&path, Json::Arr(arr).to_string_pretty())?;
        println!("report written to {}", path.display());
    }
    Ok(())
}
