//! `repro train` — CLI front-end of the training coordinator.
//!
//! Runs the real executor on the MLP tower under one or more schedules
//! and prints the measured peak / step-time / loss evidence. The backend
//! defaults to the pure-Rust `native` kernels (always available); `pjrt`
//! replays the AOT artifact path when the crate is built with the `xla`
//! feature.
//!
//! Flags:
//!   --model M         tower (default) | any zoo name (resnet, unet,
//!                     densenet161, googlenet, pspnet, …). Zoo models run
//!                     on the general DAG executor (native backend only):
//!                     the topology is lowered to heterogeneous
//!                     [batch, width_v] tensors (per-node widths from the
//!                     model's own M_v profile, capped at --width),
//!                     planned, executed under vanilla + the plan, and
//!                     verified (bit-exact gradients, observed peak ==
//!                     simulator prediction, ≥ 2 distinct per-node
//!                     activation sizes).
//!   --backend B       native | pjrt (default: native; tower only)
//!   --batch N         batch size (default 32)
//!   --width N         tower width / max zoo node width (default 64)
//!   --artifacts DIR   pjrt artifact directory (default: artifacts)
//!   --layers N        hidden layers (default 12; tower only)
//!   --steps N         training steps (default 50)
//!   --lr F            learning rate (default 0.1)
//!   --mode M          vanilla | tc | mc | all (default all). Zoo models
//!                     always run the vanilla baseline; --mode picks the
//!                     planned objectives (tc, mc, or both with `all`),
//!                     all served by one PlanSession so the lower-set
//!                     family is solved once however many modes run
//!   --sim M           liveness (default) | strict: free schedule the zoo
//!                     executor and simulator share. liveness frees every
//!                     buffer at its last use (paper Table 1); strict
//!                     honors only strategy-mandated frees (the Table 2
//!                     ablation). Tower runs always free eagerly (the
//!                     chain fast path is liveness-equivalent by
//!                     construction), so --sim applies to zoo models
//!   --budget B        absolute activation budget: bare number = GB
//!                     (same contract as `repro plan`), unit suffix =
//!                     bytes (512KiB, 2MiB, 1GiB); an infeasible budget
//!                     errors naming the graph's min_feasible_budget
//!   --budget-frac F   activation budget as a fraction of vanilla
//!                     (default without either flag: minimal feasible)
//!   --report FILE     write a JSON report (tower only)
//!   --threads N       worker threads for the planner's parallel family
//!                     construction / DP sweeps (overrides the
//!                     REPRO_THREADS environment variable; default:
//!                     available parallelism). Plans are bit-identical
//!                     at any thread count
//!   --stats           print per-kernel backend timing/byte/GFLOP-s
//!                     statistics plus buffer-pool counters (allocs,
//!                     reuses, high-water bytes), the plan-session
//!                     counters (cache hits/misses, families built) and
//!                     the planner wall-time (family build, compile)
//!   --quiet           suppress per-step loss logging

use std::path::PathBuf;

use crate::anyhow::{anyhow, bail, Result};

use crate::exec::{TowerTrainer, TrainConfig, TrainReport};
use crate::sim::SimMode;
use crate::util::json::Json;
use crate::{fmt_bytes, parse_budget};

use super::report::{
    loss_summary, pool_summary, report_json, session_json, session_summary, timing_summary,
};
use super::train::{
    compare_schedules, parse_modes, trajectories_identical, BudgetSpec, ScheduleMode,
};

struct TrainArgs {
    model: String,
    backend: String,
    batch: usize,
    width: usize,
    artifacts: PathBuf,
    layers: usize,
    steps: usize,
    lr: f32,
    mode: String,
    sim: SimMode,
    budget: Option<u64>,
    budget_frac: Option<f64>,
    report: Option<PathBuf>,
    threads: Option<usize>,
    stats: bool,
    quiet: bool,
}

impl TrainArgs {
    /// Combine `--budget` / `--budget-frac` into one [`BudgetSpec`].
    fn budget_spec(&self) -> Result<BudgetSpec> {
        match (self.budget, self.budget_frac) {
            (Some(_), Some(_)) => bail!("--budget and --budget-frac are mutually exclusive"),
            (Some(b), None) => Ok(BudgetSpec::Bytes(b)),
            (None, Some(f)) => Ok(BudgetSpec::Frac(f)),
            (None, None) => Ok(BudgetSpec::MinFeasible),
        }
    }
}

fn parse_args(args: &[String]) -> Result<TrainArgs> {
    let mut out = TrainArgs {
        model: "tower".into(),
        backend: "native".into(),
        batch: 32,
        width: 64,
        artifacts: PathBuf::from("artifacts"),
        layers: 12,
        steps: 50,
        lr: 0.1,
        mode: "all".into(),
        sim: SimMode::Liveness,
        budget: None,
        budget_frac: None,
        report: None,
        threads: None,
        stats: false,
        quiet: false,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut val = || it.next().ok_or_else(|| anyhow!("missing value for {a}"));
        match a.as_str() {
            "--model" => out.model = val()?.clone(),
            "--backend" => out.backend = val()?.clone(),
            "--batch" => out.batch = val()?.parse()?,
            "--width" => out.width = val()?.parse()?,
            "--artifacts" => out.artifacts = PathBuf::from(val()?),
            "--layers" => out.layers = val()?.parse()?,
            "--steps" => out.steps = val()?.parse()?,
            "--lr" => out.lr = val()?.parse()?,
            "--mode" => out.mode = val()?.clone(),
            "--sim" => out.sim = SimMode::parse(val()?)?,
            "--budget" => out.budget = Some(parse_budget(val()?)?),
            "--budget-frac" => out.budget_frac = Some(val()?.parse()?),
            "--report" => out.report = Some(PathBuf::from(val()?)),
            "--threads" => out.threads = Some(val()?.parse()?),
            "--stats" => out.stats = true,
            "--quiet" => out.quiet = true,
            "--help" | "-h" => {
                bail!("see module docs: repro train [--model tower|<zoo>] [--backend native|pjrt] [--batch N] [--width N] [--artifacts DIR] [--layers N] [--steps N] [--lr F] [--mode vanilla|tc|mc|all] [--sim liveness|strict] [--budget GB|512KiB] [--budget-frac F] [--report FILE] [--threads N] [--stats] [--quiet]")
            }
            other => bail!("unknown train flag {other}"),
        }
    }
    if out.batch == 0 || out.width == 0 {
        bail!("--batch and --width must be positive");
    }
    Ok(out)
}

/// Entry point for `repro train`.
pub fn cmd_train(args: &[String]) -> Result<()> {
    let a = parse_args(args)?;
    if let Some(t) = a.threads {
        // Latch the planner pool width before any session spins it up.
        crate::util::pool::set_global_threads(t);
    }
    let cfg = TrainConfig {
        layers: a.layers,
        steps: a.steps,
        lr: a.lr,
        seed: 7,
        log_every: if a.quiet { 0 } else { (a.steps / 5).max(1) },
    };
    if !a.model.eq_ignore_ascii_case("tower") {
        return train_zoo(&a, &cfg);
    }
    let modes = parse_modes(&a.mode)?;
    let budget = a.budget_spec()?;

    // Each mode gets a fresh trainer: training mutates parameters, and the
    // schedules must see identical initial conditions for the bitwise
    // loss comparison. One PlanSession serves every planned mode.
    let (results, session_stats, session_timing): (Vec<(ScheduleMode, TrainReport)>, _, _) =
        match a.backend.as_str() {
            "native" => compare_schedules(
                || TowerTrainer::native(a.batch, a.width, &cfg),
                &cfg,
                &modes,
                budget,
                a.quiet,
            )?,
            "pjrt" => run_pjrt(&a, &cfg, &modes)?,
            other => bail!("unknown backend '{other}' (native|pjrt)"),
        };

    for (mode, report) in &results {
        println!(
            "{:<8} [{}] k={:<3} peak_act={:<10} (+params {:<9}) step={:.2}ms recompute/step={} {}",
            mode.label(),
            report.backend,
            report.k,
            fmt_bytes(report.peak_bytes),
            fmt_bytes(report.param_bytes),
            report.mean_step_ms,
            report.recomputes_per_step,
            loss_summary(report),
        );
    }

    // Cross-schedule invariants worth asserting out loud.
    if results.len() > 1 {
        let v = results.iter().find(|(m, _)| *m == ScheduleMode::Vanilla);
        let tc = results.iter().find(|(m, _)| *m == ScheduleMode::Tc);
        if let (Some((_, v)), Some((_, t))) = (v, tc) {
            let same = trajectories_identical(v, t);
            println!(
                "loss trajectory vanilla vs tc: {} (recomputation must not alter outputs)",
                if same { "IDENTICAL ✓" } else { "DIVERGED ✗" }
            );
            println!(
                "peak activation memory: vanilla {} → tc {} ({:.0}% reduction)",
                fmt_bytes(v.peak_bytes),
                fmt_bytes(t.peak_bytes),
                100.0 * (1.0 - t.peak_bytes as f64 / v.peak_bytes as f64)
            );
            if !same {
                bail!("recomputation changed the training trajectory");
            }
        }
    }

    if a.stats {
        for (mode, report) in &results {
            println!("-- kernel stats ({}, {} backend) --", mode.label(), report.backend);
            for s in &report.kernel_stats {
                println!("  {}", kernel_stat_line(s));
            }
            if let Some(pool) = &report.pool {
                println!("  {}", pool_summary(pool));
            }
        }
        println!("{}", session_summary(&session_stats));
        println!("{}", timing_summary(&session_timing));
    }

    if let Some(path) = a.report {
        let mut arr: Vec<Json> =
            results.iter().map(|(m, r)| report_json(m.label(), r)).collect();
        arr.push(Json::obj().set("session", session_json(&session_stats)));
        std::fs::write(&path, Json::Arr(arr).to_string_pretty())?;
        println!("report written to {}", path.display());
    }
    Ok(())
}

/// Zoo-model path: lower once, plan every requested objective through
/// one `PlanSession`, execute on the general DAG executor, and hold each
/// run to the executor's two invariants (bit-exact gradients, observed
/// peak == simulator prediction) — failing loudly otherwise.
fn train_zoo(a: &TrainArgs, cfg: &TrainConfig) -> Result<()> {
    use crate::planner::Objective;

    if a.backend != "native" {
        bail!(
            "zoo models run on the general DAG executor, which is native-only \
             (backend '{}' requested); see README 'Execution matrix'",
            a.backend
        );
    }
    if a.report.is_some() {
        bail!("--report is not supported for zoo models yet (tower only)");
    }
    // Zoo runs always compare vanilla vs the planned schedules; --mode
    // picks the planning objectives (`all` runs tc *and* mc from the
    // same session, so the family is built once).
    let mut objectives: Vec<Objective> =
        parse_modes(&a.mode)?.iter().filter_map(|m| m.objective()).collect();
    if objectives.is_empty() {
        objectives.push(Objective::MinOverhead);
    }
    let cmp = super::train::train_zoo_model(
        &a.model,
        a.batch,
        a.width,
        cfg,
        a.budget_spec()?,
        &objectives,
        a.sim,
        a.quiet,
    )?;

    let labeled = |r: &super::train::PlannedRun| format!("planned[{}]", r.objective.label());
    println!(
        "{:<12} [{}] peak_act={:<10} (+params {:<9}) step={:.2}ms recompute/step={} {}",
        "vanilla",
        cmp.vanilla.backend,
        fmt_bytes(cmp.vanilla.observed_peak),
        fmt_bytes(cmp.vanilla.param_bytes),
        cmp.vanilla.mean_step_ms,
        cmp.vanilla.recomputes_per_step,
        dag_loss_summary(&cmp.vanilla),
    );
    for run in &cmp.runs {
        println!(
            "{:<12} [{}] peak_act={:<10} (+params {:<9}) step={:.2}ms recompute/step={} {}",
            labeled(run),
            run.report.backend,
            fmt_bytes(run.report.observed_peak),
            fmt_bytes(run.report.param_bytes),
            run.report.mean_step_ms,
            run.report.recomputes_per_step,
            dag_loss_summary(&run.report),
        );
    }
    println!(
        "model {} ({} nodes, fingerprint {}):",
        cmp.model, cmp.nodes, cmp.fingerprint
    );
    for run in &cmp.runs {
        println!(
            "  {}: k={} segments, overhead={} T_v units, budget {}{}",
            labeled(run),
            run.k,
            run.overhead,
            fmt_bytes(run.budget),
            if run.cache_hit { " (plan cached)" } else { "" },
        );
    }
    // `train_zoo_model` refuses uniform lowerings up front, so any
    // comparison that reaches this report is heterogeneous.
    println!(
        "per-node activation bytes: {} distinct sizes ({} … {}): HETEROGENEOUS ✓",
        cmp.distinct_act_bytes,
        fmt_bytes(cmp.act_bytes_range.0),
        fmt_bytes(cmp.act_bytes_range.1),
    );
    for run in &cmp.runs {
        println!(
            "gradients vanilla vs {}: {}",
            labeled(run),
            if run.grads_match { "BIT-IDENTICAL ✓" } else { "DIVERGED ✗" }
        );
        println!(
            "observed peak {} vs simulator prediction {} (sim {}): {}",
            fmt_bytes(run.report.observed_peak),
            fmt_bytes(run.sim_peak),
            cmp.mode.label(),
            if run.peak_matches_sim { "EQUAL ✓" } else { "MISMATCH ✗" }
        );
        if cmp.mode.liveness() {
            println!(
                "liveness saves over strategy-only frees: {} → {} ({:.0}% of the no-liveness peak)",
                fmt_bytes(run.sim_peak_strict),
                fmt_bytes(run.sim_peak),
                100.0 * run.sim_peak as f64 / run.sim_peak_strict.max(1) as f64
            );
        }
        println!(
            "peak activation memory: vanilla {} → {} {} ({:.0}% reduction)",
            fmt_bytes(cmp.vanilla.observed_peak),
            labeled(run),
            fmt_bytes(run.report.observed_peak),
            100.0
                * (1.0
                    - run.report.observed_peak as f64 / cmp.vanilla.observed_peak as f64)
        );
    }
    if a.stats {
        let mut rows: Vec<(String, &crate::exec::DagTrainReport)> =
            vec![("vanilla".into(), &cmp.vanilla)];
        rows.extend(cmp.runs.iter().map(|r| (labeled(r), &r.report)));
        for (label, r) in rows {
            println!("-- kernel stats ({label}, {} backend) --", r.backend);
            for s in &r.kernel_stats {
                println!("  {}", kernel_stat_line(s));
            }
            if let Some(pool) = &r.pool {
                println!("  {}", pool_summary(pool));
            }
        }
        println!("{}", session_summary(&cmp.stats));
        println!("{}", timing_summary(&cmp.timing));
    }
    for run in &cmp.runs {
        if !run.grads_match || !run.losses_identical {
            bail!(
                "recomputation ({}) changed the training outputs on {}",
                run.objective.label(),
                cmp.model
            );
        }
        if !run.peak_matches_sim {
            bail!(
                "executor-observed peak diverged from the simulator's prediction ({})",
                run.objective.label()
            );
        }
    }
    Ok(())
}

/// One `--stats` row for a kernel: calls, wall-clock, bytes and the
/// achieved GFLOP/s (0.00 when the backend attributes no flops, e.g.
/// PJRT's opaque artifacts).
fn kernel_stat_line(s: &crate::runtime::KernelStat) -> String {
    format!(
        "{:<14} calls={:<6} total={:>10.2?} mean={:>9.2?} in={:<10} out={:<10} {:>8.2} GFLOP/s",
        s.kernel,
        s.calls,
        s.total,
        s.mean(),
        fmt_bytes(s.bytes_in),
        fmt_bytes(s.bytes_out),
        s.gflops(),
    )
}

/// Loss summary for DAG reports (first → last) — shared with the serve
/// router's `train` replies.
pub(crate) fn dag_loss_summary(r: &crate::exec::DagTrainReport) -> String {
    match (r.losses.first(), r.losses.last()) {
        (Some(f), Some(l)) => format!("loss {f:.4}→{l:.4}"),
        _ => "no steps".into(),
    }
}

#[cfg(feature = "xla")]
fn run_pjrt(
    a: &TrainArgs,
    cfg: &TrainConfig,
    modes: &[ScheduleMode],
) -> Result<(
    Vec<(ScheduleMode, TrainReport)>,
    crate::session::SessionStats,
    crate::session::SessionTiming,
)> {
    let dir = a.artifacts.clone();
    compare_schedules(
        || TowerTrainer::from_artifacts(&dir, cfg),
        cfg,
        modes,
        a.budget_spec()?,
        a.quiet,
    )
}

#[cfg(not(feature = "xla"))]
fn run_pjrt(
    a: &TrainArgs,
    _cfg: &TrainConfig,
    _modes: &[ScheduleMode],
) -> Result<(
    Vec<(ScheduleMode, TrainReport)>,
    crate::session::SessionStats,
    crate::session::SessionTiming,
)> {
    bail!(
        "the pjrt backend (artifacts at {}) requires `cargo build --features xla` \
         (plus real PJRT libraries and `make artifacts`; see README 'Backend matrix')",
        a.artifacts.display()
    )
}
