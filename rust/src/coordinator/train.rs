//! Backend-generic schedule comparison — the shared engine behind
//! `repro train` and `examples/train_mlp`.
//!
//! Given a way to construct a fresh [`TowerTrainer`] (fresh = identical
//! initial parameters, so loss trajectories are comparable bitwise), runs
//! the same training configuration under a set of schedules (vanilla /
//! time-centric / memory-centric) and returns the measured reports.

use crate::anyhow::{anyhow, bail, Result};
use crate::exec::{ChainSchedule, TowerTrainer, TrainConfig, TrainReport};
use crate::fmt_bytes;
use crate::models::mlp_tower;
use crate::planner::{build_context, Family, Objective};
use crate::runtime::Backend;

/// Parse a `--mode` value into the schedule list to run.
pub fn parse_modes(mode: &str) -> Result<Vec<&'static str>> {
    Ok(match mode {
        "all" => vec!["vanilla", "tc", "mc"],
        "vanilla" => vec!["vanilla"],
        "tc" => vec!["tc"],
        "mc" => vec!["mc"],
        m => bail!("bad mode {m} (vanilla|tc|mc|all)"),
    })
}

/// Build the executable schedule for one mode over a `layers`-deep MLP
/// tower at `(batch, width)`.
///
/// `budget_frac` scales the activation budget as a fraction of the
/// tower's total activation memory (clamped to the minimal feasible
/// budget); `None` plans at the minimal feasible budget B*.
pub fn schedule_for_mode(
    mode: &str,
    layers: usize,
    width: usize,
    batch: usize,
    budget_frac: Option<f64>,
) -> Result<ChainSchedule> {
    if mode == "vanilla" {
        return Ok(ChainSchedule::vanilla(layers + 1));
    }
    let obj = match mode {
        "tc" => Objective::MinOverhead,
        "mc" => Objective::MaxOverhead,
        m => bail!("bad mode {m} (vanilla|tc|mc)"),
    };
    let g = mlp_tower(layers as u32, width as u32, batch as u64);
    let ctx = build_context(&g, Family::Exact);
    let min_b = ctx.min_feasible_budget();
    let budget = match budget_frac {
        Some(f) => ((g.total_mem() as f64 * f) as u64).max(min_b),
        None => min_b,
    };
    let sol = ctx
        .solve(budget, obj)
        .ok_or_else(|| anyhow!("budget {} infeasible", fmt_bytes(budget)))?;
    ChainSchedule::from_chain(&g, &sol.chain)
}

/// Train `cfg` under each schedule in `modes`, each on a **fresh** trainer
/// from `make_trainer` so all runs share identical initial conditions.
/// Returns `(mode, report)` pairs in the order requested.
pub fn compare_schedules<B, F>(
    make_trainer: F,
    cfg: &TrainConfig,
    modes: &[&str],
    budget_frac: Option<f64>,
    quiet: bool,
) -> Result<Vec<(String, TrainReport)>>
where
    B: Backend,
    F: Fn() -> Result<TowerTrainer<B>>,
{
    let mut results = Vec::new();
    for &mode in modes {
        let mut trainer = make_trainer()?;
        let sched = schedule_for_mode(
            mode,
            cfg.layers,
            trainer.width(),
            trainer.batch(),
            budget_frac,
        )?;
        if !quiet {
            eprintln!(
                "== mode {mode} on {} backend: k={} segments ==",
                trainer.backend().name(),
                sched.segments.len()
            );
        }
        let report = trainer.train(&sched, cfg)?;
        results.push((mode.to_string(), report));
    }
    Ok(results)
}

/// Recomputation's defining property: two schedules of the same
/// computation must produce bitwise-comparable loss trajectories
/// (tolerance covers only float noise in the loss *reduction*, which is
/// itself recomputation-free — the default is exact equality in practice).
pub fn trajectories_identical(a: &TrainReport, b: &TrainReport) -> bool {
    a.losses.len() == b.losses.len()
        && a.losses
            .iter()
            .zip(&b.losses)
            .all(|(x, y)| (x - y).abs() <= 1e-6 * x.abs().max(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse() {
        assert_eq!(parse_modes("all").unwrap(), vec!["vanilla", "tc", "mc"]);
        assert_eq!(parse_modes("tc").unwrap(), vec!["tc"]);
        assert!(parse_modes("warp").is_err());
    }

    #[test]
    fn schedules_cover_the_tower() {
        for mode in ["vanilla", "tc", "mc"] {
            let s = schedule_for_mode(mode, 12, 64, 32, None).unwrap();
            assert_eq!(s.n_layers, 13);
            let mut pos = 0;
            for seg in &s.segments {
                assert_eq!(seg.start, pos);
                pos = seg.end;
            }
            assert_eq!(pos, 13, "{mode}");
        }
        // A planned schedule on a 12-layer tower must actually cut.
        assert!(schedule_for_mode("tc", 12, 64, 32, None).unwrap().segments.len() > 1);
    }

    #[test]
    fn native_compare_runs_all_modes() {
        let cfg = TrainConfig { layers: 6, steps: 2, lr: 0.05, seed: 9, log_every: 0 };
        let results = compare_schedules(
            || TowerTrainer::native(4, 16, &cfg),
            &cfg,
            &["vanilla", "tc"],
            None,
            true,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        assert!(trajectories_identical(&results[0].1, &results[1].1));
        assert!(results[1].1.peak_bytes < results[0].1.peak_bytes);
    }
}
