//! Backend-generic schedule comparison — the shared engine behind
//! `repro train` and `examples/train_mlp`.
//!
//! Two engines share this module, both serving their plans through a
//! [`PlanSession`] (one session per graph, so families, budgets and
//! compiled programs are amortized across modes — the counters in
//! [`SessionStats`] are the evidence):
//!
//! - the tower engine ([`compare_schedules`]): given a way to construct a
//!   fresh [`TowerTrainer`] (fresh = identical initial parameters, so
//!   loss trajectories are comparable bitwise), runs the same training
//!   configuration under a set of [`ScheduleMode`]s (vanilla /
//!   time-centric / memory-centric) and returns the measured reports;
//! - the zoo engine ([`train_zoo_model`]): lowers any zoo topology to the
//!   *heterogeneous* executable form (per-node widths from the model's
//!   own `M_v` profile, see
//!   [`crate::models::executable::recost_profiled`]), then for each
//!   requested objective asks the session for an
//!   [`crate::session::CompiledPlan`] under the requested [`SimMode`]
//!   (liveness by default), verifies loss + parameter gradients are
//!   bit-identical to vanilla and the liveness invariant chain —
//!   observed peak == mode-predicted peak (equality) ≤ no-liveness
//!   peak — then trains vanilla plus every planned run and reports.
//!   The vanilla program is compiled once; a repeated [`PlanRequest`]
//!   (verify step + training run) is served from the compiled-plan
//!   cache, surfaced per run as [`PlannedRun::cache_hit`].
//!
//! Budgets for planned schedules are described by
//! [`BudgetSpec`] (re-exported from [`crate::planner`]):
//! minimal-feasible (the default), an absolute byte count (`--budget
//! 512KiB`), or a fraction of total activation memory (`--budget-frac`).
//! Absolute budgets below the graph's minimal feasible budget error out
//! *naming* that minimum, so an infeasible request is actionable.

use std::sync::Arc;

use crate::anyhow::{anyhow, bail, Result};
use crate::exec::{
    ChainSchedule, DagTask, DagTrainReport, DagTrainer, GradMap, TowerTrainer, TrainConfig,
    TrainReport,
};
use crate::graph::GraphFingerprint;
use crate::models::executable::{distinct_act_sizes, recost_profiled};
use crate::models::{mlp_tower, zoo};
use crate::planner::{Objective, PlanRequest, PlannerId};
pub use crate::planner::BudgetSpec;
use crate::runtime::NativeBackend;
use crate::session::{PlanSession, SessionRegistry, SessionStats, SessionTiming};
use crate::sim::SimMode;

/// Typed schedule selector — replaces the stringly `"vanilla"`/`"tc"`/
/// `"mc"` mode names that used to flow through the coordinator.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ScheduleMode {
    /// No recomputation: the framework-native baseline.
    Vanilla,
    /// Time-centric plan ([`Objective::MinOverhead`]).
    Tc,
    /// Memory-centric plan ([`Objective::MaxOverhead`]).
    Mc,
}

impl ScheduleMode {
    /// Parse one mode name.
    pub fn parse(s: &str) -> Result<ScheduleMode> {
        match s {
            "vanilla" => Ok(ScheduleMode::Vanilla),
            "tc" => Ok(ScheduleMode::Tc),
            "mc" => Ok(ScheduleMode::Mc),
            m => bail!("bad mode {m} (vanilla|tc|mc|all)"),
        }
    }

    /// CLI / report rendering.
    pub fn label(self) -> &'static str {
        match self {
            ScheduleMode::Vanilla => "vanilla",
            ScheduleMode::Tc => "tc",
            ScheduleMode::Mc => "mc",
        }
    }

    /// The planning objective this mode requests (`None` for vanilla).
    pub fn objective(self) -> Option<Objective> {
        match self {
            ScheduleMode::Vanilla => None,
            ScheduleMode::Tc => Some(Objective::MinOverhead),
            ScheduleMode::Mc => Some(Objective::MaxOverhead),
        }
    }
}

/// Parse a `--mode` value into the typed schedule list to run.
pub fn parse_modes(mode: &str) -> Result<Vec<ScheduleMode>> {
    Ok(match mode {
        "all" => vec![ScheduleMode::Vanilla, ScheduleMode::Tc, ScheduleMode::Mc],
        m => vec![ScheduleMode::parse(m)?],
    })
}

/// Build the executable schedule for one mode over a `layers`-deep MLP
/// tower at `(batch, width)`, planning under `budget`. Thin shim over a
/// one-shot [`PlanSession`]; [`compare_schedules`] shares one session
/// across modes instead.
pub fn schedule_for_mode(
    mode: ScheduleMode,
    layers: usize,
    width: usize,
    batch: usize,
    budget: BudgetSpec,
) -> Result<ChainSchedule> {
    let Some(objective) = mode.objective() else {
        return Ok(ChainSchedule::vanilla(layers + 1));
    };
    let session = PlanSession::new(mlp_tower(layers as u32, width as u32, batch as u64));
    let req = PlanRequest { budget, ..PlanRequest::new(PlannerId::ExactDp, objective) };
    let cp = session.plan(&req)?;
    ChainSchedule::from_chain(session.graph(), &cp.plan.chain)
}

/// Train `cfg` under each schedule in `modes`, each on a **fresh** trainer
/// from `make_trainer` so all runs share identical initial conditions.
/// One [`PlanSession`] serves every planned mode (the tower's lower-set
/// family and `B*` are solved once); its stats and wall-time counters
/// are returned alongside the `(mode, report)` pairs, in the order
/// requested.
pub fn compare_schedules<B, F>(
    make_trainer: F,
    cfg: &TrainConfig,
    modes: &[ScheduleMode],
    budget: BudgetSpec,
    quiet: bool,
) -> Result<(Vec<(ScheduleMode, TrainReport)>, SessionStats, SessionTiming)>
where
    B: crate::runtime::Backend,
    F: Fn() -> Result<TowerTrainer<B>>,
{
    let mut results = Vec::new();
    let mut session: Option<PlanSession> = None;
    for &mode in modes {
        let mut trainer = make_trainer()?;
        let sched = match mode.objective() {
            None => ChainSchedule::vanilla(cfg.layers + 1),
            Some(objective) => {
                let s = session.get_or_insert_with(|| {
                    PlanSession::new(mlp_tower(
                        cfg.layers as u32,
                        trainer.width() as u32,
                        trainer.batch() as u64,
                    ))
                });
                let req = PlanRequest { budget, ..PlanRequest::new(PlannerId::ExactDp, objective) };
                let cp = s.plan(&req)?;
                ChainSchedule::from_chain(s.graph(), &cp.plan.chain)?
            }
        };
        if !quiet {
            eprintln!(
                "== mode {} on {} backend: k={} segments ==",
                mode.label(),
                trainer.backend().name(),
                sched.segments.len()
            );
        }
        let report = trainer.train(&sched, cfg)?;
        results.push((mode, report));
    }
    let (stats, timing) = session.map(|s| (s.stats(), s.timing())).unwrap_or_default();
    Ok((results, stats, timing))
}

/// Recomputation's defining property: two schedules of the same
/// computation must produce bitwise-comparable loss trajectories
/// (tolerance covers only float noise in the loss *reduction*, which is
/// itself recomputation-free — the default is exact equality in practice).
pub fn trajectories_identical(a: &TrainReport, b: &TrainReport) -> bool {
    a.losses.len() == b.losses.len()
        && a.losses
            .iter()
            .zip(&b.losses)
            .all(|(x, y)| (x - y).abs() <= 1e-6 * x.abs().max(1.0))
}

/// One planned (non-vanilla) run of the zoo engine, with its per-run
/// verification verdicts.
pub struct PlannedRun {
    /// Planning objective this run was solved under.
    pub objective: Objective,
    /// Segments in the plan.
    pub k: usize,
    /// Planned recomputation overhead (Eq. 1 units).
    pub overhead: u64,
    /// Resolved activation budget the plan was solved under.
    pub budget: u64,
    /// Simulator-predicted peak for the plan under the run's `SimMode`.
    pub sim_peak: u64,
    /// Simulator-predicted peak with liveness off — the Table 2 ablation
    /// the liveness peak must never exceed.
    pub sim_peak_strict: u64,
    pub report: DagTrainReport,
    /// One-step verification: loss and every parameter gradient of the
    /// planned execution are bit-identical to vanilla's.
    pub grads_match: bool,
    /// The executor's observed per-step live bytes equal the program's
    /// model prediction, the observed peak equals `sim_peak` (an
    /// equality), and `sim_peak ≤ sim_peak_strict` — the full liveness
    /// invariant chain.
    pub peak_matches_sim: bool,
    /// Full-run loss trajectories are bit-identical to vanilla's.
    pub losses_identical: bool,
    /// The repeated [`PlanRequest`] (verification step, then training
    /// run) was served from the session's compiled-plan cache.
    pub cache_hit: bool,
}

/// Measured comparison of one zoo model under vanilla vs planned
/// execution on the general DAG executor — one vanilla baseline plus one
/// [`PlannedRun`] per requested objective, all served by a single
/// [`PlanSession`].
pub struct ZooComparison {
    /// Executable graph name (`ResNet50@exec32xw64het`-style).
    pub model: String,
    pub nodes: u32,
    /// Free schedule all programs were compiled under.
    pub mode: SimMode,
    /// Number of distinct per-node activation byte-sizes in the lowered
    /// graph — ≥ 2 means the heterogeneous lowering is real (the planner
    /// is cutting a non-uniform memory profile).
    pub distinct_act_bytes: usize,
    /// Smallest and largest per-node activation bytes.
    pub act_bytes_range: (u64, u64),
    /// Structural fingerprint of the lowered graph (the cache key).
    pub fingerprint: GraphFingerprint,
    pub vanilla: DagTrainReport,
    /// One entry per requested objective, in request order.
    pub runs: Vec<PlannedRun>,
    /// The session's amortization counters: for `--mode all`,
    /// `families_built == 1` even though two objectives were planned.
    pub stats: SessionStats,
    /// Wall-clock the session spent on family construction and plan
    /// compilation (the `--stats` planner line).
    pub timing: SessionTiming,
}

impl ZooComparison {
    /// All runs passed every verification (gradients, peak equality,
    /// loss trajectories).
    pub fn all_verified(&self) -> bool {
        self.runs
            .iter()
            .all(|r| r.grads_match && r.peak_matches_sim && r.losses_identical)
    }
}

/// Bitwise comparison of two f32 sequences (`NaN`-safe: compares bits).
pub fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise comparison of two per-node gradient maps: same node set, and
/// every node's `(gw, gb)` identical bit for bit.
pub fn grad_maps_equal(a: &GradMap, b: &GradMap) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, (w0, b0))| {
            b.get(k).is_some_and(|(w1, b1)| bits_equal(w0, w1) && bits_equal(b0, b1))
        })
}

/// Lower zoo model `name` to heterogeneous `[batch, width_v]` tensors
/// (per-node widths from the model's `M_v` profile, capped at
/// `max_width`), plan it under `budget` for **each** objective in
/// `objectives`, and train it under vanilla plus every planned schedule
/// on the native backend, verifying the executor's two core invariants
/// along the way (see [`PlannedRun`]). All programs are compiled under
/// `mode` (liveness by default — the paper's Table 1 measurement; strict
/// reproduces the Table 2 ablation). One [`PlanSession`] serves the
/// whole comparison: the lower-set family is solved exactly once per
/// `(graph, limit)` however many objectives run.
#[allow(clippy::too_many_arguments)]
pub fn train_zoo_model(
    name: &str,
    batch: usize,
    max_width: usize,
    cfg: &TrainConfig,
    budget: BudgetSpec,
    objectives: &[Objective],
    mode: SimMode,
    quiet: bool,
) -> Result<ZooComparison> {
    train_zoo_model_in(None, name, batch, max_width, cfg, budget, objectives, mode, quiet)
}

/// [`train_zoo_model`], optionally serving its session from a
/// [`SessionRegistry`] — the `repro serve` configuration, where repeated
/// `train` requests for the same lowered graph reuse the registered
/// session (families, `B*`, compiled plans) instead of rebuilding it,
/// and planned runs land in the registry's shared [`PlanCache`].
#[allow(clippy::too_many_arguments)]
pub fn train_zoo_model_in(
    registry: Option<&SessionRegistry>,
    name: &str,
    batch: usize,
    max_width: usize,
    cfg: &TrainConfig,
    budget: BudgetSpec,
    objectives: &[Objective],
    mode: SimMode,
    quiet: bool,
) -> Result<ZooComparison> {
    if objectives.is_empty() {
        bail!("train_zoo_model needs at least one planning objective");
    }
    let entry = zoo::find(name)
        .ok_or_else(|| anyhow!("unknown zoo model '{name}' (try resnet, unet, …)"))?;
    // Topology at batch 1 (shape metadata is replaced by the lowering —
    // only the relative M_v profile survives, as per-node widths).
    let lowered = recost_profiled(&entry.build_batch(1), batch, max_width);
    let act_sizes = distinct_act_sizes(&lowered);
    let act_bytes_range = (act_sizes[0], *act_sizes.last().unwrap());
    let distinct_act_bytes = act_sizes.len();
    // Gate *before* planning or training: a degenerate width cap makes
    // every node the same size, which defeats the whole point of the
    // heterogeneous lowering — fail in milliseconds, not after the runs.
    if distinct_act_bytes < 2 {
        bail!(
            "heterogeneous lowering degenerated to uniform shapes on {} \
             (max width {max_width} — try a larger --width)",
            lowered.name
        );
    }
    let session = match registry {
        Some(r) => r.get_or_insert(lowered).0,
        None => Arc::new(PlanSession::new(lowered)),
    };
    let g = session.shared_graph();
    // The vanilla baseline program is compiled once and reused by the
    // verification step and the reported run.
    let vanilla_prog = session.vanilla_program(mode)?;
    if !quiet {
        eprintln!(
            "== zoo model {} ({} nodes, {} distinct activation sizes, fp {}): sim {} ==",
            g.name,
            g.len(),
            distinct_act_bytes,
            session.fingerprint(),
            mode.label()
        );
    }

    // One shared batch drives every verification step: bit-exact
    // loss/grads and observed-vs-predicted memory.
    let mut task = DagTask::for_graph(&g, batch, cfg.seed ^ 0xabcd);
    let (xv, yv) = task.next_batch();
    let mut tv = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let (x, targets) = tv.upload_batch(&xv, &yv)?;
    let rv = tv.run_step(&vanilla_prog, &x, &targets, cfg.lr, true)?;

    // Fresh trainer for the reported vanilla run (identical initial
    // params across every run).
    let mut tvf = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let vanilla = tvf.train(&vanilla_prog, cfg)?;

    let mut runs = Vec::with_capacity(objectives.len());
    for &objective in objectives {
        // ApproxDP is the paper's planner of choice at zoo scale (§4.3) —
        // exact enumeration on a 500-node DenseNet lattice is a bench,
        // not a CLI default.
        let req = PlanRequest {
            budget,
            sim_mode: mode,
            ..PlanRequest::new(PlannerId::ApproxDp, objective)
        };
        let cp = session.plan(&req)?;
        if !quiet {
            eprintln!(
                "== objective {}: k={} segments, budget {} ==",
                objective.label(),
                cp.plan.chain.k(),
                crate::fmt_bytes(cp.plan.budget),
            );
        }
        // One verification step on the shared batch.
        let mut tp = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
        let rp = tp.run_step(&cp.program, &x, &targets, cfg.lr, true)?;
        let (gv, gp) = (rv.grads.as_ref().unwrap(), rp.grads.as_ref().unwrap());
        let grads_match = rv.loss.to_bits() == rp.loss.to_bits() && grad_maps_equal(gv, gp);
        let sim_peak = cp.report.peak_bytes;
        let peak_matches_sim = rp.observed_peak == sim_peak
            && rp.live_trajectory == cp.program.predicted_live
            && sim_peak <= cp.peak_strict;

        // The training run re-requests the same plan: this must be a
        // cache hit returning the very same compiled artifact.
        let again = session.plan(&req)?;
        let cache_hit = Arc::ptr_eq(&cp, &again);
        let mut tpf = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
        let report = tpf.train(&again.program, cfg)?;
        let losses_identical = bits_equal(&vanilla.losses, &report.losses);

        runs.push(PlannedRun {
            objective,
            k: cp.plan.chain.k(),
            overhead: cp.plan.overhead,
            budget: cp.plan.budget,
            sim_peak,
            sim_peak_strict: cp.peak_strict,
            report,
            grads_match,
            peak_matches_sim,
            losses_identical,
            cache_hit,
        });
    }

    Ok(ZooComparison {
        model: g.name.clone(),
        nodes: g.len(),
        mode,
        distinct_act_bytes,
        act_bytes_range,
        fingerprint: session.fingerprint(),
        vanilla,
        runs,
        stats: session.stats(),
        timing: session.timing(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse() {
        assert_eq!(
            parse_modes("all").unwrap(),
            vec![ScheduleMode::Vanilla, ScheduleMode::Tc, ScheduleMode::Mc]
        );
        assert_eq!(parse_modes("tc").unwrap(), vec![ScheduleMode::Tc]);
        assert!(parse_modes("warp").is_err());
        assert_eq!(ScheduleMode::Mc.objective(), Some(Objective::MaxOverhead));
        assert_eq!(ScheduleMode::Vanilla.objective(), None);
    }

    #[test]
    fn schedules_cover_the_tower() {
        for mode in [ScheduleMode::Vanilla, ScheduleMode::Tc, ScheduleMode::Mc] {
            let s = schedule_for_mode(mode, 12, 64, 32, BudgetSpec::MinFeasible).unwrap();
            assert_eq!(s.n_layers, 13);
            let mut pos = 0;
            for seg in &s.segments {
                assert_eq!(seg.start, pos);
                pos = seg.end;
            }
            assert_eq!(pos, 13, "{}", mode.label());
        }
        // A planned schedule on a 12-layer tower must actually cut.
        assert!(
            schedule_for_mode(ScheduleMode::Tc, 12, 64, 32, BudgetSpec::MinFeasible)
                .unwrap()
                .segments
                .len()
                > 1
        );
    }

    #[test]
    fn absolute_budget_below_min_names_the_minimum() {
        let err = schedule_for_mode(ScheduleMode::Tc, 12, 64, 32, BudgetSpec::Bytes(1))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("infeasible"), "{msg}");
        assert!(msg.contains("min_feasible_budget"), "{msg}");
    }

    #[test]
    fn bits_equal_is_exact_and_nan_safe() {
        assert!(bits_equal(&[1.0, -0.0], &[1.0, -0.0]));
        assert!(!bits_equal(&[0.0], &[-0.0]), "signed zero differs bitwise");
        assert!(bits_equal(&[f32::NAN], &[f32::NAN]), "same NaN bits compare equal");
        assert!(!bits_equal(&[1.0], &[1.0, 2.0]));
    }

    #[test]
    fn zoo_engine_verifies_unet_end_to_end() {
        let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
        let cmp = train_zoo_model(
            "unet",
            2,
            8,
            &cfg,
            BudgetSpec::MinFeasible,
            &[Objective::MinOverhead],
            SimMode::Liveness,
            true,
        )
        .unwrap();
        assert_eq!(cmp.mode, SimMode::Liveness);
        assert_eq!(cmp.runs.len(), 1);
        let run = &cmp.runs[0];
        assert!(run.grads_match, "planned grads must be bit-identical to vanilla");
        assert!(run.peak_matches_sim, "observed peak must equal the sim prediction");
        assert!(run.sim_peak <= run.sim_peak_strict, "liveness never exceeds strict");
        assert!(run.losses_identical);
        assert!(cmp.all_verified());
        assert!(run.report.observed_peak < cmp.vanilla.observed_peak);
        assert!(run.report.recomputes_per_step > 0);
        assert!(
            cmp.distinct_act_bytes >= 2,
            "heterogeneous lowering must produce ≥ 2 activation sizes"
        );
        assert!(cmp.act_bytes_range.0 < cmp.act_bytes_range.1);
        // Session amortization: one family, one miss, one hit (the
        // training run re-requested the verification step's plan).
        assert!(run.cache_hit, "repeated request must be served from the cache");
        assert_eq!(cmp.stats.families_built, 1);
        assert_eq!(cmp.stats.misses, 1);
        assert_eq!(cmp.stats.hits, 1);
        // The liveness schedule's churn exercised the backend pool.
        let pool = run.report.pool.as_ref().expect("native backend pools");
        assert!(pool.reuses > 0, "pool must recycle under the liveness schedule");
    }

    #[test]
    fn zoo_engine_shares_one_family_across_objectives() {
        let cfg = TrainConfig { layers: 0, steps: 1, lr: 0.02, seed: 5, log_every: 0 };
        let cmp = train_zoo_model(
            "unet",
            2,
            8,
            &cfg,
            BudgetSpec::MinFeasible,
            &[Objective::MinOverhead, Objective::MaxOverhead],
            SimMode::Liveness,
            true,
        )
        .unwrap();
        assert_eq!(cmp.runs.len(), 2);
        assert!(cmp.all_verified());
        assert_eq!(
            cmp.stats.families_built, 1,
            "the lower-set family must be solved once per (graph, limit)"
        );
        assert_eq!(cmp.stats.misses, 2, "one compilation per objective");
        assert_eq!(cmp.stats.hits, 2, "each training run re-used its verify plan");
        // MC trades overhead for (≤) memory at the same budget.
        assert!(cmp.runs[1].overhead >= cmp.runs[0].overhead);
    }

    #[test]
    fn native_compare_runs_all_modes() {
        let cfg = TrainConfig { layers: 6, steps: 2, lr: 0.05, seed: 9, log_every: 0 };
        let (results, stats, timing) = compare_schedules(
            || TowerTrainer::native(4, 16, &cfg),
            &cfg,
            &[ScheduleMode::Vanilla, ScheduleMode::Tc],
            BudgetSpec::MinFeasible,
            true,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        assert!(trajectories_identical(&results[0].1, &results[1].1));
        assert!(results[1].1.peak_bytes < results[0].1.peak_bytes);
        assert_eq!(stats.families_built, 1, "one tower session for the planned mode");
        assert!(
            timing.family_build > std::time::Duration::ZERO,
            "planned mode must accrue family-build wall-time"
        );
    }
}
