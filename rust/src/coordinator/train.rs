//! Backend-generic schedule comparison — the shared engine behind
//! `repro train` and `examples/train_mlp`.
//!
//! Two engines share this module:
//!
//! - the tower engine ([`compare_schedules`]): given a way to construct a
//!   fresh [`TowerTrainer`] (fresh = identical initial parameters, so
//!   loss trajectories are comparable bitwise), runs the same training
//!   configuration under a set of schedules (vanilla / time-centric /
//!   memory-centric) and returns the measured reports;
//! - the zoo engine ([`train_zoo_model`]): lowers any zoo topology to the
//!   executable `[batch, width]` form, plans it, compiles vanilla and
//!   planned [`OpProgram`]s, verifies loss + parameter gradients are
//!   bit-identical and that the observed peak equals the simulator's
//!   no-liveness prediction, then trains both and reports.

use crate::anyhow::{anyhow, bail, Result};
use crate::exec::{
    ChainSchedule, DagTrainReport, DagTrainer, GradMap, OpProgram, SyntheticTask,
    TowerTrainer, TrainConfig, TrainReport,
};
use crate::fmt_bytes;
use crate::models::executable::recost;
use crate::models::{mlp_tower, zoo};
use crate::planner::{build_context, Family, Objective};
use crate::runtime::{Backend, NativeBackend};
use crate::sim::{simulate, SimOptions};

/// Parse a `--mode` value into the schedule list to run.
pub fn parse_modes(mode: &str) -> Result<Vec<&'static str>> {
    Ok(match mode {
        "all" => vec!["vanilla", "tc", "mc"],
        "vanilla" => vec!["vanilla"],
        "tc" => vec!["tc"],
        "mc" => vec!["mc"],
        m => bail!("bad mode {m} (vanilla|tc|mc|all)"),
    })
}

/// Build the executable schedule for one mode over a `layers`-deep MLP
/// tower at `(batch, width)`.
///
/// `budget_frac` scales the activation budget as a fraction of the
/// tower's total activation memory (clamped to the minimal feasible
/// budget); `None` plans at the minimal feasible budget B*.
pub fn schedule_for_mode(
    mode: &str,
    layers: usize,
    width: usize,
    batch: usize,
    budget_frac: Option<f64>,
) -> Result<ChainSchedule> {
    if mode == "vanilla" {
        return Ok(ChainSchedule::vanilla(layers + 1));
    }
    let obj = match mode {
        "tc" => Objective::MinOverhead,
        "mc" => Objective::MaxOverhead,
        m => bail!("bad mode {m} (vanilla|tc|mc)"),
    };
    let g = mlp_tower(layers as u32, width as u32, batch as u64);
    let ctx = build_context(&g, Family::Exact);
    let min_b = ctx.min_feasible_budget();
    let budget = match budget_frac {
        Some(f) => ((g.total_mem() as f64 * f) as u64).max(min_b),
        None => min_b,
    };
    let sol = ctx
        .solve(budget, obj)
        .ok_or_else(|| anyhow!("budget {} infeasible", fmt_bytes(budget)))?;
    ChainSchedule::from_chain(&g, &sol.chain)
}

/// Train `cfg` under each schedule in `modes`, each on a **fresh** trainer
/// from `make_trainer` so all runs share identical initial conditions.
/// Returns `(mode, report)` pairs in the order requested.
pub fn compare_schedules<B, F>(
    make_trainer: F,
    cfg: &TrainConfig,
    modes: &[&str],
    budget_frac: Option<f64>,
    quiet: bool,
) -> Result<Vec<(String, TrainReport)>>
where
    B: Backend,
    F: Fn() -> Result<TowerTrainer<B>>,
{
    let mut results = Vec::new();
    for &mode in modes {
        let mut trainer = make_trainer()?;
        let sched = schedule_for_mode(
            mode,
            cfg.layers,
            trainer.width(),
            trainer.batch(),
            budget_frac,
        )?;
        if !quiet {
            eprintln!(
                "== mode {mode} on {} backend: k={} segments ==",
                trainer.backend().name(),
                sched.segments.len()
            );
        }
        let report = trainer.train(&sched, cfg)?;
        results.push((mode.to_string(), report));
    }
    Ok(results)
}

/// Recomputation's defining property: two schedules of the same
/// computation must produce bitwise-comparable loss trajectories
/// (tolerance covers only float noise in the loss *reduction*, which is
/// itself recomputation-free — the default is exact equality in practice).
pub fn trajectories_identical(a: &TrainReport, b: &TrainReport) -> bool {
    a.losses.len() == b.losses.len()
        && a.losses
            .iter()
            .zip(&b.losses)
            .all(|(x, y)| (x - y).abs() <= 1e-6 * x.abs().max(1.0))
}

/// Measured comparison of one zoo model under vanilla vs planned
/// execution on the general DAG executor.
pub struct ZooComparison {
    /// Executable graph name (`ResNet50@exec32x64`-style).
    pub model: String,
    pub nodes: u32,
    /// Segments in the plan.
    pub k: usize,
    /// Planned recomputation overhead (Eq. 1 units).
    pub overhead: u64,
    /// Simulator-predicted peak for the plan (liveness off, activations).
    pub sim_peak: u64,
    pub vanilla: DagTrainReport,
    pub planned: DagTrainReport,
    /// One-step verification: loss and every parameter gradient of the
    /// planned execution are bit-identical to vanilla's.
    pub grads_match: bool,
    /// The executor's observed per-step live bytes equal the program's
    /// model prediction, and the observed peak equals `sim_peak`.
    pub peak_matches_sim: bool,
    /// Full-run loss trajectories are bit-identical.
    pub losses_identical: bool,
}

/// Bitwise comparison of two f32 sequences (`NaN`-safe: compares bits).
pub fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise comparison of two per-node gradient maps: same node set, and
/// every node's `(gw, gb)` identical bit for bit.
pub fn grad_maps_equal(a: &GradMap, b: &GradMap) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, (w0, b0))| {
            b.get(k).is_some_and(|(w1, b1)| bits_equal(w0, w1) && bits_equal(b0, b1))
        })
}

/// Lower zoo model `name` to `[batch, width]`, plan it under a
/// planner-chosen budget (minimal feasible, or `budget_frac` of total
/// activation memory), and train it under both vanilla and the planned
/// schedule on the native backend, verifying the executor's two core
/// invariants along the way (see [`ZooComparison`]).
pub fn train_zoo_model(
    name: &str,
    batch: usize,
    width: usize,
    cfg: &TrainConfig,
    budget_frac: Option<f64>,
    objective: Objective,
    quiet: bool,
) -> Result<ZooComparison> {
    let entry = zoo::find(name)
        .ok_or_else(|| anyhow!("unknown zoo model '{name}' (try resnet, unet, …)"))?;
    // Topology at batch 1 (shape metadata is replaced by the lowering).
    let g = recost(&entry.build_batch(1), batch, width);
    // ApproxDP is the paper's planner of choice at zoo scale (§4.3) —
    // exact enumeration on a 500-node DenseNet lattice is a bench, not a
    // CLI default.
    let ctx = build_context(&g, Family::Approx);
    let min_b = ctx.min_feasible_budget();
    let budget = match budget_frac {
        Some(f) => ((g.total_mem() as f64 * f) as u64).max(min_b),
        None => min_b,
    };
    let sol = ctx
        .solve(budget, objective)
        .ok_or_else(|| anyhow!("budget {} infeasible for {}", fmt_bytes(budget), g.name))?;
    let planned_prog = OpProgram::from_chain(&g, &sol.chain)?;
    let vanilla_prog = OpProgram::vanilla(&g)?;
    let sim_peak = simulate(&g, &sol.chain, SimOptions { liveness: false, include_params: false })
        .peak_bytes;
    if !quiet {
        eprintln!(
            "== zoo model {} ({} nodes): k={} segments, budget {} ==",
            g.name,
            g.len(),
            sol.chain.k(),
            fmt_bytes(budget)
        );
    }

    // One verification step on a shared batch: bit-exact loss/grads and
    // observed-vs-predicted memory.
    let mut task = SyntheticTask::new(batch, width, cfg.seed ^ 0xabcd);
    let (xv, yv) = task.next_batch();
    let mut tv = DagTrainer::new(NativeBackend::new(batch, width), &g, cfg.seed)?;
    let x = tv.backend().upload(&xv, &[batch, width])?;
    let y = tv.backend().upload(&yv, &[batch, width])?;
    let rv = tv.run_step(&vanilla_prog, &x, &y, cfg.lr, true)?;
    let mut tp = DagTrainer::new(NativeBackend::new(batch, width), &g, cfg.seed)?;
    let rp = tp.run_step(&planned_prog, &x, &y, cfg.lr, true)?;
    let (gv, gp) = (rv.grads.as_ref().unwrap(), rp.grads.as_ref().unwrap());
    let grads_match = rv.loss.to_bits() == rp.loss.to_bits() && grad_maps_equal(gv, gp);
    let peak_matches_sim = rp.observed_peak == sim_peak
        && rp.live_trajectory == planned_prog.predicted_live;

    // Fresh trainers for the reported runs (identical initial params).
    let mut tv = DagTrainer::new(NativeBackend::new(batch, width), &g, cfg.seed)?;
    let vanilla = tv.train(&vanilla_prog, cfg)?;
    let mut tp = DagTrainer::new(NativeBackend::new(batch, width), &g, cfg.seed)?;
    let planned = tp.train(&planned_prog, cfg)?;
    let losses_identical = bits_equal(&vanilla.losses, &planned.losses);

    Ok(ZooComparison {
        model: g.name.clone(),
        nodes: g.len(),
        k: sol.chain.k(),
        overhead: sol.overhead,
        sim_peak,
        vanilla,
        planned,
        grads_match,
        peak_matches_sim,
        losses_identical,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse() {
        assert_eq!(parse_modes("all").unwrap(), vec!["vanilla", "tc", "mc"]);
        assert_eq!(parse_modes("tc").unwrap(), vec!["tc"]);
        assert!(parse_modes("warp").is_err());
    }

    #[test]
    fn schedules_cover_the_tower() {
        for mode in ["vanilla", "tc", "mc"] {
            let s = schedule_for_mode(mode, 12, 64, 32, None).unwrap();
            assert_eq!(s.n_layers, 13);
            let mut pos = 0;
            for seg in &s.segments {
                assert_eq!(seg.start, pos);
                pos = seg.end;
            }
            assert_eq!(pos, 13, "{mode}");
        }
        // A planned schedule on a 12-layer tower must actually cut.
        assert!(schedule_for_mode("tc", 12, 64, 32, None).unwrap().segments.len() > 1);
    }

    #[test]
    fn bits_equal_is_exact_and_nan_safe() {
        assert!(bits_equal(&[1.0, -0.0], &[1.0, -0.0]));
        assert!(!bits_equal(&[0.0], &[-0.0]), "signed zero differs bitwise");
        assert!(bits_equal(&[f32::NAN], &[f32::NAN]), "same NaN bits compare equal");
        assert!(!bits_equal(&[1.0], &[1.0, 2.0]));
    }

    #[test]
    fn zoo_engine_verifies_unet_end_to_end() {
        let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
        let cmp =
            train_zoo_model("unet", 2, 4, &cfg, None, Objective::MinOverhead, true).unwrap();
        assert!(cmp.grads_match, "planned grads must be bit-identical to vanilla");
        assert!(cmp.peak_matches_sim, "observed peak must equal the sim prediction");
        assert!(cmp.losses_identical);
        assert!(cmp.planned.observed_peak < cmp.vanilla.observed_peak);
        assert!(cmp.planned.recomputes_per_step > 0);
    }

    #[test]
    fn native_compare_runs_all_modes() {
        let cfg = TrainConfig { layers: 6, steps: 2, lr: 0.05, seed: 9, log_every: 0 };
        let results = compare_schedules(
            || TowerTrainer::native(4, 16, &cfg),
            &cfg,
            &["vanilla", "tc"],
            None,
            true,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        assert!(trajectories_identical(&results[0].1, &results[1].1));
        assert!(results[1].1.peak_bytes < results[0].1.peak_bytes);
    }
}
