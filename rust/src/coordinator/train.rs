//! Backend-generic schedule comparison — the shared engine behind
//! `repro train` and `examples/train_mlp`.
//!
//! Two engines share this module:
//!
//! - the tower engine ([`compare_schedules`]): given a way to construct a
//!   fresh [`TowerTrainer`] (fresh = identical initial parameters, so
//!   loss trajectories are comparable bitwise), runs the same training
//!   configuration under a set of schedules (vanilla / time-centric /
//!   memory-centric) and returns the measured reports;
//! - the zoo engine ([`train_zoo_model`]): lowers any zoo topology to the
//!   *heterogeneous* executable form (per-node widths from the model's
//!   own `M_v` profile, see
//!   [`crate::models::executable::recost_profiled`]), plans it, compiles
//!   vanilla and planned [`OpProgram`]s under the requested
//!   [`SimMode`] (liveness by default), verifies loss + parameter
//!   gradients are bit-identical and the liveness invariant chain —
//!   observed peak == mode-predicted peak (equality) ≤ no-liveness
//!   peak — then trains both and reports.
//!
//! Budgets for planned schedules are described by [`BudgetSpec`]:
//! minimal-feasible (the default), an absolute byte count (`--budget
//! 512KiB`), or a fraction of total activation memory (`--budget-frac`).
//! Absolute budgets below the graph's minimal feasible budget error out
//! *naming* that minimum, so an infeasible request is actionable.

use crate::anyhow::{anyhow, bail, Result};
use crate::exec::{
    ChainSchedule, DagTask, DagTrainReport, DagTrainer, GradMap, OpProgram, TowerTrainer,
    TrainConfig, TrainReport,
};
use crate::fmt_bytes;
use crate::graph::Graph;
use crate::models::executable::{distinct_act_sizes, recost_profiled};
use crate::models::{mlp_tower, zoo};
use crate::planner::{build_context, DpContext, Family, Objective};
use crate::runtime::NativeBackend;
use crate::sim::{canonical_trace, measure, SimMode, SimOptions};

/// How the activation budget for a planned schedule is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BudgetSpec {
    /// Plan at the minimal feasible budget B*.
    MinFeasible,
    /// Absolute activation budget in bytes. Errors (naming B*) if the
    /// graph cannot be executed under it.
    Bytes(u64),
    /// Fraction of the graph's total activation memory, clamped up to
    /// B* (a fraction can never make the problem infeasible).
    Frac(f64),
}

impl BudgetSpec {
    /// Resolve the spec against a planning context. Infeasible absolute
    /// budgets report the graph's `min_feasible_budget` instead of a
    /// bare failure.
    pub fn resolve(self, g: &Graph, ctx: &DpContext) -> Result<u64> {
        let min_b = ctx.min_feasible_budget();
        match self {
            BudgetSpec::MinFeasible => Ok(min_b),
            BudgetSpec::Frac(f) => Ok(((g.total_mem() as f64 * f) as u64).max(min_b)),
            BudgetSpec::Bytes(b) if b < min_b => bail!(
                "budget {} infeasible for {}: min_feasible_budget = {}",
                fmt_bytes(b),
                g.name,
                fmt_bytes(min_b)
            ),
            BudgetSpec::Bytes(b) => Ok(b),
        }
    }
}

/// Parse a `--mode` value into the schedule list to run.
pub fn parse_modes(mode: &str) -> Result<Vec<&'static str>> {
    Ok(match mode {
        "all" => vec!["vanilla", "tc", "mc"],
        "vanilla" => vec!["vanilla"],
        "tc" => vec!["tc"],
        "mc" => vec!["mc"],
        m => bail!("bad mode {m} (vanilla|tc|mc|all)"),
    })
}

/// Build the executable schedule for one mode over a `layers`-deep MLP
/// tower at `(batch, width)`, planning under `budget`.
pub fn schedule_for_mode(
    mode: &str,
    layers: usize,
    width: usize,
    batch: usize,
    budget: BudgetSpec,
) -> Result<ChainSchedule> {
    if mode == "vanilla" {
        return Ok(ChainSchedule::vanilla(layers + 1));
    }
    let obj = match mode {
        "tc" => Objective::MinOverhead,
        "mc" => Objective::MaxOverhead,
        m => bail!("bad mode {m} (vanilla|tc|mc)"),
    };
    let g = mlp_tower(layers as u32, width as u32, batch as u64);
    let ctx = build_context(&g, Family::Exact);
    let budget = budget.resolve(&g, &ctx)?;
    let sol = ctx.solve(budget, obj).ok_or_else(|| {
        anyhow!(
            "budget {} infeasible: min_feasible_budget = {}",
            fmt_bytes(budget),
            fmt_bytes(ctx.min_feasible_budget())
        )
    })?;
    ChainSchedule::from_chain(&g, &sol.chain)
}

/// Train `cfg` under each schedule in `modes`, each on a **fresh** trainer
/// from `make_trainer` so all runs share identical initial conditions.
/// Returns `(mode, report)` pairs in the order requested.
pub fn compare_schedules<B, F>(
    make_trainer: F,
    cfg: &TrainConfig,
    modes: &[&str],
    budget: BudgetSpec,
    quiet: bool,
) -> Result<Vec<(String, TrainReport)>>
where
    B: crate::runtime::Backend,
    F: Fn() -> Result<TowerTrainer<B>>,
{
    let mut results = Vec::new();
    for &mode in modes {
        let mut trainer = make_trainer()?;
        let sched =
            schedule_for_mode(mode, cfg.layers, trainer.width(), trainer.batch(), budget)?;
        if !quiet {
            eprintln!(
                "== mode {mode} on {} backend: k={} segments ==",
                trainer.backend().name(),
                sched.segments.len()
            );
        }
        let report = trainer.train(&sched, cfg)?;
        results.push((mode.to_string(), report));
    }
    Ok(results)
}

/// Recomputation's defining property: two schedules of the same
/// computation must produce bitwise-comparable loss trajectories
/// (tolerance covers only float noise in the loss *reduction*, which is
/// itself recomputation-free — the default is exact equality in practice).
pub fn trajectories_identical(a: &TrainReport, b: &TrainReport) -> bool {
    a.losses.len() == b.losses.len()
        && a.losses
            .iter()
            .zip(&b.losses)
            .all(|(x, y)| (x - y).abs() <= 1e-6 * x.abs().max(1.0))
}

/// Measured comparison of one zoo model under vanilla vs planned
/// execution on the general DAG executor.
pub struct ZooComparison {
    /// Executable graph name (`ResNet50@exec32xw64het`-style).
    pub model: String,
    pub nodes: u32,
    /// Segments in the plan.
    pub k: usize,
    /// Planned recomputation overhead (Eq. 1 units).
    pub overhead: u64,
    /// Free schedule both programs were compiled under.
    pub mode: SimMode,
    /// Simulator-predicted peak for the plan under `mode` (activations).
    pub sim_peak: u64,
    /// Simulator-predicted peak for the plan with liveness off — the
    /// Table 2 ablation the liveness peak must never exceed.
    pub sim_peak_strict: u64,
    /// Number of distinct per-node activation byte-sizes in the lowered
    /// graph — ≥ 2 means the heterogeneous lowering is real (the planner
    /// is cutting a non-uniform memory profile).
    pub distinct_act_bytes: usize,
    /// Smallest and largest per-node activation bytes.
    pub act_bytes_range: (u64, u64),
    pub vanilla: DagTrainReport,
    pub planned: DagTrainReport,
    /// One-step verification: loss and every parameter gradient of the
    /// planned execution are bit-identical to vanilla's.
    pub grads_match: bool,
    /// The executor's observed per-step live bytes equal the program's
    /// model prediction, the observed peak equals `sim_peak` (an
    /// equality), and `sim_peak ≤ sim_peak_strict` — the full liveness
    /// invariant chain.
    pub peak_matches_sim: bool,
    /// Full-run loss trajectories are bit-identical.
    pub losses_identical: bool,
}

/// Bitwise comparison of two f32 sequences (`NaN`-safe: compares bits).
pub fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise comparison of two per-node gradient maps: same node set, and
/// every node's `(gw, gb)` identical bit for bit.
pub fn grad_maps_equal(a: &GradMap, b: &GradMap) -> bool {
    a.len() == b.len()
        && a.iter().all(|(k, (w0, b0))| {
            b.get(k).is_some_and(|(w1, b1)| bits_equal(w0, w1) && bits_equal(b0, b1))
        })
}

/// Lower zoo model `name` to heterogeneous `[batch, width_v]` tensors
/// (per-node widths from the model's `M_v` profile, capped at
/// `max_width`), plan it under `budget`, and train it under both vanilla
/// and the planned schedule on the native backend, verifying the
/// executor's two core invariants along the way (see [`ZooComparison`]).
/// Both programs are compiled under `mode` (liveness by default — the
/// paper's Table 1 measurement; strict reproduces the Table 2 ablation).
pub fn train_zoo_model(
    name: &str,
    batch: usize,
    max_width: usize,
    cfg: &TrainConfig,
    budget: BudgetSpec,
    objective: Objective,
    mode: SimMode,
    quiet: bool,
) -> Result<ZooComparison> {
    let entry = zoo::find(name)
        .ok_or_else(|| anyhow!("unknown zoo model '{name}' (try resnet, unet, …)"))?;
    // Topology at batch 1 (shape metadata is replaced by the lowering —
    // only the relative M_v profile survives, as per-node widths).
    let g = recost_profiled(&entry.build_batch(1), batch, max_width);
    let act_sizes = distinct_act_sizes(&g);
    let act_bytes_range = (act_sizes[0], *act_sizes.last().unwrap());
    let distinct_act_bytes = act_sizes.len();
    // Gate *before* planning or training: a degenerate width cap makes
    // every node the same size, which defeats the whole point of the
    // heterogeneous lowering — fail in milliseconds, not after the runs.
    if distinct_act_bytes < 2 {
        bail!(
            "heterogeneous lowering degenerated to uniform shapes on {} \
             (max width {max_width} — try a larger --width)",
            g.name
        );
    }
    // ApproxDP is the paper's planner of choice at zoo scale (§4.3) —
    // exact enumeration on a 500-node DenseNet lattice is a bench, not a
    // CLI default.
    let ctx = build_context(&g, Family::Approx);
    let budget = budget.resolve(&g, &ctx)?;
    let sol = ctx.solve(budget, objective).ok_or_else(|| {
        anyhow!(
            "budget {} infeasible for {}: min_feasible_budget = {}",
            fmt_bytes(budget),
            g.name,
            fmt_bytes(ctx.min_feasible_budget())
        )
    })?;
    // One trace drives everything: the compiled program's typed drop
    // steps and the simulator's predicted peak come from the same
    // (mode-rewritten) event stream, so "observed == predicted" is an
    // equality between two views of one schedule — not two accountings.
    let tr = canonical_trace(&g, &sol.chain);
    let planned_prog = OpProgram::from_trace(&g, &tr, mode)?;
    let vanilla_prog = OpProgram::vanilla(&g, mode)?;
    let sim_peak = measure(&g, &tr, SimOptions { mode, include_params: false }).peak_bytes;
    let sim_peak_strict =
        measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false }).peak_bytes;
    if !quiet {
        eprintln!(
            "== zoo model {} ({} nodes, {} distinct activation sizes): k={} segments, \
             budget {}, sim {} ==",
            g.name,
            g.len(),
            distinct_act_bytes,
            sol.chain.k(),
            fmt_bytes(budget),
            mode.label()
        );
    }

    // One verification step on a shared batch: bit-exact loss/grads and
    // observed-vs-predicted memory.
    let mut task = DagTask::for_graph(&g, batch, cfg.seed ^ 0xabcd);
    let (xv, yv) = task.next_batch();
    let mut tv = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let (x, targets) = tv.upload_batch(&xv, &yv)?;
    let rv = tv.run_step(&vanilla_prog, &x, &targets, cfg.lr, true)?;
    let mut tp = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let rp = tp.run_step(&planned_prog, &x, &targets, cfg.lr, true)?;
    let (gv, gp) = (rv.grads.as_ref().unwrap(), rp.grads.as_ref().unwrap());
    let grads_match = rv.loss.to_bits() == rp.loss.to_bits() && grad_maps_equal(gv, gp);
    let peak_matches_sim = rp.observed_peak == sim_peak
        && rp.live_trajectory == planned_prog.predicted_live
        && sim_peak <= sim_peak_strict;

    // Fresh trainers for the reported runs (identical initial params).
    let mut tv = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let vanilla = tv.train(&vanilla_prog, cfg)?;
    let mut tp = DagTrainer::new(NativeBackend::new(), &g, batch, cfg.seed)?;
    let planned = tp.train(&planned_prog, cfg)?;
    let losses_identical = bits_equal(&vanilla.losses, &planned.losses);

    Ok(ZooComparison {
        model: g.name.clone(),
        nodes: g.len(),
        k: sol.chain.k(),
        overhead: sol.overhead,
        mode,
        sim_peak,
        sim_peak_strict,
        distinct_act_bytes,
        act_bytes_range,
        vanilla,
        planned,
        grads_match,
        peak_matches_sim,
        losses_identical,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse() {
        assert_eq!(parse_modes("all").unwrap(), vec!["vanilla", "tc", "mc"]);
        assert_eq!(parse_modes("tc").unwrap(), vec!["tc"]);
        assert!(parse_modes("warp").is_err());
    }

    #[test]
    fn schedules_cover_the_tower() {
        for mode in ["vanilla", "tc", "mc"] {
            let s = schedule_for_mode(mode, 12, 64, 32, BudgetSpec::MinFeasible).unwrap();
            assert_eq!(s.n_layers, 13);
            let mut pos = 0;
            for seg in &s.segments {
                assert_eq!(seg.start, pos);
                pos = seg.end;
            }
            assert_eq!(pos, 13, "{mode}");
        }
        // A planned schedule on a 12-layer tower must actually cut.
        assert!(
            schedule_for_mode("tc", 12, 64, 32, BudgetSpec::MinFeasible)
                .unwrap()
                .segments
                .len()
                > 1
        );
    }

    #[test]
    fn absolute_budget_below_min_names_the_minimum() {
        let err = schedule_for_mode("tc", 12, 64, 32, BudgetSpec::Bytes(1)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("infeasible"), "{msg}");
        assert!(msg.contains("min_feasible_budget"), "{msg}");
    }

    #[test]
    fn bits_equal_is_exact_and_nan_safe() {
        assert!(bits_equal(&[1.0, -0.0], &[1.0, -0.0]));
        assert!(!bits_equal(&[0.0], &[-0.0]), "signed zero differs bitwise");
        assert!(bits_equal(&[f32::NAN], &[f32::NAN]), "same NaN bits compare equal");
        assert!(!bits_equal(&[1.0], &[1.0, 2.0]));
    }

    #[test]
    fn zoo_engine_verifies_unet_end_to_end() {
        let cfg = TrainConfig { layers: 0, steps: 2, lr: 0.02, seed: 11, log_every: 0 };
        let cmp = train_zoo_model(
            "unet",
            2,
            8,
            &cfg,
            BudgetSpec::MinFeasible,
            Objective::MinOverhead,
            SimMode::Liveness,
            true,
        )
        .unwrap();
        assert_eq!(cmp.mode, SimMode::Liveness);
        assert!(cmp.grads_match, "planned grads must be bit-identical to vanilla");
        assert!(cmp.peak_matches_sim, "observed peak must equal the sim prediction");
        assert!(cmp.sim_peak <= cmp.sim_peak_strict, "liveness never exceeds strict");
        assert!(cmp.losses_identical);
        assert!(cmp.planned.observed_peak < cmp.vanilla.observed_peak);
        assert!(cmp.planned.recomputes_per_step > 0);
        assert!(
            cmp.distinct_act_bytes >= 2,
            "heterogeneous lowering must produce ≥ 2 activation sizes"
        );
        assert!(cmp.act_bytes_range.0 < cmp.act_bytes_range.1);
        // The liveness schedule's churn exercised the backend pool.
        let pool = cmp.planned.pool.expect("native backend pools");
        assert!(pool.reuses > 0, "pool must recycle under the liveness schedule");
    }

    #[test]
    fn native_compare_runs_all_modes() {
        let cfg = TrainConfig { layers: 6, steps: 2, lr: 0.05, seed: 9, log_every: 0 };
        let results = compare_schedules(
            || TowerTrainer::native(4, 16, &cfg),
            &cfg,
            &["vanilla", "tc"],
            BudgetSpec::MinFeasible,
            true,
        )
        .unwrap();
        assert_eq!(results.len(), 2);
        assert!(trajectories_identical(&results[0].1, &results[1].1));
        assert!(results[1].1.peak_bytes < results[0].1.peak_bytes);
    }
}
