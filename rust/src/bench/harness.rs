//! Minimal timing harness (no `criterion` available offline).
//!
//! Warmup + N timed iterations, reporting min/median/mean/max. Used by the
//! `benches/` binaries and the CLI's `timing` subcommand.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Timing statistics over a set of iterations.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} iters={:<3} min={:>10.3?} median={:>10.3?} mean={:>10.3?} max={:>10.3?}",
            self.name, self.iters, self.min, self.median, self.mean, self.max
        )
    }

    /// Machine-readable form for the `BENCH_*.json` perf-trajectory files.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str().into())
            .set("iters", (self.iters as u64).into())
            .set("min_ms", (self.min.as_secs_f64() * 1e3).into())
            .set("median_ms", (self.median.as_secs_f64() * 1e3).into())
            .set("mean_ms", (self.mean.as_secs_f64() * 1e3).into())
            .set("max_ms", (self.max.as_secs_f64() * 1e3).into())
    }
}

/// Serialize a bench suite as the standard `BENCH_*.json` document:
/// `{"suite": …, "results": [BenchStats…]}` (deterministic key order via
/// `util::json`), so the perf trajectory diffs cleanly across PRs.
pub fn bench_report_json(suite: &str, stats: &[BenchStats]) -> Json {
    Json::obj()
        .set("suite", suite.into())
        .set("results", Json::Arr(stats.iter().map(BenchStats::to_json).collect()))
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
/// The closure's return value is passed through `std::hint::black_box` so
/// the work is not optimized away.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchStats {
        name: name.to_string(),
        iters,
        min: samples[0],
        median: samples[iters / 2],
        mean,
        max: samples[iters - 1],
    }
}

/// Time a single run (for expensive planners where one run is the bench).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordered() {
        let s = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.iters, 5);
        assert!(s.summary().contains("spin"));
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn bench_json_is_parseable_and_complete() {
        let s = bench("one", 0, 3, || 1 + 1);
        let doc = bench_report_json("unit", &[s.clone(), s]);
        let reparsed = crate::util::json::Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(reparsed.get("suite").as_str(), Some("unit"));
        let results = reparsed.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").as_str(), Some("one"));
        assert_eq!(results[0].get("iters").as_u64(), Some(3));
        assert!(results[0].get("mean_ms").as_f64().unwrap() >= 0.0);
    }
}
