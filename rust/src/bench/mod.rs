//! Benchmark harnesses: timing utilities and the table/figure generators
//! for the paper's evaluation section.

pub mod harness;
pub mod tables;

pub use harness::{bench, time_once, BenchStats};
