//! Benchmark harnesses: timing utilities and the table/figure generators
//! for the paper's evaluation section.

pub mod harness;
pub mod tables;

pub use harness::{bench, bench_report_json, time_once, BenchStats};
