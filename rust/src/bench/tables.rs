//! Harnesses regenerating every table and figure of the paper's evaluation.
//!
//! - [`table1`] — peak memory with liveness analysis (paper Table 1).
//! - [`table2`] — ablation without liveness analysis (paper Table 2).
//! - [`figure3`] — batch-size vs total-runtime tradeoff (paper Figure 3).
//! - [`planner_timing`] — §5.1 ExactDP-vs-ApproxDP runtime claim.
//!
//! Peak-memory numbers come from the event-accurate simulator; absolute
//! bytes differ from the paper's CUDA measurements, so every report prints
//! the *reduction* relative to vanilla next to the paper's reduction — the
//! quantity the paper's conclusions rest on.

use std::time::Duration;

use crate::fmt_bytes;
use crate::graph::Graph;
use crate::models::zoo::{ZooEntry, TABLE1};
use crate::planner::{
    build_context, chen_plan, plan_with_context, Family, LowerSetChain, Objective, PlannerKind,
};
use crate::sim::{simulate, simulate_vanilla, SimMode, SimOptions, SimReport};
use crate::util::table::Table;

use super::harness::time_once;

/// One measured cell: peak bytes including parameters.
#[derive(Clone, Copy, Debug)]
pub struct Cell {
    pub peak_total: u64,
    pub overhead: u64,
}

/// One measured row of Table 1/2.
#[derive(Clone, Debug)]
pub struct Row {
    pub name: &'static str,
    pub nodes: u32,
    pub batch: u64,
    pub approx_mc: Cell,
    pub approx_tc: Cell,
    pub exact_mc: Cell,
    pub exact_tc: Cell,
    pub chen: Cell,
    pub vanilla: Cell,
    /// Wall-clock of the exact-DP planning (context + budget + solves).
    pub exact_time: Duration,
    /// Wall-clock of the approx-DP planning.
    pub approx_time: Duration,
}

fn cell(g: &Graph, chain: &LowerSetChain, liveness: bool) -> Cell {
    let opts = SimOptions { mode: SimMode::from_liveness(liveness), include_params: true };
    let r = simulate(g, chain, opts);
    Cell { peak_total: r.peak_total, overhead: r.overhead_time }
}

/// Measure one zoo network under all five methods.
pub fn measure_row(e: &ZooEntry, liveness: bool) -> Row {
    let g = e.build_paper();
    let opts = SimOptions { mode: SimMode::from_liveness(liveness), include_params: true };

    let ((approx_mc, approx_tc), approx_time) = time_once(|| {
        let ctx = build_context(&g, Family::Approx);
        let b = ctx.min_feasible_budget();
        let mc =
            plan_with_context(&g, &ctx, PlannerKind::ApproxDp, b, Objective::MaxOverhead).unwrap();
        let tc =
            plan_with_context(&g, &ctx, PlannerKind::ApproxDp, b, Objective::MinOverhead).unwrap();
        (cell(&g, &mc.chain, liveness), cell(&g, &tc.chain, liveness))
    });

    let ((exact_mc, exact_tc), exact_time) = time_once(|| {
        let ctx = build_context(&g, Family::Exact);
        let b = ctx.min_feasible_budget();
        let mc =
            plan_with_context(&g, &ctx, PlannerKind::ExactDp, b, Objective::MaxOverhead).unwrap();
        let tc =
            plan_with_context(&g, &ctx, PlannerKind::ExactDp, b, Objective::MinOverhead).unwrap();
        (cell(&g, &mc.chain, liveness), cell(&g, &tc.chain, liveness))
    });

    // Chen: sweep segment budgets, score each candidate segmentation with
    // the same simulator mode used for the report.
    let chen = {
        let plan = chen_plan(&g, |c| simulate(&g, c, opts).peak_total).unwrap();
        cell(&g, &plan.chain, liveness)
    };

    // Vanilla always keeps its framework-native eager freeing (Appendix C:
    // "the vanilla run of Chainer conducts some local memory reduction by
    // default") — the liveness toggle applies to the *strategies* only.
    let vanilla = {
        let r: SimReport =
            simulate_vanilla(&g, SimOptions { mode: SimMode::Liveness, include_params: true });
        Cell { peak_total: r.peak_total, overhead: 0 }
    };

    Row {
        name: e.name,
        nodes: g.len(),
        batch: e.batch,
        approx_mc,
        approx_tc,
        exact_mc,
        exact_tc,
        chen,
        vanilla,
        exact_time,
        approx_time,
    }
}

fn pct(peak: u64, vanilla: u64) -> String {
    let red = 100.0 * (1.0 - peak as f64 / vanilla as f64);
    format!("{red:+.0}%").replace('+', "-") // reductions are negative in the paper
}

fn fmt_cell(c: Cell, vanilla: u64) -> String {
    format!("{} ({})", fmt_bytes(c.peak_total), pct(c.peak_total, vanilla))
}

/// Render Table 1 (liveness on) or Table 2 (liveness off).
pub fn render_table(liveness: bool, entries: &[ZooEntry]) -> (String, Vec<Row>) {
    let mut t = Table::new(&[
        "Network",
        "ApproxDP+MC",
        "ApproxDP+TC",
        "ExactDP+MC",
        "ExactDP+TC",
        "Chen's",
        "Vanilla",
        "#V",
        "Batch",
        "paperMC%",
    ])
    .numeric();
    let mut rows = Vec::new();
    for e in entries {
        let r = measure_row(e, liveness);
        let v = r.vanilla.peak_total;
        let paper_mc = format!(
            "-{:.0}%",
            100.0 * (1.0 - e.paper.approx_mc_gb / e.paper.vanilla_gb)
        );
        t.row(vec![
            r.name.to_string(),
            fmt_cell(r.approx_mc, v),
            fmt_cell(r.approx_tc, v),
            fmt_cell(r.exact_mc, v),
            fmt_cell(r.exact_tc, v),
            fmt_cell(r.chen, v),
            fmt_bytes(v),
            r.nodes.to_string(),
            r.batch.to_string(),
            paper_mc,
        ]);
        rows.push(r);
    }
    (t.render(), rows)
}

/// §5.1 planner-runtime comparison: ExactDP vs ApproxDP wall-clock.
pub fn planner_timing(entries: &[ZooEntry]) -> String {
    let mut t = Table::new(&["Network", "#V", "#L_exact", "ExactDP", "ApproxDP"]).numeric();
    for e in entries {
        let g = e.build_paper();
        let (n_exact, _) = time_once(|| {
            crate::graph::enumerate_lower_sets(&g, crate::graph::EnumerationLimit::default())
                .map(|f| f.len())
        });
        let (_, exact_d) = time_once(|| {
            let ctx = build_context(&g, Family::Exact);
            let b = ctx.min_feasible_budget();
            ctx.solve(b, Objective::MinOverhead)
        });
        let (_, approx_d) = time_once(|| {
            let ctx = build_context(&g, Family::Approx);
            let b = ctx.min_feasible_budget();
            ctx.solve(b, Objective::MinOverhead)
        });
        t.row(vec![
            e.name.to_string(),
            g.len().to_string(),
            n_exact.map(|n| n.to_string()).unwrap_or_else(|| ">cap".into()),
            format!("{exact_d:.2?}"),
            format!("{approx_d:.2?}"),
        ]);
    }
    t.render()
}

/// One point of a Figure 3 series.
#[derive(Clone, Debug)]
pub struct Fig3Point {
    pub batch: u64,
    /// Total runtime in cost-model units (`batch × (3·T(V) + overhead)`).
    pub runtime_units: u64,
    /// Peak memory incl. params at this batch.
    pub peak_total: u64,
    pub feasible: bool,
}

/// One method's series for one network.
#[derive(Clone, Debug)]
pub struct Fig3Series {
    pub method: &'static str,
    pub points: Vec<Fig3Point>,
}

/// The device memory of the paper's K40c.
pub const DEVICE_BYTES: u64 = (114u64 << 30) / 10; // 11.4 GB

/// Sweep batch sizes for one network, producing the four Figure 3 curves:
/// vanilla, ApproxDP+TC, ApproxDP+MC, Chen.
pub fn figure3_network(e: &ZooEntry, batches: &[u64], device: u64) -> Vec<Fig3Series> {
    let mut vanilla = Vec::new();
    let mut tc = Vec::new();
    let mut mc = Vec::new();
    let mut chen = Vec::new();
    for &batch in batches {
        let g = e.build_batch(batch);
        let fwd = g.total_time();
        let base = 3 * fwd; // fwd + 2×bwd per sample-batch
        let params = g.total_param_bytes();
        let liveness = SimOptions { mode: SimMode::Liveness, include_params: true };

        // Vanilla.
        let v = simulate_vanilla(&g, liveness);
        vanilla.push(Fig3Point {
            batch,
            runtime_units: batch * base,
            peak_total: v.peak_total,
            feasible: v.peak_total <= device,
        });

        // ApproxDP at the device budget (activations budget = device − params).
        let ctx = build_context(&g, Family::Approx);
        let act_budget = device.saturating_sub(params);
        for (out, obj) in
            [(&mut tc, Objective::MinOverhead), (&mut mc, Objective::MaxOverhead)]
        {
            match ctx.solve(act_budget, obj) {
                Some(sol) => {
                    let r = simulate(&g, &sol.chain, liveness);
                    out.push(Fig3Point {
                        batch,
                        runtime_units: batch * (base + sol.overhead),
                        peak_total: r.peak_total,
                        feasible: r.peak_total <= device,
                    });
                }
                None => out.push(Fig3Point {
                    batch,
                    runtime_units: 0,
                    peak_total: u64::MAX,
                    feasible: false,
                }),
            }
        }

        // Chen.
        let cplan = chen_plan(&g, |c| simulate(&g, c, liveness).peak_total).unwrap();
        let r = simulate(&g, &cplan.chain, liveness);
        chen.push(Fig3Point {
            batch,
            runtime_units: batch * (base + r.overhead_time),
            peak_total: r.peak_total,
            feasible: r.peak_total <= device,
        });
    }
    vec![
        Fig3Series { method: "Vanilla", points: vanilla },
        Fig3Series { method: "ApproxDP+TC", points: tc },
        Fig3Series { method: "ApproxDP+MC", points: mc },
        Fig3Series { method: "Chen's", points: chen },
    ]
}

/// Render one network's Figure 3 sweep as a table of series.
pub fn render_figure3(e: &ZooEntry, batches: &[u64], device: u64) -> String {
    let series = figure3_network(e, batches, device);
    let mut t = Table::new(&["Batch", "Vanilla", "ApproxDP+TC", "ApproxDP+MC", "Chen's"]).numeric();
    for (i, &batch) in batches.iter().enumerate() {
        let cell = |s: &Fig3Series| -> String {
            let p = &s.points[i];
            if p.feasible {
                format!("{} ({})", p.runtime_units, fmt_bytes(p.peak_total))
            } else {
                "OOM".to_string()
            }
        };
        t.row(vec![
            batch.to_string(),
            cell(&series[0]),
            cell(&series[1]),
            cell(&series[2]),
            cell(&series[3]),
        ]);
    }
    format!("== Figure 3: {} (device {}) ==\n{}", e.name, fmt_bytes(device), t.render())
}

/// Default batch sweep for a network: powers-of-two-ish ladder from the
/// paper batch down/up.
pub fn default_batches(e: &ZooEntry) -> Vec<u64> {
    let b = e.batch;
    [b / 2, b, b * 2, b * 3, b * 4, b * 6, b * 8]
        .into_iter()
        .filter(|&x| x >= 1)
        .collect()
}

/// All Table-1 zoo entries.
pub fn zoo() -> &'static [ZooEntry] {
    TABLE1
}
