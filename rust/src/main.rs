//! `repro` — the L3 coordinator / launcher CLI.
//!
//! Subcommands:
//!
//! - `table1` / `table2` — regenerate the paper's Tables 1 & 2 (peak
//!   memory across the zoo, with/without liveness analysis).
//! - `figure3 [--network NAME] [--device GB]` — the batch-vs-runtime
//!   tradeoff sweeps of Figure 3.
//! - `timing` — §5.1 ExactDP vs ApproxDP planner wall-clock.
//! - `plan --network NAME [--batch N] [--budget GB|512KiB] [--objective
//!    tc|mc] [--planner exact|approx|chen|exhaustive|decomposed]
//!    [--sim liveness|strict] [--json] [--threads N] [--stats]` —
//!    plan one network and print the schedule (budgets: bare number = GB,
//!    or human-readable bytes; `--planner decomposed` splits at the
//!    graph's gate vertices and solves per-component — the scalable way
//!    to get exact-quality plans on deep networks; `--family
//!    exact|approx` and `--chen` remain as back-compat aliases;
//!    `--sim strict` reproduces the Table 2
//!    no-liveness ablation, default is the Table 1 liveness measurement;
//!    `--json` emits the compiled-plan summary as machine-readable JSON;
//!    `--threads` sets the planner worker-pool width, overriding
//!    `REPRO_THREADS` — plans are bit-identical at any thread count;
//!    `--stats` prints the session counters + planner wall-time).
//! - `plan --graph FILE.json …` — plan a user-supplied graph.
//! - `audit --network NAME [--planner P] [--sim M] [--budget B]
//!    [--json] [--deny-audit]` — compile a plan and print the static
//!    schedule auditor's findings (see `recompute::analysis`): the
//!    dataflow sweep that proves the compiled schedule frees what it
//!    allocates, never touches freed buffers, and lands exactly on the
//!    simulator's predicted peak. `--deny-audit` escalates warnings to
//!    hard errors (non-zero exit).
//! - `train …` — run the real training executor (see `exec`) on the
//!   pure-Rust native backend by default, or PJRT with `--features xla`;
//!   `repro train --help` for its flags.
//! - `export --network NAME --out FILE.json` — dump a zoo graph as JSON.
//! - `serve [--addr HOST:PORT] …` — long-running plan-serving daemon:
//!   newline-delimited JSON over TCP, many concurrent clients sharing
//!   one plan cache (`repro serve --help` for its flags; see the
//!   `recompute::serve` module docs for the protocol).

use std::process::ExitCode;

use recompute::anyhow::{anyhow, bail, Context, Result};

use recompute::bench::tables;
use recompute::coordinator;
use recompute::coordinator::report::{
    decomposition_json, session_json, session_summary, timing_summary,
};
use recompute::graph::Graph;
use recompute::{fmt_bytes, parse_budget};
use recompute::models::zoo;
use recompute::planner::{BudgetSpec, Family, Objective, PlanRequest, PlannerId};
use recompute::session::PlanSession;
use recompute::sim::{simulate_vanilla, SimMode, SimOptions};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Flags<'a> {
    rest: &'a [String],
}

impl<'a> Flags<'a> {
    fn get(&self, key: &str) -> Option<&'a str> {
        self.rest
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.rest.get(i + 1))
            .map(String::as_str)
    }

    fn has(&self, key: &str) -> bool {
        self.rest.iter().any(|a| a == key)
    }

    fn parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => {
                s.parse::<T>().map(Some).map_err(|e| anyhow!("bad value for {key}: {e}"))
            }
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags { rest: &args[1..] };
    match cmd.as_str() {
        "table1" => cmd_table(true),
        "table2" => cmd_table(false),
        "figure3" => cmd_figure3(&flags),
        "timing" => {
            println!("== §5.1 planner wall-clock (ExactDP vs ApproxDP) ==");
            println!("{}", tables::planner_timing(tables::zoo()));
            Ok(())
        }
        "plan" => cmd_plan(&flags),
        "audit" => cmd_audit(&flags),
        "experiment" => cmd_experiment(&flags),
        "export" => cmd_export(&flags),
        "train" => coordinator::cli::cmd_train(&args[1..]),
        "serve" => recompute::serve::cmd_serve(&args[1..]),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'repro help')"),
    }
}

fn print_usage() {
    println!(
        "repro — graph-theoretic recomputation for memory-efficient backprop\n\
         (Kusumoto et al., NeurIPS 2019)\n\n\
         USAGE: repro <SUBCOMMAND> [flags]\n\n\
         SUBCOMMANDS:\n\
           table1                        regenerate paper Table 1 (with liveness)\n\
           table2                        regenerate paper Table 2 (no liveness)\n\
           figure3 [--network N] [--device GB]   batch-vs-runtime sweeps\n\
           timing                        ExactDP vs ApproxDP planner runtime (§5.1)\n\
           plan --network N [--batch B] [--budget GB|512KiB]\n\
                [--objective tc|mc]\n\
                [--planner exact|approx|chen|exhaustive|decomposed]\n\
                [--family exact|approx] [--chen]  (back-compat aliases)\n\
                [--sim liveness|strict] [--json] [--threads N] [--stats]\n\
           plan --graph FILE.json [...]  plan a user-supplied graph JSON\n\
           audit --network N [--batch B] [--budget GB|512KiB]\n\
                [--planner exact|approx|chen|exhaustive|decomposed]\n\
                [--objective tc|mc] [--sim liveness|strict]\n\
                [--json] [--deny-audit]\n\
                                         static schedule audit of a compiled plan\n\
           experiment --config F.json [--csv out.csv]  declarative sweep runner\n\
           export --network N --out F    dump a zoo graph as JSON\n\
           train [flags]                 real training with a recompute plan\n\
                                         (--model tower or any zoo name, e.g.\n\
                                         'train --model resnet'; native backend by\n\
                                         default, --backend pjrt needs --features\n\
                                         xla; 'repro train --help')\n\
           serve [--addr HOST:PORT]      plan-serving daemon: JSON lines over TCP,\n\
                                         concurrent clients, shared plan cache\n\
                                         ('repro serve --help')"
    );
}

fn cmd_table(liveness: bool) -> Result<()> {
    let which = if liveness { "Table 1 (liveness analysis ON)" } else { "Table 2 (liveness OFF)" };
    println!("== {which} ==");
    println!("simulated peak incl. parameters; (−x%) = reduction vs vanilla\n");
    let (rendered, rows) = tables::render_table(liveness, tables::zoo());
    println!("{rendered}");
    println!("planner wall-clock per network (context + budget search + 2 solves):");
    for r in &rows {
        println!(
            "  {:<12} exactDP {:>8.2?}   approxDP {:>8.2?}",
            r.name, r.exact_time, r.approx_time
        );
    }
    Ok(())
}

fn cmd_figure3(flags: &Flags) -> Result<()> {
    let device_gb: f64 = flags.parse::<f64>("--device")?.unwrap_or(11.4);
    let device = (device_gb * (1u64 << 30) as f64) as u64;
    let entries: Vec<&zoo::ZooEntry> = match flags.get("--network") {
        Some(n) => vec![zoo::find(n).ok_or_else(|| anyhow!("unknown network {n}"))?],
        None => tables::zoo().iter().collect(),
    };
    for e in entries {
        let batches = tables::default_batches(e);
        println!("{}", tables::render_figure3(e, &batches, device));
        // §5.2 headline claims, where applicable.
        summarize_figure3(e, &batches, device);
    }
    Ok(())
}

fn summarize_figure3(e: &zoo::ZooEntry, batches: &[u64], device: u64) {
    let series = tables::figure3_network(e, batches, device);
    let max_vanilla =
        series[0].points.iter().filter(|p| p.feasible).map(|p| p.batch).max().unwrap_or(0);
    let max_tc =
        series[1].points.iter().filter(|p| p.feasible).map(|p| p.batch).max().unwrap_or(0);
    println!(
        "  max feasible batch: vanilla {} → ApproxDP+TC {} ({}×)\n",
        max_vanilla,
        max_tc,
        if max_vanilla > 0 { max_tc / max_vanilla.max(1) } else { 0 },
    );
}

fn cmd_plan(flags: &Flags) -> Result<()> {
    if let Some(t) = flags.parse::<usize>("--threads")? {
        // Latch the planner pool width before the session spins it up.
        recompute::util::pool::set_global_threads(t);
    }
    let g: Graph = if let Some(path) = flags.get("--graph") {
        Graph::from_json_file(std::path::Path::new(path))?
    } else if let Some(name) = flags.get("--network") {
        let e = zoo::find(name).ok_or_else(|| anyhow!("unknown network {name}"))?;
        let batch = flags.parse::<u64>("--batch")?.unwrap_or(e.batch);
        e.build_batch(batch)
    } else {
        bail!("plan needs --network NAME or --graph FILE.json");
    };

    let objective = match flags.get("--objective").unwrap_or("tc") {
        "tc" => Objective::MinOverhead,
        "mc" => Objective::MaxOverhead,
        o => bail!("bad --objective {o} (tc|mc)"),
    };
    let family = match flags.get("--family").unwrap_or("approx") {
        "exact" => Family::Exact,
        "approx" => Family::Approx,
        f => bail!("bad --family {f} (exact|approx)"),
    };
    let mode = SimMode::parse(flags.get("--sim").unwrap_or("liveness"))?;
    let json_out = flags.has("--json");
    let stats_out = flags.has("--stats");
    // `--planner` is the first-class selector; `--family`/`--chen` stay
    // as back-compat aliases for scripts written before it existed.
    let planner = if let Some(p) = flags.get("--planner") {
        PlannerId::parse(p)?
    } else if flags.has("--chen") {
        PlannerId::Chen
    } else if family == Family::Exact {
        PlannerId::ExactDp
    } else {
        PlannerId::ApproxDp
    };
    let budget_spec = match flags.get("--budget") {
        Some(s) => BudgetSpec::Bytes(parse_budget(s)?),
        None => BudgetSpec::MinFeasible,
    };

    let session = PlanSession::new(g);
    let g = session.graph();

    if !json_out {
        println!(
            "network {} — #V={} M(V)={} params={} T(V)={}",
            g.name,
            g.len(),
            fmt_bytes(g.total_mem()),
            fmt_bytes(g.total_param_bytes()),
            g.total_time()
        );
    }
    // Vanilla always keeps its framework-native eager freeing (Appendix C)
    // — the --sim toggle applies to the *strategies* only, matching
    // table1/table2 and the experiment runner.
    let vanilla =
        simulate_vanilla(g, SimOptions { mode: SimMode::Liveness, include_params: true });
    if !json_out {
        println!("vanilla peak: {} (liveness)", fmt_bytes(vanilla.peak_total));
        // Whole-graph B* is only meaningful (and only affordable) for the
        // planners that solve over a whole-graph family — Chen sweeps its
        // own budgets and the decomposed planner resolves per component.
        if let Some(fam) = planner.family() {
            if budget_spec == BudgetSpec::MinFeasible {
                // Memoized: the session's plan below reuses this B*.
                println!(
                    "minimal feasible budget B* = {} (activations)",
                    fmt_bytes(session.min_feasible_budget(fam))
                );
            }
        }
    }

    let req = PlanRequest { budget: budget_spec, sim_mode: mode, ..PlanRequest::new(planner, objective) };
    let before = session.stats();
    let cp = session.plan(&req)?;
    let cache_hit = session.stats().hits > before.hits;

    if json_out {
        // The canonical summary (shared with the serve daemon's `plan`
        // reply) plus the CLI-only context fields.
        let mut j = cp
            .summary_json()
            .set("network", g.name.as_str().into())
            .set("nodes", (g.len() as u64).into())
            .set("requested_planner", req.planner.label().into())
            .set(
                "overhead_pct",
                (100.0 * cp.plan.overhead as f64 / g.total_time() as f64).into(),
            )
            .set("peak_eq2", cp.plan.peak_eq2.into())
            .set("peak_strict", cp.peak_strict.into())
            .set("vanilla_peak", vanilla.peak_total.into())
            .set("recompute_count", cp.program.recompute_count.into())
            .set("cache_hit", cache_hit.into())
            .set("session", session_json(&session.stats()));
        if let Some(info) = &cp.plan.decomposition {
            // Replace the summary's compact decomposition with the full
            // per-component rendering.
            j = j.set("decomposition", decomposition_json(info));
        }
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    if planner == PlannerId::Chen {
        println!(
            "chen: k={} segment_budget={} peak={} (-{:.0}%) overhead={} (+{:.0}% of T(V))",
            cp.plan.chain.k(),
            fmt_bytes(cp.plan.budget),
            fmt_bytes(cp.report.peak_total),
            100.0 * (1.0 - cp.report.peak_total as f64 / vanilla.peak_total as f64),
            cp.report.overhead_time,
            100.0 * cp.report.overhead_time as f64 / g.total_time() as f64,
        );
        if stats_out {
            print_plan_stats(&session);
        }
        return Ok(());
    }

    println!(
        "{} plan: k={} segments, overhead={} (+{:.0}% of T(V))",
        cp.plan.kind.label(),
        cp.plan.chain.k(),
        cp.plan.overhead,
        100.0 * cp.plan.overhead as f64 / g.total_time() as f64
    );
    println!(
        "peak: eq2={}  measured({})={} (-{:.0}% vs vanilla)",
        fmt_bytes(cp.plan.peak_eq2 + g.total_param_bytes()),
        mode.label(),
        fmt_bytes(cp.report.peak_total),
        100.0 * (1.0 - cp.report.peak_total as f64 / vanilla.peak_total as f64)
    );
    if let Some(info) = &cp.plan.decomposition {
        let kinds: Vec<&str> = info.kinds.iter().map(|k| k.label()).collect();
        println!(
            "decomposition: components={} cut_vertices={} cache_hits={} sizes={:?} kinds={}",
            info.components,
            info.cut_vertices,
            info.cache_hits,
            info.sizes,
            kinds.join(",")
        );
    }
    if flags.has("--segments") {
        for (i, l) in cp.plan.chain.lower_sets().iter().enumerate() {
            println!("  L{} — |L|={}", i + 1, l.len());
        }
    }
    if stats_out {
        print_plan_stats(&session);
    }
    Ok(())
}

/// `repro audit` — compile a plan exactly like `cmd_plan` would, then
/// print the static schedule auditor's report instead of the schedule.
///
/// The session runs the auditor on every compile, so this command is a
/// thin lens over [`recompute::session::CompiledPlan::audit`]; a plan
/// with audit *errors* never reaches us (the session refuses to cache
/// it), so the table below shows warnings on an admitted plan, or
/// `clean`. With `--deny-audit` even warnings abort the compile and the
/// command exits non-zero with the offending rule code in the message.
fn cmd_audit(flags: &Flags) -> Result<()> {
    if let Some(t) = flags.parse::<usize>("--threads")? {
        recompute::util::pool::set_global_threads(t);
    }
    let g: Graph = if let Some(path) = flags.get("--graph") {
        Graph::from_json_file(std::path::Path::new(path))?
    } else if let Some(name) = flags.get("--network").or_else(|| flags.get("--model")) {
        let e = zoo::find(name).ok_or_else(|| anyhow!("unknown network {name}"))?;
        let batch = flags.parse::<u64>("--batch")?.unwrap_or(e.batch);
        e.build_batch(batch)
    } else {
        bail!("audit needs --network NAME or --graph FILE.json");
    };

    let objective = match flags.get("--objective").unwrap_or("tc") {
        "tc" => Objective::MinOverhead,
        "mc" => Objective::MaxOverhead,
        o => bail!("bad --objective {o} (tc|mc)"),
    };
    let mode = SimMode::parse(flags.get("--sim").unwrap_or("liveness"))?;
    let planner = PlannerId::parse(flags.get("--planner").unwrap_or("approx"))?;
    let budget_spec = match flags.get("--budget") {
        Some(s) => BudgetSpec::Bytes(parse_budget(s)?),
        None => BudgetSpec::MinFeasible,
    };
    let json_out = flags.has("--json");

    let session = PlanSession::new(g);
    session.set_deny_audit(flags.has("--deny-audit"));
    let g = session.graph();

    let req =
        PlanRequest { budget: budget_spec, sim_mode: mode, ..PlanRequest::new(planner, objective) };
    let cp = session.plan(&req)?;

    if json_out {
        let j = cp
            .audit
            .to_json()
            .set("network", g.name.as_str().into())
            .set("planner", cp.plan.kind.label().into())
            .set("sim", mode.label().into())
            .set("segments", (cp.plan.chain.k() as u64).into())
            .set("peak_bytes", cp.report.peak_bytes.into());
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    println!(
        "audit {} — planner {} sim {} k={} events={}: {}",
        g.name,
        cp.plan.kind.label(),
        mode.label(),
        cp.plan.chain.k(),
        cp.audit.events,
        cp.audit.verdict()
    );
    println!(
        "static peak {} (simulator predicted {})",
        fmt_bytes(cp.audit.static_peak),
        fmt_bytes(cp.report.peak_bytes)
    );
    if !cp.audit.is_clean() {
        print!("{}", cp.audit.render_table());
    }
    Ok(())
}

/// `plan --stats`: the session's amortization counters, the planner
/// wall-time (family build + compile) and the worker-pool width that
/// produced them. Deliberately absent from `--json` output, whose bytes
/// must be identical at any thread count.
fn print_plan_stats(session: &PlanSession) {
    println!("{}", session_summary(&session.stats()));
    println!("{}", timing_summary(&session.timing()));
    println!("threads: {}", session.pool().threads());
}

fn cmd_experiment(flags: &Flags) -> Result<()> {
    let path = flags.get("--config").ok_or_else(|| anyhow!("experiment needs --config"))?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let exp = recompute::coordinator::experiment::Experiment::from_json(&text)?;
    println!("== experiment: {} (liveness {}) ==", exp.name, exp.liveness);
    let results = recompute::coordinator::experiment::run_experiment(&exp)?;
    println!("{}", recompute::coordinator::experiment::render(&results));
    if let Some(csv_path) = flags.get("--csv") {
        std::fs::write(csv_path, recompute::coordinator::experiment::to_csv(&results))?;
        println!("csv written to {csv_path}");
    }
    Ok(())
}

fn cmd_export(flags: &Flags) -> Result<()> {
    let name = flags.get("--network").ok_or_else(|| anyhow!("export needs --network"))?;
    let out = flags.get("--out").ok_or_else(|| anyhow!("export needs --out"))?;
    let e = zoo::find(name).ok_or_else(|| anyhow!("unknown network {name}"))?;
    let batch = flags.parse::<u64>("--batch")?.unwrap_or(e.batch);
    let g = e.build_batch(batch);
    std::fs::write(out, g.to_json()).with_context(|| format!("writing {out}"))?;
    println!("wrote {} ({} nodes) to {out}", g.name, g.len());
    Ok(())
}
