//! The tower trainer: real training steps through any [`Backend`],
//! following a [`ChainSchedule`].
//!
//! Memory protocol per step (the canonical strategy of §3, specialized to
//! chains):
//!
//! - **forward**: run segments in order; inside a segment activations flow
//!   layer to layer and intermediates are dropped immediately; at the end
//!   of each segment its boundary activation is cached;
//! - **backward**: walk segments in reverse; recompute the segment's
//!   interior activations from the checkpoint below it, backprop each
//!   layer, apply SGD immediately (gradients die young), and drop the
//!   segment's activations before moving down.
//!
//! Every allocate/drop updates the live-byte counter; `peak_bytes` is the
//! measured maximum — the executor-side analogue of the simulator's
//! number, and the end-to-end evidence for the paper's claim. The trainer
//! is generic over [`Backend`], so the same schedule-following logic runs
//! on the pure-Rust [`NativeBackend`] and on PJRT artifacts alike.

use std::time::Instant;

use crate::analysis::Rule;
use crate::anyhow::{bail, Context, Result};

use crate::runtime::{Backend, KernelStat, NativeBackend, PoolStats};
use crate::util::rng::Pcg32;

use super::schedule::ChainSchedule;

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Hidden layers (excluding the loss head).
    pub layers: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// Log every n steps (0 = silent).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { layers: 12, steps: 50, lr: 0.1, seed: 7, log_every: 10 }
    }
}

/// Synthetic regression task: y = sin of a scaled copy of x — smooth,
/// deterministic, learnable by the tower with loss visibly decreasing
/// within tens of steps.
pub struct SyntheticTask {
    batch: usize,
    width: usize,
    rng: Pcg32,
}

impl SyntheticTask {
    pub fn new(batch: usize, width: usize, seed: u64) -> Self {
        SyntheticTask { batch, width, rng: Pcg32::seeded(seed) }
    }

    /// Next (x, y) batch as flat f32 vectors.
    pub fn next_batch(&mut self) -> (Vec<f32>, Vec<f32>) {
        let n = self.batch * self.width;
        let x: Vec<f32> = (0..n).map(|_| self.rng.normal() as f32).collect();
        // Deterministic target: smooth function of the input.
        let y: Vec<f32> = x.iter().map(|v| (1.7 * v).sin()).collect();
        (x, y)
    }
}

/// Measured results of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Which backend executed the run (`"native"`, `"pjrt"`).
    pub backend: &'static str,
    pub losses: Vec<f32>,
    /// Peak live activation bytes over all steps (params excluded).
    pub peak_bytes: u64,
    /// Parameter bytes (constant).
    pub param_bytes: u64,
    /// Mean per-step wall-clock in milliseconds.
    pub mean_step_ms: f64,
    /// Forward recomputations performed per step.
    pub recomputes_per_step: usize,
    /// Number of segments in the schedule.
    pub k: usize,
    /// Per-kernel timing/byte statistics from the backend.
    pub kernel_stats: Vec<KernelStat>,
    /// Buffer-pool counters from the backend (`None` for backends that
    /// allocate tensors individually, e.g. PJRT).
    pub pool: Option<PoolStats>,
}

/// The trainer: parameters + an execution backend + live-byte accounting.
///
/// The tower is a uniform-width chain, so unlike the shape-polymorphic
/// [`super::DagTrainer`] it carries its `(batch, width)` itself — the
/// backend no longer advertises any shape (kernels are dimension-driven).
pub struct TowerTrainer<B: Backend> {
    backend: B,
    batch: usize,
    width: usize,
    /// (w, b) per layer; `layers + 1` entries (last = loss head).
    params: Vec<(B::Tensor, B::Tensor)>,
    live_bytes: u64,
    peak_bytes: u64,
}

impl TowerTrainer<NativeBackend> {
    /// Pure-Rust trainer: He-initialized tower on [`NativeBackend`] at the
    /// given `(batch, width)`. No artifacts, no Python, no native libs.
    pub fn native(batch: usize, width: usize, cfg: &TrainConfig) -> Result<Self> {
        TowerTrainer::new(NativeBackend::new(), batch, width, cfg)
    }
}

#[cfg(feature = "xla")]
impl TowerTrainer<crate::runtime::PjrtBackend> {
    /// PJRT trainer over the AOT artifact set in `dir`, at the shape the
    /// artifacts were compiled for.
    pub fn from_artifacts(dir: &std::path::Path, cfg: &TrainConfig) -> Result<Self> {
        let backend = crate::runtime::PjrtBackend::load(dir)?;
        let (batch, width) = (backend.batch(), backend.width());
        TowerTrainer::new(backend, batch, width, cfg)
    }
}

impl<B: Backend> TowerTrainer<B> {
    /// He-initialize a tower with `cfg.layers` hidden layers (+1 head) at
    /// `(batch, width)`, with parameters living on the backend.
    pub fn new(
        backend: B,
        batch: usize,
        width: usize,
        cfg: &TrainConfig,
    ) -> Result<TowerTrainer<B>> {
        if batch == 0 || width == 0 {
            bail!("batch/width must be positive");
        }
        let mut rng = Pcg32::seeded(cfg.seed);
        let scale = (2.0 / width as f64).sqrt();
        let mut params = Vec::with_capacity(cfg.layers + 1);
        for _ in 0..cfg.layers + 1 {
            let w: Vec<f32> =
                (0..width * width).map(|_| (rng.normal() * scale) as f32).collect();
            let b = vec![0f32; width];
            params.push((
                backend.upload(&w, &[width, width])?,
                backend.upload(&b, &[width])?,
            ));
        }
        Ok(TowerTrainer { backend, batch, width, params, live_bytes: 0, peak_bytes: 0 })
    }

    /// The execution backend (for kernel stats and the backend name).
    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn param_bytes(&self) -> u64 {
        self.params
            .iter()
            .map(|(w, b)| self.backend.tensor_bytes(w) + self.backend.tensor_bytes(b))
            .sum()
    }

    fn alloc(&mut self, bytes: u64) {
        self.live_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
    }

    /// Release live-byte accounting. Underflow is the executor-side
    /// analogue of the static auditor's [`Rule::LiveUnderflow`] — it was
    /// a `debug_assert`, but carries the same rule code as a hard error
    /// in release builds now so a miscounted schedule can never silently
    /// report a bogus peak.
    fn free(&mut self, bytes: u64) -> Result<()> {
        if self.live_bytes < bytes {
            bail!(
                "{} {}: freeing {} bytes with only {} live",
                Rule::LiveUnderflow.code(),
                Rule::LiveUnderflow.name(),
                bytes,
                self.live_bytes
            );
        }
        self.live_bytes -= bytes;
        Ok(())
    }

    /// One training step under `sched`. Returns (loss, recompute_count).
    ///
    /// `x`/`y` are the batch input/target tensors (always live; their
    /// bytes are excluded like the paper excludes input nodes).
    // Index loops are load-bearing here: iterating `&self.params[..]`
    // would hold the borrow across the `&mut self` accounting calls.
    #[allow(clippy::needless_range_loop)]
    pub fn step(
        &mut self,
        sched: &ChainSchedule,
        x: &B::Tensor,
        y: &B::Tensor,
        lr: f32,
    ) -> Result<(f32, usize)> {
        let n = sched.n_layers; // includes loss head at index n-1
        let lr_t = self.backend.upload(&[lr], &[])?;
        let act_bytes = (self.batch * self.width * 4) as u64;
        let mut recomputes = 0usize;

        // --- forward: keep only checkpoint activations -------------------
        // checkpoints[s] = activation index cached at end of segment s
        // (activation i = input of layer i; activation 0 = x).
        let mut ckpt: Vec<Option<B::Tensor>> = vec![None; n + 1];
        let mut h: Option<B::Tensor> = None; // current activation (None = x)
        for seg in &sched.segments {
            for li in seg.start..seg.end.min(n - 1) {
                let (w, b) = &self.params[li];
                let inp = h.as_ref().unwrap_or(x);
                let out = self
                    .backend
                    .run("layer_fwd", &[inp.clone(), w.clone(), b.clone()])?
                    .pop()
                    .context("layer_fwd output")?;
                self.alloc(act_bytes);
                if h.take().is_some() {
                    self.free(act_bytes)?; // intermediate dropped
                }
                h = Some(out);
            }
            // Cache the boundary activation (input of layer seg.end).
            if seg.end < n {
                if let Some(ref hval) = h {
                    ckpt[seg.end] = Some(hval.clone());
                    self.alloc(act_bytes); // cached copy stays live
                }
            }
            // The running activation beyond the boundary is dropped unless
            // it is exactly the checkpoint we just stored; in a chain they
            // coincide, so nothing extra to do. The loss head consumes the
            // final activation inside the backward pass below.
        }
        // Forward ends with h = activation n-1 (input of the loss head)
        // live only if the last segment ends at the head; the canonical
        // strategy discards non-boundary values, so we drop it and let the
        // backward pass recompute from the last checkpoint.
        if h.take().is_some() {
            self.free(act_bytes)?;
        }

        // --- backward: segments in reverse -------------------------------
        let mut loss_val = f32::NAN;
        let mut gh: Option<B::Tensor> = None; // gradient flowing down
        for seg in sched.segments.iter().rev() {
            // 1. Recompute the segment's interior input activations from
            //    the checkpoint below it (or x for the first segment).
            //    Backprop of layer li needs act[li] (its input); the
            //    segment's boundary *output* act[seg.end] belongs to the
            //    segment above, whose backward already ran — so only
            //    layers seg.start .. seg.end-1 (exclusive) re-execute.
            let base: Option<&B::Tensor> =
                if seg.start == 0 { None } else { ckpt[seg.start].as_ref() };
            let mut acts: Vec<B::Tensor> = Vec::with_capacity(seg.end - seg.start);
            {
                let mut cur: Option<B::Tensor> = base.cloned();
                for li in seg.start..seg.end - 1 {
                    let inp_owned;
                    let inp = match &cur {
                        Some(c) => c,
                        None => {
                            inp_owned = x.clone();
                            &inp_owned
                        }
                    };
                    acts.push(inp.clone()); // input activation of layer li
                    let (w, b) = &self.params[li];
                    let out = self
                        .backend
                        .run("layer_fwd", &[inp.clone(), w.clone(), b.clone()])?
                        .pop()
                        .context("recompute layer_fwd")?;
                    self.alloc(act_bytes);
                    recomputes += 1;
                    cur = Some(out);
                }
                // Input of the segment's last layer.
                match cur {
                    Some(c) => acts.push(c),
                    None => acts.push(x.clone()),
                }
            }
            // acts[j] is the INPUT of layer seg.start + j; the first entry
            // aliases the checkpoint/x (no new allocation), the rest were
            // allocated in the loop above (one alloc per recompute).

            // 2. Backprop layers of the segment in reverse.
            for li in (seg.start..seg.end).rev() {
                let a_in = &acts[li - seg.start];
                let (w, b) = self.params[li].clone();
                if li == n - 1 {
                    // Loss head: loss + gradients in one kernel call.
                    let outs = self.backend.run(
                        "loss_head_bwd",
                        &[a_in.clone(), w.clone(), b.clone(), y.clone()],
                    )?;
                    let [loss, ghead, gw, gb]: [B::Tensor; 4] =
                        outs.try_into().ok().context("loss_head_bwd arity")?;
                    loss_val = self.backend.download(&loss)?[0];
                    self.alloc(act_bytes); // ghead
                    gh = Some(ghead);
                    self.apply_sgd(li, &gw, &gb, &lr_t)?;
                } else {
                    let g_out = gh.take().context("missing upstream gradient")?;
                    let outs = self.backend.run(
                        "layer_bwd",
                        &[a_in.clone(), w.clone(), b.clone(), g_out.clone()],
                    )?;
                    let [gx, gw, gb]: [B::Tensor; 3] =
                        outs.try_into().ok().context("layer_bwd arity")?;
                    drop(g_out);
                    // gx replaces g_out: net zero on the counter.
                    gh = Some(gx);
                    self.apply_sgd(li, &gw, &gb, &lr_t)?;
                }
            }
            // 3. Drop this segment's recomputed activations and its
            //    checkpoint — backward below no longer needs them.
            let n_interior = acts.len().saturating_sub(1); // first aliases ckpt/x
            drop(acts);
            self.free(n_interior as u64 * act_bytes)?;
            if seg.start > 0 && ckpt[seg.start].take().is_some() {
                self.free(act_bytes)?;
            }
        }
        // The gradient flowing below layer 0 is w.r.t. the input — dropped.
        if gh.take().is_some() {
            self.free(act_bytes)?;
        }
        // Executor-side analogue of the auditor's leak-at-exit sweep
        // ([`Rule::LeakAtExit`]) — promoted from a debug_assert so release
        // builds refuse to report a peak off a leaky step.
        if self.live_bytes != 0 {
            bail!(
                "{} {}: step leaked {} activation bytes",
                Rule::LeakAtExit.code(),
                Rule::LeakAtExit.name(),
                self.live_bytes
            );
        }
        Ok((loss_val, recomputes))
    }

    fn apply_sgd(
        &mut self,
        li: usize,
        gw: &B::Tensor,
        gb: &B::Tensor,
        lr: &B::Tensor,
    ) -> Result<()> {
        let (w, b) = self.params[li].clone();
        let new_w = self
            .backend
            .run("sgd_mat", &[w, gw.clone(), lr.clone()])?
            .pop()
            .context("sgd_mat output")?;
        let new_b = self
            .backend
            .run("sgd_vec", &[b, gb.clone(), lr.clone()])?
            .pop()
            .context("sgd_vec output")?;
        self.params[li] = (new_w, new_b);
        Ok(())
    }

    /// Train for `cfg.steps` steps on the synthetic task.
    pub fn train(&mut self, sched: &ChainSchedule, cfg: &TrainConfig) -> Result<TrainReport> {
        let (batch, width) = (self.batch, self.width);
        let mut task = SyntheticTask::new(batch, width, cfg.seed ^ 0xabcd);
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut recomputes = 0usize;
        let t0 = Instant::now();
        for step in 0..cfg.steps {
            let (xv, yv) = task.next_batch();
            let x = self.backend.upload(&xv, &[batch, width])?;
            let y = self.backend.upload(&yv, &[batch, width])?;
            let (loss, rec) = self.step(sched, &x, &y, cfg.lr)?;
            recomputes = rec;
            losses.push(loss);
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!("step {step:>4}  loss {loss:.6}");
            }
        }
        let elapsed = t0.elapsed();
        Ok(TrainReport {
            backend: self.backend.name(),
            losses,
            peak_bytes: self.peak_bytes,
            param_bytes: self.param_bytes(),
            mean_step_ms: elapsed.as_secs_f64() * 1000.0 / cfg.steps as f64,
            recomputes_per_step: recomputes,
            k: sched.segments.len(),
            kernel_stats: self.backend.stats(),
            pool: self.backend.pool_stats(),
        })
    }

    /// Reset the live/peak accounting (e.g. between schedules).
    pub fn reset_accounting(&mut self) {
        self.live_bytes = 0;
        self.peak_bytes = 0;
    }

    /// Fetch the current loss-head weight row 0 (diagnostics).
    pub fn probe_weights(&self) -> Result<Vec<f32>> {
        let (w, _) = &self.params[self.params.len() - 1];
        let v = self.backend.download(w)?;
        Ok(v[..8.min(self.width)].to_vec())
    }
}
