//! The general-DAG trainer: executes an [`OpProgram`] on any
//! [`Backend`], over arbitrary computation graphs with *per-node* tensor
//! shapes.
//!
//! Where [`super::trainer::TowerTrainer`] hand-specializes the canonical
//! strategy to chains, this executor is *trace-driven*: the compiled
//! program already says which forward value to (re)materialize when,
//! when each backward op runs, and when each buffer dies — the trainer
//! just follows the steps with real kernels, under the executable
//! lowering of [`crate::models::executable`]. Each node `v` owns a
//! `[batch, width_v]` tensor (widths read from the lowered graph, so
//! heterogeneous `M_v` profiles execute as heterogeneous shapes; dense
//! nodes carry rectangular `[w_in, w_out]` weights), and every sink
//! regresses against a target of its own width.
//!
//! Two properties the design guarantees, both property-tested end to end:
//!
//! - **Bit-exact schedules.** Recomputed forward values rerun the same
//!   kernels on the same inputs (a node's parameters are only updated at
//!   its own backward, which the canonical strategy orders after every
//!   recomputation that needs them), and gradient fan-in is reduced in
//!   ascending contributor-id order regardless of the order contributions
//!   arrive in — so any plan's loss *and* parameter gradients are
//!   bit-identical to vanilla execution.
//! - **Observed = predicted memory.** Every step updates a live-byte
//!   counter: forward values from real tensor sizes, gradients from the
//!   graph's per-node `M_v` (which, on graphs lowered with
//!   [`crate::models::executable::recost_widths`], *is* the real tensor
//!   size — `batch · width_v · 4`). The per-step counter equals the
//!   program's model-side prediction and the observed peak equals
//!   [`crate::sim::SimReport::peak_bytes`] *of the mode the program was
//!   compiled under* — an equality, not a bound. In liveness mode (the
//!   default) the program's `FreeFwd`/`FreeGrad` steps sit at each
//!   buffer's last use, so the trainer actually releases tensors there
//!   and the observed peak is the paper's Table 1 number; in strict
//!   mode the frees are the strategy-mandated ones (Table 2). One
//!   caveat: a gradient is booked as the canonical model's *single*
//!   logical buffer (one `M_v` from its alloc step to its free step).
//!   The deferred fan-in contributions backing that buffer are real
//!   tensors the counter does not itemize — at a node with `s`
//!   consumers, actual transient memory can exceed the counter by up to
//!   `(s−1)·M_v` until the node's backprop reduces them.
//!
//! Loss-gradient seeding is lazy: the trace accounts a sink's gradient at
//! the start of the backward pass (when the sink's forward value may
//! already be discarded), so the executor reserves the bytes there but
//! runs the `mse` kernel at the sink's own backprop step, where the
//! canonical strategy guarantees `fwd(sink)` is live again.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::anyhow::{bail, Context, Result};

use crate::graph::builder::BYTES_PER_ELEM;
use crate::graph::{Graph, NodeId};
use crate::models::executable::{input_width, node_role, node_width, NodeRole};
use crate::runtime::{Backend, KernelStat, PoolStats};
use crate::util::rng::Pcg32;

use super::program::{OpProgram, Step};
use super::trainer::TrainConfig;

/// Per-dense-node parameter gradients `(gw, gb)` keyed by node id.
pub type GradMap = BTreeMap<u32, (Vec<f32>, Vec<f32>)>;

/// Synthetic task for (possibly heterogeneous) DAG lowerings: one batch
/// input at the sources' shared width plus one regression target per
/// sink at *that sink's* width. Targets are a smooth function of the
/// input (`sin(1.7·x)`, columns wrapped modulo the input width), so the
/// task is learnable and bit-reproducible across schedules — two tasks
/// built alike stream identical data.
pub struct DagTask {
    batch: usize,
    in_width: usize,
    /// `(sink id, sink width)` in ascending node-id order.
    sinks: Vec<(u32, usize)>,
    rng: Pcg32,
}

impl DagTask {
    /// A task matching the shapes of the executable lowering `g`.
    pub fn for_graph(g: &Graph, batch: usize, seed: u64) -> DagTask {
        let sinks = g.sinks().iter().map(|&v| (v.0, node_width(g, v))).collect();
        DagTask { batch, in_width: input_width(g), sinks, rng: Pcg32::seeded(seed) }
    }

    /// Next `(input, per-sink targets)` batch as flat f32 vectors.
    pub fn next_batch(&mut self) -> (Vec<f32>, BTreeMap<u32, Vec<f32>>) {
        let x: Vec<f32> =
            (0..self.batch * self.in_width).map(|_| self.rng.normal() as f32).collect();
        let mut targets = BTreeMap::new();
        for &(id, w) in &self.sinks {
            let mut y = Vec::with_capacity(self.batch * w);
            for row in 0..self.batch {
                for col in 0..w {
                    y.push((1.7 * x[row * self.in_width + col % self.in_width]).sin());
                }
            }
            targets.insert(id, y);
        }
        (x, targets)
    }
}

/// Measured outcome of one executed training step.
#[derive(Clone, Debug)]
pub struct StepReport {
    /// Total loss: sum of per-sink MSE in ascending node-id order.
    pub loss: f32,
    /// Peak of the observed live-byte counter.
    pub observed_peak: u64,
    /// Program step index at which the peak was reached.
    pub peak_step: usize,
    /// Observed live bytes after every program step (compare against
    /// [`OpProgram::predicted_live`]).
    pub live_trajectory: Vec<u64>,
    /// Forward recomputations performed.
    pub recomputes: u64,
    /// Per-dense-node parameter gradients `(gw, gb)` downloaded before
    /// the optimizer ran; `None` unless requested.
    pub grads: Option<GradMap>,
}

/// Measured results of a multi-step DAG training run.
#[derive(Clone, Debug)]
pub struct DagTrainReport {
    pub backend: &'static str,
    pub losses: Vec<f32>,
    /// Peak observed live activation+gradient bytes over all steps.
    pub observed_peak: u64,
    pub param_bytes: u64,
    pub recomputes_per_step: u64,
    pub mean_step_ms: f64,
    pub kernel_stats: Vec<KernelStat>,
    /// Buffer-pool counters from the backend (`None` for backends that
    /// allocate tensors individually).
    pub pool: Option<PoolStats>,
}

/// The general-DAG trainer: per-node parameters + a backend + the graph.
pub struct DagTrainer<B: Backend> {
    backend: B,
    g: Graph,
    batch: usize,
    /// Execution width of each node (from the lowered graph's shapes).
    widths: Vec<usize>,
    /// `(w, b)` for dense nodes, `None` otherwise; indexed by node id.
    params: Vec<Option<(B::Tensor, B::Tensor)>>,
    /// Per-node `1/√k` fan-in normalizer for merge nodes (uploaded once),
    /// `None` otherwise; indexed by node id.
    merge_scale: Vec<Option<B::Tensor>>,
}

impl<B: Backend> DagTrainer<B> {
    /// He-initialize parameters for every dense node of `g` (deterministic
    /// in `seed` and node order, so two trainers built alike start
    /// bit-identically — the precondition for schedule comparisons).
    ///
    /// `g` must be an executable lowering (see
    /// [`crate::models::executable::recost_widths`]): every node carries
    /// its width in `shape[0]` and `M_v` equals its tensor's bytes at
    /// `batch` — the contract behind observed == predicted memory.
    pub fn new(backend: B, g: &Graph, batch: usize, seed: u64) -> Result<DagTrainer<B>> {
        if batch == 0 {
            bail!("batch must be positive");
        }
        let mut widths = Vec::with_capacity(g.len() as usize);
        for (_, n) in g.nodes() {
            let Some(&w) = n.shape.first() else {
                bail!(
                    "node {} has no execution width — lower the graph with \
                     models::executable::recost first",
                    n.name
                );
            };
            if w == 0 {
                bail!("node {} has zero execution width", n.name);
            }
            let expect = (batch * w as usize) as u64 * BYTES_PER_ELEM;
            if n.mem != expect {
                bail!(
                    "node {} M_v is {} bytes but its [{}x{}] f32 tensor is {} — \
                     graph not lowered for batch {}",
                    n.name,
                    n.mem,
                    batch,
                    w,
                    expect,
                    batch
                );
            }
            widths.push(w as usize);
        }
        let mut rng = Pcg32::seeded(seed);
        let mut params = Vec::with_capacity(g.len() as usize);
        let mut merge_scale = Vec::with_capacity(g.len() as usize);
        for (v, _) in g.nodes() {
            match node_role(g, v) {
                NodeRole::Dense => {
                    let w_in = widths[g.preds(v)[0].0 as usize];
                    let w_out = widths[v.0 as usize];
                    let scale = (2.0 / w_in as f64).sqrt();
                    let w: Vec<f32> =
                        (0..w_in * w_out).map(|_| (rng.normal() * scale) as f32).collect();
                    let b = vec![0f32; w_out];
                    params.push(Some((
                        backend.upload(&w, &[w_in, w_out])?,
                        backend.upload(&b, &[w_out])?,
                    )));
                    merge_scale.push(None);
                }
                NodeRole::Merge => {
                    let k = g.preds(v).len() as f32;
                    params.push(None);
                    merge_scale.push(Some(backend.upload(&[1.0 / k.sqrt()], &[])?));
                }
                NodeRole::Source => {
                    params.push(None);
                    merge_scale.push(None);
                }
            }
        }
        Ok(DagTrainer { backend, g: g.clone(), batch, widths, params, merge_scale })
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn graph(&self) -> &Graph {
        &self.g
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Execution width of each node, indexed by node id.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    pub fn param_bytes(&self) -> u64 {
        self.params
            .iter()
            .flatten()
            .map(|(w, b)| self.backend.tensor_bytes(w) + self.backend.tensor_bytes(b))
            .sum()
    }

    /// Upload one task batch: the shared input plus per-sink targets.
    pub fn upload_batch(
        &self,
        x: &[f32],
        targets: &BTreeMap<u32, Vec<f32>>,
    ) -> Result<(B::Tensor, BTreeMap<u32, B::Tensor>)> {
        let xt = self.backend.upload(x, &[self.batch, input_width(&self.g)])?;
        let mut ts = BTreeMap::new();
        for (&id, y) in targets {
            let w = self.widths[id as usize];
            ts.insert(id, self.backend.upload(y, &[self.batch, w])?);
        }
        Ok((xt, ts))
    }

    /// Execute one training step following `prog`. `x` is the batch input
    /// and `targets` maps each sink's node id to its regression target
    /// (always live; excluded from the byte counter like the paper
    /// excludes input nodes).
    pub fn run_step(
        &mut self,
        prog: &OpProgram,
        x: &B::Tensor,
        targets: &BTreeMap<u32, B::Tensor>,
        lr: f32,
        record_grads: bool,
    ) -> Result<StepReport> {
        let n = self.g.len() as usize;
        let lr_t = self.backend.upload(&[lr], &[])?;
        let mut fwd: Vec<Option<B::Tensor>> = vec![None; n];
        // Gradient contributions per node, keyed by contributor id;
        // reduced in ascending key order at the node's own backprop so the
        // sum is independent of arrival order (bit-exact across plans).
        let mut pending: Vec<Vec<(u32, B::Tensor)>> = vec![Vec::new(); n];
        let mut seeded = vec![false; n];
        let mut sink_losses: BTreeMap<u32, f32> = BTreeMap::new();
        let mut grads = GradMap::new();
        let mut live = 0u64;
        let mut peak = 0u64;
        let mut peak_step = 0usize;
        let mut traj = Vec::with_capacity(prog.steps.len());

        for (i, step) in prog.steps.iter().enumerate() {
            match *step {
                Step::Compute { node, .. } => {
                    let t = self.forward(node, &fwd, x)?;
                    live += self.backend.tensor_bytes(&t);
                    fwd[node.0 as usize] = Some(t);
                }
                Step::SeedGrad { node } => {
                    seeded[node.0 as usize] = true;
                    live += self.g.node(node).mem;
                }
                Step::AllocGrad { node } => {
                    if pending[node.0 as usize].is_empty() {
                        bail!(
                            "grad({}) allocated before any contribution",
                            self.g.node(node).name
                        );
                    }
                    live += self.g.node(node).mem;
                }
                Step::Backprop { node } => {
                    let gv = self.materialize_grad(
                        node,
                        &mut pending,
                        &seeded,
                        &fwd,
                        targets,
                        &mut sink_losses,
                    )?;
                    self.backprop_node(
                        node,
                        &gv,
                        &fwd,
                        &lr_t,
                        &mut pending,
                        record_grads.then_some(&mut grads),
                    )?;
                }
                Step::FreeFwd { node, .. } => {
                    let t = fwd[node.0 as usize]
                        .take()
                        .with_context(|| format!("free of dead fwd({})", self.g.node(node).name))?;
                    live -= self.backend.tensor_bytes(&t);
                }
                Step::FreeGrad { node } => {
                    pending[node.0 as usize].clear();
                    seeded[node.0 as usize] = false;
                    live -= self.g.node(node).mem;
                }
            }
            traj.push(live);
            if live > peak {
                peak = live;
                peak_step = i;
            }
        }
        if live != 0 {
            bail!("executor leaked {live} live bytes at end of step");
        }
        let loss = sink_losses.values().sum();
        Ok(StepReport {
            loss,
            observed_peak: peak,
            peak_step,
            live_trajectory: traj,
            recomputes: prog.recompute_count,
            grads: if record_grads { Some(grads) } else { None },
        })
    }

    /// Forward op of `node` under the executable lowering.
    fn forward(
        &self,
        node: NodeId,
        fwd: &[Option<B::Tensor>],
        x: &B::Tensor,
    ) -> Result<B::Tensor> {
        let input = |p: NodeId| {
            fwd[p.0 as usize]
                .clone()
                .with_context(|| format!("fwd({}) not live", self.g.node(p).name))
        };
        match node_role(&self.g, node) {
            NodeRole::Source => Ok(x.clone()),
            NodeRole::Dense => {
                let xin = input(self.g.preds(node)[0])?;
                let (w, b) = self.params[node.0 as usize]
                    .clone()
                    .context("dense node has no parameters")?;
                self.backend.run("layer_fwd", &[xin, w, b])?.pop().context("layer_fwd output")
            }
            NodeRole::Merge => {
                let preds = self.g.preds(node);
                let mut acc = input(preds[0])?;
                for &p in &preds[1..] {
                    acc = self
                        .backend
                        .run("add", &[acc, input(p)?])?
                        .pop()
                        .context("add output")?;
                }
                let s = self.merge_scale[node.0 as usize]
                    .clone()
                    .context("merge node has no scale")?;
                self.backend.run("scale", &[acc, s])?.pop().context("scale output")
            }
        }
    }

    /// Produce `grad(node)`: run the lazy loss seed for sinks (against the
    /// sink's own target), otherwise reduce the pending contributions in
    /// ascending contributor order.
    fn materialize_grad(
        &self,
        node: NodeId,
        pending: &mut [Vec<(u32, B::Tensor)>],
        seeded: &[bool],
        fwd: &[Option<B::Tensor>],
        targets: &BTreeMap<u32, B::Tensor>,
        sink_losses: &mut BTreeMap<u32, f32>,
    ) -> Result<B::Tensor> {
        let i = node.0 as usize;
        if seeded[i] {
            let f = fwd[i]
                .clone()
                .with_context(|| format!("fwd({}) dead at loss", self.g.node(node).name))?;
            let y = targets
                .get(&node.0)
                .with_context(|| format!("no target for sink {}", self.g.node(node).name))?;
            let outs = self.backend.run("mse", &[f, y.clone()])?;
            let [loss, grad]: [B::Tensor; 2] = outs.try_into().ok().context("mse arity")?;
            sink_losses.insert(node.0, self.backend.download(&loss)?[0]);
            return Ok(grad);
        }
        let mut contribs = std::mem::take(&mut pending[i]);
        if contribs.is_empty() {
            bail!("backprop of {} with no gradient contributions", self.g.node(node).name);
        }
        contribs.sort_by_key(|&(src, _)| src);
        let mut it = contribs.into_iter();
        let mut acc = it.next().unwrap().1;
        for (_, c) in it {
            acc = self.backend.run("add", &[acc, c])?.pop().context("add output")?;
        }
        Ok(acc)
    }

    /// Backward op of `node`: propagate contributions to predecessors and
    /// (for dense nodes) apply SGD to its parameters.
    fn backprop_node(
        &mut self,
        node: NodeId,
        gv: &B::Tensor,
        fwd: &[Option<B::Tensor>],
        lr_t: &B::Tensor,
        pending: &mut [Vec<(u32, B::Tensor)>],
        record: Option<&mut GradMap>,
    ) -> Result<()> {
        match node_role(&self.g, node) {
            NodeRole::Source => Ok(()), // gradient w.r.t. the input: dropped
            NodeRole::Merge => {
                let s = self.merge_scale[node.0 as usize]
                    .clone()
                    .context("merge node has no scale")?;
                let scaled = self
                    .backend
                    .run("scale", &[gv.clone(), s])?
                    .pop()
                    .context("scale output")?;
                for &p in self.g.preds(node) {
                    pending[p.0 as usize].push((node.0, scaled.clone()));
                }
                Ok(())
            }
            NodeRole::Dense => {
                let p = self.g.preds(node)[0];
                let xin = fwd[p.0 as usize]
                    .clone()
                    .with_context(|| format!("fwd({}) dead at backprop", self.g.node(p).name))?;
                let (w, b) = self.params[node.0 as usize]
                    .clone()
                    .context("dense node has no parameters")?;
                let outs =
                    self.backend.run("layer_bwd", &[xin, w.clone(), b.clone(), gv.clone()])?;
                let [gx, gw, gb]: [B::Tensor; 3] =
                    outs.try_into().ok().context("layer_bwd arity")?;
                pending[p.0 as usize].push((node.0, gx));
                if let Some(rec) = record {
                    rec.insert(
                        node.0,
                        (self.backend.download(&gw)?, self.backend.download(&gb)?),
                    );
                }
                let new_w = self
                    .backend
                    .run("sgd_mat", &[w, gw, lr_t.clone()])?
                    .pop()
                    .context("sgd_mat output")?;
                let new_b = self
                    .backend
                    .run("sgd_vec", &[b, gb, lr_t.clone()])?
                    .pop()
                    .context("sgd_vec output")?;
                self.params[node.0 as usize] = Some((new_w, new_b));
                Ok(())
            }
        }
    }

    /// Train for `cfg.steps` steps on the synthetic DAG task (seeded like
    /// the tower trainer's stream, so runs are comparable across seeds).
    pub fn train(&mut self, prog: &OpProgram, cfg: &TrainConfig) -> Result<DagTrainReport> {
        let mut task = DagTask::for_graph(&self.g, self.batch, cfg.seed ^ 0xabcd);
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut peak = 0u64;
        let t0 = Instant::now();
        for step in 0..cfg.steps {
            let (xv, yv) = task.next_batch();
            let (x, targets) = self.upload_batch(&xv, &yv)?;
            let r = self.run_step(prog, &x, &targets, cfg.lr, false)?;
            peak = peak.max(r.observed_peak);
            losses.push(r.loss);
            if cfg.log_every > 0 && step % cfg.log_every == 0 {
                eprintln!("step {step:>4}  loss {:.6}", r.loss);
            }
        }
        let elapsed = t0.elapsed();
        Ok(DagTrainReport {
            backend: self.backend.name(),
            losses,
            observed_peak: peak,
            param_bytes: self.param_bytes(),
            recomputes_per_step: prog.recompute_count,
            mean_step_ms: elapsed.as_secs_f64() * 1000.0 / cfg.steps.max(1) as f64,
            kernel_stats: self.backend.stats(),
            pool: self.backend.pool_stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::OpProgram;
    use crate::models::executable::{distinct_act_sizes, recost, recost_profiled};
    use crate::planner::{plan_at_min_budget, Family, Objective};
    use crate::runtime::NativeBackend;
    use crate::sim::SimMode;
    use crate::testutil::diamond;

    fn trainer_for(g: &Graph, batch: usize) -> DagTrainer<NativeBackend> {
        DagTrainer::new(NativeBackend::new(), g, batch, 7).unwrap()
    }

    /// Shared fixed batch (input + per-sink targets) for a graph.
    fn batch_for(
        t: &DagTrainer<NativeBackend>,
        fill_x: f32,
        fill_y: f32,
    ) -> (crate::runtime::HostTensor, BTreeMap<u32, crate::runtime::HostTensor>) {
        let g = t.graph();
        let xv = vec![fill_x; t.batch() * input_width(g)];
        let mut ys = BTreeMap::new();
        for v in g.sinks() {
            ys.insert(v.0, vec![fill_y; t.batch() * node_width(g, v)]);
        }
        t.upload_batch(&xv, &ys).unwrap()
    }

    #[test]
    fn diamond_trains_and_schedules_agree_bitwise() {
        let g = recost(&diamond(), 4, 8);
        let vanilla = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let planned = OpProgram::from_chain(&g, &plan.chain, SimMode::Strict).unwrap();

        let mut tv = trainer_for(&g, 4);
        let (x, y) = batch_for(&tv, 0.3, 0.1);
        let rv = tv.run_step(&vanilla, &x, &y, 0.05, true).unwrap();
        let mut tp = trainer_for(&g, 4);
        let rp = tp.run_step(&planned, &x, &y, 0.05, true).unwrap();

        assert_eq!(rv.loss.to_bits(), rp.loss.to_bits(), "loss must be bit-identical");
        let (gv, gp) = (rv.grads.unwrap(), rp.grads.unwrap());
        assert_eq!(gv.len(), gp.len());
        for (k, (w0, b0)) in &gv {
            let (w1, b1) = &gp[k];
            assert!(w0.iter().zip(w1).all(|(a, b)| a.to_bits() == b.to_bits()), "gw {k}");
            assert!(b0.iter().zip(b1).all(|(a, b)| a.to_bits() == b.to_bits()), "gb {k}");
        }
    }

    #[test]
    fn observed_bytes_track_prediction_on_diamond() {
        let g = recost(&diamond(), 2, 4);
        let prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let mut t = trainer_for(&g, 2);
        let (x, y) = batch_for(&t, 0.0, 0.0);
        let r = t.run_step(&prog, &x, &y, 0.1, false).unwrap();
        assert_eq!(r.live_trajectory, prog.predicted_live);
        assert_eq!(r.observed_peak, prog.predicted_peak());
    }

    #[test]
    fn heterogeneous_diamond_executes_with_distinct_shapes() {
        // Profiled lowering of the diamond: source at width 2, merge
        // class at width 8 — rectangular dense layers in between.
        let g = recost_profiled(&diamond(), 2, 8);
        let sizes = distinct_act_sizes(&g);
        assert!(sizes.len() >= 2, "lowering must be heterogeneous: {sizes:?}");

        let vanilla = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let planned = OpProgram::from_chain(&g, &plan.chain, SimMode::Strict).unwrap();

        let mut tv = trainer_for(&g, 2);
        let (x, y) = batch_for(&tv, 0.3, 0.1);
        let rv = tv.run_step(&vanilla, &x, &y, 0.05, true).unwrap();
        assert_eq!(rv.live_trajectory, vanilla.predicted_live, "vanilla trajectory");
        let mut tp = trainer_for(&g, 2);
        let rp = tp.run_step(&planned, &x, &y, 0.05, true).unwrap();
        assert_eq!(rp.live_trajectory, planned.predicted_live, "planned trajectory");
        assert_eq!(rv.loss.to_bits(), rp.loss.to_bits(), "heterogeneous bit-exactness");
    }

    #[test]
    fn liveness_program_executes_with_matching_trajectory_and_lower_peak() {
        // The liveness-compiled plan really frees tensors at last use:
        // the observed trajectory equals the liveness prediction, the
        // peak never exceeds the strict compilation's, and the numerics
        // are untouched (same loss bits as the strict schedule).
        let g = recost(&diamond(), 2, 4);
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let strict = OpProgram::from_chain(&g, &plan.chain, SimMode::Strict).unwrap();
        let live = OpProgram::from_chain(&g, &plan.chain, SimMode::Liveness).unwrap();

        let mut ts = trainer_for(&g, 2);
        let (x, y) = batch_for(&ts, 0.3, 0.1);
        let rs = ts.run_step(&strict, &x, &y, 0.05, false).unwrap();
        let mut tl = trainer_for(&g, 2);
        let rl = tl.run_step(&live, &x, &y, 0.05, false).unwrap();

        assert_eq!(rl.live_trajectory, live.predicted_live, "liveness trajectory");
        assert_eq!(rl.observed_peak, live.predicted_peak());
        assert!(rl.observed_peak <= rs.observed_peak, "liveness never costs more");
        assert_eq!(rl.loss.to_bits(), rs.loss.to_bits(), "frees don't change numerics");
        // The backend recycled freed buffers while executing the churn.
        let pool = tl.backend().pool_stats().expect("native backend pools");
        assert!(pool.reuses > 0, "liveness churn must hit the pool");
    }

    #[test]
    fn training_loss_is_finite_and_decreasing_on_towerlike_dag() {
        let g = recost(&crate::models::mlp_tower(6, 8, 4), 4, 8);
        let prog = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        let mut t = trainer_for(&g, 4);
        let cfg = TrainConfig { layers: 6, steps: 25, lr: 0.1, seed: 3, log_every: 0 };
        let rep = t.train(&prog, &cfg).unwrap();
        let (first, last) = (rep.losses[0], *rep.losses.last().unwrap());
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first, "loss must drop: {first} → {last}");
    }

    #[test]
    fn trainer_rejects_unlowered_graphs() {
        // The raw diamond has no execution widths (empty shapes).
        let err = match DagTrainer::new(NativeBackend::new(), &diamond(), 2, 7) {
            Ok(_) => panic!("unlowered graph must be rejected"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("execution width"), "{err}");
        // And a lowering executed at the wrong batch is caught too.
        let g = recost(&diamond(), 4, 8);
        let err = match DagTrainer::new(NativeBackend::new(), &g, 2, 7) {
            Ok(_) => panic!("wrong batch must be rejected"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("not lowered for batch"), "{err}");
    }
}
