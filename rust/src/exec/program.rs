//! Trace → executable op program compilation.
//!
//! A [`crate::sim::Trace`] is the single source of truth for what a
//! canonical strategy does: which forward value is materialized when
//! (original or recomputation), when each backward op runs, and when each
//! buffer is freed. [`OpProgram::compile`] turns that event stream into a
//! flat list of typed [`Step`]s that an executor can run on any
//! [`crate::runtime::Backend`] — over *arbitrary DAGs*, not just chains.
//!
//! Compilation is **mode-aware** ([`crate::sim::SimMode`]): in liveness
//! mode (the default everywhere user-facing) the trace is first rewritten
//! by [`crate::sim::apply_liveness`], so the typed drop steps
//! ([`Step::FreeFwd`]/[`Step::FreeGrad`]) land at each buffer's last use
//! and `predicted_live` carries the *liveness* schedule's live bytes; in
//! strict mode the strategy-mandated frees compile as-is (the Table 2
//! ablation). Either way the steps and the prediction come from one
//! trace — the executor frees tensors exactly where the simulator
//! priced them.
//!
//! Compilation also re-validates the trace's safety invariants (every
//! read targets a live buffer, every allocation is balanced by a free)
//! and records the model-predicted live bytes after every step, so the
//! executor's *observed* live bytes can be cross-checked step by step
//! against the simulator's prediction — the end-to-end evidence that the
//! measured peak is the planned peak.

use crate::anyhow::{bail, Result};

use crate::graph::{Graph, NodeId};
use crate::planner::LowerSetChain;
use crate::sim::{apply_liveness, canonical_trace, vanilla_trace, Buffer, Event, SimMode, Trace};

/// One executable step of a training iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Run the forward op of `node`, materializing `Fwd { node, gen }`
    /// (`recompute` marks backward-phase re-materializations).
    Compute { node: NodeId, gen: u8, recompute: bool },
    /// Allocate the loss gradient of sink `node`. The actual loss kernel
    /// runs lazily at the sink's [`Step::Backprop`] (where the canonical
    /// strategy guarantees `fwd(node)` is live again); this step only
    /// reserves the buffer, exactly where the trace accounts for it.
    SeedGrad { node: NodeId },
    /// Allocate the gradient buffer of `node` (first backward contribution
    /// from one of its successors just materialized).
    AllocGrad { node: NodeId },
    /// Run the backward op of `node`: reduce its gradient contributions,
    /// emit contributions into each predecessor's gradient, and apply the
    /// optimizer to the node's parameters.
    Backprop { node: NodeId },
    /// Release the forward value of `node`.
    FreeFwd { node: NodeId, gen: u8 },
    /// Release the gradient of `node`.
    FreeGrad { node: NodeId },
}

impl Step {
    /// The node this step operates on.
    pub fn node(&self) -> NodeId {
        match *self {
            Step::Compute { node, .. }
            | Step::SeedGrad { node }
            | Step::AllocGrad { node }
            | Step::Backprop { node }
            | Step::FreeFwd { node, .. }
            | Step::FreeGrad { node } => node,
        }
    }

    /// Human-readable rendering (for divergence reports and logs).
    pub fn describe(&self, g: &Graph) -> String {
        let name = |v: NodeId| g.node(v).name.clone();
        match *self {
            Step::Compute { node, gen, recompute } => {
                let tag = if recompute { "recompute" } else { "compute" };
                format!("{tag} fwd({}) gen {gen}", name(node))
            }
            Step::SeedGrad { node } => format!("seed grad({})", name(node)),
            Step::AllocGrad { node } => format!("alloc grad({})", name(node)),
            Step::Backprop { node } => format!("backprop {}", name(node)),
            Step::FreeFwd { node, gen } => format!("free fwd({}) gen {gen}", name(node)),
            Step::FreeGrad { node } => format!("free grad({})", name(node)),
        }
    }
}

/// An executable training-step program plus the model-side accounting it
/// was compiled against.
#[derive(Clone, Debug)]
pub struct OpProgram {
    pub steps: Vec<Step>,
    /// Model-predicted live bytes *after* each step, using the graph's
    /// `M_v` metadata — identical to the simulator's counter at the
    /// corresponding events of the trace the program was compiled from
    /// (the liveness-rewritten trace in liveness mode, the raw trace in
    /// strict mode).
    pub predicted_live: Vec<u64>,
    /// Number of forward recomputations the program performs.
    pub recompute_count: u64,
}

impl OpProgram {
    /// Compile the canonical strategy of `chain` into an executable
    /// program under the given free schedule.
    pub fn from_chain(g: &Graph, chain: &LowerSetChain, mode: SimMode) -> Result<OpProgram> {
        OpProgram::from_trace(g, &canonical_trace(g, chain), mode)
    }

    /// Compile vanilla (no-recomputation) execution under the given free
    /// schedule (liveness = Chainer-style eager freeing).
    pub fn vanilla(g: &Graph, mode: SimMode) -> Result<OpProgram> {
        OpProgram::from_trace(g, &vanilla_trace(g), mode)
    }

    /// Compile a trace under `mode`: liveness first rewrites the frees to
    /// last uses (the same rewrite [`crate::sim::measure`] folds over, so
    /// `predicted_live` *is* the simulator's liveness accounting).
    pub fn from_trace(g: &Graph, tr: &Trace, mode: SimMode) -> Result<OpProgram> {
        match mode {
            SimMode::Liveness => OpProgram::compile(g, &apply_liveness(tr)),
            SimMode::Strict => OpProgram::compile(g, tr),
        }
    }

    /// Compile a trace into steps, re-validating liveness along the way.
    pub fn compile(g: &Graph, tr: &Trace) -> Result<OpProgram> {
        let n = g.len() as usize;
        let mut fwd_live: Vec<Option<u8>> = vec![None; n];
        let mut grad_live = vec![false; n];
        let mut live = 0u64;
        let mut steps = Vec::with_capacity(tr.events.len());
        let mut predicted_live = Vec::with_capacity(tr.events.len());
        for ev in &tr.events {
            match *ev {
                Event::Alloc { buffer: Buffer::Fwd { node, gen }, bytes, recompute, .. } => {
                    let i = node.0 as usize;
                    if fwd_live[i].is_some() {
                        bail!("trace double-computes fwd({})", g.node(node).name);
                    }
                    fwd_live[i] = Some(gen);
                    live += bytes;
                    steps.push(Step::Compute { node, gen, recompute });
                    predicted_live.push(live);
                }
                Event::Alloc { buffer: Buffer::Grad { node }, bytes, .. } => {
                    let i = node.0 as usize;
                    if grad_live[i] {
                        bail!("trace double-allocates grad({})", g.node(node).name);
                    }
                    grad_live[i] = true;
                    live += bytes;
                    // A sink's gradient can only come from the loss; any
                    // other node's gradient is opened by a successor's
                    // backward contribution.
                    let step = if g.succs(node).is_empty() {
                        Step::SeedGrad { node }
                    } else {
                        Step::AllocGrad { node }
                    };
                    steps.push(step);
                    predicted_live.push(live);
                }
                Event::Use { buffer } => match buffer {
                    Buffer::Fwd { node, gen } => {
                        if fwd_live[node.0 as usize] != Some(gen) {
                            bail!(
                                "trace reads dead fwd({}) gen {gen} at step {}",
                                g.node(node).name,
                                steps.len()
                            );
                        }
                    }
                    Buffer::Grad { node } => {
                        if !grad_live[node.0 as usize] {
                            bail!(
                                "trace reads dead grad({}) at step {}",
                                g.node(node).name,
                                steps.len()
                            );
                        }
                    }
                },
                Event::Free { buffer } => {
                    let (step, bytes) = match buffer {
                        Buffer::Fwd { node, gen } => {
                            if fwd_live[node.0 as usize] != Some(gen) {
                                bail!("trace frees dead fwd({})", g.node(node).name);
                            }
                            fwd_live[node.0 as usize] = None;
                            (Step::FreeFwd { node, gen }, g.node(node).mem)
                        }
                        Buffer::Grad { node } => {
                            if !grad_live[node.0 as usize] {
                                bail!("trace frees dead grad({})", g.node(node).name);
                            }
                            grad_live[node.0 as usize] = false;
                            (Step::FreeGrad { node }, g.node(node).mem)
                        }
                    };
                    live -= bytes;
                    steps.push(step);
                    predicted_live.push(live);
                }
                Event::Backprop { node } => {
                    if !grad_live[node.0 as usize] {
                        bail!(
                            "backprop of {} before its gradient exists",
                            g.node(node).name
                        );
                    }
                    steps.push(Step::Backprop { node });
                    predicted_live.push(live);
                }
            }
        }
        if live != 0 || fwd_live.iter().any(Option::is_some) || grad_live.iter().any(|&b| b) {
            bail!("trace leaks buffers ({live} bytes live at end of step)");
        }
        Ok(OpProgram { steps, predicted_live, recompute_count: tr.recompute_count })
    }

    /// Model-predicted peak live bytes over the whole program.
    pub fn predicted_peak(&self) -> u64 {
        self.predicted_live.iter().copied().max().unwrap_or(0)
    }

    /// Index of the step at which the predicted peak is reached.
    pub fn predicted_peak_step(&self) -> usize {
        let peak = self.predicted_peak();
        self.predicted_live.iter().position(|&b| b == peak).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{plan_at_min_budget, singleton_chain, Family, Objective};
    use crate::sim::{measure, SimMode, SimOptions};
    use crate::testutil::{chain_graph, diamond, random_dag};
    use crate::util::rng::Pcg32;

    #[test]
    fn vanilla_program_shape_on_chain() {
        let g = chain_graph(&[1, 2, 3]);
        let p = OpProgram::vanilla(&g, SimMode::Strict).unwrap();
        // 3 computes, 3 backprops, 3 grad allocs (one sink seed), 6 frees.
        let computes = p.steps.iter().filter(|s| matches!(s, Step::Compute { .. })).count();
        let backprops = p.steps.iter().filter(|s| matches!(s, Step::Backprop { .. })).count();
        let seeds = p.steps.iter().filter(|s| matches!(s, Step::SeedGrad { .. })).count();
        assert_eq!(computes, 3);
        assert_eq!(backprops, 3);
        assert_eq!(seeds, 1, "one sink");
        assert_eq!(p.recompute_count, 0);
        assert_eq!(*p.predicted_live.last().unwrap(), 0, "balanced");
    }

    #[test]
    fn predicted_peak_matches_simulator_no_liveness() {
        let mut rng = Pcg32::seeded(91);
        for _ in 0..15 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let tr = canonical_trace(&g, &plan.chain);
            let prog = OpProgram::compile(&g, &tr).unwrap();
            let rep =
                measure(&g, &tr, SimOptions { mode: SimMode::Strict, include_params: false });
            assert_eq!(prog.predicted_peak(), rep.peak_bytes);
            assert_eq!(prog.recompute_count, rep.recompute_count);
        }
    }

    #[test]
    fn liveness_compilation_matches_simulator_and_never_costs_more() {
        // The liveness-compiled program's per-step prediction is the
        // simulator's liveness accounting (equality), and its peak never
        // exceeds the strict compilation of the same trace.
        let mut rng = Pcg32::seeded(92);
        for _ in 0..15 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let tr = canonical_trace(&g, &plan.chain);
            let live = OpProgram::from_trace(&g, &tr, SimMode::Liveness).unwrap();
            let strict = OpProgram::from_trace(&g, &tr, SimMode::Strict).unwrap();
            let rep =
                measure(&g, &tr, SimOptions { mode: SimMode::Liveness, include_params: false });
            assert_eq!(live.predicted_peak(), rep.peak_bytes, "liveness equality");
            assert!(live.predicted_peak() <= strict.predicted_peak());
            assert_eq!(live.recompute_count, strict.recompute_count, "frees move, ops don't");
            assert_eq!(*live.predicted_live.last().unwrap(), 0, "balanced");
            // Same computation: identical non-free step sequences.
            let ops = |p: &OpProgram| -> Vec<Step> {
                p.steps
                    .iter()
                    .filter(|s| !matches!(s, Step::FreeFwd { .. } | Step::FreeGrad { .. }))
                    .copied()
                    .collect()
            };
            assert_eq!(ops(&live), ops(&strict), "liveness must not reorder computation");
        }
    }

    #[test]
    fn diamond_fan_in_compiles_with_merge_semantics_visible() {
        let g = diamond();
        let p = OpProgram::from_chain(&g, &singleton_chain(&g), SimMode::Strict).unwrap();
        // Node 3 (fan-in) is backpropped before nodes 1 and 2.
        let order: Vec<u32> = p
            .steps
            .iter()
            .filter_map(|s| match s {
                Step::Backprop { node } => Some(node.0),
                _ => None,
            })
            .collect();
        assert_eq!(order, vec![3, 2, 1, 0]);
        // Every step renders without panicking.
        for (i, s) in p.steps.iter().enumerate() {
            assert!(!s.describe(&g).is_empty(), "step {i}");
        }
    }
}
