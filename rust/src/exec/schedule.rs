//! Translation of a lower-set chain over a tower graph into an executable
//! layer schedule — the chain *fast path* of the executor.
//!
//! Tower graphs (`models::mlp_tower`) are chains `input → layer_0 → … →
//! layer_{n-1} → loss_head`, so every lower set of the graph is a prefix
//! and a plan is exactly a list of cut points. The schedule records, per
//! segment, which layer range it covers and which activation the strategy
//! caches at its end (the segment's boundary node).
//!
//! Graphs with any fan-in (residual adds, concats — the whole model zoo)
//! are rejected here with an error naming the offending node; they are
//! executed through the general trace-driven path instead
//! ([`super::OpProgram`] + [`super::DagTrainer`]).

use crate::anyhow::{bail, Result};

use crate::graph::Graph;
use crate::planner::LowerSetChain;

/// One executable segment: layers `[start, end)` (indices into the tower,
/// where index `n_layers` is the loss head).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    pub start: usize,
    pub end: usize,
}

/// The full schedule: segments in forward order.
#[derive(Clone, Debug)]
pub struct ChainSchedule {
    pub segments: Vec<Segment>,
    /// Total number of compute layers including the loss head.
    pub n_layers: usize,
}

impl ChainSchedule {
    /// Build from a plan over a tower graph. Validates that the graph is a
    /// chain and that the plan's lower sets are prefixes.
    pub fn from_chain(g: &Graph, chain: &LowerSetChain) -> Result<ChainSchedule> {
        // Tower graphs: node 0 is the input stub; nodes 1..n are layers in
        // topo order (graph construction guarantees id order = topo order).
        for (v, node) in g.nodes() {
            let fan_in = g.preds(v).len();
            if fan_in > 1 {
                let inputs: Vec<&str> =
                    g.preds(v).iter().map(|&p| g.node(p).name.as_str()).collect();
                bail!(
                    "graph '{}' is not a chain: node '{}' (id {}) has fan-in {} \
                     (inputs: {}); the tower fast path only schedules chains — \
                     use the general DAG executor (exec::OpProgram + exec::DagTrainer, \
                     `repro train --model <zoo>`) for branching graphs",
                    g.name,
                    node.name,
                    v.0,
                    fan_in,
                    inputs.join(", ")
                );
            }
        }
        let n_layers = g.len() as usize - 1; // minus input stub
        let mut segments = Vec::new();
        let mut prev_end = 0usize; // layer index
        for l in chain.lower_sets() {
            // The lower set is a prefix {0..=k} of node ids; layers are
            // node id − 1.
            let size = l.len() as usize;
            // Number of layers inside: size − 1 if input included, else size.
            let covered = if l.contains(crate::graph::NodeId(0)) { size - 1 } else { size };
            if covered < prev_end {
                bail!("plan lower sets are not increasing prefixes");
            }
            // Verify prefix-ness: all member ids < size.
            for v in l.iter() {
                if (v.0 as usize) >= size {
                    bail!("plan lower set is not a prefix — not a tower plan");
                }
            }
            if covered > prev_end {
                segments.push(Segment { start: prev_end, end: covered });
                prev_end = covered;
            }
        }
        if prev_end != n_layers {
            bail!("plan does not cover all {n_layers} layers (got {prev_end})");
        }
        Ok(ChainSchedule { segments, n_layers })
    }

    /// The vanilla schedule: one segment per layer (cache everything).
    pub fn vanilla(n_layers: usize) -> ChainSchedule {
        ChainSchedule {
            segments: (0..n_layers).map(|i| Segment { start: i, end: i + 1 }).collect(),
            n_layers,
        }
    }

    /// Activation indices cached at segment ends: activation `i` is the
    /// *input* of layer `i` (activation 0 = the batch input, always held).
    /// The canonical strategy caches each segment's boundary = the output
    /// of its last layer = activation `end`.
    pub fn checkpoints(&self) -> Vec<usize> {
        self.segments.iter().map(|s| s.end).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp_tower;
    use crate::planner::{plan_at_min_budget, Family, Objective};

    #[test]
    fn vanilla_schedule_shape() {
        let s = ChainSchedule::vanilla(4);
        assert_eq!(s.segments.len(), 4);
        assert_eq!(s.checkpoints(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn plan_to_schedule_roundtrip() {
        let g = mlp_tower(15, 64, 8); // 15 layers + head = 16 compute nodes
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let sched = ChainSchedule::from_chain(&g, &plan.chain).unwrap();
        assert_eq!(sched.n_layers, 16);
        // Segments partition [0, 17).
        let mut pos = 0;
        for s in &sched.segments {
            assert_eq!(s.start, pos);
            assert!(s.end > s.start);
            pos = s.end;
        }
        assert_eq!(pos, 16);
        // A min-budget plan on a long chain must cut several times.
        assert!(sched.segments.len() >= 3, "k = {}", sched.segments.len());
    }

    #[test]
    fn rejects_non_chain_graphs() {
        let g = crate::models::transformer_tower(2, 32, 8, 4); // has residual fan-out
        let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
        assert!(ChainSchedule::from_chain(&g, &plan.chain).is_err());
    }

    #[test]
    fn non_chain_error_names_offending_node_and_fan_in() {
        // Regression: the old message ("executor only schedules chain
        // graphs") left zoo users with nothing actionable. The structured
        // error must name the first fan-in node, its degree and inputs,
        // and point at the general executor.
        let g = crate::models::transformer_tower(2, 32, 8, 4);
        let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
        let msg = ChainSchedule::from_chain(&g, &plan.chain).unwrap_err().to_string();
        assert!(msg.contains("block0/add1"), "names the node: {msg}");
        assert!(msg.contains("fan-in 2"), "names the degree: {msg}");
        assert!(msg.contains("block0/attn"), "lists the inputs: {msg}");
        assert!(msg.contains("DAG executor"), "points at the fix: {msg}");
    }
}
