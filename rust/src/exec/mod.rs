//! The training executor: runs real forward/backward steps on any
//! execution [`Backend`](crate::runtime::Backend), caching / discarding /
//! recomputing activations exactly as a canonical strategy prescribes.
//!
//! Two execution paths share the backend layer:
//!
//! - the **chain fast path** ([`ChainSchedule`] + [`TowerTrainer`]) —
//!   hand-specialized to tower graphs, also usable with PJRT artifacts
//!   under the `xla` feature;
//! - the **general path** ([`OpProgram`] + [`DagTrainer`]) — compiles the
//!   event trace of [`crate::sim`] into a typed step program and
//!   executes it over *arbitrary DAGs* (the whole model zoo: residual
//!   adds, concats, fan-out reuse) with *per-node tensor shapes*
//!   (heterogeneous widths from the model's own `M_v` profile, see
//!   [`crate::models::executable`]), with per-step observed live-byte
//!   instrumentation that is cross-checked against the simulator's
//!   predicted peak.
//!
//! Both paths are the end-to-end proof that the layers compose: the L3
//! plan drives which backend kernels run when, the *measured* peak drops
//! exactly as the simulator predicted, and the loss trajectory (and on
//! the general path, every parameter gradient) stays bit-identical to
//! vanilla execution — recomputation's defining property.

mod dag;
mod program;
mod schedule;
mod trainer;

pub use dag::{DagTask, DagTrainReport, DagTrainer, GradMap, StepReport};
pub use program::{OpProgram, Step};
pub use schedule::{ChainSchedule, Segment};
pub use trainer::{SyntheticTask, TowerTrainer, TrainConfig, TrainReport};
