//! The training executor: runs real forward/backward steps on the PJRT
//! runtime, caching / discarding / recomputing activations exactly as a
//! canonical strategy prescribes.
//!
//! This is the end-to-end proof that the three layers compose: the L3
//! plan (lower-set chain over the tower graph) drives which of the
//! L2-compiled, L1-Pallas-powered artifacts run when, and the executor's
//! live-byte accounting shows the *measured* peak dropping exactly as the
//! simulator predicted — while the loss trajectory stays bitwise identical
//! to vanilla execution, recomputation's defining property.

mod schedule;
mod trainer;

pub use schedule::{ChainSchedule, Segment};
pub use trainer::{SyntheticTask, TowerTrainer, TrainConfig, TrainReport};
