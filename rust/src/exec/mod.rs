//! The training executor: runs real forward/backward steps on any
//! execution [`Backend`](crate::runtime::Backend), caching / discarding /
//! recomputing activations exactly as a canonical strategy prescribes.
//!
//! This is the end-to-end proof that the layers compose: the L3 plan
//! (lower-set chain over the tower graph) drives which backend kernels
//! run when, and the executor's live-byte accounting shows the *measured*
//! peak dropping exactly as the simulator predicted — while the loss
//! trajectory stays bitwise identical to vanilla execution,
//! recomputation's defining property. By default the kernels are the
//! pure-Rust `NativeBackend`; with the `xla` feature the same trainer
//! drives PJRT-compiled artifacts instead.

mod schedule;
mod trainer;

pub use schedule::{ChainSchedule, Segment};
pub use trainer::{SyntheticTask, TowerTrainer, TrainConfig, TrainReport};
