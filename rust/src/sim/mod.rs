//! Event-accurate memory simulator with liveness analysis.
//!
//! The planners optimize the *analytic* peak (Eq. 2); what the paper
//! reports in Table 1 is the peak of the real execution after applying
//! **liveness analysis** [Appel & Palsberg] — each buffer is released right
//! after its last use in the whole step schedule. Table 2 is the ablation
//! without liveness: buffers are released only at the points the canonical
//! strategy mandates. Both measurements run over the same [`trace`].

mod trace;

pub use trace::{canonical_trace, vanilla_trace, Buffer, Event, Trace};

use std::collections::HashMap;

use crate::graph::Graph;
use crate::planner::LowerSetChain;

/// Simulator options.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Apply liveness analysis (free each buffer after its last use)
    /// instead of honoring only the strategy-mandated frees.
    pub liveness: bool,
    /// Add the model's parameter bytes to the reported peak (the paper's
    /// Table 1 "includes the memory used by the model parameters itself").
    pub include_params: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { liveness: true, include_params: true }
    }
}

/// Result of simulating one training step.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Peak live activation+gradient bytes.
    pub peak_bytes: u64,
    /// `peak_bytes` plus parameter bytes if `include_params`.
    pub peak_total: u64,
    /// Recomputation overhead actually incurred (Eq. 1 units).
    pub overhead_time: u64,
    /// Total compute time of the step: forward `T(V)` + backward (modeled
    /// as `BACKWARD_FACTOR × T(V)`) + recomputation overhead.
    pub step_time: u64,
    /// Number of recomputed forward values.
    pub recompute_count: u64,
    /// Index of the trace event at which the peak was reached.
    pub peak_event: usize,
    /// Number of events in the trace.
    pub trace_len: usize,
}

/// Backward compute is modeled as 2× forward (one matmul each for input
/// and weight gradients vs one for forward) — standard roofline accounting.
pub const BACKWARD_FACTOR: u64 = 2;

/// Measure the peak memory of a canonical strategy (Tables 1 & 2).
pub fn simulate(g: &Graph, chain: &LowerSetChain, opts: SimOptions) -> SimReport {
    let tr = canonical_trace(g, chain);
    measure(g, &tr, opts)
}

/// Measure vanilla (no-recomputation) execution.
pub fn simulate_vanilla(g: &Graph, opts: SimOptions) -> SimReport {
    let tr = vanilla_trace(g);
    measure(g, &tr, opts)
}

/// Core measurement over a trace.
pub fn measure(g: &Graph, tr: &Trace, opts: SimOptions) -> SimReport {
    let (peak, peak_event) =
        if opts.liveness { peak_with_liveness(tr) } else { peak_without_liveness(tr) };
    let params = if opts.include_params { g.total_param_bytes() } else { 0 };
    let fwd = g.total_time();
    SimReport {
        peak_bytes: peak,
        peak_total: peak + params,
        overhead_time: tr.recompute_time,
        step_time: fwd + BACKWARD_FACTOR * fwd + tr.recompute_time,
        recompute_count: tr.recompute_count,
        peak_event,
        trace_len: tr.events.len(),
    }
}

/// Peak honoring only strategy-mandated frees (Table 2 mode).
fn peak_without_liveness(tr: &Trace) -> (u64, usize) {
    let mut live = 0u64;
    let mut peak = 0u64;
    let mut peak_at = 0usize;
    let mut sizes: HashMap<Buffer, u64> = HashMap::new();
    for (i, ev) in tr.events.iter().enumerate() {
        match *ev {
            Event::Alloc { buffer, bytes, .. } => {
                let prev = sizes.insert(buffer, bytes);
                assert!(prev.is_none(), "double alloc in trace: {buffer:?}");
                live += bytes;
                if live > peak {
                    peak = live;
                    peak_at = i;
                }
            }
            Event::Use { buffer } => {
                assert!(sizes.contains_key(&buffer), "use of dead buffer {buffer:?}");
            }
            Event::Free { buffer } => {
                let bytes = sizes.remove(&buffer).expect("free of dead buffer");
                live -= bytes;
            }
            Event::Backprop { .. } => {}
        }
    }
    assert!(sizes.is_empty(), "buffers leaked: {}", sizes.len());
    (peak, peak_at)
}

/// Peak with liveness analysis: every buffer is freed immediately after
/// its last use (or its allocation, if never used). Strategy frees are
/// ignored — liveness strictly refines them (a buffer's last use never
/// comes after the strategy's free, since the trace would have panicked
/// on a dead read).
fn peak_with_liveness(tr: &Trace) -> (u64, usize) {
    // Last-use position per buffer.
    let mut last_use: HashMap<Buffer, usize> = HashMap::new();
    for (i, ev) in tr.events.iter().enumerate() {
        match *ev {
            Event::Alloc { buffer, .. } | Event::Use { buffer } => {
                last_use.insert(buffer, i);
            }
            Event::Free { .. } | Event::Backprop { .. } => {}
        }
    }
    // Buffers to free after each position.
    let mut frees_at: Vec<Vec<Buffer>> = vec![Vec::new(); tr.events.len()];
    for (&buf, &pos) in &last_use {
        frees_at[pos].push(buf);
    }
    let mut live = 0u64;
    let mut peak = 0u64;
    let mut peak_at = 0usize;
    let mut sizes: HashMap<Buffer, u64> = HashMap::new();
    for (i, ev) in tr.events.iter().enumerate() {
        if let Event::Alloc { buffer, bytes, .. } = *ev {
            sizes.insert(buffer, bytes);
            live += bytes;
            if live > peak {
                peak = live;
                peak_at = i;
            }
        }
        for buf in &frees_at[i] {
            live -= sizes.remove(buf).expect("liveness double free");
        }
    }
    assert!(sizes.is_empty(), "liveness leaked buffers");
    (peak, peak_at)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{
        plan_at_min_budget, singleton_chain, whole_graph_chain, Family, Objective,
    };
    use crate::testutil::{chain_graph, random_dag};
    use crate::util::rng::Pcg32;

    #[test]
    fn liveness_never_exceeds_no_liveness() {
        let mut rng = Pcg32::seeded(70);
        for _ in 0..20 {
            let n = rng.range(4, 14);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
            let with =
                simulate(&g, &plan.chain, SimOptions { liveness: true, include_params: false });
            let without =
                simulate(&g, &plan.chain, SimOptions { liveness: false, include_params: false });
            assert!(with.peak_bytes <= without.peak_bytes);
            assert_eq!(with.overhead_time, without.overhead_time);
        }
    }

    #[test]
    fn no_liveness_peak_close_to_eq2() {
        // The event-accurate no-liveness peak stays within the analytic
        // Eq. 2 peak plus the cross-segment gradient buffers Eq. 2 books on
        // the producer side (see trace.rs docs). Sanity band: within 2×.
        let mut rng = Pcg32::seeded(71);
        for _ in 0..20 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let eq2 = plan.chain.peak_mem(&g);
            let meas =
                simulate(&g, &plan.chain, SimOptions { liveness: false, include_params: false });
            assert!(meas.peak_bytes <= 2 * eq2, "measured {} vs eq2 {}", meas.peak_bytes, eq2);
            assert!(2 * meas.peak_bytes >= eq2, "measured {} vs eq2 {}", meas.peak_bytes, eq2);
        }
    }

    #[test]
    fn vanilla_peak_at_least_total_mem() {
        let g = chain_graph(&[5, 5, 5, 5, 5]);
        let r = simulate_vanilla(&g, SimOptions { liveness: true, include_params: false });
        assert!(r.peak_bytes >= g.total_mem());
        assert_eq!(r.overhead_time, 0);
        assert_eq!(r.step_time, 3 * g.total_time());
    }

    #[test]
    fn recomputation_reduces_peak_on_chain() {
        // Long uniform chain: any reasonable plan beats vanilla.
        let g = chain_graph(&[10; 40]);
        let vanilla = simulate_vanilla(&g, SimOptions { liveness: true, include_params: false });
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let ours =
            simulate(&g, &plan.chain, SimOptions { liveness: true, include_params: false });
        assert!(
            ours.peak_bytes < vanilla.peak_bytes,
            "ours {} vanilla {}",
            ours.peak_bytes,
            vanilla.peak_bytes
        );
        // √n-checkpointing scale: 40 nodes ⇒ peak well under half vanilla.
        assert!(ours.peak_bytes * 2 < vanilla.peak_bytes);
    }

    #[test]
    fn mc_with_liveness_beats_or_ties_tc_peak_on_average() {
        // §4.4's empirical claim, checked as a tendency over many random
        // graphs: the *average* MC peak (with liveness) must not exceed the
        // average TC peak.
        let mut rng = Pcg32::seeded(72);
        let (mut mc_sum, mut tc_sum) = (0u64, 0u64);
        for _ in 0..30 {
            let n = rng.range(6, 14);
            let g = random_dag(&mut rng, n);
            let tc = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let mc = plan_at_min_budget(&g, Family::Exact, Objective::MaxOverhead).unwrap();
            let opts = SimOptions { liveness: true, include_params: false };
            tc_sum += simulate(&g, &tc.chain, opts).peak_bytes;
            mc_sum += simulate(&g, &mc.chain, opts).peak_bytes;
        }
        assert!(mc_sum <= tc_sum, "mc {} vs tc {}", mc_sum, tc_sum);
    }

    #[test]
    fn overhead_time_matches_plan() {
        let mut rng = Pcg32::seeded(73);
        for _ in 0..10 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
            let r = simulate(&g, &plan.chain, SimOptions::default());
            assert_eq!(r.overhead_time, plan.overhead);
        }
    }

    #[test]
    fn params_included_when_requested() {
        use crate::graph::{GraphBuilder, OpKind};
        let mut b = GraphBuilder::new("p", 1);
        let x = b.add_with("c", OpKind::Conv, &[4, 4, 4], &[], 1234);
        let _ = b.add("r", OpKind::Activation, &[4, 4, 4], &[x]);
        let g = b.build();
        let with = simulate_vanilla(&g, SimOptions { liveness: true, include_params: true });
        let without = simulate_vanilla(&g, SimOptions { liveness: true, include_params: false });
        assert_eq!(with.peak_total, without.peak_bytes + 1234);
    }

    #[test]
    fn whole_graph_chain_extreme() {
        // Single-segment plan: maximal overhead (T(V)), maximal fwd+bwd
        // working set without liveness.
        let g = chain_graph(&[3, 3, 3, 3]);
        let w = whole_graph_chain(&g);
        let r = simulate(&g, &w, SimOptions { liveness: false, include_params: false });
        assert_eq!(r.overhead_time, g.total_time());
        let s = singleton_chain(&g);
        let rs = simulate(&g, &s, SimOptions { liveness: false, include_params: false });
        assert!(rs.overhead_time <= r.overhead_time);
    }
}
