//! Event-accurate memory simulator with liveness analysis.
//!
//! The planners optimize the *analytic* peak (Eq. 2); what the paper
//! reports in Table 1 is the peak of the real execution after applying
//! **liveness analysis** [Appel & Palsberg] — each buffer is released
//! right after the op that last uses it. Table 2 is the ablation without
//! liveness: buffers are released only at the points the canonical
//! strategy mandates. Both measurements run over the same [`trace`], and
//! liveness is a trace *rewrite* ([`apply_liveness`]) rather than a
//! second accounting: the rewritten trace carries explicit last-use
//! `Free` events, one fold ([`measure`]) computes the peak of either
//! mode, and [`crate::exec::OpProgram`] compiles the very same rewritten
//! trace — so the schedule the real executor frees buffers on *is* the
//! schedule the simulator priced.

mod trace;

pub use trace::{apply_liveness, canonical_trace, vanilla_trace, Buffer, Event, Trace};

use std::collections::HashMap;

use crate::anyhow::{bail, Result};
use crate::graph::Graph;
use crate::planner::LowerSetChain;

/// Which free schedule a measurement (or a compiled program) honors.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, Default)]
pub enum SimMode {
    /// Free each buffer at the end of the op that last uses it
    /// (Table 1 / Chainer-style eager freeing) — the default, and what
    /// the paper's headline reductions are measured with.
    #[default]
    Liveness,
    /// Honor only the strategy-mandated frees (the Table 2 ablation).
    Strict,
}

impl SimMode {
    /// True in liveness mode.
    pub fn liveness(self) -> bool {
        self == SimMode::Liveness
    }

    /// The mode matching a Table 1 (`true`) / Table 2 (`false`) toggle.
    pub fn from_liveness(on: bool) -> SimMode {
        if on {
            SimMode::Liveness
        } else {
            SimMode::Strict
        }
    }

    /// CLI rendering (`--sim` value).
    pub fn label(self) -> &'static str {
        match self {
            SimMode::Liveness => "liveness",
            SimMode::Strict => "strict",
        }
    }

    /// Parse a `--sim` value.
    pub fn parse(s: &str) -> Result<SimMode> {
        match s.to_ascii_lowercase().as_str() {
            "liveness" => Ok(SimMode::Liveness),
            "strict" => Ok(SimMode::Strict),
            other => bail!("bad sim mode '{other}' (liveness|strict)"),
        }
    }
}

/// Simulator options.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Free schedule: liveness analysis (free each buffer after the op
    /// that last uses it) or strict strategy-mandated frees.
    pub mode: SimMode,
    /// Add the model's parameter bytes to the reported peak (the paper's
    /// Table 1 "includes the memory used by the model parameters itself").
    pub include_params: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { mode: SimMode::Liveness, include_params: true }
    }
}

/// Result of simulating one training step.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Peak live activation+gradient bytes.
    pub peak_bytes: u64,
    /// `peak_bytes` plus parameter bytes if `include_params`.
    pub peak_total: u64,
    /// Recomputation overhead actually incurred (Eq. 1 units).
    pub overhead_time: u64,
    /// Total compute time of the step: forward `T(V)` + backward (modeled
    /// as `BACKWARD_FACTOR × T(V)`) + recomputation overhead.
    pub step_time: u64,
    /// Number of recomputed forward values.
    pub recompute_count: u64,
    /// Index of the trace event at which the peak was reached.
    pub peak_event: usize,
    /// Number of events in the trace.
    pub trace_len: usize,
}

/// Backward compute is modeled as 2× forward (one matmul each for input
/// and weight gradients vs one for forward) — standard roofline accounting.
pub const BACKWARD_FACTOR: u64 = 2;

/// Measure the peak memory of a canonical strategy (Tables 1 & 2).
pub fn simulate(g: &Graph, chain: &LowerSetChain, opts: SimOptions) -> SimReport {
    let tr = canonical_trace(g, chain);
    measure(g, &tr, opts)
}

/// Measure vanilla (no-recomputation) execution.
pub fn simulate_vanilla(g: &Graph, opts: SimOptions) -> SimReport {
    let tr = vanilla_trace(g);
    measure(g, &tr, opts)
}

/// Core measurement over a trace: liveness mode first rewrites the trace
/// so its frees sit at last uses ([`apply_liveness`]), then both modes
/// share the same single fold ([`peak_of_trace`]) — one source of truth
/// for what a free schedule costs. `peak_event`/`trace_len` refer to the
/// trace actually folded (the rewritten one in liveness mode).
pub fn measure(g: &Graph, tr: &Trace, opts: SimOptions) -> SimReport {
    let rewritten;
    let folded: &Trace = match opts.mode {
        SimMode::Liveness => {
            rewritten = apply_liveness(tr);
            &rewritten
        }
        SimMode::Strict => tr,
    };
    let (peak, peak_event) = peak_of_trace(folded);
    let params = if opts.include_params { g.total_param_bytes() } else { 0 };
    let fwd = g.total_time();
    SimReport {
        peak_bytes: peak,
        peak_total: peak + params,
        overhead_time: tr.recompute_time,
        step_time: fwd + BACKWARD_FACTOR * fwd + tr.recompute_time,
        recompute_count: tr.recompute_count,
        peak_event,
        trace_len: folded.events.len(),
    }
}

/// The one peak fold: honor exactly the `Free` events the trace carries
/// (strategy frees in a raw trace, last-use frees in a liveness-rewritten
/// one), validating that reads hit live buffers and that the step ends
/// balanced.
fn peak_of_trace(tr: &Trace) -> (u64, usize) {
    let mut live = 0u64;
    let mut peak = 0u64;
    let mut peak_at = 0usize;
    let mut sizes: HashMap<Buffer, u64> = HashMap::new();
    for (i, ev) in tr.events.iter().enumerate() {
        match *ev {
            Event::Alloc { buffer, bytes, .. } => {
                let prev = sizes.insert(buffer, bytes);
                assert!(prev.is_none(), "double alloc in trace: {buffer:?}");
                live += bytes;
                if live > peak {
                    peak = live;
                    peak_at = i;
                }
            }
            Event::Use { buffer } => {
                assert!(sizes.contains_key(&buffer), "use of dead buffer {buffer:?}");
            }
            Event::Free { buffer } => {
                let bytes = sizes.remove(&buffer).expect("free of dead buffer");
                live -= bytes;
            }
            Event::Backprop { .. } => {}
        }
    }
    assert!(sizes.is_empty(), "buffers leaked: {}", sizes.len());
    (peak, peak_at)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{
        plan_at_min_budget, singleton_chain, whole_graph_chain, Family, Objective,
    };
    use crate::testutil::{chain_graph, random_dag};
    use crate::util::rng::Pcg32;

    #[test]
    fn liveness_never_exceeds_no_liveness() {
        let mut rng = Pcg32::seeded(70);
        for _ in 0..20 {
            let n = rng.range(4, 14);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
            let live = SimOptions { mode: SimMode::Liveness, include_params: false };
            let strict = SimOptions { mode: SimMode::Strict, include_params: false };
            let with = simulate(&g, &plan.chain, live);
            let without = simulate(&g, &plan.chain, strict);
            assert!(with.peak_bytes <= without.peak_bytes);
            assert_eq!(with.overhead_time, without.overhead_time);
        }
    }

    #[test]
    fn liveness_measure_is_the_strict_fold_of_the_rewritten_trace() {
        // One source of truth: measuring a trace in liveness mode must be
        // *exactly* measuring its liveness rewrite in strict mode — the
        // same fold, over the same explicit Free events the executor
        // compiles. Also pins down that the rewrite preserves the
        // recomputation totals (it moves frees, never computation).
        let mut rng = Pcg32::seeded(74);
        for _ in 0..15 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let tr = canonical_trace(&g, &plan.chain);
            let rewritten = apply_liveness(&tr);
            let opts = SimOptions { mode: SimMode::Liveness, include_params: false };
            let strict = SimOptions { mode: SimMode::Strict, include_params: false };
            let via_mode = measure(&g, &tr, opts);
            let via_rewrite = measure(&g, &rewritten, strict);
            assert_eq!(via_mode.peak_bytes, via_rewrite.peak_bytes);
            assert_eq!(via_mode.peak_event, via_rewrite.peak_event);
            assert_eq!(via_mode.trace_len, via_rewrite.trace_len);
            assert_eq!(rewritten.recompute_time, tr.recompute_time);
            assert_eq!(rewritten.recompute_count, tr.recompute_count);
        }
    }

    #[test]
    fn no_liveness_peak_close_to_eq2() {
        // The event-accurate no-liveness peak stays within the analytic
        // Eq. 2 peak plus the cross-segment gradient buffers Eq. 2 books on
        // the producer side (see trace.rs docs). Sanity band: within 2×.
        let mut rng = Pcg32::seeded(71);
        for _ in 0..20 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let eq2 = plan.chain.peak_mem(&g);
            let strict = SimOptions { mode: SimMode::Strict, include_params: false };
            let meas = simulate(&g, &plan.chain, strict);
            assert!(meas.peak_bytes <= 2 * eq2, "measured {} vs eq2 {}", meas.peak_bytes, eq2);
            assert!(2 * meas.peak_bytes >= eq2, "measured {} vs eq2 {}", meas.peak_bytes, eq2);
        }
    }

    #[test]
    fn vanilla_peak_at_least_total_mem() {
        let g = chain_graph(&[5, 5, 5, 5, 5]);
        let r = simulate_vanilla(&g, SimOptions { mode: SimMode::Liveness, include_params: false });
        assert!(r.peak_bytes >= g.total_mem());
        assert_eq!(r.overhead_time, 0);
        assert_eq!(r.step_time, 3 * g.total_time());
    }

    #[test]
    fn recomputation_reduces_peak_on_chain() {
        // Long uniform chain: any reasonable plan beats vanilla.
        let g = chain_graph(&[10; 40]);
        let live = SimOptions { mode: SimMode::Liveness, include_params: false };
        let vanilla = simulate_vanilla(&g, live);
        let plan = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
        let ours = simulate(&g, &plan.chain, live);
        assert!(
            ours.peak_bytes < vanilla.peak_bytes,
            "ours {} vanilla {}",
            ours.peak_bytes,
            vanilla.peak_bytes
        );
        // √n-checkpointing scale: 40 nodes ⇒ peak well under half vanilla.
        assert!(ours.peak_bytes * 2 < vanilla.peak_bytes);
    }

    #[test]
    fn mc_with_liveness_beats_or_ties_tc_peak_on_average() {
        // §4.4's empirical claim, checked as a tendency over many random
        // graphs: the *average* MC peak (with liveness) must not exceed the
        // average TC peak.
        let mut rng = Pcg32::seeded(72);
        let (mut mc_sum, mut tc_sum) = (0u64, 0u64);
        for _ in 0..30 {
            let n = rng.range(6, 14);
            let g = random_dag(&mut rng, n);
            let tc = plan_at_min_budget(&g, Family::Exact, Objective::MinOverhead).unwrap();
            let mc = plan_at_min_budget(&g, Family::Exact, Objective::MaxOverhead).unwrap();
            let opts = SimOptions { mode: SimMode::Liveness, include_params: false };
            tc_sum += simulate(&g, &tc.chain, opts).peak_bytes;
            mc_sum += simulate(&g, &mc.chain, opts).peak_bytes;
        }
        assert!(mc_sum <= tc_sum, "mc {} vs tc {}", mc_sum, tc_sum);
    }

    #[test]
    fn overhead_time_matches_plan() {
        let mut rng = Pcg32::seeded(73);
        for _ in 0..10 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Approx, Objective::MinOverhead).unwrap();
            let r = simulate(&g, &plan.chain, SimOptions::default());
            assert_eq!(r.overhead_time, plan.overhead);
        }
    }

    #[test]
    fn params_included_when_requested() {
        use crate::graph::{GraphBuilder, OpKind};
        let mut b = GraphBuilder::new("p", 1);
        let x = b.add_with("c", OpKind::Conv, &[4, 4, 4], &[], 1234);
        let _ = b.add("r", OpKind::Activation, &[4, 4, 4], &[x]);
        let g = b.build();
        let with =
            simulate_vanilla(&g, SimOptions { mode: SimMode::Liveness, include_params: true });
        let without =
            simulate_vanilla(&g, SimOptions { mode: SimMode::Liveness, include_params: false });
        assert_eq!(with.peak_total, without.peak_bytes + 1234);
    }

    #[test]
    fn whole_graph_chain_extreme() {
        // Single-segment plan: maximal overhead (T(V)), maximal fwd+bwd
        // working set without liveness.
        let g = chain_graph(&[3, 3, 3, 3]);
        let w = whole_graph_chain(&g);
        let r = simulate(&g, &w, SimOptions { mode: SimMode::Strict, include_params: false });
        assert_eq!(r.overhead_time, g.total_time());
        let s = singleton_chain(&g);
        let rs = simulate(&g, &s, SimOptions { mode: SimMode::Strict, include_params: false });
        assert!(rs.overhead_time <= r.overhead_time);
    }
}
