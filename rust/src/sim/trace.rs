//! Event-trace generation for canonical strategies.
//!
//! Translates a [`LowerSetChain`] into the exact sequence of buffer events
//! (allocate / read / strategy-mandated free) that one training step
//! executes under the canonical strategy of §3:
//!
//! **Forward** — per segment `V_i` in topo order: compute every node
//! (reading its predecessors), then discard `V_i \ ∂(L_i)`.
//!
//! **Backward** — per segment `i = k..1`:
//! 1. recompute the discarded forward values of `V_i` from the caches;
//! 2. backprop each `v ∈ V_i` in reverse topo order, reading `fwd(preds)`,
//!    `fwd(v)` and `grad(v)`, allocating `grad(p)` for predecessors;
//! 3. free the segment's recomputed forward values, its forward caches
//!    (this was the last segment that needed them) and its own gradients,
//!    keeping gradients that flow into earlier segments.
//!
//! The trace is the single source of truth for both memory-measurement
//! modes (Table 1 with liveness, Table 2 without) and is structurally
//! checked: every read must target a live buffer, which proves the
//! canonical strategy never uses a value it discarded — the core safety
//! property of the whole approach. The liveness mode is itself a trace
//! *rewrite* ([`apply_liveness`]): strategy-mandated frees are replaced
//! by a `Free` at each buffer's last use, so both modes are measured by
//! the same single fold over events — and the rewritten trace stays
//! executable, because frees land at **op-group boundaries** (after the
//! op that performed the last read completes, never mid-op; a real
//! kernel needs its inputs and its output live simultaneously).
//!
//! Byte accounting is **per node** throughout: every `Fwd` *and* `Grad`
//! allocation charges that node's own `M_v` (a gradient has its node's
//! shape), so traces of heterogeneously-shaped lowerings — where each
//! node holds a different `[batch, width_v]` tensor — predict exactly
//! the bytes the executor observes.
//!
//! Traces are also *executable*: every forward materialization is an
//! [`Event::Alloc`] of a `Fwd` buffer and every backward op is announced
//! by an explicit [`Event::Backprop`] marker, so
//! [`crate::exec::OpProgram`] can compile a trace into the exact kernel
//! schedule a real backend runs — same events drive the simulator's
//! accounting and the executor's kernels.

use crate::graph::{Graph, NodeId, NodeSet};
use crate::planner::LowerSetChain;

/// A buffer instance in the trace. Forward values can be materialized
/// twice (original + recomputation), so instances carry a generation tag.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Buffer {
    /// Forward value of a node; `gen` 0 = original, 1 = recomputed.
    Fwd { node: NodeId, gen: u8 },
    /// Gradient w.r.t. a node's output.
    Grad { node: NodeId },
}

impl Buffer {
    pub fn node(&self) -> NodeId {
        match *self {
            Buffer::Fwd { node, .. } | Buffer::Grad { node } => node,
        }
    }
}

/// One event of the step trace.
#[derive(Clone, Copy, Debug)]
pub enum Event {
    /// Materialize `buffer` (`bytes` = `M_v`); `compute_time` is the `T_v`
    /// charged for producing it (0 for gradient allocations, which are
    /// accounted on the consumer's backward node).
    Alloc { buffer: Buffer, bytes: u64, compute_time: u64, recompute: bool },
    /// Read `buffer` (must be live).
    Use { buffer: Buffer },
    /// Strategy-mandated free (honored in no-liveness mode; liveness mode
    /// recomputes frees from last uses).
    Free { buffer: Buffer },
    /// The backward op of `node` executes at this point; its reads
    /// (`fwd(node)`, `grad(node)`, `fwd(preds)`) and the gradient
    /// allocations for its predecessors follow as separate events. No
    /// memory effect of its own — the marker exists so the executor can
    /// compile the trace into real kernel calls.
    Backprop { node: NodeId },
}

/// The step trace plus bookkeeping totals.
#[derive(Clone, Debug)]
pub struct Trace {
    pub events: Vec<Event>,
    /// Op-group id of each event (parallel to `events`, nondecreasing).
    /// A group is one executable unit — a forward materialization with
    /// its input reads, a backward op with its reads and gradient
    /// allocations, or a loss-gradient seed. [`apply_liveness`] frees
    /// each buffer at the end of the group holding its last use, which
    /// keeps rewritten traces executable by real kernels.
    pub op_of: Vec<u32>,
    /// Total recomputation time charged (should equal Eq. 1 overhead).
    pub recompute_time: u64,
    /// Number of forward-value recomputations.
    pub recompute_count: u64,
}

/// Rewrite a trace so that every buffer is freed exactly once, at the
/// end of the op group containing its last use (or its allocation, if
/// never read). Strategy-mandated frees are dropped — liveness strictly
/// refines them, since a buffer's last use never comes after the
/// strategy's free (the builder would have panicked on the dead read).
/// Frees within one group are emitted in a deterministic buffer order,
/// so rewritten traces — and the programs compiled from them — are
/// bit-reproducible. Recomputation totals are preserved: liveness moves
/// frees, never computation.
pub fn apply_liveness(tr: &Trace) -> Trace {
    use std::collections::HashMap;
    debug_assert_eq!(tr.events.len(), tr.op_of.len(), "op_of must parallel events");
    // Last op group that materializes or reads each buffer, plus the
    // index of each group's last non-free event (frees trail groups, so
    // they never define a group's end).
    let mut last_op: HashMap<Buffer, u32> = HashMap::new();
    let mut group_end: HashMap<u32, usize> = HashMap::new();
    for (i, (ev, &op)) in tr.events.iter().zip(&tr.op_of).enumerate() {
        match *ev {
            Event::Alloc { buffer, .. } | Event::Use { buffer } => {
                last_op.insert(buffer, op);
                group_end.insert(op, i);
            }
            Event::Backprop { .. } => {
                group_end.insert(op, i);
            }
            Event::Free { .. } => {}
        }
    }
    // Buffers to free after each group, sorted for determinism.
    let mut frees: HashMap<u32, Vec<Buffer>> = HashMap::new();
    for (&buf, &op) in &last_op {
        frees.entry(op).or_default().push(buf);
    }
    for list in frees.values_mut() {
        list.sort_by_key(|b| match *b {
            Buffer::Fwd { node, gen } => (0u8, node.0, gen),
            Buffer::Grad { node } => (1u8, node.0, 0),
        });
    }
    let mut events = Vec::with_capacity(tr.events.len());
    let mut op_of = Vec::with_capacity(tr.events.len());
    for (i, (&ev, &op)) in tr.events.iter().zip(&tr.op_of).enumerate() {
        if matches!(ev, Event::Free { .. }) {
            continue; // replaced by the last-use frees below
        }
        events.push(ev);
        op_of.push(op);
        if group_end.get(&op) == Some(&i) {
            for buf in frees.remove(&op).unwrap_or_default() {
                events.push(Event::Free { buffer: buf });
                op_of.push(op);
            }
        }
    }
    debug_assert!(frees.is_empty(), "liveness left unfreed buffers behind");
    Trace {
        events,
        op_of,
        recompute_time: tr.recompute_time,
        recompute_count: tr.recompute_count,
    }
}

/// Generate the canonical-strategy trace for one training step.
pub fn canonical_trace(g: &Graph, chain: &LowerSetChain) -> Trace {
    let mut tb = TraceBuilder::new(g);
    let segments = chain.segments();
    let lower_sets = chain.lower_sets();

    // ---- forward ---------------------------------------------------------
    for (i, seg) in segments.iter().enumerate() {
        for &v in g.topo_order() {
            if !seg.contains(v) {
                continue;
            }
            tb.begin_op();
            for &p in g.preds(v) {
                tb.use_fwd(p);
            }
            tb.alloc_fwd(v, false);
        }
        // Discard V_i \ ∂(L_i).
        let boundary = g.boundary(&lower_sets[i]);
        for &v in g.topo_order() {
            if seg.contains(v) && !boundary.contains(v) {
                tb.free_fwd(v);
            }
        }
    }

    // ---- backward --------------------------------------------------------
    // Loss gradients: every global sink receives its gradient up front.
    for v in g.sinks() {
        tb.begin_op();
        tb.alloc_grad(v);
    }
    for i in (0..segments.len()).rev() {
        let seg = &segments[i];
        let boundary = g.boundary(&lower_sets[i]);
        // 1. Recompute discarded forward values (topo order). Their inputs
        //    are either cached boundaries of earlier segments or previously
        //    recomputed nodes of this segment.
        for &v in g.topo_order() {
            if seg.contains(v) && !boundary.contains(v) {
                tb.begin_op();
                for &p in g.preds(v) {
                    tb.use_fwd(p);
                }
                tb.alloc_fwd(v, true);
            }
        }
        // 2. Backprop in reverse topo order.
        for &v in g.topo_order().iter().rev() {
            if !seg.contains(v) {
                continue;
            }
            tb.begin_op();
            tb.backprop(v);
            // Reads: own output, own gradient, predecessors' outputs.
            tb.use_fwd(v);
            tb.use_grad(v);
            for &p in g.preds(v) {
                tb.use_fwd(p);
                tb.alloc_grad(p); // no-op if already allocated
            }
        }
        // 3. Strategy-mandated frees.
        //    Forward values of V_i (cached or recomputed): the backward of
        //    this segment was their last consumer.
        for &v in g.topo_order() {
            if seg.contains(v) {
                tb.free_fwd(v);
            }
        }
        //    Gradients of V_i: consumed by their own backward nodes.
        //    Gradients allocated for predecessors in earlier segments stay.
        for &v in g.topo_order() {
            if seg.contains(v) {
                tb.free_grad(v);
            }
        }
    }
    tb.finish()
}

/// Vanilla execution: cache every forward value, no recomputation.
/// Frees are emitted at natural points (forward values and gradients after
/// their last backward consumer) so the *no-liveness* measurement of this
/// trace matches a naive deep-learning framework; the liveness measurement
/// matches Chainer's eager freeing (Appendix C discussion).
pub fn vanilla_trace(g: &Graph) -> Trace {
    let mut tb = TraceBuilder::new(g);
    for &v in g.topo_order() {
        tb.begin_op();
        for &p in g.preds(v) {
            tb.use_fwd(p);
        }
        tb.alloc_fwd(v, false);
    }
    for v in g.sinks() {
        tb.begin_op();
        tb.alloc_grad(v);
    }
    for &v in g.topo_order().iter().rev() {
        tb.begin_op();
        tb.backprop(v);
        tb.use_fwd(v);
        tb.use_grad(v);
        for &p in g.preds(v) {
            tb.use_fwd(p);
            tb.alloc_grad(p);
        }
        // Naive framework: keeps everything until the step ends. Emit the
        // frees at the very end (below), not here.
    }
    let all: Vec<NodeId> = g.topo_order().to_vec();
    for &v in &all {
        tb.free_fwd(v);
        tb.free_grad(v);
    }
    tb.finish()
}

// ---------------------------------------------------------------------------

struct TraceBuilder<'g> {
    g: &'g Graph,
    events: Vec<Event>,
    /// Op-group id per event (see [`Trace::op_of`]).
    ops: Vec<u32>,
    cur_op: u32,
    /// Current generation of each node's forward value: None = not live.
    fwd_gen: Vec<Option<u8>>,
    grad_live: NodeSet,
    recompute_time: u64,
    recompute_count: u64,
}

impl<'g> TraceBuilder<'g> {
    fn new(g: &'g Graph) -> Self {
        TraceBuilder {
            g,
            events: Vec::with_capacity(g.len() as usize * 8),
            ops: Vec::with_capacity(g.len() as usize * 8),
            cur_op: 0,
            fwd_gen: vec![None; g.len() as usize],
            grad_live: NodeSet::empty(g.len()),
            recompute_time: 0,
            recompute_count: 0,
        }
    }

    /// Start a new op group; subsequent events belong to it. The
    /// generators call this once per executable unit (forward compute,
    /// loss seed, backward op); strategy frees stay attached to the
    /// preceding group, which is harmless — [`apply_liveness`] drops
    /// them and group ends are defined by non-free events only.
    fn begin_op(&mut self) {
        self.cur_op += 1;
    }

    fn push(&mut self, ev: Event) {
        self.events.push(ev);
        self.ops.push(self.cur_op);
    }

    fn alloc_fwd(&mut self, v: NodeId, recompute: bool) {
        let gen = if recompute { 1 } else { 0 };
        assert!(
            self.fwd_gen[v.0 as usize].is_none(),
            "double allocation of fwd({}) — strategy bug",
            self.g.node(v).name
        );
        self.fwd_gen[v.0 as usize] = Some(gen);
        let node = self.g.node(v);
        if recompute {
            self.recompute_time += node.time;
            self.recompute_count += 1;
        }
        self.push(Event::Alloc {
            buffer: Buffer::Fwd { node: v, gen },
            bytes: node.mem,
            compute_time: node.time,
            recompute,
        });
    }

    fn use_fwd(&mut self, v: NodeId) {
        let gen = self.fwd_gen[v.0 as usize].unwrap_or_else(|| {
            panic!(
                "use of dead fwd({}) — canonical strategy read a discarded value",
                self.g.node(v).name
            )
        });
        self.push(Event::Use { buffer: Buffer::Fwd { node: v, gen } });
    }

    fn free_fwd(&mut self, v: NodeId) {
        if let Some(gen) = self.fwd_gen[v.0 as usize].take() {
            self.push(Event::Free { buffer: Buffer::Fwd { node: v, gen } });
        }
    }

    fn alloc_grad(&mut self, v: NodeId) {
        if self.grad_live.contains(v) {
            return; // gradient accumulates into the existing buffer
        }
        self.grad_live.insert(v);
        self.push(Event::Alloc {
            buffer: Buffer::Grad { node: v },
            bytes: self.g.node(v).mem,
            compute_time: 0,
            recompute: false,
        });
    }

    fn backprop(&mut self, v: NodeId) {
        self.push(Event::Backprop { node: v });
    }

    fn use_grad(&mut self, v: NodeId) {
        assert!(
            self.grad_live.contains(v),
            "use of dead grad({}) — gradient freed too early",
            self.g.node(v).name
        );
        self.push(Event::Use { buffer: Buffer::Grad { node: v } });
    }

    fn free_grad(&mut self, v: NodeId) {
        if self.grad_live.contains(v) {
            self.grad_live.remove(v);
            self.push(Event::Free { buffer: Buffer::Grad { node: v } });
        }
    }

    fn finish(self) -> Trace {
        // Everything must have been freed — a trace that leaks buffers
        // would misreport the next step's baseline.
        debug_assert!(
            self.fwd_gen.iter().all(Option::is_none),
            "forward buffers leaked at end of step"
        );
        debug_assert!(self.grad_live.is_empty(), "gradient buffers leaked at end of step");
        Trace {
            events: self.events,
            op_of: self.ops,
            recompute_time: self.recompute_time,
            recompute_count: self.recompute_count,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, OpKind};
    use crate::planner::{singleton_chain, whole_graph_chain, LowerSetChain};

    fn chain_graph(mems: &[u64]) -> Graph {
        let mut b = GraphBuilder::new("chain", 1);
        let mut prev: Option<NodeId> = None;
        for (i, &m) in mems.iter().enumerate() {
            let inputs: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, m, 1, &inputs));
        }
        b.build()
    }

    #[test]
    fn recompute_time_matches_eq1() {
        let g = chain_graph(&[1, 2, 3, 4, 5, 6]);
        for chain in [
            singleton_chain(&g),
            whole_graph_chain(&g),
            LowerSetChain::new(
                &g,
                vec![
                    NodeSet::from_iter(6, (0..3).map(NodeId)),
                    NodeSet::from_iter(6, (0..6).map(NodeId)),
                ],
            )
            .unwrap(),
        ] {
            let trace = canonical_trace(&g, &chain);
            assert_eq!(trace.recompute_time, chain.overhead(&g), "Eq. 1 consistency");
        }
    }

    #[test]
    fn vanilla_has_no_recompute() {
        let g = chain_graph(&[1, 2, 3]);
        let t = vanilla_trace(&g);
        assert_eq!(t.recompute_time, 0);
        assert_eq!(t.recompute_count, 0);
    }

    #[test]
    fn canonical_trace_never_reads_dead_buffers_on_random_graphs() {
        // The TraceBuilder panics on any dead read, so simply generating
        // traces for random graphs × random plans is the assertion.
        use crate::planner::{plan_at_min_budget, Family, Objective};
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(60);
        for _ in 0..15 {
            let n = rng.range(4, 12);
            let g = crate::testutil::random_dag(&mut rng, n);
            for family in [Family::Exact, Family::Approx] {
                for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
                    let plan = plan_at_min_budget(&g, family, obj).unwrap();
                    let _ = canonical_trace(&g, &plan.chain);
                }
            }
        }
    }

    #[test]
    fn liveness_frees_never_precede_the_consuming_op() {
        // Chain 0→1→2, vanilla, backward order 2, 1, 0. fwd(1)'s last
        // read is the backward of node 1 itself (its own output); that op
        // also reads fwd(0) and allocates grad(0). The rewrite must place
        // Free(fwd 1) after that *whole* op group — after grad(0) is
        // allocated, never between the op's reads — and before the next
        // backward op begins. Likewise the sink's activation dies right
        // after the sink's own backward, long before the strategy's
        // end-of-step frees.
        let g = chain_graph(&[1, 1, 1]);
        let tr = apply_liveness(&vanilla_trace(&g));
        let pos = |pred: &dyn Fn(&Event) -> bool| {
            tr.events.iter().position(|e| pred(e)).expect("event present")
        };
        let free_fwd = |id: u32| {
            pos(&move |e| {
                matches!(e, Event::Free { buffer: Buffer::Fwd { node, .. } } if node.0 == id)
            })
        };
        let backprop = |id: u32| pos(&move |e| {
            matches!(e, Event::Backprop { node } if node.0 == id)
        });
        let alloc_grad0 = pos(&|e| {
            matches!(e, Event::Alloc { buffer: Buffer::Grad { node }, .. } if node.0 == 0)
        });
        assert!(backprop(2) < free_fwd(2), "sink activation outlives its own backward");
        assert!(free_fwd(2) < backprop(1), "…but dies before the next backward op");
        assert!(backprop(1) < free_fwd(1), "freed only after its last consumer runs");
        assert!(alloc_grad0 < free_fwd(1), "freed after the whole op group, not mid-op");
        assert!(free_fwd(1) < backprop(0), "freed before the next op begins");
    }

    #[test]
    fn liveness_rewrite_is_balanced_and_readable_on_random_plans() {
        // Every Use in the rewritten trace must target a live buffer and
        // every Alloc must be balanced by exactly one Free — checked by
        // replaying the rewrite with a strict interpreter.
        use crate::planner::{plan_at_min_budget, Family, Objective};
        use crate::util::rng::Pcg32;
        use std::collections::HashSet;
        let mut rng = Pcg32::seeded(61);
        for _ in 0..12 {
            let n = rng.range(4, 12);
            let g = crate::testutil::random_dag(&mut rng, n);
            let plan = plan_at_min_budget(&g, Family::Approx, Objective::MaxOverhead).unwrap();
            let tr = apply_liveness(&canonical_trace(&g, &plan.chain));
            assert_eq!(tr.events.len(), tr.op_of.len());
            let mut live: HashSet<Buffer> = HashSet::new();
            for ev in &tr.events {
                match *ev {
                    Event::Alloc { buffer, .. } => {
                        assert!(live.insert(buffer), "double alloc {buffer:?}");
                    }
                    Event::Use { buffer } => {
                        assert!(live.contains(&buffer), "dead read {buffer:?}");
                    }
                    Event::Free { buffer } => {
                        assert!(live.remove(&buffer), "double free {buffer:?}");
                    }
                    Event::Backprop { .. } => {}
                }
            }
            assert!(live.is_empty(), "rewrite leaked {} buffers", live.len());
        }
    }

    #[test]
    fn skip_connection_cache_survives_until_consumer_segment() {
        // 0→1→2→3 with skip 1→3; chain {0,1} ≺ {0,1,2} ≺ V.
        let mut b = GraphBuilder::new("skip", 1);
        let n0 = b.add_raw("n0", OpKind::Other, 1, 1, &[]);
        let n1 = b.add_raw("n1", OpKind::Other, 1, 1, &[n0]);
        let n2 = b.add_raw("n2", OpKind::Other, 1, 1, &[n1]);
        let _n3 = b.add_raw("n3", OpKind::Other, 1, 1, &[n2, n1]);
        let g = b.build();
        let chain = LowerSetChain::new(
            &g,
            vec![
                NodeSet::from_iter(4, [n0, n1]),
                NodeSet::from_iter(4, [n0, n1, n2]),
                NodeSet::full(4),
            ],
        )
        .unwrap();
        // Would panic if the cache of n1 were discarded before segment 3's
        // backward (n3 reads fwd(n1)).
        let trace = canonical_trace(&g, &chain);
        assert!(trace.events.len() > 10);
    }
}
