//! `NativeBackend` — the pure-Rust f32 CPU reference backend.
//!
//! Implements the dense kernels of `python/compile/kernels/ref.py`
//! (matmul + bias + tanh-approximated GELU, the MSE regression head, and
//! plain SGD), so the whole training stack runs with zero Python, zero
//! AOT artifacts, and zero native libraries. Every kernel is
//! *dimension-driven*: shapes are read from the argument tensors, the
//! dense path is rectangular (`[m, k_in] × [k_in, k_out]`), and nothing
//! is specialized to a fixed `(batch, width)` — the backend executes
//! heterogeneous per-node shapes as naturally as uniform ones. Gradients
//! were derived analytically and are cross-checked in the tests below by
//! central finite differences against the forward kernels.
//!
//! Tensors are `Rc`-shared host buffers: cloning is O(1), which matches
//! how the trainer models checkpoint caching. Every buffer the backend
//! produces (uploads and kernel outputs) is counted in a live-byte
//! tracker that its `Drop` decrements, so [`Backend::live_bytes`] is an
//! exact census of outstanding allocations — the leak regression tests
//! assert it returns to baseline after training.
//!
//! Allocation goes through a size-classed [`MemoryPool`]: every buffer a
//! kernel or upload materializes is drawn from per-power-of-two free
//! lists, and a dropped tensor's storage is parked back into its class
//! instead of hitting the allocator. Under a liveness schedule — where
//! activations die at last use and recomputation re-materializes them
//! moments later — nearly every allocation after warm-up is a reuse, so
//! the extra free/recompute churn costs no malloc traffic. The census
//! above is *unchanged* by pooling (it counts live tensors); the pool's
//! own footprint is reported separately via [`Backend::pool_stats`].

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use crate::anyhow::{bail, Result};

use super::gemm::{matmul_auto as matmul, matmul_nt_auto as matmul_nt, matmul_tn_auto as matmul_tn};
use super::{Backend, KernelStat, PoolStats, DAG_KERNELS, TOWER_KERNELS};

/// A size-classed recycling allocator for f32 host buffers.
///
/// Buffers are bucketed by their length rounded up to a power of two;
/// [`MemoryPool::writable`]/[`MemoryPool::zeroed`]/[`MemoryPool::copied`]
/// pop a parked buffer of the exact class when one exists (a *reuse*)
/// and fall back to a fresh `Vec` otherwise (an *alloc*). Returning
/// storage happens automatically: the owning [`TensorBuf`]'s `Drop`
/// parks its data back into the pool, bounded per class so pathological
/// shape mixes cannot hoard memory. Handles are cheap `Rc` clones of one
/// shared pool, mirroring how tensors share the live-byte tracker.
#[derive(Clone, Default)]
pub struct MemoryPool {
    inner: Rc<RefCell<PoolInner>>,
}

#[derive(Default)]
struct PoolInner {
    /// Parked buffers per size class (class = elems rounded up to pow2).
    classes: BTreeMap<usize, Vec<Vec<f32>>>,
    allocs: u64,
    reuses: u64,
    /// Bytes currently parked in `classes`.
    parked: u64,
    /// Bytes currently handed out to live buffers (class-granular).
    outstanding: u64,
    high_water: u64,
}

impl MemoryPool {
    /// Parked buffers kept per class; beyond this, freed storage really
    /// goes back to the allocator (keeps worst-case hoarding bounded).
    const MAX_PER_CLASS: usize = 32;

    /// Size class of a buffer length: the next power of two (≥ 1).
    fn class_of(len: usize) -> usize {
        len.max(1).next_power_of_two()
    }

    /// A buffer with `len == 0` and capacity ≥ `len` — for kernels that
    /// `push` exactly `len` elements. The charged class is `class_of(len)`,
    /// so the producer must fill it to exactly `len` (every kernel does).
    pub fn writable(&self, len: usize) -> Vec<f32> {
        let cls = Self::class_of(len);
        let mut inner = self.inner.borrow_mut();
        let buf = inner.classes.get_mut(&cls).and_then(Vec::pop);
        let buf = match buf {
            Some(mut b) => {
                inner.reuses += 1;
                inner.parked -= (cls * 4) as u64;
                b.clear();
                b
            }
            None => {
                inner.allocs += 1;
                Vec::with_capacity(cls)
            }
        };
        inner.outstanding += (cls * 4) as u64;
        inner.high_water = inner.high_water.max(inner.outstanding + inner.parked);
        buf
    }

    /// A zero-filled buffer of exactly `len` elements.
    pub fn zeroed(&self, len: usize) -> Vec<f32> {
        let mut b = self.writable(len);
        b.resize(len, 0.0);
        b
    }

    /// A buffer holding a copy of `src`.
    pub fn copied(&self, src: &[f32]) -> Vec<f32> {
        let mut b = self.writable(src.len());
        b.extend_from_slice(src);
        b
    }

    /// Park a dropped tensor's storage for reuse (called from
    /// [`TensorBuf`]'s `Drop`, by kernels returning scratch buffers, and
    /// by the GEMM pack panels in [`super::gemm`]). The class is
    /// recomputed from the length, which never changes after adoption —
    /// tensors are immutable. `saturating_sub` keeps the ledger safe
    /// even for storage that was built outside the pool and adopted
    /// later.
    pub(crate) fn give(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let cls = Self::class_of(v.len());
        let mut guard = self.inner.borrow_mut();
        let inner = &mut *guard;
        inner.outstanding = inner.outstanding.saturating_sub((cls * 4) as u64);
        let bucket = inner.classes.entry(cls).or_default();
        if bucket.len() < Self::MAX_PER_CLASS {
            bucket.push(v);
            inner.parked += (cls * 4) as u64;
        }
    }

    /// Snapshot of the pool counters.
    pub fn stats(&self) -> PoolStats {
        let inner = self.inner.borrow();
        PoolStats {
            allocs: inner.allocs,
            reuses: inner.reuses,
            parked_bytes: inner.parked,
            high_water_bytes: inner.high_water,
        }
    }
}

/// The backing store of a [`HostTensor`]: the flat data plus (once the
/// owning backend adopts the tensor) a live-byte tracker decremented on
/// drop and the pool the storage returns to.
struct TensorBuf {
    data: Vec<f32>,
    tracker: Option<Rc<Cell<u64>>>,
    pool: Option<MemoryPool>,
}

impl Drop for TensorBuf {
    fn drop(&mut self) {
        if let Some(t) = &self.tracker {
            t.set(t.get() - (self.data.len() * 4) as u64);
        }
        if let Some(pool) = &self.pool {
            pool.give(std::mem::take(&mut self.data));
        }
    }
}

/// A host-side f32 tensor: row-major data + dims (`[]` = scalar).
#[derive(Clone)]
pub struct HostTensor {
    buf: Rc<TensorBuf>,
    dims: Vec<usize>,
}

impl HostTensor {
    fn new(data: Vec<f32>, dims: Vec<usize>) -> HostTensor {
        debug_assert_eq!(data.len(), dims.iter().product::<usize>().max(1));
        HostTensor { buf: Rc::new(TensorBuf { data, tracker: None, pool: None }), dims }
    }

    /// Flat row-major view of the data.
    pub fn data(&self) -> &[f32] {
        &self.buf.data
    }

    /// Dimensions (`[]` = scalar).
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.buf.data.len()
    }

    /// True iff the tensor holds no elements (unreachable for tensors
    /// built through `upload`, which always hold at least a scalar).
    pub fn is_empty(&self) -> bool {
        self.buf.data.is_empty()
    }

    /// Logical size in bytes (f32).
    pub fn bytes(&self) -> u64 {
        (self.buf.data.len() * 4) as u64
    }
}

/// The pure-Rust CPU backend. Shape-free: kernels validate and size
/// themselves from their argument tensors, so one instance serves any
/// mix of tensor shapes. All buffer storage — uploads and kernel
/// outputs — is drawn from (and returned to) the backend's
/// [`MemoryPool`].
#[derive(Default)]
pub struct NativeBackend {
    /// Bytes held by live tensors this backend has produced.
    live: Rc<Cell<u64>>,
    /// Recycling allocator behind every tensor this backend produces.
    pool: MemoryPool,
    stats: RefCell<BTreeMap<String, KernelStat>>,
}

impl NativeBackend {
    /// A fresh backend with empty stats, a zeroed live-byte tracker and
    /// an empty buffer pool.
    pub fn new() -> NativeBackend {
        NativeBackend::default()
    }

    fn record(&self, kernel: &str, t0: Instant, bytes_in: u64, bytes_out: u64, flops: u64) {
        super::record_call(
            &mut self.stats.borrow_mut(),
            kernel,
            t0.elapsed(),
            bytes_in,
            bytes_out,
            flops,
        );
    }

    /// Attach the live-byte tracker and the pool to a freshly built
    /// tensor (uploads and kernel outputs have refcount 1 here;
    /// already-adopted or shared tensors pass through unchanged). From
    /// here on the tensor's storage returns to the pool when it drops.
    fn adopt(&self, mut t: HostTensor) -> HostTensor {
        if let Some(buf) = Rc::get_mut(&mut t.buf) {
            if buf.tracker.is_none() {
                self.live.set(self.live.get() + (buf.data.len() * 4) as u64);
                buf.tracker = Some(Rc::clone(&self.live));
                buf.pool = Some(self.pool.clone());
            }
        }
        t
    }
}

impl Backend for NativeBackend {
    type Tensor = HostTensor;

    fn name(&self) -> &'static str {
        "native"
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<HostTensor> {
        let expect: usize = dims.iter().product::<usize>().max(1);
        if data.len() != expect {
            bail!("upload shape mismatch: {} elems for dims {dims:?}", data.len());
        }
        Ok(self.adopt(HostTensor::new(self.pool.copied(data), dims.to_vec())))
    }

    fn download(&self, t: &HostTensor) -> Result<Vec<f32>> {
        Ok(t.buf.data.clone())
    }

    fn tensor_bytes(&self, t: &HostTensor) -> u64 {
        t.bytes()
    }

    fn live_bytes(&self) -> Option<u64> {
        Some(self.live.get())
    }

    fn pool_stats(&self) -> Option<PoolStats> {
        Some(self.pool.stats())
    }

    fn run(&self, name: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let bytes_in: u64 = args.iter().map(HostTensor::bytes).sum();
        let flops = flops_of(name, args);
        let pool = &self.pool;
        let outs = match name {
            "layer_fwd" => layer_fwd(pool, args)?,
            "layer_bwd" => layer_bwd(pool, args)?,
            "loss_head_fwd" => loss_head_fwd(pool, args)?,
            "loss_head_bwd" => loss_head_bwd(pool, args)?,
            "sgd_mat" => sgd(pool, name, args, 2)?,
            "sgd_vec" => sgd(pool, name, args, 1)?,
            "add" => add(pool, args)?,
            "scale" => scale(pool, args)?,
            "mse" => mse(pool, args)?,
            other => bail!(
                "native backend has no kernel '{other}' (have: {TOWER_KERNELS:?} + {DAG_KERNELS:?})"
            ),
        };
        let outs: Vec<HostTensor> = outs.into_iter().map(|t| self.adopt(t)).collect();
        let bytes_out: u64 = outs.iter().map(HostTensor::bytes).sum();
        self.record(name, t0, bytes_in, bytes_out, flops);
        Ok(outs)
    }

    fn kernels(&self) -> Vec<String> {
        let mut ks: Vec<String> =
            TOWER_KERNELS.iter().chain(DAG_KERNELS.iter()).map(|s| s.to_string()).collect();
        ks.sort();
        ks
    }

    fn stats(&self) -> Vec<KernelStat> {
        self.stats.borrow().values().cloned().collect()
    }
}

/// Attributed floating-point operations of one kernel call, read from
/// the argument shapes *before* validation (malformed calls attribute 0
/// and then fail inside the kernel). Dense kernels count `2·m·k·n` per
/// matmul — one forward product, or three products (`dz`-recompute +
/// `gx` + `gw`) for the backward passes; elementwise kernels count one
/// flop per input element. These feed `KernelStat::gflops()`.
fn flops_of(name: &str, args: &[HostTensor]) -> u64 {
    let dense_mkn = || -> u64 {
        match (args.first().map(HostTensor::dims), args.get(1).map(HostTensor::dims)) {
            (Some([m, k]), Some([k2, n])) if k == k2 => (m * k * n) as u64,
            _ => 0,
        }
    };
    match name {
        "layer_fwd" | "loss_head_fwd" => 2 * dense_mkn(),
        "layer_bwd" | "loss_head_bwd" => 6 * dense_mkn(),
        _ => args.first().map_or(0, |t| t.len() as u64),
    }
}

// ---- kernel math ---------------------------------------------------------

/// sqrt(2/π), f32 — the tanh-GELU constant.
const GELU_C: f32 = 0.797_884_6;
/// The cubic coefficient of the tanh-GELU approximation.
const GELU_A: f32 = 0.044_715;

/// GELU, tanh approximation — identical to `jax.nn.gelu(approximate=True)`.
fn gelu(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    0.5 * x * (1.0 + inner.tanh())
}

/// d gelu / dx of the tanh approximation.
fn gelu_prime(x: f32) -> f32 {
    let inner = GELU_C * (x + GELU_A * x * x * x);
    let t = inner.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * x * x)
}

// The three matrix products (`A·B`, `A·Bᵀ`, `Aᵀ·B`) live in
// [`super::gemm`], imported above under their historical local names:
// each dispatches to the blocked/SIMD tiled path (or the naive
// reference loops) per the process-wide `gemm::active_tier()`.

/// `z[m,n] += bias[n]` broadcast over rows.
fn add_bias(z: &mut [f32], bias: &[f32]) {
    for zrow in z.chunks_exact_mut(bias.len()) {
        for (zv, &bv) in zrow.iter_mut().zip(bias) {
            *zv += bv;
        }
    }
}

/// Column sums of `a[m,n]` → `[n]`.
fn colsum(pool: &MemoryPool, a: &[f32], n: usize) -> Vec<f32> {
    let mut out = pool.zeroed(n);
    for arow in a.chunks_exact(n) {
        for (o, &av) in out.iter_mut().zip(arow) {
            *o += av;
        }
    }
    out
}

/// Validate the rectangular `(x[m,k_in], w[k_in,k_out], bias[k_out], …)`
/// dense-layer argument shape shared by the forward, backward and
/// loss-head kernels; returns `(m, k_in, k_out)`.
fn dense_shape(kernel: &str, args: &[HostTensor], arity: usize) -> Result<(usize, usize, usize)> {
    if args.len() != arity {
        bail!("{kernel}: expected {arity} args, got {}", args.len());
    }
    let (x, w, bias) = (&args[0], &args[1], &args[2]);
    let [m, k_in] = x.dims() else {
        bail!("{kernel}: input must be 2-d, got {:?}", x.dims());
    };
    let (m, k_in) = (*m, *k_in);
    let [wk, k_out] = w.dims() else {
        bail!("{kernel}: weight must be 2-d, got {:?}", w.dims());
    };
    let (wk, k_out) = (*wk, *k_out);
    if wk != k_in {
        bail!("{kernel}: weight dims {:?} incompatible with input [{m}, {k_in}]", w.dims());
    }
    if bias.dims() != [k_out] {
        bail!("{kernel}: bias dims {:?}, want [{k_out}]", bias.dims());
    }
    Ok((m, k_in, k_out))
}

/// `gelu(x @ w + b)` — the fused dense layer forward, rectangular:
/// `[m, k_in] × [k_in, k_out] → [m, k_out]`.
fn layer_fwd(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let (m, k_in, k_out) = dense_shape("layer_fwd", args, 3)?;
    let mut z = matmul(pool, args[0].data(), args[1].data(), m, k_in, k_out);
    add_bias(&mut z, args[2].data());
    for v in z.iter_mut() {
        *v = gelu(*v);
    }
    Ok(vec![HostTensor::new(z, vec![m, k_out])])
}

/// Gradients of `layer_fwd` w.r.t. `(x, w, b)` given upstream `gh`:
/// `dz = gh ⊙ gelu'(z)`, `gx = dz @ wᵀ`, `gw = xᵀ @ dz`, `gb = Σ_batch dz`.
fn layer_bwd(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let (m, k_in, k_out) = dense_shape("layer_bwd", args, 4)?;
    let gh = &args[3];
    if gh.dims() != [m, k_out] {
        bail!("layer_bwd: upstream grad dims {:?}, want [{m}, {k_out}]", gh.dims());
    }
    let (x, w) = (args[0].data(), args[1].data());
    let mut dz = matmul(pool, x, w, m, k_in, k_out);
    add_bias(&mut dz, args[2].data());
    for (d, &g) in dz.iter_mut().zip(gh.data()) {
        *d = g * gelu_prime(*d);
    }
    let gx = matmul_nt(pool, &dz, w, m, k_out, k_in);
    let gw = matmul_tn(pool, x, &dz, m, k_in, k_out);
    let gb = colsum(pool, &dz, k_out);
    pool.give(dz); // scratch: return to the pool, not the allocator
    Ok(vec![
        HostTensor::new(gx, vec![m, k_in]),
        HostTensor::new(gw, vec![k_in, k_out]),
        HostTensor::new(gb, vec![k_out]),
    ])
}

/// MSE regression head forward: `mean((h @ w + b − y)²)` → scalar loss.
fn loss_head_fwd(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let (m, k_in, k_out) = dense_shape("loss_head_fwd", args, 4)?;
    let y = &args[3];
    if y.dims() != [m, k_out] {
        bail!("loss_head_fwd: target dims {:?}, want [{m}, {k_out}]", y.dims());
    }
    let mut pred = matmul(pool, args[0].data(), args[1].data(), m, k_in, k_out);
    add_bias(&mut pred, args[2].data());
    let n = (m * k_out) as f32;
    let loss: f32 =
        pred.iter().zip(y.data()).map(|(&p, &t)| (p - t) * (p - t)).sum::<f32>() / n;
    pool.give(pred); // scratch: return to the pool, not the allocator
    Ok(vec![HostTensor::new(pool.copied(&[loss]), vec![])])
}

/// Loss head forward + backward in one call:
/// returns `(loss, gh, gw, gb)` for `loss = mean((h @ w + b − y)²)`.
fn loss_head_bwd(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let (m, k_in, k_out) = dense_shape("loss_head_bwd", args, 4)?;
    let y = &args[3];
    if y.dims() != [m, k_out] {
        bail!("loss_head_bwd: target dims {:?}, want [{m}, {k_out}]", y.dims());
    }
    let (h, w) = (args[0].data(), args[1].data());
    let mut pred = matmul(pool, h, w, m, k_in, k_out);
    add_bias(&mut pred, args[2].data());
    let n = (m * k_out) as f32;
    let mut loss = 0.0f32;
    // dpred = 2 (pred − y) / n, computed in place.
    for (p, &t) in pred.iter_mut().zip(y.data()) {
        let diff = *p - t;
        loss += diff * diff;
        *p = 2.0 * diff / n;
    }
    loss /= n;
    let dpred = pred;
    let gh = matmul_nt(pool, &dpred, w, m, k_out, k_in);
    let gw = matmul_tn(pool, h, &dpred, m, k_in, k_out);
    let gb = colsum(pool, &dpred, k_out);
    pool.give(dpred); // scratch: return to the pool, not the allocator
    Ok(vec![
        HostTensor::new(pool.copied(&[loss]), vec![]),
        HostTensor::new(gh, vec![m, k_in]),
        HostTensor::new(gw, vec![k_in, k_out]),
        HostTensor::new(gb, vec![k_out]),
    ])
}

/// Elementwise `a + b` — the fan-in merge building block and the
/// gradient-accumulation kernel of the general-DAG executor.
fn add(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    if args.len() != 2 {
        bail!("add: expected 2 args, got {}", args.len());
    }
    let (a, b) = (&args[0], &args[1]);
    if a.dims() != b.dims() {
        bail!("add: dims {:?} vs {:?}", a.dims(), b.dims());
    }
    let mut out = pool.writable(a.len());
    out.extend(a.data().iter().zip(b.data()).map(|(&x, &y)| x + y));
    Ok(vec![HostTensor::new(out, a.dims().to_vec())])
}

/// Elementwise `x · s` for scalar `s` — normalizes merge fan-ins (and
/// their backward pass-through) by `1/√k`.
fn scale(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    if args.len() != 2 {
        bail!("scale: expected 2 args, got {}", args.len());
    }
    let (x, s) = (&args[0], &args[1]);
    if !s.dims().is_empty() {
        bail!("scale: factor must be a scalar, got {:?}", s.dims());
    }
    let f = s.data()[0];
    let mut out = pool.writable(x.len());
    out.extend(x.data().iter().map(|&v| v * f));
    Ok(vec![HostTensor::new(out, x.dims().to_vec())])
}

/// Mean-squared-error loss + gradient in one call:
/// `(mean((p − y)²), 2(p − y)/n)` — the per-sink loss of the DAG executor.
fn mse(pool: &MemoryPool, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
    if args.len() != 2 {
        bail!("mse: expected 2 args, got {}", args.len());
    }
    let (p, y) = (&args[0], &args[1]);
    if p.dims() != y.dims() {
        bail!("mse: pred dims {:?} vs target dims {:?}", p.dims(), y.dims());
    }
    if p.is_empty() {
        bail!("mse: empty prediction");
    }
    let n = p.len() as f32;
    let mut loss = 0.0f32;
    let mut grad = pool.writable(p.len());
    for (&pv, &yv) in p.data().iter().zip(y.data()) {
        let diff = pv - yv;
        loss += diff * diff;
        grad.push(2.0 * diff / n);
    }
    loss /= n;
    Ok(vec![
        HostTensor::new(pool.copied(&[loss]), vec![]),
        HostTensor::new(grad, p.dims().to_vec()),
    ])
}

/// `p − lr·g` elementwise; `rank` pins the expected dimensionality so the
/// mat/vec variants keep the artifact-manifest arity contract.
fn sgd(
    pool: &MemoryPool,
    kernel: &str,
    args: &[HostTensor],
    rank: usize,
) -> Result<Vec<HostTensor>> {
    if args.len() != 3 {
        bail!("{kernel}: expected 3 args, got {}", args.len());
    }
    let (p, g, lr) = (&args[0], &args[1], &args[2]);
    if p.dims().len() != rank {
        bail!("{kernel}: param must be {rank}-d, got {:?}", p.dims());
    }
    if p.dims() != g.dims() {
        bail!("{kernel}: param dims {:?} vs grad dims {:?}", p.dims(), g.dims());
    }
    if !lr.dims().is_empty() {
        bail!("{kernel}: lr must be a scalar, got {:?}", lr.dims());
    }
    let lr = lr.data()[0];
    let mut out = pool.writable(p.len());
    out.extend(p.data().iter().zip(g.data()).map(|(&pv, &gv)| pv - lr * gv));
    Ok(vec![HostTensor::new(out, p.dims().to_vec())])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    fn be() -> NativeBackend {
        NativeBackend::new()
    }

    /// Central-finite-difference check of an analytic gradient against a
    /// scalar function of one flattened parameter tensor.
    fn fd_check(analytic: &[f32], base: &[f32], mut eval: impl FnMut(&[f32]) -> f64) {
        let eps = 1e-3f32;
        for (i, &a) in analytic.iter().enumerate() {
            let mut hi = base.to_vec();
            hi[i] += eps;
            let mut lo = base.to_vec();
            lo[i] -= eps;
            let numeric = (eval(&hi) - eval(&lo)) / (2.0 * eps as f64);
            assert!(
                (numeric - a as f64).abs() < 5e-3,
                "elem {i}: numeric {numeric} vs analytic {a}"
            );
        }
    }

    #[test]
    fn layer_fwd_matches_host_gelu_with_identity_weights() {
        let b = be();
        let (m, k) = (3usize, 4usize);
        let x: Vec<f32> = (0..m * k).map(|i| (i % 7) as f32 * 0.1 - 0.3).collect();
        let mut wmat = vec![0.0f32; k * k];
        for i in 0..k {
            wmat[i * k + i] = 1.0;
        }
        let bias = vec![0.5f32; k];
        let out = b
            .run(
                "layer_fwd",
                &[
                    b.upload(&x, &[m, k]).unwrap(),
                    b.upload(&wmat, &[k, k]).unwrap(),
                    b.upload(&bias, &[k]).unwrap(),
                ],
            )
            .unwrap();
        let got = b.download(&out[0]).unwrap();
        for (g, &xi) in got.iter().zip(&x) {
            let want = gelu(xi + 0.5);
            assert!((g - want).abs() < 1e-6, "got {g} want {want}");
        }
    }

    /// Central finite differences of `L(θ) = Σ fwd(θ) · r` must match the
    /// analytic VJP with upstream gradient `r`, for every parameter —
    /// on a *rectangular* layer (`k_in ≠ k_out`), the shape-polymorphic
    /// dense path.
    #[test]
    fn rectangular_layer_bwd_matches_finite_differences() {
        let b = be();
        let (m, k_in, k_out) = (3usize, 5usize, 2usize);
        let mut rng = Pcg32::seeded(11);
        let x = randn(&mut rng, m * k_in, 1.0);
        let w = randn(&mut rng, k_in * k_out, 0.5);
        let bias = randn(&mut rng, k_out, 0.1);
        let r = randn(&mut rng, m * k_out, 1.0);

        let fwd_sum = |x: &[f32], w: &[f32], bias: &[f32]| -> f64 {
            let out = b
                .run(
                    "layer_fwd",
                    &[
                        b.upload(x, &[m, k_in]).unwrap(),
                        b.upload(w, &[k_in, k_out]).unwrap(),
                        b.upload(bias, &[k_out]).unwrap(),
                    ],
                )
                .unwrap();
            out[0].data().iter().zip(&r).map(|(&o, &rv)| o as f64 * rv as f64).sum()
        };

        let outs = b
            .run(
                "layer_bwd",
                &[
                    b.upload(&x, &[m, k_in]).unwrap(),
                    b.upload(&w, &[k_in, k_out]).unwrap(),
                    b.upload(&bias, &[k_out]).unwrap(),
                    b.upload(&r, &[m, k_out]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(outs[0].dims(), [m, k_in], "gx shape");
        assert_eq!(outs[1].dims(), [k_in, k_out], "gw shape");
        assert_eq!(outs[2].dims(), [k_out], "gb shape");
        let (gx, gw, gb) = (outs[0].data(), outs[1].data(), outs[2].data());

        fd_check(gx, &x, |v| fwd_sum(v, &w, &bias));
        fd_check(gw, &w, |v| fwd_sum(&x, v, &bias));
        fd_check(gb, &bias, |v| fwd_sum(&x, &w, v));
    }

    #[test]
    fn rectangular_loss_head_bwd_matches_finite_differences_and_fwd() {
        let b = be();
        let (m, k_in, k_out) = (3usize, 4usize, 2usize);
        let mut rng = Pcg32::seeded(5);
        let h = randn(&mut rng, m * k_in, 1.0);
        let w = randn(&mut rng, k_in * k_out, 0.5);
        let bias = randn(&mut rng, k_out, 0.1);
        let y = randn(&mut rng, m * k_out, 1.0);

        let loss_of = |h: &[f32], w: &[f32], bias: &[f32]| -> f64 {
            let out = b
                .run(
                    "loss_head_fwd",
                    &[
                        b.upload(h, &[m, k_in]).unwrap(),
                        b.upload(w, &[k_in, k_out]).unwrap(),
                        b.upload(bias, &[k_out]).unwrap(),
                        b.upload(&y, &[m, k_out]).unwrap(),
                    ],
                )
                .unwrap();
            out[0].data()[0] as f64
        };

        let outs = b
            .run(
                "loss_head_bwd",
                &[
                    b.upload(&h, &[m, k_in]).unwrap(),
                    b.upload(&w, &[k_in, k_out]).unwrap(),
                    b.upload(&bias, &[k_out]).unwrap(),
                    b.upload(&y, &[m, k_out]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[1].dims(), [m, k_in], "gh shape");
        let loss = outs[0].data()[0];
        assert!((loss as f64 - loss_of(&h, &w, &bias)).abs() < 1e-6);

        fd_check(outs[1].data(), &h, |v| loss_of(v, &w, &bias));
        fd_check(outs[2].data(), &w, |v| loss_of(&h, v, &bias));
        fd_check(outs[3].data(), &bias, |v| loss_of(&h, &w, v));
    }

    #[test]
    fn sgd_updates_elementwise() {
        let b = be();
        let w = vec![1.0f32; 16];
        let g = vec![2.0f32; 16];
        let out = b
            .run(
                "sgd_mat",
                &[
                    b.upload(&w, &[4, 4]).unwrap(),
                    b.upload(&g, &[4, 4]).unwrap(),
                    b.upload(&[0.25], &[]).unwrap(),
                ],
            )
            .unwrap();
        assert!(out[0].data().iter().all(|&v| (v - 0.5).abs() < 1e-6));

        let bv = vec![1.0f32; 4];
        let gv = vec![-1.0f32; 4];
        let out = b
            .run(
                "sgd_vec",
                &[
                    b.upload(&bv, &[4]).unwrap(),
                    b.upload(&gv, &[4]).unwrap(),
                    b.upload(&[0.5], &[]).unwrap(),
                ],
            )
            .unwrap();
        assert!(out[0].data().iter().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn shape_validation_rejects_mismatches() {
        let b = be();
        let x = b.upload(&[0.0; 12], &[3, 4]).unwrap();
        let w_bad = b.upload(&[0.0; 9], &[3, 3]).unwrap();
        let bias = b.upload(&[0.0; 4], &[4]).unwrap();
        assert!(b.run("layer_fwd", &[x.clone(), w_bad, bias.clone()]).is_err());
        // Rectangular weights with the wrong *input* dimension still fail.
        let w_rect_bad = b.upload(&[0.0; 6], &[3, 2]).unwrap();
        assert!(b.run("layer_fwd", &[x.clone(), w_rect_bad, bias.clone()]).is_err());
        assert!(b.run("layer_fwd", &[x.clone(), x.clone(), bias]).is_err());
        assert!(b.run("nope", &[]).is_err());
        assert!(b.upload(&[0.0; 3], &[2, 2]).is_err());
    }

    #[test]
    fn stats_accumulate_per_kernel() {
        let b = be();
        let x = b.upload(&[0.1; 12], &[3, 4]).unwrap();
        let w = b.upload(&[0.1; 16], &[4, 4]).unwrap();
        let bias = b.upload(&[0.0; 4], &[4]).unwrap();
        for _ in 0..3 {
            b.run("layer_fwd", &[x.clone(), w.clone(), bias.clone()]).unwrap();
        }
        let stats = b.stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].kernel, "layer_fwd");
        assert_eq!(stats[0].calls, 3);
        assert_eq!(stats[0].bytes_in, 3 * (12 + 16 + 4) * 4);
        assert_eq!(stats[0].bytes_out, 3 * 12 * 4);
        // layer_fwd on [3,4]×[4,4] attributes 2·m·k·n = 96 flops per call.
        assert_eq!(stats[0].flops, 3 * 96);
        assert!(stats[0].gflops() > 0.0, "nonzero flops over nonzero time");
        assert_eq!(b.kernels().len(), TOWER_KERNELS.len() + DAG_KERNELS.len());
    }

    #[test]
    fn add_and_scale_are_elementwise() {
        let b = be();
        let x = b.upload(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let y = b.upload(&[0.5, 0.5, -1.0, 1.0], &[2, 2]).unwrap();
        let sum = b.run("add", &[x.clone(), y]).unwrap();
        assert_eq!(b.download(&sum[0]).unwrap(), vec![1.5, 2.5, 2.0, 5.0]);
        let s = b.upload(&[0.5], &[]).unwrap();
        let half = b.run("scale", &[x.clone(), s]).unwrap();
        assert_eq!(b.download(&half[0]).unwrap(), vec![0.5, 1.0, 1.5, 2.0]);
        // Shape validation.
        let bad = b.upload(&[0.0; 2], &[2]).unwrap();
        assert!(b.run("add", &[x.clone(), bad.clone()]).is_err());
        assert!(b.run("scale", &[x, bad]).is_err());
    }

    #[test]
    fn mse_gradient_matches_finite_differences() {
        let b = be();
        let (m, k) = (3usize, 4usize);
        let mut rng = Pcg32::seeded(21);
        let p = randn(&mut rng, m * k, 1.0);
        let y = randn(&mut rng, m * k, 1.0);
        let loss_of = |p: &[f32]| -> f64 {
            let out = b
                .run(
                    "mse",
                    &[b.upload(p, &[m, k]).unwrap(), b.upload(&y, &[m, k]).unwrap()],
                )
                .unwrap();
            out[0].data()[0] as f64
        };
        let outs = b
            .run("mse", &[b.upload(&p, &[m, k]).unwrap(), b.upload(&y, &[m, k]).unwrap()])
            .unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs[0].dims().is_empty(), "scalar loss");
        fd_check(outs[1].data(), &p, loss_of);
    }

    #[test]
    fn pool_recycles_freed_buffers() {
        let b = be();
        let x = b.upload(&[1.0f32; 64], &[64]).unwrap();
        let s0 = b.pool_stats().unwrap();
        assert!(s0.allocs >= 1, "upload allocates through the pool");
        assert_eq!(s0.reuses, 0, "nothing to reuse yet");
        drop(x);
        let s1 = b.pool_stats().unwrap();
        assert!(s1.parked_bytes >= 64 * 4, "freed storage parks in the pool");
        // Same-class upload must be served from the free list, and the
        // recycled buffer must carry the new contents, not stale data.
        let y = b.upload(&[2.0f32; 64], &[64]).unwrap();
        let s2 = b.pool_stats().unwrap();
        assert_eq!(s2.reuses, s1.reuses + 1, "second upload reuses the parked buffer");
        assert_eq!(s2.allocs, s1.allocs, "no fresh allocation");
        assert_eq!(b.download(&y).unwrap(), vec![2.0f32; 64]);
        assert!(s2.high_water_bytes >= 64 * 4);
        // Kernel outputs recycle too: scale 300×; after warm-up every
        // output draws from the pool instead of the allocator.
        let s = b.upload(&[0.5], &[]).unwrap();
        for _ in 0..300 {
            let _ = b.run("scale", &[y.clone(), s.clone()]).unwrap();
        }
        let s3 = b.pool_stats().unwrap();
        assert!(
            s3.reuses >= s2.reuses + 299,
            "kernel outputs must recycle: {} → {}",
            s2.reuses,
            s3.reuses
        );
        // The census stays a pure live-tensor count — pooling never
        // inflates it.
        assert_eq!(b.live_bytes(), Some(64 * 4 + 4));
    }

    #[test]
    fn pool_bounds_parked_storage_per_class() {
        let b = be();
        // Park far more than MAX_PER_CLASS buffers of one class…
        let tensors: Vec<_> =
            (0..64).map(|_| b.upload(&[0.0f32; 16], &[16]).unwrap()).collect();
        drop(tensors);
        let s = b.pool_stats().unwrap();
        // …and only a bounded number may be retained (class 16 → 64 B each).
        assert!(
            s.parked_bytes <= 32 * 16 * 4,
            "parked {} exceeds the per-class bound",
            s.parked_bytes
        );
        assert_eq!(b.live_bytes(), Some(0), "census unaffected by parked storage");
    }

    #[test]
    fn live_bytes_census_is_exact() {
        let b = be();
        assert_eq!(b.live_bytes(), Some(0));
        let x = b.upload(&[1.0f32; 8], &[2, 4]).unwrap();
        assert_eq!(b.live_bytes(), Some(32));
        let x2 = x.clone(); // shares the buffer: no new allocation
        assert_eq!(b.live_bytes(), Some(32));
        let s = b.upload(&[2.0], &[]).unwrap();
        let doubled = b.run("scale", &[x2, s.clone()]).unwrap().pop().unwrap();
        assert_eq!(b.live_bytes(), Some(32 + 4 + 32), "output tracked too");
        drop(doubled);
        drop(x);
        assert_eq!(b.live_bytes(), Some(4), "only the scalar factor remains");
    }
}
