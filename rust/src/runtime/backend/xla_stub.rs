//! Offline API stub for the `xla` (PJRT) crate.
//!
//! The build environment has no crates.io access, so the real `xla`
//! bindings cannot be declared as a cargo dependency without breaking
//! `cargo check --features xla` everywhere. This module declares the
//! exact API surface `pjrt.rs` uses — same type names, same signatures —
//! and fails cleanly at *runtime* (`PjRtClient::cpu()` errors before any
//! other call is reachable).
//!
//! To run on real PJRT: add `xla = "0.1"` (with `libxla_extension` on the
//! rpath) to `rust/Cargo.toml`, delete this module, and drop the
//! `use … xla_stub as xla;` alias at the top of `pjrt.rs` — the rest of
//! `pjrt.rs` is written against the real crate's API and compiles
//! unchanged.

/// Error type mirroring `xla::Error` for `{:?}` interpolation.
#[derive(Debug)]
pub struct XlaError(pub &'static str);

type XlaResult<T> = std::result::Result<T, XlaError>;

const UNAVAILABLE: &str =
    "compiled against the offline xla stub — swap in the real `xla` crate (see runtime::backend::xla_stub docs)";

/// Host literal (stub): constructible, but all device I/O errors out.
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> XlaResult<Literal> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn to_vec<T>(&self) -> XlaResult<Vec<T>> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn decompose_tuple(&mut self) -> XlaResult<Vec<Literal>> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn size_bytes(&self) -> usize {
        0
    }
}

/// PJRT client (stub): construction always fails, which gates the whole
/// backend path with one clear error.
pub struct PjRtClient;

static CLIENT: PjRtClient = PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(XlaError(UNAVAILABLE))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> XlaResult<PjRtBuffer> {
        Err(XlaError(UNAVAILABLE))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(XlaError(UNAVAILABLE))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn client(&self) -> &PjRtClient {
        &CLIENT
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(UNAVAILABLE))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> XlaResult<HloModuleProto> {
        Err(XlaError(UNAVAILABLE))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
