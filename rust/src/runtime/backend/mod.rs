//! The pluggable execution-backend layer.
//!
//! Everything above this module — the trainer ([`crate::exec`]), the
//! coordinator, the benches — talks to hardware exclusively through the
//! [`Backend`] trait: upload host buffers, run a named kernel, download
//! results, and read per-kernel timing/byte statistics. Two
//! implementations exist:
//!
//! - [`native::NativeBackend`] (always available, the default): a pure-Rust
//!   f32 CPU implementation of the dense kernels, mathematically
//!   mirroring `python/compile/kernels/ref.py`. Zero Python, zero
//!   artifacts, zero native libraries — the whole repo trains end-to-end
//!   with `cargo run` alone.
//! - [`pjrt::PjrtBackend`] (behind the `xla` cargo feature): loads the
//!   AOT-compiled HLO-text artifacts produced by `python/compile/aot.py`
//!   and executes them through PJRT.
//!
//! The trait is **shape-polymorphic**: a backend instance is not
//! specialized to any `(batch, width)` — dimensions travel with each
//! tensor (set at [`Backend::upload`], validated by every kernel from
//! its arguments), and the dense path is rectangular
//! (`[m, k_in] × [k_in, k_out] → [m, k_out]`). One backend therefore
//! executes graphs whose nodes all have *different* tensor shapes, which
//! is what gives the planner's non-uniform `M_v` cut choices a real
//! workload. Shape-specialized implementations (the PJRT artifact set is
//! compiled for one fixed shape) advertise their shapes through inherent
//! methods, not through this trait.
//!
//! The kernel *names* are the interchange contract shared by all
//! backends (and by the artifact manifest): `layer_fwd`, `layer_bwd`,
//! `loss_head_fwd`, `loss_head_bwd`, `sgd_mat`, `sgd_vec`
//! ([`TOWER_KERNELS`]), plus `add`, `scale`, `mse` for general-DAG
//! execution ([`DAG_KERNELS`]).

use std::time::Duration;

use crate::anyhow::Result;

pub mod gemm;
pub mod native;
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(feature = "xla")]
pub mod xla_stub;

pub use native::{HostTensor, MemoryPool, NativeBackend};
#[cfg(feature = "xla")]
pub use pjrt::PjrtBackend;

/// Aggregate counters of a backend's buffer pool (see
/// [`Backend::pool_stats`]). A pool recycles freed device buffers into
/// subsequent allocations so the free/recompute churn of a liveness
/// schedule does not translate into allocator traffic on the hot path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffer requests served by a fresh allocation.
    pub allocs: u64,
    /// Buffer requests served from the pool's free lists.
    pub reuses: u64,
    /// Bytes currently parked in the free lists (freed, awaiting reuse).
    pub parked_bytes: u64,
    /// Peak bytes the pool ever administered at once — buffers handed
    /// out and not yet returned, plus parked free-list bytes. This is
    /// the allocator-footprint analogue of the executor's observed peak.
    pub high_water_bytes: u64,
}

impl PoolStats {
    /// Fraction of requests served without touching the allocator.
    pub fn reuse_ratio(&self) -> f64 {
        let total = self.allocs + self.reuses;
        if total == 0 {
            0.0
        } else {
            self.reuses as f64 / total as f64
        }
    }
}

/// Aggregate execution statistics for one kernel on one backend.
#[derive(Clone, Debug, Default)]
pub struct KernelStat {
    pub kernel: String,
    /// Number of `run` calls.
    pub calls: u64,
    /// Total wall-clock across those calls.
    pub total: Duration,
    /// Bytes of tensor arguments consumed across all calls.
    pub bytes_in: u64,
    /// Bytes of tensor outputs produced across all calls.
    pub bytes_out: u64,
    /// Floating-point operations performed across all calls (2·m·k·n per
    /// dense matmul, counted from the kernel's argument shapes). Zero for
    /// backends that cannot attribute flops (PJRT executes opaque
    /// artifacts).
    pub flops: u64,
}

impl KernelStat {
    /// Mean wall-clock per call (zero if never called).
    pub fn mean(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            self.total / self.calls as u32
        }
    }

    /// Achieved throughput in GFLOP/s over the accumulated wall-clock
    /// (zero if no flops were attributed or no time elapsed).
    pub fn gflops(&self) -> f64 {
        let secs = self.total.as_secs_f64();
        if self.flops == 0 || secs <= 0.0 {
            0.0
        } else {
            self.flops as f64 / secs / 1e9
        }
    }
}

/// Accumulate one kernel call into a per-kernel stats map — the shared
/// recorder behind every backend's `stats()` view.
pub(crate) fn record_call(
    stats: &mut std::collections::BTreeMap<String, KernelStat>,
    kernel: &str,
    elapsed: Duration,
    bytes_in: u64,
    bytes_out: u64,
    flops: u64,
) {
    let entry = stats
        .entry(kernel.to_string())
        .or_insert_with(|| KernelStat { kernel: kernel.to_string(), ..KernelStat::default() });
    entry.calls += 1;
    entry.total += elapsed;
    entry.bytes_in += bytes_in;
    entry.bytes_out += bytes_out;
    entry.flops += flops;
}

/// An execution backend: owns device buffers, runs named kernels, and
/// accounts for what it did.
///
/// `run` takes `&self` — backends use interior mutability for their stats
/// so the trainer can hold tensor borrows across calls.
pub trait Backend {
    /// The backend's buffer handle. Cloning must be cheap *or* correct —
    /// the trainer clones tensors to model caching, and the live-bytes
    /// accounting is done host-side, so either a refcount (native) or a
    /// deep copy (PJRT literal) is acceptable.
    type Tensor: Clone;

    /// Human-readable backend name (`"native"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Upload a row-major f32 host buffer (`dims = []` is a scalar).
    /// The dims become the tensor's shape — kernels are dimension-driven
    /// and accept any consistent sizes.
    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<Self::Tensor>;

    /// Download a tensor to a flat host vec.
    fn download(&self, t: &Self::Tensor) -> Result<Vec<f32>>;

    /// Logical size of a tensor in bytes (for live-memory accounting).
    fn tensor_bytes(&self, t: &Self::Tensor) -> u64;

    /// Execute kernel `name` on `args`, returning its outputs.
    fn run(&self, name: &str, args: &[Self::Tensor]) -> Result<Vec<Self::Tensor>>;

    /// Names of the kernels this backend has loaded, sorted.
    fn kernels(&self) -> Vec<String>;

    /// Per-kernel timing/byte statistics accumulated so far, sorted by
    /// kernel name.
    fn stats(&self) -> Vec<KernelStat>;

    /// Bytes currently held by live tensors this backend produced
    /// (uploads + kernel outputs not yet dropped), or `None` if the
    /// backend cannot census its allocations. Backends that return
    /// `Some` power the leak regression tests: after training, live
    /// bytes must return exactly to the post-init baseline.
    fn live_bytes(&self) -> Option<u64> {
        None
    }

    /// Counters of the backend's buffer pool, or `None` if the backend
    /// allocates tensors individually. Pooled backends (native) recycle
    /// freed buffers into later allocations; the census above is
    /// unaffected (it counts live tensors, not the allocator's
    /// footprint — `PoolStats::high_water_bytes` tracks that).
    fn pool_stats(&self) -> Option<PoolStats> {
        None
    }
}

/// Names of the kernels every tower backend must provide. All of them
/// are shape-generic on the native backend (`layer_*`/`loss_head_*` take
/// rectangular `[m, k_in] × [k_in, k_out]` operands); PJRT artifacts
/// provide the same names compiled for one fixed `(batch, width)`.
pub const TOWER_KERNELS: [&str; 6] =
    ["layer_bwd", "layer_fwd", "loss_head_bwd", "loss_head_fwd", "sgd_mat", "sgd_vec"];

/// Extra kernels the general-DAG executor ([`crate::exec::DagTrainer`])
/// needs beyond the tower set: elementwise fan-in/gradient accumulation
/// (`add`), the merge normalization (`scale`), and the per-sink loss
/// (`mse`) — each shape-generic, operating on whatever dims its
/// arguments carry. Currently provided by the native backend only — the
/// PJRT artifact manifest predates general-DAG execution.
pub const DAG_KERNELS: [&str; 3] = ["add", "mse", "scale"];
