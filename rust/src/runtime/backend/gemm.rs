//! Tiered GEMM kernels behind the native backend's dense path.
//!
//! Every dense kernel of [`super::native::NativeBackend`] bottoms out in
//! one of three matrix products — `A·B` (forward), `A·Bᵀ` (input
//! gradient) and `Aᵀ·B` (weight gradient). This module owns all three in
//! three implementation tiers:
//!
//! - [`GemmTier::Naive`] — the straightforward triple loops
//!   ([`matmul_naive`] and friends), always available and kept as the
//!   reference the fast paths are property-tested against.
//! - [`GemmTier::Blocked`] — a register-tiled micro-kernel
//!   ([`MR`]`×`[`NR`] accumulator tile) over operands packed into
//!   contiguous panels, portable scalar code.
//! - [`GemmTier::Simd`] — the *same* micro-kernel body compiled inside a
//!   `#[target_feature(enable = "avx2")]` function on `x86_64`, letting
//!   LLVM vectorize the [`NR`]-wide inner loop with 256-bit lanes.
//!   Selected only when the CPU reports AVX2 at runtime.
//!
//! **Bit-exactness contract.** The k dimension is deliberately left
//! unblocked and every output element accumulates its `k` products in
//! ascending order — exactly the order of the naive loops. Rust never
//! contracts separate f32 mul/add into a fused multiply-add, so the
//! Blocked and Simd tiers are bit-identical to each other, and identical
//! to Naive up to the sign of zero (the naive loops skip `a == 0.0`
//! rows, which can preserve a `-0.0` the tiled path rounds to `+0.0`).
//! Within one process a single tier serves every call (see
//! [`active_tier`]), so the trainer's bit-exact vanilla-vs-recompute
//! gradient invariants hold under any tier.
//!
//! Pack buffers are drawn from — and returned to — the backend's
//! [`MemoryPool`], so the tiled path adds no steady-state allocator
//! traffic on top of the naive one.

use std::sync::OnceLock;

use super::native::MemoryPool;

/// Rows of the register accumulator tile.
pub const MR: usize = 4;
/// Columns of the register accumulator tile — two 256-bit f32 lanes.
pub const NR: usize = 16;

/// The implementation tier the dense kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmTier {
    /// Reference triple loops; always available.
    Naive,
    /// Register-tiled micro-kernel over packed panels (portable scalar).
    Blocked,
    /// The tiled micro-kernel compiled with AVX2 enabled (`x86_64` with
    /// runtime feature detection only).
    Simd,
}

impl GemmTier {
    /// Stable lower-case name (`naive` / `blocked` / `simd`) — the
    /// values `REPRO_GEMM` accepts and what `--stats` reports.
    pub fn name(self) -> &'static str {
        match self {
            GemmTier::Naive => "naive",
            GemmTier::Blocked => "blocked",
            GemmTier::Simd => "simd",
        }
    }
}

/// Parse a `REPRO_GEMM` value (case-insensitive tier name).
pub fn parse_tier(s: &str) -> Option<GemmTier> {
    match s.to_ascii_lowercase().as_str() {
        "naive" => Some(GemmTier::Naive),
        "blocked" => Some(GemmTier::Blocked),
        "simd" => Some(GemmTier::Simd),
        _ => None,
    }
}

/// The best tier this CPU supports: `Simd` when AVX2 is reported,
/// otherwise `Blocked`.
fn detected_tier() -> GemmTier {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return GemmTier::Simd;
        }
    }
    GemmTier::Blocked
}

/// The tier every dense kernel in this process dispatches to, latched on
/// first use: the `REPRO_GEMM` environment variable when set to a valid
/// tier name, otherwise the best tier the CPU supports. Requesting
/// `simd` on a machine without AVX2 degrades to `blocked` — the override
/// can never select an unsupported instruction set.
pub fn active_tier() -> GemmTier {
    static TIER: OnceLock<GemmTier> = OnceLock::new();
    *TIER.get_or_init(|| {
        match std::env::var("REPRO_GEMM").ok().as_deref().and_then(parse_tier) {
            Some(GemmTier::Simd) | None => detected_tier(),
            Some(tier) => tier,
        }
    })
}

// ---- naive reference kernels ---------------------------------------------

/// `a[m,k] @ b[k,n]` → `[m,n]` — reference triple loop (output drawn
/// from the pool).
pub fn matmul_naive(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut out = pool.zeroed(m * n);
    for (arow, orow) in a.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (&av, brow) in arow.iter().zip(b.chunks_exact(n)) {
            if av != 0.0 {
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    out
}

/// `a[m,k] @ b[n,k]ᵀ` → `[m,n]` — reference row-by-row dot products.
pub fn matmul_nt_naive(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    let mut out = pool.writable(m * n);
    for arow in a.chunks_exact(k) {
        for brow in b.chunks_exact(k) {
            out.push(arow.iter().zip(brow).map(|(&x, &y)| x * y).sum());
        }
    }
    out
}

/// `a[k,m]ᵀ @ b[k,n]` → `[m,n]` — reference rank-1 accumulation.
pub fn matmul_tn_naive(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    let mut out = pool.zeroed(m * n);
    for (arow, brow) in a.chunks_exact(m).zip(b.chunks_exact(n)) {
        for (&av, orow) in arow.iter().zip(out.chunks_exact_mut(n)) {
            if av != 0.0 {
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
    out
}

// ---- tiled path ----------------------------------------------------------

/// A strided read-only 2-d view over a flat buffer: element `(r, c)`
/// lives at `data[r·rs + c·cs]`. All three transpose variants are plain
/// views of their row-major inputs, so one packing routine serves
/// `A·B`, `A·Bᵀ` and `Aᵀ·B`.
#[derive(Clone, Copy)]
struct View<'a> {
    data: &'a [f32],
    rs: usize,
    cs: usize,
}

impl View<'_> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.rs + c * self.cs]
    }
}

/// The register-tiled micro-kernel: accumulate a full `MR×NR` output
/// tile over all `k` — ascending `p`, matching the naive accumulation
/// order (the bit-exactness contract). `apanel` is `k` columns of `MR`
/// packed A values; `bpanel` is `k` rows of `NR` packed B values.
#[inline(always)]
fn tile_body(apanel: &[f32], bpanel: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (acol, brow) in apanel.chunks_exact(MR).zip(bpanel.chunks_exact(NR)) {
        for (&av, accrow) in acol.iter().zip(acc.iter_mut()) {
            for (c, &bv) in accrow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
}

/// [`tile_body`] compiled with AVX2 enabled: LLVM vectorizes the
/// `NR`-wide inner loop into 256-bit mul/add (no FMA contraction, so the
/// result stays bit-identical to the scalar tier).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn tile_avx2(apanel: &[f32], bpanel: &[f32], acc: &mut [[f32; NR]; MR]) {
    tile_body(apanel, bpanel, acc);
}

#[inline]
fn run_tile(simd: bool, apanel: &[f32], bpanel: &[f32], acc: &mut [[f32; NR]; MR]) {
    #[cfg(target_arch = "x86_64")]
    {
        if simd {
            // SAFETY: callers pass `simd == true` only after runtime
            // detection reported AVX2 (the `active_tier` probe, or a
            // test that checked `detected_tier()` itself).
            unsafe { tile_avx2(apanel, bpanel, acc) };
            return;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    tile_body(apanel, bpanel, acc);
}

/// The blocked GEMM core: pack A into `MR`-row panels and B into
/// `NR`-column panels (both drawn from — and returned to — the pool,
/// zero-padded at the edges), then sweep the micro-kernel over the
/// output tiles.
fn gemm(pool: &MemoryPool, a: View, b: View, m: usize, k: usize, n: usize, simd: bool) -> Vec<f32> {
    let mut out = pool.zeroed(m * n);
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let mpanels = m.div_ceil(MR);
    let mut apack = pool.writable(mpanels * MR * k);
    apack.resize(mpanels * MR * k, 0.0);
    for (ip, panel) in apack.chunks_exact_mut(MR * k).enumerate() {
        let i0 = ip * MR;
        let mr = (m - i0).min(MR);
        for (p, col) in panel.chunks_exact_mut(MR).enumerate() {
            for (i, slot) in col.iter_mut().enumerate() {
                *slot = if i < mr { a.at(i0 + i, p) } else { 0.0 };
            }
        }
    }
    let mut bpack = pool.writable(k * NR);
    bpack.resize(k * NR, 0.0);
    for j0 in (0..n).step_by(NR) {
        let nr = (n - j0).min(NR);
        for (p, row) in bpack.chunks_exact_mut(NR).enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = if j < nr { b.at(p, j0 + j) } else { 0.0 };
            }
        }
        for (ip, panel) in apack.chunks_exact(MR * k).enumerate() {
            let i0 = ip * MR;
            let mr = (m - i0).min(MR);
            let mut acc = [[0.0f32; NR]; MR];
            run_tile(simd, panel, &bpack, &mut acc);
            for (i, accrow) in acc.iter().enumerate().take(mr) {
                let row0 = (i0 + i) * n + j0;
                out[row0..row0 + nr].copy_from_slice(&accrow[..nr]);
            }
        }
    }
    pool.give(bpack);
    pool.give(apack);
    out
}

/// `a[m,k] @ b[k,n]` → `[m,n]` through the tiled path (`simd` selects
/// the AVX2-compiled micro-kernel; pass `active_tier() == Simd`).
pub fn matmul(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(pool, View { data: a, rs: k, cs: 1 }, View { data: b, rs: n, cs: 1 }, m, k, n, simd)
}

/// `a[m,k] @ b[n,k]ᵀ` → `[m,n]` through the tiled path — `b`'s
/// transpose is absorbed into the packing strides, no materialization.
pub fn matmul_nt(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(pool, View { data: a, rs: k, cs: 1 }, View { data: b, rs: 1, cs: k }, m, k, n, simd)
}

/// `a[k,m]ᵀ @ b[k,n]` → `[m,n]` through the tiled path — `a`'s
/// transpose is absorbed into the packing strides, no materialization.
pub fn matmul_tn(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
    simd: bool,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm(pool, View { data: a, rs: 1, cs: m }, View { data: b, rs: n, cs: 1 }, m, k, n, simd)
}

// ---- tier-dispatched entry points (what the native kernels call) ---------

/// `a[m,k] @ b[k,n]` through the process-wide [`active_tier`].
pub fn matmul_auto(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    match active_tier() {
        GemmTier::Naive => matmul_naive(pool, a, b, m, k, n),
        tier => matmul(pool, a, b, m, k, n, tier == GemmTier::Simd),
    }
}

/// `a[m,k] @ b[n,k]ᵀ` through the process-wide [`active_tier`].
pub fn matmul_nt_auto(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    match active_tier() {
        GemmTier::Naive => matmul_nt_naive(pool, a, b, m, k, n),
        tier => matmul_nt(pool, a, b, m, k, n, tier == GemmTier::Simd),
    }
}

/// `a[k,m]ᵀ @ b[k,n]` through the process-wide [`active_tier`].
pub fn matmul_tn_auto(
    pool: &MemoryPool,
    a: &[f32],
    b: &[f32],
    k: usize,
    m: usize,
    n: usize,
) -> Vec<f32> {
    match active_tier() {
        GemmTier::Naive => matmul_tn_naive(pool, a, b, k, m, n),
        tier => matmul_tn(pool, a, b, k, m, n, tier == GemmTier::Simd),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn randn(rng: &mut Pcg32, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    /// Shapes that exercise every edge: unit dims, non-multiples of the
    /// MR×NR tile in each direction, and a deep-k skinny output.
    const SHAPES: [(usize, usize, usize); 8] = [
        (1, 1, 1),
        (1, 7, 5),
        (3, 1, 8),
        (4, 16, 16),
        (5, 256, 2),
        (17, 33, 65),
        (2, 9, 31),
        (64, 64, 64),
    ];

    fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; src.len()];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = src[r * cols + c];
            }
        }
        out
    }

    #[test]
    fn blocked_matches_naive_on_awkward_shapes_for_all_transposes() {
        let pool = MemoryPool::default();
        let mut rng = Pcg32::seeded(99);
        for &(m, k, n) in &SHAPES {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let want = matmul_naive(&pool, &a, &b, m, k, n);

            let nn = matmul(&pool, &a, &b, m, k, n, false);
            assert_eq!(nn, want, "nn mismatch at ({m},{k},{n})");

            // A·Bᵀ with bt = Bᵀ laid out [n,k] must reproduce A·B.
            let bt = transpose(&b, k, n);
            let nt = matmul_nt(&pool, &a, &bt, m, k, n, false);
            assert_eq!(nt, want, "nt mismatch at ({m},{k},{n})");
            let nt_ref = matmul_nt_naive(&pool, &a, &bt, m, k, n);
            assert_eq!(nt, nt_ref, "nt vs naive-nt at ({m},{k},{n})");

            // Aᵀ·B with at = Aᵀ laid out [k,m] must reproduce A·B.
            let at = transpose(&a, m, k);
            let tn = matmul_tn(&pool, &at, &b, k, m, n, false);
            assert_eq!(tn, want, "tn mismatch at ({m},{k},{n})");
        }
    }

    #[test]
    fn simd_tier_is_bit_identical_to_blocked() {
        if detected_tier() != GemmTier::Simd {
            return; // no AVX2 on this machine — nothing to compare
        }
        let pool = MemoryPool::default();
        let mut rng = Pcg32::seeded(7);
        for &(m, k, n) in &SHAPES {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let blocked = matmul(&pool, &a, &b, m, k, n, false);
            let simd = matmul(&pool, &a, &b, m, k, n, true);
            let same = blocked.iter().zip(&simd).all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(same, "simd not bit-identical to blocked at ({m},{k},{n})");
        }
    }

    #[test]
    fn zero_extent_products_are_empty_or_zero() {
        let pool = MemoryPool::default();
        assert_eq!(matmul(&pool, &[], &[1.0; 12], 0, 3, 4, false), Vec::<f32>::new());
        assert_eq!(matmul(&pool, &[], &[], 3, 0, 2, false), vec![0.0; 6]);
        assert_eq!(matmul_nt(&pool, &[], &[], 2, 0, 3, false), vec![0.0; 6]);
        assert_eq!(matmul_tn(&pool, &[], &[], 0, 2, 3, false), vec![0.0; 6]);
    }

    #[test]
    fn pack_scratch_returns_to_the_pool() {
        let pool = MemoryPool::default();
        let mut rng = Pcg32::seeded(3);
        let (m, k, n) = (9, 17, 21);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let _ = matmul(&pool, &a, &b, m, k, n, false);
        let s1 = pool.stats();
        assert!(s1.parked_bytes > 0, "pack panels must park back into the pool");
        let _ = matmul(&pool, &a, &b, m, k, n, false);
        let s2 = pool.stats();
        assert!(s2.reuses > s1.reuses, "second call must reuse the parked panels");
    }

    #[test]
    fn tier_parsing_and_names() {
        assert_eq!(parse_tier("naive"), Some(GemmTier::Naive));
        assert_eq!(parse_tier("Blocked"), Some(GemmTier::Blocked));
        assert_eq!(parse_tier("SIMD"), Some(GemmTier::Simd));
        assert_eq!(parse_tier(""), None);
        assert_eq!(parse_tier("fast"), None);
        for tier in [GemmTier::Naive, GemmTier::Blocked, GemmTier::Simd] {
            assert_eq!(parse_tier(tier.name()), Some(tier));
        }
    }

    #[test]
    fn auto_entry_points_agree_with_the_reference() {
        let pool = MemoryPool::default();
        let mut rng = Pcg32::seeded(41);
        let (m, k, n) = (6, 13, 10);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let want = matmul_naive(&pool, &a, &b, m, k, n);
        assert_eq!(matmul_auto(&pool, &a, &b, m, k, n), want);
        let bt = transpose(&b, k, n);
        assert_eq!(matmul_nt_auto(&pool, &a, &bt, m, k, n), want);
        let at = transpose(&a, m, k);
        assert_eq!(matmul_tn_auto(&pool, &at, &b, k, m, n), want);
    }
}
