//! PJRT backend: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! The interchange contract with `python/compile/aot.py`:
//!
//! - each artifact is XLA HLO **text** (`HloModuleProto::from_text_file`
//!   re-assigns instruction ids, sidestepping the 64-bit-id protos jax ≥
//!   0.5 emits that xla_extension 0.5.1 rejects);
//! - every artifact's root is a tuple (lowered with `return_tuple=True`),
//!   so execution returns one buffer that we decompose host-side;
//! - `manifest.json` describes the artifact set: input shapes, output
//!   arity, and the `(batch, width)` the artifacts were specialized for.
//!
//! Compilation happens once per artifact at startup (`ArtifactSet::load`);
//! the training hot path only calls `execute`, which is pure Rust + XLA —
//! Python never runs after `make artifacts`.
//!
//! This module compiles against [`super::xla_stub`] in the offline build;
//! see that module's docs for how to link the real `xla` crate.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::xla_stub as xla;
use super::{Backend, KernelStat};

/// Metadata of one artifact, parsed from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Input shapes (row-major dims; `[]` = scalar).
    pub inputs: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub outputs: usize,
}

/// A compiled artifact: executable + metadata.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host literals; returns the decomposed tuple outputs.
    ///
    /// Inputs are uploaded through `buffer_from_host_literal` and executed
    /// with `execute_b` — NOT the crate's `execute`, whose C shim
    /// `BufferFromHostLiteral(..).release()`s every input buffer and never
    /// frees it (≈4.5 MB leaked per training step at width 768; see
    /// EXPERIMENTS.md §Perf-L3-2). With caller-owned buffers every
    /// allocation is dropped on return.
    pub fn execute(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        let client = self.exe.client();
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|lit| {
                client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("{}: upload failed: {e:?}", self.meta.name))
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("{}: execute failed: {e:?}", self.meta.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: fetch failed: {e:?}", self.meta.name))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose failed: {e:?}", self.meta.name))?;
        if parts.len() != self.meta.outputs {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs,
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// The artifact runtime: one PJRT CPU client + the compiled artifact set.
pub struct ArtifactSet {
    pub batch: usize,
    pub width: usize,
    pub dir: PathBuf,
    artifacts: HashMap<String, Artifact>,
    /// Wall-clock spent compiling each artifact (startup diagnostics).
    pub compile_times: Vec<(String, Duration)>,
    // Kept alive for the executables' lifetime.
    _client: xla::PjRtClient,
}

impl ArtifactSet {
    /// Load `manifest.json` from `dir`, compile every artifact.
    pub fn load(dir: &Path) -> Result<ArtifactSet> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!("reading {} — run `make artifacts` first", manifest_path.display())
        })?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let batch =
            manifest.get("batch").as_u64().context("manifest: missing batch")? as usize;
        let width =
            manifest.get("width").as_u64().context("manifest: missing width")? as usize;

        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut artifacts = HashMap::new();
        let mut compile_times = Vec::new();
        let arts = manifest
            .get("artifacts")
            .as_obj()
            .context("manifest: missing artifacts object")?;
        for (name, meta_json) in arts {
            let file = meta_json
                .get("file")
                .as_str()
                .with_context(|| format!("artifact {name}: missing file"))?
                .to_string();
            let inputs: Vec<Vec<usize>> = meta_json
                .get("inputs")
                .as_arr()
                .with_context(|| format!("artifact {name}: missing inputs"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .map(|dims| {
                            dims.iter()
                                .filter_map(|d| d.as_u64())
                                .map(|d| d as usize)
                                .collect()
                        })
                        .unwrap_or_default()
                })
                .collect();
            let outputs = meta_json
                .get("outputs")
                .as_u64()
                .with_context(|| format!("artifact {name}: missing outputs"))?
                as usize;

            let path = dir.join(&file);
            let t0 = Instant::now();
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                    .map_err(|e| anyhow!("{name}: parsing HLO {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).map_err(|e| anyhow!("{name}: XLA compile: {e:?}"))?;
            compile_times.push((name.clone(), t0.elapsed()));
            artifacts.insert(
                name.clone(),
                Artifact {
                    meta: ArtifactMeta { name: name.clone(), file, inputs, outputs },
                    exe,
                },
            );
        }
        Ok(ArtifactSet {
            batch,
            width,
            dir: dir.to_path_buf(),
            artifacts,
            compile_times,
            _client: client,
        })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(String::as_str).collect();
        v.sort();
        v
    }

    /// Convenience: execute an artifact by name.
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.get(name)?.execute(args)
    }
}

/// Build an `f32` literal of the given shape from host data.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let expect: usize = dims.iter().product::<usize>().max(1);
    if data.len() != expect {
        bail!("literal shape mismatch: {} elems for dims {dims:?}", data.len());
    }
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let lit = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

/// Fetch an `f32` literal's data to a host vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Logical size in bytes of a literal (for the live-bytes accounting).
pub fn literal_bytes(lit: &xla::Literal) -> u64 {
    lit.size_bytes() as u64
}

/// [`Backend`] over a compiled [`ArtifactSet`]: the artifact names ARE the
/// kernel names, so the trainer's calls map 1:1 onto artifact executions.
pub struct PjrtBackend {
    arts: ArtifactSet,
    stats: RefCell<BTreeMap<String, KernelStat>>,
}

impl PjrtBackend {
    /// Load + compile the artifact set in `dir` (`manifest.json` et al.).
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { arts: ArtifactSet::load(dir)?, stats: RefCell::new(BTreeMap::new()) })
    }

    /// The underlying artifact set (compile times, manifest metadata).
    pub fn artifacts(&self) -> &ArtifactSet {
        &self.arts
    }

    /// Batch size the artifact set was AOT-compiled for. Unlike the
    /// shape-generic native kernels, PJRT artifacts are fixed-shape —
    /// these inherent accessors (no longer part of the [`Backend`]
    /// trait) let callers build matching host buffers.
    pub fn batch(&self) -> usize {
        self.arts.batch
    }

    /// Tower width the artifact set was AOT-compiled for.
    pub fn width(&self) -> usize {
        self.arts.width
    }
}

impl Backend for PjrtBackend {
    type Tensor = xla::Literal;

    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        literal_f32(data, dims)
    }

    fn download(&self, t: &xla::Literal) -> Result<Vec<f32>> {
        to_vec_f32(t)
    }

    fn tensor_bytes(&self, t: &xla::Literal) -> u64 {
        literal_bytes(t)
    }

    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        let bytes_in: u64 = args.iter().map(literal_bytes).sum();
        let outs = self.arts.run(name, args)?;
        let bytes_out: u64 = outs.iter().map(literal_bytes).sum();
        // PJRT executes opaque artifacts — no flop attribution (0).
        super::record_call(
            &mut self.stats.borrow_mut(),
            name,
            t0.elapsed(),
            bytes_in,
            bytes_out,
            0,
        );
        Ok(outs)
    }

    fn kernels(&self) -> Vec<String> {
        self.arts.names().into_iter().map(str::to_string).collect()
    }

    fn stats(&self) -> Vec<KernelStat> {
        self.stats.borrow().values().cloned().collect()
    }

    /// PJRT owns its device buffers inside the runtime; there is no
    /// host-side pool to report (the native backend's `MemoryPool` is
    /// the pooled path). Explicit `None` rather than the trait default
    /// so the contract is visible at the implementation site.
    fn pool_stats(&self) -> Option<super::PoolStats> {
        None
    }
}
