//! Execution runtime, organized as pluggable backends.
//!
//! The concrete device code lives in [`backend`]: a [`Backend`] trait
//! (upload / run-kernel / download / stats) with two implementations —
//! the always-available pure-Rust [`NativeBackend`], and the
//! feature-gated PJRT artifact runtime (`backend::pjrt`, cargo feature
//! `xla`). Everything above this layer (`exec`, `coordinator`, benches)
//! is generic over `Backend`; nothing outside `backend::pjrt` mentions
//! `xla::*`.
//!
//! Compatibility re-exports keep the seed's `runtime::ArtifactSet` /
//! `runtime::literal_f32` paths alive when the `xla` feature is on.

pub mod backend;

pub use backend::{
    Backend, HostTensor, KernelStat, MemoryPool, NativeBackend, PoolStats, DAG_KERNELS,
    TOWER_KERNELS,
};

#[cfg(feature = "xla")]
pub use backend::pjrt::{
    literal_bytes, literal_f32, to_vec_f32, Artifact, ArtifactMeta, ArtifactSet, PjrtBackend,
};
