//! Lazy, validating JSON field scanner — the serve daemon's request
//! fast path.
//!
//! [`scan_fields`] walks a document with the *same grammar* as
//! [`Json::parse`] (same depth cap, number syntax, escape rules,
//! trailing-character check — it literally reuses the eager parser's
//! internals for literals, numbers and re-decoding) but builds no tree:
//! containers are skipped, strings are skipped with a span recorded, and
//! only the requested top-level fields come back, borrowed from the
//! input wherever no unescaping is needed. On the daemon's hot path
//! (`{"cmd":"plan","fingerprint":…}`) that means zero allocation per
//! request instead of a `BTreeMap` per object and a `String` per key.
//!
//! The one contract that makes the scanner safe to put in front of the
//! tree parser: **it accepts exactly the inputs [`Json::parse`]
//! accepts**. A document the scanner validates can be handed to the
//! eager parser later (the `graph_upload` fallback) without changing
//! the error surface, and the differential fuzz suite in
//! `tests/json_hostile.rs` holds the two to that agreement — including
//! duplicate-key last-wins, lone-surrogate replacement, and the
//! [`MAX_DEPTH`] nesting cap.

use std::borrow::Cow;

use super::json::{Json, JsonError, Parser};

/// One extracted top-level field, borrowed from the request line where
/// possible. `Container` carries the raw span of an array/object value
/// (validated but unparsed) so callers that need the tree can parse
/// just that slice.
#[derive(Clone, Debug, PartialEq)]
pub enum LazyValue<'a> {
    Null,
    Bool(bool),
    Num(f64),
    Str(Cow<'a, str>),
    Container(&'a str),
}

impl<'a> LazyValue<'a> {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            LazyValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            LazyValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Mirror of [`Json::as_u64`]: non-negative integral numbers up to
    /// 2^53 (the f64 exactness boundary).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            LazyValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            LazyValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, LazyValue::Null)
    }

    /// Materialize this field as a [`Json`] value. Containers parse
    /// their recorded span — infallible in practice because the scan
    /// already validated it (`Json::Null` on the impossible failure).
    pub fn to_json(&self) -> Json {
        match self {
            LazyValue::Null => Json::Null,
            LazyValue::Bool(b) => Json::Bool(*b),
            LazyValue::Num(n) => Json::Num(*n),
            LazyValue::Str(s) => Json::Str(s.clone().into_owned()),
            LazyValue::Container(src) => Json::parse(src).unwrap_or(Json::Null),
        }
    }
}

/// Validate `input` as one JSON document and extract the named
/// top-level object fields without building a tree.
///
/// Returns one slot per `wanted` name: `None` when the document's top
/// level is not an object or the key is absent, `Some` with the last
/// occurrence's value otherwise (duplicate keys: last wins, matching
/// [`Json::parse`]'s `BTreeMap` insert). Errors on exactly the inputs
/// [`Json::parse`] errors on.
pub fn scan_fields<'a, const N: usize>(
    input: &'a str,
    wanted: &[&str; N],
) -> Result<[Option<LazyValue<'a>>; N], JsonError> {
    let mut out: [Option<LazyValue<'a>>; N] = std::array::from_fn(|_| None);
    let mut p = Parser::new(input);
    p.skip_ws();
    if p.peek() == Some(b'{') {
        scan_top_object(&mut p, input, wanted, &mut out)?;
    } else {
        skip_value(&mut p)?;
    }
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(out)
}

/// What one skipped value was — enough to build a [`LazyValue`] without
/// having allocated anything during the skip.
enum Skipped {
    Null,
    Bool(bool),
    Num(f64),
    Str { start: usize, end: usize, escaped: bool },
    Container,
}

/// Skip one value, validating with the eager parser's exact grammar.
/// Literals and numbers reuse [`Parser::literal`] / [`Parser::number`]
/// directly (both allocation-free); strings and containers get skip
/// variants that make the same accept/reject decisions.
fn skip_value(p: &mut Parser<'_>) -> Result<Skipped, JsonError> {
    match p.peek() {
        Some(b'n') => p.literal("null", Json::Null).map(|_| Skipped::Null),
        Some(b't') => p.literal("true", Json::Bool(true)).map(|_| Skipped::Bool(true)),
        Some(b'f') => p.literal("false", Json::Bool(false)).map(|_| Skipped::Bool(false)),
        Some(b'"') => {
            let (start, end, escaped) = skip_string(p)?;
            Ok(Skipped::Str { start, end, escaped })
        }
        Some(b'[') => skip_array(p).map(|_| Skipped::Container),
        Some(b'{') => skip_object(p).map(|_| Skipped::Container),
        Some(c) if c == b'-' || c.is_ascii_digit() => {
            let n = match p.number()? {
                Json::Num(n) => n,
                _ => 0.0, // Parser::number only returns Json::Num
            };
            Ok(Skipped::Num(n))
        }
        Some(_) => Err(p.err("unexpected character")),
        None => Err(p.err("unexpected end of input")),
    }
}

/// Skip a string, returning `(content_start, content_end, escaped)` —
/// the span between the quotes and whether any escape occurred (when
/// not, the raw span *is* the decoded string and can be borrowed).
/// Validates escapes exactly like [`Parser::string`], including the
/// truncated-`\u` and bad-hex checks, without decoding.
fn skip_string(p: &mut Parser<'_>) -> Result<(usize, usize, bool), JsonError> {
    p.expect(b'"')?;
    let start = p.pos;
    let mut escaped = false;
    loop {
        match p.peek() {
            None => return Err(p.err("unterminated string")),
            Some(b'"') => {
                let end = p.pos;
                p.pos += 1;
                return Ok((start, end, escaped));
            }
            Some(b'\\') => {
                escaped = true;
                p.pos += 1;
                match p.peek() {
                    Some(b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b' | b'f') => {}
                    Some(b'u') => {
                        if p.pos + 4 >= p.b.len() {
                            return Err(p.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&p.b[p.pos + 1..p.pos + 5])
                            .map_err(|_| p.err("bad \\u escape"))?;
                        u32::from_str_radix(hex, 16).map_err(|_| p.err("bad \\u escape"))?;
                        p.pos += 4;
                    }
                    _ => return Err(p.err("bad escape")),
                }
                p.pos += 1;
            }
            Some(_) => {
                // Fast-forward to the next delimiter. Multi-byte UTF-8
                // sequences cannot contain the ASCII bytes '"' or '\\',
                // so byte stepping accepts exactly what the eager
                // parser's char stepping accepts.
                while p.pos < p.b.len() && !matches!(p.b[p.pos], b'"' | b'\\') {
                    p.pos += 1;
                }
            }
        }
    }
}

fn skip_array(p: &mut Parser<'_>) -> Result<(), JsonError> {
    p.expect(b'[')?;
    p.descend()?;
    p.skip_ws();
    if p.peek() == Some(b']') {
        p.pos += 1;
        p.depth -= 1;
        return Ok(());
    }
    loop {
        p.skip_ws();
        skip_value(p)?;
        p.skip_ws();
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b']') => {
                p.pos += 1;
                p.depth -= 1;
                return Ok(());
            }
            _ => return Err(p.err("expected ',' or ']'")),
        }
    }
}

fn skip_object(p: &mut Parser<'_>) -> Result<(), JsonError> {
    p.expect(b'{')?;
    p.descend()?;
    p.skip_ws();
    if p.peek() == Some(b'}') {
        p.pos += 1;
        p.depth -= 1;
        return Ok(());
    }
    loop {
        p.skip_ws();
        skip_string(p)?;
        p.skip_ws();
        p.expect(b':')?;
        p.skip_ws();
        skip_value(p)?;
        p.skip_ws();
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b'}') => {
                p.pos += 1;
                p.depth -= 1;
                return Ok(());
            }
            _ => return Err(p.err("expected ',' or '}'")),
        }
    }
}

/// [`skip_object`] for the top level, additionally matching keys
/// against `wanted` and recording matched values.
fn scan_top_object<'a, const N: usize>(
    p: &mut Parser<'a>,
    input: &'a str,
    wanted: &[&str; N],
    out: &mut [Option<LazyValue<'a>>; N],
) -> Result<(), JsonError> {
    p.expect(b'{')?;
    p.descend()?;
    p.skip_ws();
    if p.peek() == Some(b'}') {
        p.pos += 1;
        p.depth -= 1;
        return Ok(());
    }
    loop {
        p.skip_ws();
        let (kstart, kend, kescaped) = skip_string(p)?;
        let slot = match_key(input, wanted, kstart, kend, kescaped);
        p.skip_ws();
        p.expect(b':')?;
        p.skip_ws();
        let vstart = p.pos;
        let sk = skip_value(p)?;
        let vend = p.pos;
        if let Some(i) = slot {
            // Duplicate keys: the later value wins, like the eager
            // parser's map insert.
            out[i] = Some(lazy_value(input, sk, vstart, vend));
        }
        p.skip_ws();
        match p.peek() {
            Some(b',') => p.pos += 1,
            Some(b'}') => {
                p.pos += 1;
                p.depth -= 1;
                return Ok(());
            }
            _ => return Err(p.err("expected ',' or '}'")),
        }
    }
}

fn match_key<const N: usize>(
    input: &str,
    wanted: &[&str; N],
    start: usize,
    end: usize,
    escaped: bool,
) -> Option<usize> {
    if !escaped {
        let raw = &input[start..end];
        return wanted.iter().position(|w| *w == raw);
    }
    // Escaped key (rare for protocol traffic): decode through the eager
    // string parser — skip_string already validated the span.
    let mut sp = Parser::new_at(input, start - 1);
    let decoded = sp.string().ok()?;
    wanted.iter().position(|w| *w == decoded)
}

fn lazy_value(input: &str, sk: Skipped, vstart: usize, vend: usize) -> LazyValue<'_> {
    match sk {
        Skipped::Null => LazyValue::Null,
        Skipped::Bool(b) => LazyValue::Bool(b),
        Skipped::Num(n) => LazyValue::Num(n),
        Skipped::Str { start, end, escaped: false } => {
            LazyValue::Str(Cow::Borrowed(&input[start..end]))
        }
        Skipped::Str { start, escaped: true, .. } => {
            let mut sp = Parser::new_at(input, start - 1);
            // skip_string validated the span; decoding cannot fail.
            LazyValue::Str(Cow::Owned(sp.string().unwrap_or_default()))
        }
        Skipped::Container => LazyValue::Container(&input[vstart..vend]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::MAX_DEPTH;

    fn scan1<'a>(input: &'a str, key: &str) -> Option<LazyValue<'a>> {
        let [v] = scan_fields(input, &[key]).unwrap();
        v
    }

    #[test]
    fn extracts_scalars_without_allocation() {
        let line = r#"{"cmd":"plan","batch":32,"deep":{"cmd":"nested"},"flag":true,"n":null}"#;
        let [cmd, batch, flag, n, missing] =
            scan_fields(line, &["cmd", "batch", "flag", "n", "nope"]).unwrap();
        let cmd = cmd.unwrap();
        assert_eq!(cmd.as_str(), Some("plan"));
        assert!(matches!(cmd, LazyValue::Str(Cow::Borrowed(_))), "unescaped strings borrow");
        assert_eq!(batch.unwrap().as_u64(), Some(32));
        assert_eq!(flag.unwrap().as_bool(), Some(true));
        assert!(n.unwrap().is_null());
        assert!(missing.is_none(), "absent key stays None");
        // The nested object's "cmd" must NOT shadow the top-level one.
        assert_eq!(scan1(line, "deep").unwrap(), LazyValue::Container(r#"{"cmd":"nested"}"#));
    }

    #[test]
    fn escaped_keys_and_values_decode_like_the_eager_parser() {
        let line = r#"{"c\u006dd":"a\nb","plain":"caf\u00e9"}"#;
        let eager = Json::parse(line).unwrap();
        assert_eq!(scan1(line, "cmd").unwrap().as_str(), eager.get("cmd").as_str());
        assert_eq!(scan1(line, "plain").unwrap().as_str(), Some("café"));
        // Lone surrogates degrade to the replacement char, both paths.
        let lone = r#"{"s":"\ud800"}"#;
        assert_eq!(scan1(lone, "s").unwrap().as_str(), Json::parse(lone).unwrap().get("s").as_str());
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let line = r#"{"a":1,"a":2,"a":"three"}"#;
        assert_eq!(scan1(line, "a").unwrap().as_str(), Some("three"));
        assert_eq!(Json::parse(line).unwrap().get("a").as_str(), Some("three"));
    }

    #[test]
    fn non_object_top_level_validates_with_no_fields() {
        for doc in ["[1,2,3]", "\"str\"", "42", "true", "null"] {
            let [v] = scan_fields(doc, &["cmd"]).unwrap();
            assert!(v.is_none(), "{doc}");
        }
    }

    #[test]
    fn rejects_exactly_what_the_eager_parser_rejects() {
        for src in [
            "",
            "   ",
            "{",
            "}",
            "[",
            "[1,]",
            "[1 2]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{a: 1}",
            "{\"a\": 1,}",
            "nul",
            "tru",
            "falsy",
            "'single'",
            "\"unterminated",
            "\"bad escape \\q\"",
            "\"trunc \\u00",
            "01x",
            "- 1",
            "+1",
            "NaN",
            "Infinity",
            "[1] extra",
            "{\"a\": 1} {\"b\": 2}",
            "{\"a\":1}x",
        ] {
            assert_eq!(
                scan_fields(src, &["a"]).is_err(),
                Json::parse(src).is_err(),
                "disagreement on {src:?}"
            );
            assert!(scan_fields(src, &["a"]).is_err(), "should reject: {src:?}");
        }
    }

    #[test]
    fn depth_limit_matches_the_eager_parser() {
        let ok = "[".repeat(MAX_DEPTH) + "1" + &"]".repeat(MAX_DEPTH);
        assert!(scan_fields(&ok, &["a"]).is_ok());
        let deep = "[".repeat(MAX_DEPTH + 1) + "1" + &"]".repeat(MAX_DEPTH + 1);
        let e = scan_fields(&deep, &["a"]).unwrap_err();
        assert!(e.to_string().contains("nesting too deep"), "{e}");
        assert!(scan_fields(&"[".repeat(200_000), &["a"]).is_err());
        assert!(scan_fields(&"{\"a\":".repeat(200_000), &["a"]).is_err());
        // The wanted value itself may be a deep container — the span
        // comes back raw and parses to the same tree.
        let nested = format!("{{\"a\":{ok}}}");
        let v = scan1(&nested, "a").unwrap();
        assert_eq!(v.to_json(), Json::parse(&nested).unwrap().get("a").clone());
    }

    #[test]
    fn number_semantics_mirror_json() {
        for (doc, want) in [
            (r#"{"n":7}"#, Some(7u64)),
            (r#"{"n":-7}"#, None),
            (r#"{"n":7.5}"#, None),
            (r#"{"n":-0.0}"#, Some(0)),
            (r#"{"n":1e3}"#, Some(1000)),
            (r#"{"n":18014398509481984}"#, None),
        ] {
            let lazy = scan1(doc, "n").unwrap().as_u64();
            assert_eq!(lazy, want, "{doc}");
            assert_eq!(lazy, Json::parse(doc).unwrap().get("n").as_u64(), "{doc}");
        }
        assert_eq!(scan1(r#"{"n":2.5e10}"#, "n").unwrap().as_f64(), Some(2.5e10));
    }

    #[test]
    fn whitespace_and_to_json_roundtrip() {
        let line = " \t\r\n { \"a\" : [ 1 , {\"b\": \"x\"} ] , \"c\" : \"d\" } \n";
        let [a, c] = scan_fields(line, &["a", "c"]).unwrap();
        let eager = Json::parse(line).unwrap();
        assert_eq!(a.unwrap().to_json(), eager.get("a").clone());
        assert_eq!(c.unwrap().to_json(), eager.get("c").clone());
    }
}
