//! In-tree utility substrates.
//!
//! The build environment is offline, so the usual ecosystem crates are
//! replaced by small, fully-tested local implementations:
//!
//! - [`json`] — JSON value model + parser + serializer (graph files, the
//!   AOT artifact manifest, configs, reports).
//! - [`rng`] — deterministic PCG32 generator (synthetic data, random-DAG
//!   property tests, workload generation).
//! - [`table`] — plain-text table rendering for the paper-style reports.

pub mod json;
pub mod rng;
pub mod table;
