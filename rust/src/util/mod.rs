//! In-tree utility substrates.
//!
//! The build environment is offline, so the usual ecosystem crates are
//! replaced by small, fully-tested local implementations:
//!
//! - [`json`] — JSON value model + parser + serializer (graph files, the
//!   AOT artifact manifest, configs, reports).
//! - [`json_lazy`] — validating field scanner over the same grammar,
//!   building no tree (the serve daemon's request fast path).
//! - [`pool`] — zero-dependency worker pool with deterministic indexed
//!   maps (the threaded planner's substrate).
//! - [`rng`] — deterministic PCG32 generator (synthetic data, random-DAG
//!   property tests, workload generation).
//! - [`table`] — plain-text table rendering for the paper-style reports.

pub mod json;
pub mod json_lazy;
pub mod pool;
pub mod rng;
pub mod table;
