//! Minimal JSON value model, parser, and serializer.
//!
//! The build environment is offline (no `serde_json`), so the interchange
//! needs of the repo — graph files, the AOT artifact manifest written by
//! `python/compile/aot.py`, config files, and report emission — are served
//! by this self-contained module. It implements the full JSON grammar
//! (RFC 8259) minus some exotic float corner cases, with precise error
//! positions.
//!
//! The parser also feeds the `repro serve` daemon, i.e. it faces
//! **untrusted input**: every malformed byte must come back as a
//! [`JsonError`], never a panic. In particular, nesting depth is capped
//! at [`MAX_DEPTH`] so `[[[[…` cannot blow the recursive-descent stack.
//!
//! Serialization policy for non-finite numbers: RFC 8259 has no NaN or
//! infinity literal, so `Json::Num(f64::NAN)` (and ±∞) serialize as
//! `null` — the report writers prefer a lossy-but-valid document over
//! emitting `NaN`, which no conforming parser (including this one)
//! would accept back.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting depth [`Json::parse`] accepts. Deep enough
/// for any report or graph file the repo emits (whose nesting is ≤ 8),
/// shallow enough that hostile `[[[[…` input errors out long before the
/// parser's recursion threatens the stack.
pub const MAX_DEPTH: usize = 128;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order) — reports diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_u64(v: u64) -> Json {
        Json::Num(v as f64)
    }

    // ---- accessors -------------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Fluent object construction: `Json::obj().set("a", 1.0.into())`.
    pub fn set(mut self, key: &str, value: Json) -> Json {
        if let Json::Obj(ref mut o) = self {
            o.insert(key.to_string(), value);
        }
        self
    }

    // ---- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Compact serialization appended to an existing buffer — the
    /// allocation-free path the serve loop and [`RawJson`] use.
    pub fn write_compact_into(&self, out: &mut String) {
        self.write(out, None, 0);
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal (see module docs).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 2f64.powi(53) {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser::new(input);
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

/// Incremental single-line JSON *object* writer that can splice
/// pre-serialized fragments between tree-built fields — the zero-copy
/// reply path of the serve daemon. The caller is responsible for
/// splicing only valid `"key":value[,…]` fragments (the daemon's come
/// from [`Json::write_compact_into`] with the outer braces stripped);
/// fields built through [`RawJson::field`] are escaped properly.
pub struct RawJson {
    buf: String,
}

impl RawJson {
    /// An empty object writer (`{` already emitted).
    pub fn obj() -> RawJson {
        RawJson::with_capacity(64)
    }

    /// An empty object writer with a pre-sized buffer.
    pub fn with_capacity(cap: usize) -> RawJson {
        let mut buf = String::with_capacity(cap.max(2));
        buf.push('{');
        RawJson { buf }
    }

    fn sep(&mut self) {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
    }

    /// Append one `"key":value` field, serializing `v` compactly.
    pub fn field(&mut self, key: &str, v: &Json) {
        self.sep();
        write_escaped(&mut self.buf, key);
        self.buf.push(':');
        v.write_compact_into(&mut self.buf);
    }

    /// Append one boolean field without building a [`Json`] value.
    pub fn field_bool(&mut self, key: &str, v: bool) {
        self.sep();
        write_escaped(&mut self.buf, key);
        self.buf.push(':');
        self.buf.push_str(if v { "true" } else { "false" });
    }

    /// Append one string field without building a [`Json`] value.
    pub fn field_str(&mut self, key: &str, v: &str) {
        self.sep();
        write_escaped(&mut self.buf, key);
        self.buf.push(':');
        write_escaped(&mut self.buf, v);
    }

    /// Splice a pre-serialized `"key":value[,…]` fragment verbatim
    /// (empty fragments are a no-op). This is the zero-copy step:
    /// the fragment's fields were serialized once when their source
    /// was built and are reused byte-for-byte on every reply.
    pub fn splice(&mut self, fragment: &str) {
        if fragment.is_empty() {
            return;
        }
        self.sep();
        self.buf.push_str(fragment);
    }

    /// Like [`RawJson::splice`] for fragments stored as bytes (the
    /// serve cache stores `Arc<[u8]>`). Invalid UTF-8 — impossible for
    /// fragments this module produced — is dropped rather than spliced.
    pub fn splice_bytes(&mut self, fragment: &[u8]) {
        self.splice(std::str::from_utf8(fragment).unwrap_or(""));
    }

    /// Close the object and return the serialized line.
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}

impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}

impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Num(v as f64)
    }
}

impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}

impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The recursive-descent parser behind [`Json::parse`]. Crate-visible
/// (not `pub`) so the lazy scanner in [`crate::util::json_lazy`] can
/// reuse the exact same grammar decisions — depth cap, number syntax,
/// escape handling, error positions — via skip-variants of these
/// methods; the two must accept and reject *identical* inputs.
pub(crate) struct Parser<'a> {
    pub(crate) b: &'a [u8],
    pub(crate) pos: usize,
    /// Current container nesting depth, capped at [`MAX_DEPTH`].
    pub(crate) depth: usize,
}

impl<'a> Parser<'a> {
    /// A parser positioned at the start of `input`.
    pub(crate) fn new(input: &'a str) -> Parser<'a> {
        Parser::new_at(input, 0)
    }

    /// A parser positioned at byte `pos` of `input` — used by the lazy
    /// scanner to re-decode a validated span (e.g. an escaped string).
    pub(crate) fn new_at(input: &'a str, pos: usize) -> Parser<'a> {
        Parser { b: input.as_bytes(), pos, depth: 0 }
    }

    pub(crate) fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    /// Enter one level of container nesting, erroring past [`MAX_DEPTH`]
    /// — the guard that keeps hostile `[[[[…` input from overflowing the
    /// recursive-descent stack.
    pub(crate) fn descend(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    pub(crate) fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    pub(crate) fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    pub(crate) fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    pub(crate) fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.descend()?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.descend()?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    pub(crate) fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are rare in our data; map
                            // lone surrogates to the replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar. The input arrived as
                    // `&str` so this cannot fail mid-document, but the
                    // error path stays a positioned JsonError (not an
                    // unwrap) in case a byte-slice entry point appears.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().ok_or_else(|| self.err("unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    pub(crate) fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // The scanned range is ASCII digits/signs/dots by construction;
        // still, no unwrap on the parse path.
        let text = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (src, want) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5", Json::Num(-3.5)),
            ("1e3", Json::Num(1000.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(Json::parse(src).unwrap(), want, "{src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        // serialize → parse is identity
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.pos >= 3, "{e}");
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").unwrap_err().to_string().contains("trailing"));
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"日本語 ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("日本語 ✓"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn fluent_builder() {
        let v = Json::obj()
            .set("name", "resnet50".into())
            .set("nodes", 176u64.into())
            .set("ok", true.into());
        assert_eq!(v.get("name").as_str(), Some("resnet50"));
        assert_eq!(v.get("nodes").as_u64(), Some(176));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn deterministic_key_order() {
        let a = Json::obj().set("z", 1u64.into()).set("a", 2u64.into());
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    // -- extended coverage: the artifact manifest and the BENCH_*.json ----
    // -- outputs both ride on this module, so the edges get their own ----
    // -- regression net. ---------------------------------------------------

    #[test]
    fn malformed_inputs_all_error() {
        for src in [
            "",
            "   ",
            "{",
            "}",
            "[",
            "[1,]",
            "[1 2]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{a: 1}",
            "{\"a\": 1,}",
            "nul",
            "tru",
            "falsy",
            "'single'",
            "\"unterminated",
            "\"bad escape \\q\"",
            "\"trunc \\u00",
            "01x",
            "- 1",
            "+1",
            "NaN",
            "Infinity",
            "[1] extra",
            "{\"a\": 1} {\"b\": 2}",
        ] {
            assert!(Json::parse(src).is_err(), "should reject: {src:?}");
        }
    }

    #[test]
    fn number_edges_u64_and_f64() {
        // Exact integers survive up to 2^53 (f64 mantissa).
        let max_exact = 1u64 << 53;
        let v = Json::parse(&max_exact.to_string()).unwrap();
        assert_eq!(v.as_u64(), Some(max_exact));
        // 2^53 + 1 is not representable: it silently rounds down to 2^53 —
        // the documented precision boundary of the f64 value model.
        assert_eq!(Json::parse("9007199254740993").unwrap().as_u64(), Some(max_exact));
        // Far beyond 2^53 the u64 accessor refuses outright.
        assert_eq!(Json::parse("18014398509481984").unwrap().as_u64(), None);
        // u64::MAX round-trips only through f64 semantics.
        assert_eq!(Json::parse(&u64::MAX.to_string()).unwrap().as_u64(), None);
        // Negative and fractional values are not u64.
        assert_eq!(Json::parse("-0.0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("1e-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e-3").unwrap().as_f64(), Some(0.001));
        // Large exponents parse as f64.
        assert_eq!(Json::parse("2.5e10").unwrap().as_f64(), Some(2.5e10));
        // Serialization of integral f64 prints without a fraction.
        assert_eq!(Json::Num(4096.0).to_string(), "4096");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
        // Round-trip: serialize → parse is identity for both forms.
        for n in [0.0, -1.5, 1e15, 123456789.25] {
            let s = Json::Num(n).to_string();
            assert_eq!(Json::parse(&s).unwrap().as_f64(), Some(n), "{s}");
        }
    }

    #[test]
    fn deeply_nested_arrays_and_objects_roundtrip() {
        // Build [[[…[42]…]]] 64 levels deep, plus an object ladder.
        let mut v = Json::Num(42.0);
        for _ in 0..64 {
            v = Json::Arr(vec![v]);
        }
        let reparsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(reparsed, v);

        let mut o = Json::obj().set("leaf", true.into());
        for i in 0..32 {
            o = Json::obj().set(&format!("k{i}"), o);
        }
        let pretty = o.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), o);
        // Mixed nesting as emitted by the bench reports.
        let src = r#"{"runs": [{"name": "a", "samples": [1, 2.5, 3e2]},
                      {"name": "b", "samples": []}], "meta": {"n": 2}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("runs").as_arr().unwrap().len(), 2);
        assert_eq!(v.get("runs").as_arr().unwrap()[0].get("samples").as_arr().unwrap().len(), 3);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escape_roundtrip_all_control_chars() {
        let s: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let v = Json::Str(s.clone());
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(s.as_str()));
        // \u escapes for printable chars decode too.
        assert_eq!(Json::parse(r#""\u0041\u00e9""#).unwrap().as_str(), Some("Aé"));
        // Solidus may be escaped or bare.
        assert_eq!(Json::parse(r#""a\/b""#).unwrap().as_str(), Some("a/b"));
        // Lone surrogates degrade to the replacement character, not a panic.
        assert_eq!(Json::parse(r#""\ud800""#).unwrap().as_str(), Some("\u{fffd}"));
    }

    #[test]
    fn nesting_depth_is_limited() {
        // At the limit: parses fine.
        let ok = "[".repeat(MAX_DEPTH) + "1" + &"]".repeat(MAX_DEPTH);
        assert!(Json::parse(&ok).is_ok());
        // One past the limit: positioned error, not a stack overflow.
        let deep = "[".repeat(MAX_DEPTH + 1) + "1" + &"]".repeat(MAX_DEPTH + 1);
        let e = Json::parse(&deep).unwrap_err();
        assert!(e.to_string().contains("nesting too deep"), "{e}");
        assert_eq!(e.pos, MAX_DEPTH + 1);
        // Far past the limit (the hostile case): still just an error.
        let hostile = "[".repeat(200_000);
        assert!(Json::parse(&hostile).is_err());
        let hostile_obj = "{\"a\":".repeat(200_000);
        assert!(Json::parse(&hostile_obj).is_err());
        // Mixed arrays/objects share one depth budget.
        let mixed: String = (0..MAX_DEPTH).map(|_| "{\"a\":[").collect();
        assert!(Json::parse(&mixed).is_err());
        // Depth is released on the way out: many *sibling* containers at
        // modest depth are fine.
        let wide = format!("[{}1]", "[[[]]],".repeat(10_000));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        // In context: the document stays valid JSON and round-trips
        // (lossily: the slot comes back as Json::Null).
        let j = Json::obj().set("rate", Json::Num(f64::NAN)).set("ok", 1u64.into());
        let s = j.to_string();
        assert_eq!(s, r#"{"ok":1,"rate":null}"#);
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("rate"), &Json::Null);
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.get("a").as_u64(), Some(2));
    }

    #[test]
    fn rawjson_splices_fragments_between_fields() {
        let summary = Json::obj().set("k_segments", 4u64.into()).set("overhead", 17u64.into());
        let s = summary.to_string();
        let fragment = &s[1..s.len() - 1]; // strip the outer braces
        let mut w = RawJson::with_capacity(64);
        w.field_bool("ok", true);
        w.field_str("reply", "plan");
        w.field("id", &Json::Num(7.0));
        w.splice(fragment);
        let back = Json::parse(&w.finish()).unwrap();
        assert_eq!(back.get("ok").as_bool(), Some(true));
        assert_eq!(back.get("reply").as_str(), Some("plan"));
        assert_eq!(back.get("id").as_u64(), Some(7));
        assert_eq!(back.get("k_segments").as_u64(), Some(4));
        assert_eq!(back.get("overhead").as_u64(), Some(17));
    }

    #[test]
    fn rawjson_empty_object_escaping_and_byte_fragments() {
        assert_eq!(RawJson::obj().finish(), "{}");
        let mut w = RawJson::obj();
        w.splice(""); // no-op, must not emit a stray comma
        w.field_str("a\"b", "x\ny");
        let back = Json::parse(&w.finish()).unwrap();
        assert_eq!(back.get("a\"b").as_str(), Some("x\ny"));
        let mut w = RawJson::obj();
        w.splice_bytes(br#""n":1"#);
        assert_eq!(w.finish(), r#"{"n":1}"#);
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" \t\r\n { \"a\" : [ 1 , 2 ] } \n").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }
}
