//! Plain-text table rendering for paper-style reports.
//!
//! Every experiment harness (`repro table1`, `table2`, `figure3`, …)
//! renders its rows through this module so the output format is uniform
//! and diffs cleanly into EXPERIMENTS.md.

/// Column alignment.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple monospace table builder.
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with headers; all columns left-aligned by default.
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Left; headers.len()],
            rows: Vec::new(),
        }
    }

    /// Right-align all columns except the first (the usual numeric layout).
    pub fn numeric(mut self) -> Self {
        for a in self.aligns.iter_mut().skip(1) {
            *a = Align::Right;
        }
        self
    }

    /// Append a row. Panics if the arity differs from the header count.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with `|`-separated columns and a header rule, markdown-style.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        line.push(' ');
                        line.push_str(&cells[i]);
                        line.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad + 1));
                        line.push_str(&cells[i]);
                        line.push(' ');
                    }
                }
                line.push('|');
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Network", "Peak", "Reduction"]).numeric();
        t.row(vec!["ResNet50".into(), "3.4 GB".into(), "-62%".into()]);
        t.row(vec!["U-Net".into(), "5.0 GB".into(), "-45%".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Network"));
        assert!(lines[1].starts_with("|-"));
        assert!(lines[2].contains("ResNet50"));
        // All lines same width.
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w), "{s}");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
