//! Deterministic PRNG (PCG-XSH-RR 64/32) and sampling helpers.
//!
//! Used by the synthetic-data generator, the random-DAG property tests, and
//! workload generation in the benches. No external `rand` crate is
//! available offline; PCG is small, fast, and statistically solid for
//! these purposes. Always seeded explicitly — every experiment is
//! reproducible from its seed.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seeded constructor; `seq` selects the stream (any value works).
    pub fn new(seed: u64, seq: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (seq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience single-seed constructor.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's method (unbiased).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (bound as u64);
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Bernoulli.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — only used to initialize synthetic training data).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg32::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::seeded(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(4);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "astronomically unlikely identity");
    }
}
