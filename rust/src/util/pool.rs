//! Zero-dependency worker pool for deterministic data-parallel maps.
//!
//! The build environment is offline (no `rayon`), so this is a minimal
//! `std::thread` + `mpsc` pool shaped for exactly what the planner
//! needs: [`WorkerPool::map`], an indexed map over `0..n` whose output
//! is **always in index order and bit-identical to the serial loop** at
//! any thread count. Work is claimed in contiguous chunks off a shared
//! atomic counter, each chunk's results are sent back tagged with its
//! start index, and the caller reassembles them by position — the
//! schedule is nondeterministic, the merge never is.
//!
//! Thread-count resolution (used by [`global`]):
//! 1. `set_global_threads` (the CLI's `--threads` flag), if called
//!    before the global pool is first used;
//! 2. the `REPRO_THREADS` environment variable;
//! 3. [`std::thread::available_parallelism`].
//!
//! A pool with `threads == 1` spawns no workers and runs every map
//! inline, so the serial path stays the trivially-auditable reference.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::thread;

/// A unit of work shipped to a worker thread.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// One chunk's results: the chunk's start index plus either the mapped
/// values or the payload of a panic raised while computing them.
type ChunkResult<R> = (usize, thread::Result<Vec<R>>);

/// A fixed-size pool of persistent worker threads.
///
/// `threads` counts the *caller* as one of the workers: a pool of `t`
/// threads spawns `t - 1` background workers and the mapping thread
/// claims chunks alongside them (so `with_threads(1)` is exactly the
/// serial loop, and a map never deadlocks even when every background
/// worker is busy with somebody else's jobs).
pub struct WorkerPool {
    threads: usize,
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.threads).finish()
    }
}

impl WorkerPool {
    /// Build a pool with the given total parallelism (clamped to ≥ 1).
    pub fn with_threads(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (1..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("repro-pool-{i}"))
                    .spawn(move || loop {
                        // Hold the lock only while *receiving*; jobs run
                        // unlocked so workers drain the queue in parallel.
                        let job = rx.lock().expect("pool queue lock").recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: pool shut down
                        }
                    })
                    .expect("spawning pool worker thread")
            })
            .collect();
        WorkerPool { threads, tx: Some(tx), workers }
    }

    /// Total parallelism of this pool (background workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Map `f` over `0..n`, returning results in index order.
    ///
    /// The output is the same `Vec` the serial loop `(0..n).map(f)`
    /// produces, at any thread count — chunks are tagged with their
    /// start index and reassembled by position, so scheduling order
    /// never leaks into the result. A panic inside `f` is re-raised on
    /// the calling thread (after every in-flight chunk has finished,
    /// keeping the pool reusable).
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        if self.threads <= 1 || n <= 1 || self.workers.is_empty() {
            return (0..n).map(f).collect();
        }
        // ~4 chunks per thread: coarse enough to amortize channel
        // traffic, fine enough to balance uneven per-item cost.
        let chunk = n.div_ceil(self.threads * 4).max(1);
        let nchunks = n.div_ceil(chunk);
        let next = AtomicUsize::new(0);
        let (res_tx, res_rx) = mpsc::channel::<ChunkResult<R>>();
        let (done_tx, done_rx) = mpsc::channel::<()>();

        // Claim chunks off the shared counter until none remain. Run by
        // the helper jobs *and* by the calling thread below.
        let run_chunks = |tx: &mpsc::Sender<ChunkResult<R>>| loop {
            let start = next.fetch_add(chunk, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + chunk).min(n);
            let out = catch_unwind(AssertUnwindSafe(|| (start..end).map(&f).collect::<Vec<R>>()));
            let _ = tx.send((start, out));
        };

        let helpers = self.workers.len();
        {
            let pool_tx = self.tx.as_ref().expect("pool alive while borrowed");
            let run = &run_chunks;
            for _ in 0..helpers {
                let res_tx = res_tx.clone();
                let done_tx = done_tx.clone();
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    run(&res_tx);
                    // Termination signal — sent only after the job's last
                    // use of anything borrowed from this stack frame.
                    let _ = done_tx.send(());
                });
                // SAFETY: the job borrows `run_chunks` (and through it
                // `next`, `f`, `chunk`, `n`) from this stack frame. We
                // erase that lifetime to ship it through the 'static job
                // queue, which is sound because this function does not
                // return until `done_rx` has received one signal per
                // helper job — i.e. until every job has finished its last
                // use of those borrows. Box<dyn FnOnce> layout does not
                // depend on the lifetime parameter.
                let job = unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job) };
                pool_tx.send(job).expect("pool workers alive");
            }
        }

        // The caller works too — this also guarantees progress when the
        // background workers are saturated (e.g. nested maps).
        run_chunks(&res_tx);

        // Every start index < n is claimed exactly once and reported
        // exactly once, so exactly `nchunks` messages arrive.
        let mut parts: Vec<ChunkResult<R>> = Vec::with_capacity(nchunks);
        for _ in 0..nchunks {
            parts.push(res_rx.recv().expect("every claimed chunk reports a result"));
        }
        // Wait for job *termination* (not just chunk completion) before
        // returning: the borrows erased above must outlive the jobs.
        for _ in 0..helpers {
            done_rx.recv().expect("every helper job terminates");
        }

        parts.sort_by_key(|&(start, _)| start);
        let mut out: Vec<R> = Vec::with_capacity(n);
        let mut panic_payload = None;
        for (_, part) in parts {
            match part {
                Ok(mut v) => out.append(&mut v),
                Err(payload) => {
                    // Keep the *first* panic in index order — deterministic
                    // even when several chunks panic concurrently.
                    panic_payload.get_or_insert(payload);
                }
            }
        }
        if let Some(payload) = panic_payload {
            resume_unwind(payload);
        }
        debug_assert_eq!(out.len(), n);
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the queue: workers see Err(recv) and exit
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
static OVERRIDE: OnceLock<usize> = OnceLock::new();

/// Parse a `REPRO_THREADS`-style value; `Some(n ≥ 1)` on success.
fn parse_threads(v: &str) -> Option<usize> {
    v.trim().parse::<usize>().ok().map(|n| n.max(1))
}

/// Thread count the global pool will use absent a CLI override:
/// `REPRO_THREADS` if set and parseable, else available parallelism.
fn env_threads() -> usize {
    std::env::var("REPRO_THREADS")
        .ok()
        .as_deref()
        .and_then(parse_threads)
        .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Override the global pool's thread count (the CLI's `--threads`).
///
/// Returns `true` if the override takes effect — i.e. it was the first
/// override and the global pool had not been built yet. Call it before
/// any planning work.
pub fn set_global_threads(threads: usize) -> bool {
    OVERRIDE.set(threads.max(1)).is_ok() && GLOBAL.get().is_none()
}

/// The process-wide shared pool, built on first use (see the module
/// docs for thread-count resolution).
pub fn global() -> Arc<WorkerPool> {
    Arc::clone(GLOBAL.get_or_init(|| {
        let t = OVERRIDE.get().copied().unwrap_or_else(env_threads);
        Arc::new(WorkerPool::with_threads(t))
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_matches_serial_at_any_thread_count() {
        let want: Vec<u64> = (0..257).map(|i| (i as u64) * 3 + 1).collect();
        for t in [1, 2, 4, 7] {
            let pool = WorkerPool::with_threads(t);
            assert_eq!(pool.map(257, |i| (i as u64) * 3 + 1), want, "threads={t}");
        }
    }

    #[test]
    fn empty_and_singleton_maps_run_inline() {
        let pool = WorkerPool::with_threads(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn results_may_borrow_from_the_closure_environment() {
        let data: Vec<String> = (0..40).map(|i| format!("s{i}")).collect();
        let pool = WorkerPool::with_threads(3);
        let refs: Vec<&str> = pool.map(data.len(), |i| data[i].as_str());
        assert_eq!(refs.len(), 40);
        assert_eq!(refs[7], "s7");
    }

    #[test]
    fn panics_propagate_and_the_pool_survives() {
        let pool = WorkerPool::with_threads(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(64, |i| {
                assert_ne!(i, 33, "boom");
                i
            })
        }));
        assert!(r.is_err(), "the chunk panic must reach the caller");
        assert_eq!(pool.map(8, |i| i), (0..8).collect::<Vec<_>>(), "pool reusable after panic");
    }

    #[test]
    fn nested_maps_complete_without_deadlock() {
        let pool = WorkerPool::with_threads(2);
        let sums = pool.map(6, |i| pool.map(5, |j| i * j).into_iter().sum::<usize>());
        assert_eq!(sums, (0..6).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn thread_count_parsing() {
        assert_eq!(parse_threads("4"), Some(4));
        assert_eq!(parse_threads(" 2 "), Some(2));
        assert_eq!(parse_threads("0"), Some(1), "zero clamps to one");
        assert_eq!(parse_threads("many"), None);
        assert_eq!(parse_threads(""), None);
    }
}
