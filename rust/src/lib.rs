//! # recompute — graph-theoretic recomputation for memory-efficient backprop
//!
//! A production reimplementation of *"A Graph Theoretic Framework of
//! Recomputation Algorithms for Memory-Efficient Backpropagation"*
//! (Kusumoto, Inoue, Watanabe, Akiba & Koyama, NeurIPS 2019).
//!
//! The library is organized bottom-up:
//!
//! - [`graph`] — the computation-DAG substrate: bitset node sets, lower
//!   sets (order ideals), boundaries, δ±-neighborhoods, enumeration,
//!   articulation points.
//! - [`models`] — a network zoo (ResNet, VGG, DenseNet, GoogLeNet, U-Net,
//!   PSPNet, MLP/transformer towers) with shape-propagated memory costs,
//!   reproducing the graphs of the paper's evaluation.
//! - [`planner`] — the paper's contribution: the general recomputation
//!   problem, the exhaustive DFS oracle, the exact DP (Algorithm 1), the
//!   approximate DP over `L^Pruned`, time-centric vs memory-centric
//!   strategies, minimal-budget binary search, and Chen's √n checkpointing
//!   baseline — all behind the [`planner::Planner`] trait, addressed by
//!   typed [`planner::PlannerId`]s.
//! - [`session`] — the serving layer: [`session::PlanSession`] owns a
//!   graph plus its amortized artifacts (lower-set families, DP
//!   contexts, memoized `B*`, the vanilla program) and answers
//!   [`planner::PlanRequest`]s with cached
//!   [`session::CompiledPlan`]s from an LRU keyed by
//!   `(graph fingerprint, request)`.
//! - [`sim`] — an event-accurate execution simulator with liveness
//!   analysis, measuring true peak memory of any strategy (Tables 1 & 2).
//!   Liveness is a trace *rewrite* (`apply_liveness`): explicit last-use
//!   `Free` events that one shared fold measures and the executor
//!   compiles, so simulated and executed free schedules are the same
//!   object.
//! - [`runtime`] — the pluggable execution-backend layer: a
//!   *shape-polymorphic* [`runtime::Backend`] trait (upload / run-kernel
//!   / download / per-kernel stats; dims travel with each tensor, the
//!   dense path is rectangular) with two implementations. The default
//!   [`runtime::NativeBackend`] is pure-Rust f32 CPU kernels — the whole
//!   stack builds and trains with `cargo` alone, no Python, no artifacts,
//!   no native libraries — backed by a size-classed buffer pool
//!   (`runtime::MemoryPool`) that recycles freed tensors into later
//!   allocations, so liveness-schedule churn costs no malloc traffic.
//!   The `xla` cargo feature adds the PJRT backend, which loads
//!   AOT-compiled HLO-text artifacts produced by `python/compile/aot.py`.
//! - [`exec`] — the training executors, generic over `Backend`: the chain
//!   fast path (`TowerTrainer`) and the trace-driven general-DAG path
//!   (`OpProgram` + `DagTrainer`, running the whole zoo's branch/merge
//!   graphs for real with heterogeneous per-node tensor shapes), both
//!   following a recomputation plan exactly as the canonical strategy
//!   prescribes, with measured live-byte accounting cross-checked against
//!   the simulator.
//! - [`testutil`] — shared seeded fixtures (`random_dag`, `chain_graph`,
//!   `diamond`) used by the unit, integration and property suites.
//! - [`coordinator`] — the training-loop driver: backend selection,
//!   schedule comparison, metrics, JSON reports.
//! - [`bench`] — shared harness code regenerating every table/figure of
//!   the paper's evaluation section, with machine-readable `BENCH_*.json`
//!   output.
//! - [`anyhow`] — in-tree stand-in for the `anyhow` crate ([`util`] holds
//!   the other offline substrates: JSON, RNG, tables).
//!
//! Planning quickstart (also the `quickstart` example, which additionally
//! trains a tower end-to-end on the native backend):
//!
//! ```
//! use recompute::models::zoo;
//! use recompute::planner::{self, Objective};
//! use recompute::sim::{simulate, SimOptions};
//!
//! let g = zoo::resnet50(4, 224); // batch 4, 224×224 input
//! let budget = g.total_mem(); // any feasible budget
//! let plan = planner::approx_dp(&g, budget, Objective::MinOverhead).unwrap();
//! let report = simulate(&g, &plan.chain, SimOptions::default());
//! assert!(report.peak_bytes <= g.total_mem() * 3);
//! ```
//!
//! Training quickstart — pure Rust, no setup:
//!
//! ```
//! use recompute::coordinator::train::{schedule_for_mode, BudgetSpec, ScheduleMode};
//! use recompute::exec::{TowerTrainer, TrainConfig};
//!
//! let cfg = TrainConfig { layers: 4, steps: 2, ..TrainConfig::default() };
//! let sched =
//!     schedule_for_mode(ScheduleMode::Tc, cfg.layers, 16, 4, BudgetSpec::MinFeasible).unwrap();
//! let mut trainer = TowerTrainer::native(4, 16, &cfg).unwrap();
//! let report = trainer.train(&sched, &cfg).unwrap();
//! assert!(report.losses.iter().all(|l| l.is_finite()));
//! ```
//!
//! Session quickstart — repeated requests are served from the cache:
//!
//! ```
//! use recompute::planner::{Objective, PlanRequest, PlannerId};
//! use recompute::session::PlanSession;
//!
//! let session = PlanSession::new(recompute::models::zoo::vgg19(4, 224));
//! let req = PlanRequest::new(PlannerId::ApproxDp, Objective::MinOverhead);
//! let first = session.plan(&req).unwrap(); // planned + compiled
//! let again = session.plan(&req).unwrap(); // cache hit: same Arc
//! assert!(std::sync::Arc::ptr_eq(&first, &again));
//! assert_eq!(session.stats().hits, 1);
//! ```

pub mod anyhow;
pub mod bench;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod models;
pub mod planner;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod util;

pub mod testutil;

pub use graph::{Graph, GraphBuilder, NodeId, NodeSet, OpKind};

/// Human-readable byte formatting used across reports (GiB with 1 decimal
/// for large values, MiB otherwise) — mirrors the paper's "2.7 GB" style.
pub fn fmt_bytes(b: u64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    let bf = b as f64;
    if bf >= GIB {
        format!("{:.1} GB", bf / GIB)
    } else if bf >= MIB {
        format!("{:.0} MB", bf / MIB)
    } else {
        format!("{b} B")
    }
}

/// Parse a human-readable byte size: `"512"`, `"64KiB"`, `"1.5MiB"`,
/// `"2GiB"`. Units are binary; `KB`/`MB`/`GB` (and bare `K`/`M`/`G`)
/// are accepted as aliases of the binary units, matching how
/// [`fmt_bytes`] renders. The inverse direction of `fmt_bytes`, used by
/// the CLI's `--budget` flags.
pub fn parse_bytes(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    let unit_start = t.find(|c: char| !(c.is_ascii_digit() || c == '.')).unwrap_or(t.len());
    let (num, unit) = t.split_at(unit_start);
    let mult: f64 = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" | "kib" => (1u64 << 10) as f64,
        "m" | "mb" | "mib" => (1u64 << 20) as f64,
        "g" | "gb" | "gib" => (1u64 << 30) as f64,
        other => {
            return Err(anyhow::Error::msg(format!(
                "bad byte unit '{other}' in '{s}' (use B, KiB, MiB or GiB)"
            )))
        }
    };
    let value: f64 = num
        .parse()
        .map_err(|_| anyhow::Error::msg(format!("bad byte size '{s}'")))?;
    if !value.is_finite() || value < 0.0 {
        return Err(anyhow::Error::msg(format!("bad byte size '{s}'")));
    }
    Ok((value * mult).round() as u64)
}

/// Parse a CLI `--budget` value, shared by `repro plan` and `repro
/// train` so the flag means the same thing everywhere: a bare number is
/// **gigabytes** (the CLI's original contract), a value with a unit
/// suffix goes through [`parse_bytes`] (`512KiB`, `1.5MiB`, `2GiB`).
pub fn parse_budget(s: &str) -> anyhow::Result<u64> {
    let s = s.trim();
    if let Ok(gb) = s.parse::<f64>() {
        if !gb.is_finite() || gb < 0.0 {
            return Err(anyhow::Error::msg(format!("bad budget '{s}'")));
        }
        return Ok((gb * (1u64 << 30) as f64) as u64);
    }
    parse_bytes(s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_bytes_bands() {
        assert_eq!(super::fmt_bytes(512), "512 B");
        assert_eq!(super::fmt_bytes(3 << 20), "3 MB");
        assert_eq!(super::fmt_bytes((27 << 30) / 10), "2.7 GB");
    }

    #[test]
    fn parse_bytes_units_and_errors() {
        assert_eq!(super::parse_bytes("512").unwrap(), 512);
        assert_eq!(super::parse_bytes("512B").unwrap(), 512);
        assert_eq!(super::parse_bytes("512KiB").unwrap(), 512 << 10);
        assert_eq!(super::parse_bytes("512kb").unwrap(), 512 << 10);
        assert_eq!(super::parse_bytes("1.5MiB").unwrap(), 3 << 19);
        assert_eq!(super::parse_bytes("2GiB").unwrap(), 2 << 30);
        assert_eq!(super::parse_bytes(" 64 KiB ").unwrap(), 64 << 10);
        assert!(super::parse_bytes("12parsecs").is_err());
        assert!(super::parse_bytes("KiB").is_err());
        assert!(super::parse_bytes("-3KiB").is_err());
        // Round-trips with fmt_bytes' rendering.
        assert_eq!(super::parse_bytes("3 MB").unwrap(), 3 << 20);
    }

    #[test]
    fn parse_budget_bare_is_gb_suffixed_is_bytes() {
        assert_eq!(super::parse_budget("2").unwrap(), 2 << 30);
        assert_eq!(super::parse_budget(" 2 ").unwrap(), 2 << 30, "whitespace still means GB");
        assert_eq!(super::parse_budget("0.5").unwrap(), 1 << 29);
        assert_eq!(super::parse_budget("512KiB").unwrap(), 512 << 10);
        assert!(super::parse_budget("-1").is_err());
        assert!(super::parse_budget("chonk").is_err());
    }
}
