//! # recompute — graph-theoretic recomputation for memory-efficient backprop
//!
//! A production reimplementation of *"A Graph Theoretic Framework of
//! Recomputation Algorithms for Memory-Efficient Backpropagation"*
//! (Kusumoto, Inoue, Watanabe, Akiba & Koyama, NeurIPS 2019).
//!
//! The library is organized bottom-up:
//!
//! - [`graph`] — the computation-DAG substrate: bitset node sets, lower
//!   sets (order ideals), boundaries, δ±-neighborhoods, enumeration,
//!   articulation points.
//! - [`models`] — a network zoo (ResNet, VGG, DenseNet, GoogLeNet, U-Net,
//!   PSPNet, MLP/transformer towers) with shape-propagated memory costs,
//!   reproducing the graphs of the paper's evaluation.
//! - [`planner`] — the paper's contribution: the general recomputation
//!   problem, the exhaustive DFS oracle, the exact DP (Algorithm 1), the
//!   approximate DP over `L^Pruned`, time-centric vs memory-centric
//!   strategies, minimal-budget binary search, and Chen's √n checkpointing
//!   baseline — all behind the [`planner::Planner`] trait, addressed by
//!   typed [`planner::PlannerId`]s.
//! - [`session`] — the serving layer: [`session::PlanSession`] owns a
//!   graph plus its amortized artifacts (lower-set families, DP
//!   contexts, memoized `B*`, the vanilla program) and answers
//!   [`planner::PlanRequest`]s with cached
//!   [`session::CompiledPlan`]s from an LRU keyed by
//!   `(graph fingerprint, request)`.
//! - [`sim`] — an event-accurate execution simulator with liveness
//!   analysis, measuring true peak memory of any strategy (Tables 1 & 2).
//!   Liveness is a trace *rewrite* (`apply_liveness`): explicit last-use
//!   `Free` events that one shared fold measures and the executor
//!   compiles, so simulated and executed free schedules are the same
//!   object.
//! - [`runtime`] — the pluggable execution-backend layer: a
//!   *shape-polymorphic* [`runtime::Backend`] trait (upload / run-kernel
//!   / download / per-kernel stats; dims travel with each tensor, the
//!   dense path is rectangular) with two implementations. The default
//!   [`runtime::NativeBackend`] is pure-Rust f32 CPU kernels — the whole
//!   stack builds and trains with `cargo` alone, no Python, no artifacts,
//!   no native libraries — backed by a size-classed buffer pool
//!   (`runtime::MemoryPool`) that recycles freed tensors into later
//!   allocations, so liveness-schedule churn costs no malloc traffic.
//!   The `xla` cargo feature adds the PJRT backend, which loads
//!   AOT-compiled HLO-text artifacts produced by `python/compile/aot.py`.
//! - [`exec`] — the training executors, generic over `Backend`: the chain
//!   fast path (`TowerTrainer`) and the trace-driven general-DAG path
//!   (`OpProgram` + `DagTrainer`, running the whole zoo's branch/merge
//!   graphs for real with heterogeneous per-node tensor shapes), both
//!   following a recomputation plan exactly as the canonical strategy
//!   prescribes, with measured live-byte accounting cross-checked against
//!   the simulator.
//! - [`analysis`] — the static schedule auditor: an abstract
//!   interpretation of a trace's event stream (per-buffer lifetime
//!   states) plus chain/coverage/budget cross-checks, emitting
//!   stable-coded [`analysis::Diagnostic`]s; every `CompiledPlan` is
//!   audited at compile time and the daemon rejects plans that fail.
//! - [`serve`] — the plan-serving daemon behind `repro serve`: a
//!   zero-dependency newline-delimited-JSON-over-TCP listener that
//!   multiplexes many concurrent clients onto one shared
//!   [`session::SessionRegistry`] (upload a graph, plan it, train a zoo
//!   model, read cache/latency stats), with admission control, bounded
//!   hostile-input handling (every bad request gets a structured JSON
//!   error, never a panic or a silent disconnect) and graceful shutdown.
//! - [`testutil`] — shared seeded fixtures (`random_dag`, `chain_graph`,
//!   `diamond`) used by the unit, integration and property suites.
//! - [`coordinator`] — the training-loop driver: backend selection,
//!   schedule comparison, metrics, JSON reports.
//! - [`bench`] — shared harness code regenerating every table/figure of
//!   the paper's evaluation section, with machine-readable `BENCH_*.json`
//!   output.
//! - [`anyhow`] — in-tree stand-in for the `anyhow` crate ([`util`] holds
//!   the other offline substrates: JSON, RNG, tables).
//!
//! Planning quickstart (also the `quickstart` example, which additionally
//! trains a tower end-to-end on the native backend):
//!
//! ```
//! use recompute::models::zoo;
//! use recompute::planner::{self, Objective};
//! use recompute::sim::{simulate, SimOptions};
//!
//! let g = zoo::resnet50(4, 224); // batch 4, 224×224 input
//! let budget = g.total_mem(); // any feasible budget
//! let plan = planner::approx_dp(&g, budget, Objective::MinOverhead).unwrap();
//! let report = simulate(&g, &plan.chain, SimOptions::default());
//! assert!(report.peak_bytes <= g.total_mem() * 3);
//! ```
//!
//! Training quickstart — pure Rust, no setup:
//!
//! ```
//! use recompute::coordinator::train::{schedule_for_mode, BudgetSpec, ScheduleMode};
//! use recompute::exec::{TowerTrainer, TrainConfig};
//!
//! let cfg = TrainConfig { layers: 4, steps: 2, ..TrainConfig::default() };
//! let sched =
//!     schedule_for_mode(ScheduleMode::Tc, cfg.layers, 16, 4, BudgetSpec::MinFeasible).unwrap();
//! let mut trainer = TowerTrainer::native(4, 16, &cfg).unwrap();
//! let report = trainer.train(&sched, &cfg).unwrap();
//! assert!(report.losses.iter().all(|l| l.is_finite()));
//! ```
//!
//! Session quickstart — repeated requests are served from the cache:
//!
//! ```
//! use recompute::planner::{Objective, PlanRequest, PlannerId};
//! use recompute::session::PlanSession;
//!
//! let session = PlanSession::new(recompute::models::zoo::vgg19(4, 224));
//! let req = PlanRequest::new(PlannerId::ApproxDp, Objective::MinOverhead);
//! let first = session.plan(&req).unwrap(); // planned + compiled
//! let again = session.plan(&req).unwrap(); // cache hit: same Arc
//! assert!(std::sync::Arc::ptr_eq(&first, &again));
//! assert_eq!(session.stats().hits, 1);
//! ```

// The auditor, the serving layer and the session cache are the modules
// that stand between a defective schedule and a client — they hold the
// repo's hardest lint bar: no unwrap/expect outside tests (clippy.toml
// sets `allow-unwrap-in-tests`/`allow-expect-in-tests`).
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod analysis;
pub mod anyhow;
pub mod bench;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod models;
pub mod planner;
pub mod runtime;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod serve;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod session;
pub mod sim;
pub mod util;

pub mod testutil;

pub use graph::{Graph, GraphBuilder, NodeId, NodeSet, OpKind};

/// Human-readable byte formatting used across reports (GiB with 1 decimal
/// for large values, MiB otherwise) — mirrors the paper's "2.7 GB" style.
pub fn fmt_bytes(b: u64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    let bf = b as f64;
    if bf >= GIB {
        format!("{:.1} GB", bf / GIB)
    } else if bf >= MIB {
        format!("{:.0} MB", bf / MIB)
    } else {
        format!("{b} B")
    }
}

/// End of the numeric prefix of a byte-size string: digits, dots, and an
/// exponent (`e`/`E` with optional sign) — so `"1e3KiB"` splits as
/// `("1e3", "KiB")` rather than at the `e`.
fn numeric_prefix_len(t: &str) -> usize {
    let b = t.as_bytes();
    let mut i = 0;
    while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'.') {
        i += 1;
    }
    if i > 0 && i < b.len() && (b[i] == b'e' || b[i] == b'E') {
        let mut j = i + 1;
        if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
            j += 1;
        }
        // Only consume the exponent if digits actually follow — "1KiB"
        // must not lose its 'K' to a half-parsed exponent... and "1e" /
        // "1eGiB" stay unit errors rather than silently dropping bytes.
        if j < b.len() && b[j].is_ascii_digit() {
            i = j;
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
        }
    }
    i
}

/// Reject byte counts that do not fit in `u64` instead of silently
/// saturating: `f64 → u64` casts clamp, so `"99999999999999GiB"` would
/// otherwise come back as `u64::MAX` and sail through budget checks.
fn checked_bytes(bytes: f64, s: &str) -> anyhow::Result<u64> {
    // `u64::MAX as f64` rounds up to 2^64 exactly; every finite f64
    // strictly below it casts losslessly into range.
    if !bytes.is_finite() || bytes >= u64::MAX as f64 {
        return Err(anyhow::Error::msg(format!(
            "byte size '{s}' overflows the u64 byte range (max ~16 EiB)"
        )));
    }
    Ok(bytes.round() as u64)
}

/// Parse a human-readable byte size: `"512"`, `"64KiB"`, `"1.5MiB"`,
/// `"2GiB"`, `"1e3KiB"`. Units are binary; `KB`/`MB`/`GB` (and bare
/// `K`/`M`/`G`) are accepted as aliases of the binary units, matching
/// how [`fmt_bytes`] renders. The inverse direction of `fmt_bytes`,
/// used by the CLI's `--budget` flags and the serve request router.
/// Values whose byte count exceeds `u64::MAX` are rejected with a named
/// overflow error (no silent saturation).
pub fn parse_bytes(s: &str) -> anyhow::Result<u64> {
    let t = s.trim();
    let (num, unit) = t.split_at(numeric_prefix_len(t));
    let mult: f64 = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "k" | "kb" | "kib" => (1u64 << 10) as f64,
        "m" | "mb" | "mib" => (1u64 << 20) as f64,
        "g" | "gb" | "gib" => (1u64 << 30) as f64,
        other => {
            return Err(anyhow::Error::msg(format!(
                "bad byte unit '{other}' in '{s}' (use B, KiB, MiB or GiB)"
            )))
        }
    };
    let value: f64 = num
        .parse()
        .map_err(|_| anyhow::Error::msg(format!("bad byte size '{s}'")))?;
    if !value.is_finite() || value < 0.0 {
        return Err(anyhow::Error::msg(format!("bad byte size '{s}'")));
    }
    checked_bytes(value * mult, s)
}

/// Parse a CLI `--budget` value, shared by `repro plan` and `repro
/// train` so the flag means the same thing everywhere: a bare number is
/// **gigabytes** (the CLI's original contract), a value with a unit
/// suffix goes through [`parse_bytes`] (`512KiB`, `1.5MiB`, `2GiB`).
/// Budgets beyond the `u64` byte range error (see [`parse_bytes`]).
pub fn parse_budget(s: &str) -> anyhow::Result<u64> {
    let s = s.trim();
    if let Ok(gb) = s.parse::<f64>() {
        if !gb.is_finite() || gb < 0.0 {
            return Err(anyhow::Error::msg(format!("bad budget '{s}'")));
        }
        return checked_bytes(gb * (1u64 << 30) as f64, s);
    }
    parse_bytes(s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_bytes_bands() {
        assert_eq!(super::fmt_bytes(512), "512 B");
        assert_eq!(super::fmt_bytes(3 << 20), "3 MB");
        assert_eq!(super::fmt_bytes((27 << 30) / 10), "2.7 GB");
    }

    #[test]
    fn parse_bytes_units_and_errors() {
        assert_eq!(super::parse_bytes("512").unwrap(), 512);
        assert_eq!(super::parse_bytes("512B").unwrap(), 512);
        assert_eq!(super::parse_bytes("512KiB").unwrap(), 512 << 10);
        assert_eq!(super::parse_bytes("512kb").unwrap(), 512 << 10);
        assert_eq!(super::parse_bytes("1.5MiB").unwrap(), 3 << 19);
        assert_eq!(super::parse_bytes("2GiB").unwrap(), 2 << 30);
        assert_eq!(super::parse_bytes(" 64 KiB ").unwrap(), 64 << 10);
        assert!(super::parse_bytes("12parsecs").is_err());
        assert!(super::parse_bytes("KiB").is_err());
        assert!(super::parse_bytes("-3KiB").is_err());
        // Round-trips with fmt_bytes' rendering.
        assert_eq!(super::parse_bytes("3 MB").unwrap(), 3 << 20);
    }

    #[test]
    fn parse_bytes_rejects_u64_overflow_instead_of_saturating() {
        // The original bug: f64 → u64 casts clamp, so this returned
        // u64::MAX instead of erroring.
        let err = super::parse_bytes("99999999999999GiB").unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
        for s in ["1e30KiB", "20000000000GiB", "18446744073709551616", "1e100"] {
            let err = super::parse_bytes(s).unwrap_err().to_string();
            assert!(err.contains("overflow"), "{s}: {err}");
        }
        // Near the boundary: in-range values still parse (u64::MAX
        // itself is not representable in f64; the largest representable
        // value below 2^64 is fine).
        assert_eq!(super::parse_bytes("9223372036854775808").unwrap(), 1u64 << 63);
        assert_eq!(super::parse_bytes("8589934592GiB").unwrap(), 8_589_934_592u64 << 30);
    }

    #[test]
    fn parse_bytes_exponent_inputs() {
        // Scientific-notation numerics split before the unit, not at 'e'.
        assert_eq!(super::parse_bytes("1e3KiB").unwrap(), 1000 << 10);
        assert_eq!(super::parse_bytes("1E3KiB").unwrap(), 1000 << 10);
        assert_eq!(super::parse_bytes("2.5e2MiB").unwrap(), 250 << 20);
        assert_eq!(super::parse_bytes("1e-3KiB").unwrap(), 1, "rounded from 1.024 bytes");
        assert_eq!(super::parse_bytes("1e3").unwrap(), 1000);
        // A half-formed exponent is a unit error, not a silent truncation.
        assert!(super::parse_bytes("1e").is_err());
        assert!(super::parse_bytes("1eGiB").is_err());
        assert!(super::parse_bytes("1e+GiB").is_err());
    }

    #[test]
    fn parse_budget_bare_is_gb_suffixed_is_bytes() {
        assert_eq!(super::parse_budget("2").unwrap(), 2 << 30);
        assert_eq!(super::parse_budget(" 2 ").unwrap(), 2 << 30, "whitespace still means GB");
        assert_eq!(super::parse_budget("0.5").unwrap(), 1 << 29);
        assert_eq!(super::parse_budget("512KiB").unwrap(), 512 << 10);
        assert!(super::parse_budget("-1").is_err());
        assert!(super::parse_budget("chonk").is_err());
        // GB values that overflow the u64 byte range error by name on
        // the bare-number path too.
        let err = super::parse_budget("1e30").unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
        assert!(super::parse_budget("99999999999999GiB").is_err());
    }
}
