//! # recompute — graph-theoretic recomputation for memory-efficient backprop
//!
//! A production reimplementation of *"A Graph Theoretic Framework of
//! Recomputation Algorithms for Memory-Efficient Backpropagation"*
//! (Kusumoto, Inoue, Watanabe, Akiba & Koyama, NeurIPS 2019).
//!
//! The library is organized bottom-up:
//!
//! - [`graph`] — the computation-DAG substrate: bitset node sets, lower
//!   sets (order ideals), boundaries, δ±-neighborhoods, enumeration,
//!   articulation points.
//! - [`models`] — a network zoo (ResNet, VGG, DenseNet, GoogLeNet, U-Net,
//!   PSPNet, MLP/transformer towers) with shape-propagated memory costs,
//!   reproducing the graphs of the paper's evaluation.
//! - [`planner`] — the paper's contribution: the general recomputation
//!   problem, the exhaustive DFS oracle, the exact DP (Algorithm 1), the
//!   approximate DP over `L^Pruned`, time-centric vs memory-centric
//!   strategies, minimal-budget binary search, and Chen's √n checkpointing
//!   baseline.
//! - [`sim`] — an event-accurate execution simulator with liveness
//!   analysis, measuring true peak memory of any strategy (Tables 1 & 2).
//! - [`runtime`] — the pluggable execution-backend layer: a
//!   [`runtime::Backend`] trait (upload / run-kernel / download /
//!   per-kernel stats) with two implementations. The default
//!   [`runtime::NativeBackend`] is pure-Rust f32 CPU kernels — the whole
//!   stack builds and trains with `cargo` alone, no Python, no artifacts,
//!   no native libraries. The `xla` cargo feature adds the PJRT backend,
//!   which loads AOT-compiled HLO-text artifacts produced by
//!   `python/compile/aot.py`.
//! - [`exec`] — the training executors, generic over `Backend`: the chain
//!   fast path (`TowerTrainer`) and the trace-driven general-DAG path
//!   (`OpProgram` + `DagTrainer`, running the whole zoo's branch/merge
//!   graphs for real), both following a recomputation plan exactly as the
//!   canonical strategy prescribes, with measured live-byte accounting
//!   cross-checked against the simulator.
//! - [`testutil`] — shared seeded fixtures (`random_dag`, `chain_graph`,
//!   `diamond`) used by the unit, integration and property suites.
//! - [`coordinator`] — the training-loop driver: backend selection,
//!   schedule comparison, metrics, JSON reports.
//! - [`bench`] — shared harness code regenerating every table/figure of
//!   the paper's evaluation section, with machine-readable `BENCH_*.json`
//!   output.
//! - [`anyhow`] — in-tree stand-in for the `anyhow` crate ([`util`] holds
//!   the other offline substrates: JSON, RNG, tables).
//!
//! Planning quickstart (also the `quickstart` example, which additionally
//! trains a tower end-to-end on the native backend):
//!
//! ```
//! use recompute::models::zoo;
//! use recompute::planner::{self, Objective};
//! use recompute::sim::{simulate, SimOptions};
//!
//! let g = zoo::resnet50(4, 224); // batch 4, 224×224 input
//! let budget = g.total_mem(); // any feasible budget
//! let plan = planner::approx_dp(&g, budget, Objective::MinOverhead).unwrap();
//! let report = simulate(&g, &plan.chain, SimOptions::default());
//! assert!(report.peak_bytes <= g.total_mem() * 3);
//! ```
//!
//! Training quickstart — pure Rust, no setup:
//!
//! ```
//! use recompute::coordinator::train::schedule_for_mode;
//! use recompute::exec::{TowerTrainer, TrainConfig};
//!
//! let cfg = TrainConfig { layers: 4, steps: 2, ..TrainConfig::default() };
//! let sched = schedule_for_mode("tc", cfg.layers, 16, 4, None).unwrap();
//! let mut trainer = TowerTrainer::native(4, 16, &cfg).unwrap();
//! let report = trainer.train(&sched, &cfg).unwrap();
//! assert!(report.losses.iter().all(|l| l.is_finite()));
//! ```

pub mod anyhow;
pub mod bench;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod models;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod util;

pub mod testutil;

pub use graph::{Graph, GraphBuilder, NodeId, NodeSet, OpKind};

/// Human-readable byte formatting used across reports (GiB with 1 decimal
/// for large values, MiB otherwise) — mirrors the paper's "2.7 GB" style.
pub fn fmt_bytes(b: u64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    let bf = b as f64;
    if bf >= GIB {
        format!("{:.1} GB", bf / GIB)
    } else if bf >= MIB {
        format!("{:.0} MB", bf / MIB)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn fmt_bytes_bands() {
        assert_eq!(super::fmt_bytes(512), "512 B");
        assert_eq!(super::fmt_bytes(3 << 20), "3 MB");
        assert_eq!(super::fmt_bytes((27 << 30) / 10), "2.7 GB");
    }
}
