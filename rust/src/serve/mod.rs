//! `repro serve` — the long-running plan-serving daemon.
//!
//! A zero-dependency newline-delimited-JSON-over-TCP listener: each
//! client connection sends one JSON object per line and receives exactly
//! one JSON object per line back (see [`protocol`] for the command set).
//! Every connection runs on its own thread, but all of them are
//! multiplexed onto **one** [`SessionRegistry`] — fingerprint-keyed
//! [`crate::session::PlanSession`]s over one shared
//! [`crate::session::PlanCache`] — so a plan compiled for one client is
//! a cache hit for every other client asking for the same (isomorphic)
//! graph and request.
//!
//! The hot path is allocation-shy end to end: requests are framed by
//! incremental newline scanning over one persistent per-connection
//! accumulator (no per-line `Vec`), routed through the lazy-JSON
//! dispatcher (see [`protocol`] — `ping`/`stats` and every `plan`
//! request answer without building a request tree), and replies are
//! serialized into one reusable buffer and written with a single
//! vectored syscall; warm `plan` cache hits splice pre-serialized
//! summary bytes instead of re-serializing. [`ServeMetrics`] counts
//! `bytes_in`/`bytes_out`/`fast_path_hits` so the fast path shows up in
//! `stats`, not just in latency.
//!
//! Hardening, because the listener faces arbitrary bytes:
//!
//! - **admission control** — a global in-flight request cap
//!   ([`ServeConfig::max_inflight`]) and a connection cap
//!   ([`ServeConfig::max_connections`]); refused work gets a structured
//!   `busy` reply, not a hang;
//! - **bounded reads** — request lines are capped at
//!   [`ServeConfig::max_request_bytes`] (complete lines are processed
//!   before the socket is read again, so resident memory stays bounded
//!   by the cap plus one read chunk even against an endless line), and
//!   a connection idle past [`ServeConfig::read_timeout`] is told so
//!   and closed;
//! - **total replies** — malformed JSON, invalid UTF-8, unknown
//!   commands, out-of-cap requests and even handler panics all come back
//!   as `{"ok": false, "error": {...}}`; the daemon never answers a
//!   request with a disconnect;
//! - **graceful shutdown** — SIGINT or a `shutdown` command stops the
//!   accept loop, joins every connection thread and returns from
//!   [`Server::run`] normally.
//!
//! ```text
//! $ repro serve --addr 127.0.0.1:7878
//! repro serve listening on 127.0.0.1:7878
//!
//! $ printf '{"cmd":"plan","network":"unet"}\n' | nc 127.0.0.1 7878
//! {"ok":true,"reply":"plan","cache_hit":false,...}
//! ```

pub mod protocol;
pub mod stats;

pub use protocol::{error_reply, ReplyBody, Routed, Router, RouterConfig};
pub use stats::{LatencyPercentiles, LatencyRing, ServeMetrics, LATENCY_RING_CAPACITY};

use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::anyhow::{anyhow, bail, Context, Result};
use crate::session::{PlanCache, SessionRegistry};

/// Daemon configuration: where to listen and the resource caps.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Maximum simultaneously open client connections.
    pub max_connections: usize,
    /// Maximum requests processing at once across all connections.
    pub max_inflight: usize,
    /// Maximum bytes in one request line (longer lines are refused and
    /// the connection closed — framing can't be trusted past that).
    pub max_request_bytes: usize,
    /// How long a connection may sit idle (or stall mid-request) before
    /// it is told `idle-timeout` and closed.
    pub read_timeout: Duration,
    /// Capacity of the shared compiled-plan LRU.
    pub cache_capacity: usize,
    /// Optional byte cap on the shared compiled-plan LRU (`--cache-bytes`):
    /// approximate resident bytes, evicting least-recently-used first.
    /// `None` = entry-count bound only.
    pub cache_bytes: Option<u64>,
    /// Maximum live sessions in the registry (LRU beyond that).
    pub max_sessions: usize,
    /// Per-request caps enforced by the [`Router`].
    pub router: RouterConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            max_connections: 64,
            max_inflight: 8,
            max_request_bytes: 1 << 20,
            read_timeout: Duration::from_secs(30),
            cache_capacity: 256,
            cache_bytes: None,
            max_sessions: 64,
            router: RouterConfig::default(),
        }
    }
}

/// Per-connection limits, copied out of [`ServeConfig`] for the worker
/// threads.
#[derive(Clone, Copy)]
struct ConnLimits {
    max_request_bytes: usize,
    idle: Duration,
    /// Socket read timeout — the granularity at which a blocked reader
    /// re-checks the shutdown flag and the idle deadline.
    poll: Duration,
    max_inflight: usize,
}

/// A handle for stopping a running [`Server`] from another thread (or
/// inspecting whether it has been stopped).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// The address the server actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the accept loop and every connection thread to stop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// True once shutdown has been requested.
    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// The bound daemon: a nonblocking listener plus the shared [`Router`].
pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    router: Arc<Router>,
    metrics: Arc<ServeMetrics>,
    stop: Arc<AtomicBool>,
    cfg: ServeConfig,
}

impl Server {
    /// Bind `cfg.addr` and build the shared serving state (registry,
    /// cache, metrics, router). The listener is nonblocking so the
    /// accept loop can poll the shutdown flag.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        if cfg.max_inflight == 0 || cfg.max_connections == 0 || cfg.max_request_bytes == 0 {
            bail!("serve caps must be positive (connections, inflight, request bytes)");
        }
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("setting listener nonblocking")?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let cache = PlanCache::shared_with_bytes(cfg.cache_capacity.max(1), cfg.cache_bytes);
        let registry = SessionRegistry::new(cfg.max_sessions.max(1), cache);
        let metrics = Arc::new(ServeMetrics::new());
        let router = Arc::new(Router::new(registry, metrics.clone(), cfg.router));
        Ok(Server {
            listener,
            local_addr,
            router,
            metrics,
            stop: Arc::new(AtomicBool::new(false)),
            cfg,
        })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A clonable handle that can stop this server from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: self.stop.clone(), addr: self.local_addr }
    }

    /// The shared router (tests inspect its registry).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Accept connections until shutdown is requested (via
    /// [`ServerHandle::shutdown`], a client's `shutdown` command, or
    /// SIGINT when installed by [`cmd_serve`]), then join every
    /// connection thread and return.
    pub fn run(self) -> Result<()> {
        let (poll_min, poll_max) = (Duration::from_millis(1), Duration::from_millis(100));
        let lim = ConnLimits {
            max_request_bytes: self.cfg.max_request_bytes,
            idle: self.cfg.read_timeout,
            poll: self.cfg.read_timeout.clamp(poll_min, poll_max),
            max_inflight: self.cfg.max_inflight,
        };
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_id = 0u64;
        loop {
            if sigint::pending() {
                self.stop.store(true, Ordering::SeqCst);
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    workers.retain(|h| !h.is_finished());
                    self.metrics.connections_total.fetch_add(1, Ordering::Relaxed);
                    if workers.len() >= self.cfg.max_connections {
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        refuse(stream);
                        continue;
                    }
                    self.metrics.connections.fetch_add(1, Ordering::SeqCst);
                    let router = self.router.clone();
                    let metrics = self.metrics.clone();
                    let stop = self.stop.clone();
                    next_id += 1;
                    let spawned = std::thread::Builder::new()
                        .name(format!("repro-serve-{next_id}"))
                        .spawn(move || {
                            let _ = serve_connection(stream, &router, &metrics, &stop, lim);
                            metrics.connections.fetch_sub(1, Ordering::SeqCst);
                        });
                    match spawned {
                        Ok(h) => workers.push(h),
                        Err(_) => {
                            // Could not get a thread: shed the connection.
                            self.metrics.connections.fetch_sub(1, Ordering::SeqCst);
                            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => return Err(anyhow!("accept failed: {e}")),
            }
        }
        for h in workers {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Refuse a connection over the cap with one `busy` line.
fn refuse(mut stream: TcpStream) {
    let mut s = error_reply("busy", "server is at its connection limit; retry later").to_string();
    s.push('\n');
    let _ = stream.write_all(s.as_bytes());
}

/// Write `a` then `b` as one vectored write (retrying partial writes),
/// then flush — the reply body and its newline leave in a single
/// syscall instead of being copied into a combined buffer first.
/// (`write_all_vectored` is unstable, hence the manual loop.)
fn write_all_vectored2(w: &mut TcpStream, a: &[u8], b: &[u8]) -> std::io::Result<()> {
    let (mut wrote_a, mut wrote_b) = (0usize, 0usize);
    while wrote_a < a.len() || wrote_b < b.len() {
        let bufs = [IoSlice::new(&a[wrote_a..]), IoSlice::new(&b[wrote_b..])];
        let n = w.write_vectored(&bufs)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "socket accepted no reply bytes",
            ));
        }
        let from_a = n.min(a.len() - wrote_a);
        wrote_a += from_a;
        wrote_b += n - from_a;
    }
    w.flush()
}

/// Serialize one reply into the connection's reusable buffer and write
/// it with its trailing newline. `Raw` replies append pre-serialized
/// bytes; `Tree` replies serialize into the same buffer — either way no
/// per-reply `String` is allocated once the buffer has grown.
fn write_reply(
    w: &mut TcpStream,
    out: &mut String,
    reply: &ReplyBody,
    metrics: &ServeMetrics,
) -> std::io::Result<()> {
    out.clear();
    reply.write_line(out);
    metrics.bytes_out.fetch_add(out.len() as u64 + 1, Ordering::Relaxed);
    write_all_vectored2(w, out.as_bytes(), b"\n")
}

fn write_error(
    w: &mut TcpStream,
    out: &mut String,
    metrics: &ServeMetrics,
    code: &str,
    msg: &str,
) -> std::io::Result<()> {
    write_reply(w, out, &ReplyBody::Tree(error_reply(code, msg)), metrics)
}

/// What [`handle_line`] tells the connection loop to do next.
enum LineOutcome {
    Continue,
    Shutdown,
}

/// Route one framed request line and write its reply.
fn handle_line(
    raw: &[u8],
    router: &Router,
    metrics: &ServeMetrics,
    writer: &mut TcpStream,
    reply_buf: &mut String,
    lim: &ConnLimits,
) -> std::io::Result<LineOutcome> {
    let Ok(line) = std::str::from_utf8(raw) else {
        metrics.record(Duration::ZERO, true);
        write_error(writer, reply_buf, metrics, "bad-utf8", "request line is not valid UTF-8")?;
        return Ok(LineOutcome::Continue);
    };
    if !metrics.try_admit(lim.max_inflight) {
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        write_error(
            writer,
            reply_buf,
            metrics,
            "busy",
            "server is at its in-flight request limit; retry shortly",
        )?;
        return Ok(LineOutcome::Continue);
    }
    let t0 = Instant::now();
    let routed = router.route_line(line);
    metrics.release();
    metrics.record(t0.elapsed(), routed.is_error);
    write_reply(writer, reply_buf, &routed.reply, metrics)?;
    Ok(if routed.shutdown { LineOutcome::Shutdown } else { LineOutcome::Continue })
}

/// One connection's request loop: incremental newline framing over a
/// persistent read accumulator, one reusable reply buffer, vectored
/// reply writes. Repeat until EOF / idle timeout / shutdown.
///
/// Framing invariants: complete lines are processed (and drained from
/// the accumulator) before the socket is read again, so whenever a read
/// happens the accumulator holds at most one partial line — which keeps
/// resident memory bounded by `max_request_bytes` + one read chunk even
/// against a client that pipelines or never sends a newline.
fn serve_connection(
    stream: TcpStream,
    router: &Router,
    metrics: &ServeMetrics,
    stop: &AtomicBool,
    lim: ConnLimits,
) -> std::io::Result<()> {
    // Short read timeouts turn the blocking read into a poll so the
    // thread can observe shutdown and the idle deadline.
    stream.set_read_timeout(Some(lim.poll))?;
    let mut writer = stream.try_clone()?;
    let mut reader = stream;
    // Persistent per-connection buffers, reused for every request.
    let mut acc: Vec<u8> = Vec::with_capacity(4096);
    let mut reply_buf = String::with_capacity(1024);
    let mut chunk = [0u8; 16 * 1024];
    // `acc[..searched]` is known newline-free (no rescans on retry).
    let mut searched = 0usize;
    let mut at_eof = false;
    let mut deadline = Instant::now() + lim.idle;
    loop {
        // Frame and process every complete line already buffered.
        while let Some(off) = acc[searched..].iter().position(|&b| b == b'\n') {
            let nl = searched + off;
            // The line's content is acc[..nl] (lines always start at 0:
            // processed lines are drained). Content + '\n' over the cap
            // is refused exactly like the pre-rework reader, which
            // buffered at most cap+1 bytes of line+newline.
            if nl + 1 > lim.max_request_bytes {
                return oversize(&mut reader, &mut writer, &mut reply_buf, metrics, &lim);
            }
            let mut end = nl;
            while end > 0 && acc[end - 1] == b'\r' {
                end -= 1;
            }
            if end > 0 {
                match handle_line(&acc[..end], router, metrics, &mut writer, &mut reply_buf, &lim)?
                {
                    LineOutcome::Continue => {}
                    LineOutcome::Shutdown => {
                        stop.store(true, Ordering::SeqCst);
                        return Ok(());
                    }
                }
            }
            acc.drain(..=nl);
            searched = 0;
            deadline = Instant::now() + lim.idle;
        }
        // No complete line buffered: the accumulator is one (possibly
        // empty) partial line, all of it known newline-free.
        searched = acc.len();
        if acc.len() > lim.max_request_bytes {
            return oversize(&mut reader, &mut writer, &mut reply_buf, metrics, &lim);
        }
        if at_eof {
            if acc.is_empty() {
                return Ok(());
            }
            // Final unterminated line.
            let mut end = acc.len();
            while end > 0 && acc[end - 1] == b'\r' {
                end -= 1;
            }
            if end > 0 {
                if let LineOutcome::Shutdown =
                    handle_line(&acc[..end], router, metrics, &mut writer, &mut reply_buf, &lim)?
                {
                    stop.store(true, Ordering::SeqCst);
                }
            }
            return Ok(());
        }
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read(&mut chunk) {
            Ok(0) => at_eof = true,
            Ok(n) => {
                metrics.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
                acc.extend_from_slice(&chunk[..n]);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if Instant::now() >= deadline {
                    let msg = if acc.is_empty() {
                        "connection idle past the server's read timeout"
                    } else {
                        "request stalled mid-line past the server's read timeout"
                    };
                    let _ = write_error(&mut writer, &mut reply_buf, metrics, "idle-timeout", msg);
                    return Ok(());
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Refuse an over-long request line and close: past the cap the framing
/// can't be trusted (resyncing would mean skipping unbounded bytes).
fn oversize(
    reader: &mut TcpStream,
    writer: &mut TcpStream,
    reply_buf: &mut String,
    metrics: &ServeMetrics,
    lim: &ConnLimits,
) -> std::io::Result<()> {
    metrics.rejected.fetch_add(1, Ordering::Relaxed);
    let msg = format!("request exceeds {} bytes", lim.max_request_bytes);
    let _ = write_error(writer, reply_buf, metrics, "request-too-large", &msg);
    // Drain whatever the client already sent before closing: dropping a
    // socket with unread receive data turns the close into an RST,
    // which can destroy the reply in flight.
    let mut sink = [0u8; 4096];
    let mut drained = 0usize;
    loop {
        match reader.read(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                drained += n;
                // Bounded courtesy: a firehose client gets cut off.
                if drained > lim.max_request_bytes {
                    break;
                }
            }
        }
    }
    Ok(())
}

/// Zero-dependency SIGINT latch: a C `signal` handler that flips an
/// atomic the accept loop polls. On non-Unix targets this is a no-op
/// (Ctrl-C then terminates the process the default way).
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static PENDING: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        // Only async-signal-safe work here: one atomic store.
        PENDING.store(true, Ordering::SeqCst);
    }

    /// Install the handler (idempotent).
    pub fn install() {
        const SIGINT: i32 = 2;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGINT, on_sigint);
        }
    }

    /// True once SIGINT has been received.
    pub fn pending() -> bool {
        PENDING.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}

    pub fn pending() -> bool {
        false
    }
}

fn parse_num<T: std::str::FromStr>(flag: &str, v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>().map_err(|e| anyhow!("bad value for {flag}: {e}"))
}

const SERVE_USAGE: &str = "\
repro serve — long-running plan-serving daemon (newline-delimited JSON over TCP)

USAGE: repro serve [flags]

FLAGS:
  --addr HOST:PORT        listen address (default 127.0.0.1:7878; port 0 = auto)
  --max-connections N     simultaneous client connections (default 64)
  --max-inflight N        requests processing at once (default 8)
  --max-request-bytes N   request line size cap (default 1048576)
  --read-timeout-ms N     per-connection idle/stall timeout (default 30000)
  --cache-capacity N      shared compiled-plan LRU capacity (default 256)
  --cache-bytes BYTES     byte cap on the shared plan LRU, e.g. 256MiB
                          (default: unbounded; entries evict LRU-first)
  --max-sessions N        live sessions kept in the registry (default 64)
  --max-budget BYTES      largest budget a request may name (default 64GiB)
  --max-graph-nodes N     largest accepted graph (default 4096)
  --max-train-steps N     largest training request (default 50)
  --threads N             planner worker-pool width (default: REPRO_THREADS)

PROTOCOL: one JSON object per line; commands
  ping | graph_upload | plan | train | stats | shutdown
(see the serve module docs / README 'Serving' for fields and examples)";

/// `repro serve` entry point: parse flags, bind, print the bound
/// address, serve until SIGINT or a `shutdown` command.
pub fn cmd_serve(args: &[String]) -> Result<()> {
    let mut cfg = ServeConfig::default();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--help" || a == "-h" {
            println!("{SERVE_USAGE}");
            return Ok(());
        }
        let mut val = || it.next().ok_or_else(|| anyhow!("{a} needs a value"));
        match a.as_str() {
            "--addr" => cfg.addr = val()?.clone(),
            "--max-connections" => cfg.max_connections = parse_num(a, val()?)?,
            "--max-inflight" => cfg.max_inflight = parse_num(a, val()?)?,
            "--max-request-bytes" => cfg.max_request_bytes = parse_num(a, val()?)?,
            "--read-timeout-ms" => cfg.read_timeout = Duration::from_millis(parse_num(a, val()?)?),
            "--cache-capacity" => cfg.cache_capacity = parse_num(a, val()?)?,
            "--cache-bytes" => cfg.cache_bytes = Some(crate::parse_bytes(val()?)?),
            "--max-sessions" => cfg.max_sessions = parse_num(a, val()?)?,
            "--max-budget" => cfg.router.max_budget_bytes = crate::parse_bytes(val()?)?,
            "--max-graph-nodes" => cfg.router.max_graph_nodes = parse_num(a, val()?)?,
            "--max-train-steps" => cfg.router.max_train_steps = parse_num(a, val()?)?,
            "--threads" => crate::util::pool::set_global_threads(parse_num(a, val()?)?),
            other => bail!("unknown serve flag '{other}' (try 'repro serve --help')"),
        }
    }
    sigint::install();
    let server = Server::bind(cfg)?;
    // One parseable line on stdout so scripts (and the CI smoke job) can
    // learn the bound port before connecting.
    println!("repro serve listening on {}", server.local_addr());
    std::io::stdout().flush().ok();
    server.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_binds_port_zero_and_shuts_down_cleanly() {
        let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() };
        let server = Server::bind(cfg).unwrap();
        let handle = server.handle();
        assert_ne!(handle.addr().port(), 0, "port 0 must resolve to a real port");
        assert!(!handle.is_shutdown());
        let t = std::thread::spawn(move || server.run());
        handle.shutdown();
        assert!(handle.is_shutdown());
        t.join().unwrap().unwrap();
    }

    #[test]
    fn zero_caps_are_rejected() {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 0,
            ..ServeConfig::default()
        };
        assert!(Server::bind(cfg).is_err());
    }

    #[test]
    fn serve_flags_parse_and_unknown_flags_error() {
        let bad = ["--warp".to_string()];
        let err = cmd_serve(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown serve flag"), "{err}");
        let missing = ["--addr".to_string()];
        assert!(cmd_serve(&missing).is_err(), "--addr without a value must error");
        let badnum = ["--max-inflight".to_string(), "chonk".to_string()];
        assert!(cmd_serve(&badnum).is_err());
    }
}
