//! `repro serve` — the long-running plan-serving daemon.
//!
//! A zero-dependency newline-delimited-JSON-over-TCP listener: each
//! client connection sends one JSON object per line and receives exactly
//! one JSON object per line back (see [`protocol`] for the command set).
//! Every connection runs on its own thread, but all of them are
//! multiplexed onto **one** [`SessionRegistry`] — fingerprint-keyed
//! [`crate::session::PlanSession`]s over one shared
//! [`crate::session::PlanCache`] — so a plan compiled for one client is
//! a cache hit for every other client asking for the same (isomorphic)
//! graph and request.
//!
//! Hardening, because the listener faces arbitrary bytes:
//!
//! - **admission control** — a global in-flight request cap
//!   ([`ServeConfig::max_inflight`]) and a connection cap
//!   ([`ServeConfig::max_connections`]); refused work gets a structured
//!   `busy` reply, not a hang;
//! - **bounded reads** — request lines are capped at
//!   [`ServeConfig::max_request_bytes`] (the read itself is bounded via
//!   `Read::take`, so an endless line cannot exhaust memory), and a
//!   connection idle past [`ServeConfig::read_timeout`] is told so and
//!   closed;
//! - **total replies** — malformed JSON, invalid UTF-8, unknown
//!   commands, out-of-cap requests and even handler panics all come back
//!   as `{"ok": false, "error": {...}}`; the daemon never answers a
//!   request with a disconnect;
//! - **graceful shutdown** — SIGINT or a `shutdown` command stops the
//!   accept loop, joins every connection thread and returns from
//!   [`Server::run`] normally.
//!
//! ```text
//! $ repro serve --addr 127.0.0.1:7878
//! repro serve listening on 127.0.0.1:7878
//!
//! $ printf '{"cmd":"plan","network":"unet"}\n' | nc 127.0.0.1 7878
//! {"ok":true,"reply":"plan","cache_hit":false,...}
//! ```

pub mod protocol;
pub mod stats;

pub use protocol::{error_reply, Routed, Router, RouterConfig};
pub use stats::{LatencyPercentiles, LatencyRing, ServeMetrics, LATENCY_RING_CAPACITY};

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::anyhow::{anyhow, bail, Context, Result};
use crate::session::{PlanCache, SessionRegistry};
use crate::util::json::Json;

/// Daemon configuration: where to listen and the resource caps.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Listen address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Maximum simultaneously open client connections.
    pub max_connections: usize,
    /// Maximum requests processing at once across all connections.
    pub max_inflight: usize,
    /// Maximum bytes in one request line (longer lines are refused and
    /// the connection closed — framing can't be trusted past that).
    pub max_request_bytes: usize,
    /// How long a connection may sit idle (or stall mid-request) before
    /// it is told `idle-timeout` and closed.
    pub read_timeout: Duration,
    /// Capacity of the shared compiled-plan LRU.
    pub cache_capacity: usize,
    /// Optional byte cap on the shared compiled-plan LRU (`--cache-bytes`):
    /// approximate resident bytes, evicting least-recently-used first.
    /// `None` = entry-count bound only.
    pub cache_bytes: Option<u64>,
    /// Maximum live sessions in the registry (LRU beyond that).
    pub max_sessions: usize,
    /// Per-request caps enforced by the [`Router`].
    pub router: RouterConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7878".to_string(),
            max_connections: 64,
            max_inflight: 8,
            max_request_bytes: 1 << 20,
            read_timeout: Duration::from_secs(30),
            cache_capacity: 256,
            cache_bytes: None,
            max_sessions: 64,
            router: RouterConfig::default(),
        }
    }
}

/// Per-connection limits, copied out of [`ServeConfig`] for the worker
/// threads.
#[derive(Clone, Copy)]
struct ConnLimits {
    max_request_bytes: usize,
    idle: Duration,
    /// Socket read timeout — the granularity at which a blocked reader
    /// re-checks the shutdown flag and the idle deadline.
    poll: Duration,
    max_inflight: usize,
}

/// A handle for stopping a running [`Server`] from another thread (or
/// inspecting whether it has been stopped).
#[derive(Clone)]
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ServerHandle {
    /// The address the server actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the accept loop and every connection thread to stop.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// True once shutdown has been requested.
    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// The bound daemon: a nonblocking listener plus the shared [`Router`].
pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    router: Arc<Router>,
    metrics: Arc<ServeMetrics>,
    stop: Arc<AtomicBool>,
    cfg: ServeConfig,
}

impl Server {
    /// Bind `cfg.addr` and build the shared serving state (registry,
    /// cache, metrics, router). The listener is nonblocking so the
    /// accept loop can poll the shutdown flag.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        if cfg.max_inflight == 0 || cfg.max_connections == 0 || cfg.max_request_bytes == 0 {
            bail!("serve caps must be positive (connections, inflight, request bytes)");
        }
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("setting listener nonblocking")?;
        let local_addr = listener.local_addr().context("reading bound address")?;
        let cache = PlanCache::shared_with_bytes(cfg.cache_capacity.max(1), cfg.cache_bytes);
        let registry = SessionRegistry::new(cfg.max_sessions.max(1), cache);
        let metrics = Arc::new(ServeMetrics::new());
        let router = Arc::new(Router::new(registry, metrics.clone(), cfg.router));
        Ok(Server {
            listener,
            local_addr,
            router,
            metrics,
            stop: Arc::new(AtomicBool::new(false)),
            cfg,
        })
    }

    /// The address the listener actually bound (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// A clonable handle that can stop this server from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { stop: self.stop.clone(), addr: self.local_addr }
    }

    /// The shared router (tests inspect its registry).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Accept connections until shutdown is requested (via
    /// [`ServerHandle::shutdown`], a client's `shutdown` command, or
    /// SIGINT when installed by [`cmd_serve`]), then join every
    /// connection thread and return.
    pub fn run(self) -> Result<()> {
        let (poll_min, poll_max) = (Duration::from_millis(1), Duration::from_millis(100));
        let lim = ConnLimits {
            max_request_bytes: self.cfg.max_request_bytes,
            idle: self.cfg.read_timeout,
            poll: self.cfg.read_timeout.clamp(poll_min, poll_max),
            max_inflight: self.cfg.max_inflight,
        };
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut next_id = 0u64;
        loop {
            if sigint::pending() {
                self.stop.store(true, Ordering::SeqCst);
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    workers.retain(|h| !h.is_finished());
                    self.metrics.connections_total.fetch_add(1, Ordering::Relaxed);
                    if workers.len() >= self.cfg.max_connections {
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        refuse(stream);
                        continue;
                    }
                    self.metrics.connections.fetch_add(1, Ordering::SeqCst);
                    let router = self.router.clone();
                    let metrics = self.metrics.clone();
                    let stop = self.stop.clone();
                    next_id += 1;
                    let spawned = std::thread::Builder::new()
                        .name(format!("repro-serve-{next_id}"))
                        .spawn(move || {
                            let _ = serve_connection(stream, &router, &metrics, &stop, lim);
                            metrics.connections.fetch_sub(1, Ordering::SeqCst);
                        });
                    match spawned {
                        Ok(h) => workers.push(h),
                        Err(_) => {
                            // Could not get a thread: shed the connection.
                            self.metrics.connections.fetch_sub(1, Ordering::SeqCst);
                            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => return Err(anyhow!("accept failed: {e}")),
            }
        }
        for h in workers {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Refuse a connection over the cap with one `busy` line.
fn refuse(mut stream: TcpStream) {
    let mut s = error_reply("busy", "server is at its connection limit; retry later").to_string();
    s.push('\n');
    let _ = stream.write_all(s.as_bytes());
}

fn write_reply(w: &mut TcpStream, reply: &Json) -> std::io::Result<()> {
    let mut s = reply.to_string();
    s.push('\n');
    w.write_all(s.as_bytes())?;
    w.flush()
}

/// One connection's request loop: read a bounded line, route it, write
/// the reply, repeat until EOF / idle timeout / shutdown.
fn serve_connection(
    stream: TcpStream,
    router: &Router,
    metrics: &ServeMetrics,
    stop: &AtomicBool,
    lim: ConnLimits,
) -> std::io::Result<()> {
    // Short read timeouts turn the blocking read into a poll so the
    // thread can observe shutdown and the idle deadline.
    stream.set_read_timeout(Some(lim.poll))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let mut buf: Vec<u8> = Vec::new();
        let deadline = Instant::now() + lim.idle;
        loop {
            if stop.load(Ordering::SeqCst) {
                return Ok(());
            }
            // Cap the read at one byte past the limit: a line that fills
            // the whole allowance is over-long, detected below without
            // ever buffering more than `max_request_bytes + 1` bytes.
            let allowance = (lim.max_request_bytes + 1).saturating_sub(buf.len());
            if allowance == 0 {
                break;
            }
            match (&mut reader).take(allowance as u64).read_until(b'\n', &mut buf) {
                // EOF: a clean close between requests, or a final
                // unterminated line to process.
                Ok(0) => {
                    if buf.is_empty() {
                        return Ok(());
                    }
                    break;
                }
                Ok(_) => {
                    if buf.last() == Some(&b'\n') {
                        break;
                    }
                    // No newline yet: the `take` allowance ran out (next
                    // iteration flags the oversize) or EOF follows.
                }
                // Timeout expiry — note `read_until` has already
                // appended any bytes it got before the timeout, so
                // partial requests accumulate across retries.
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    if Instant::now() >= deadline {
                        let msg = if buf.is_empty() {
                            "connection idle past the server's read timeout"
                        } else {
                            "request stalled mid-line past the server's read timeout"
                        };
                        let _ = write_reply(&mut writer, &error_reply("idle-timeout", msg));
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        }
        if buf.len() > lim.max_request_bytes {
            // The line framing can't be trusted past the cap (we'd have
            // to skip unbounded bytes to resync), so reply and close.
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let reply = error_reply(
                "request-too-large",
                &format!("request exceeds {} bytes", lim.max_request_bytes),
            );
            let _ = write_reply(&mut writer, &reply);
            // Drain whatever the client already sent before closing:
            // dropping a socket with unread receive data turns the close
            // into an RST, which can destroy the reply in flight.
            let mut sink = [0u8; 4096];
            let mut drained = 0usize;
            loop {
                match reader.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(n) => {
                        drained += n;
                        // Bounded courtesy: a firehose client gets cut off.
                        if drained > lim.max_request_bytes {
                            break;
                        }
                    }
                }
            }
            return Ok(());
        }
        while matches!(buf.last(), Some(&b'\n') | Some(&b'\r')) {
            buf.pop();
        }
        if buf.is_empty() {
            continue;
        }
        let Ok(line) = std::str::from_utf8(&buf) else {
            metrics.record(Duration::ZERO, true);
            write_reply(&mut writer, &error_reply("bad-utf8", "request line is not valid UTF-8"))?;
            continue;
        };
        if !metrics.try_admit(lim.max_inflight) {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            let reply =
                error_reply("busy", "server is at its in-flight request limit; retry shortly");
            write_reply(&mut writer, &reply)?;
            continue;
        }
        let t0 = Instant::now();
        let routed = router.route_line(line);
        metrics.release();
        metrics.record(t0.elapsed(), routed.is_error);
        write_reply(&mut writer, &routed.reply)?;
        if routed.shutdown {
            stop.store(true, Ordering::SeqCst);
            return Ok(());
        }
    }
}

/// Zero-dependency SIGINT latch: a C `signal` handler that flips an
/// atomic the accept loop polls. On non-Unix targets this is a no-op
/// (Ctrl-C then terminates the process the default way).
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static PENDING: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        // Only async-signal-safe work here: one atomic store.
        PENDING.store(true, Ordering::SeqCst);
    }

    /// Install the handler (idempotent).
    pub fn install() {
        const SIGINT: i32 = 2;
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        unsafe {
            signal(SIGINT, on_sigint);
        }
    }

    /// True once SIGINT has been received.
    pub fn pending() -> bool {
        PENDING.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}

    pub fn pending() -> bool {
        false
    }
}

fn parse_num<T: std::str::FromStr>(flag: &str, v: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    v.parse::<T>().map_err(|e| anyhow!("bad value for {flag}: {e}"))
}

const SERVE_USAGE: &str = "\
repro serve — long-running plan-serving daemon (newline-delimited JSON over TCP)

USAGE: repro serve [flags]

FLAGS:
  --addr HOST:PORT        listen address (default 127.0.0.1:7878; port 0 = auto)
  --max-connections N     simultaneous client connections (default 64)
  --max-inflight N        requests processing at once (default 8)
  --max-request-bytes N   request line size cap (default 1048576)
  --read-timeout-ms N     per-connection idle/stall timeout (default 30000)
  --cache-capacity N      shared compiled-plan LRU capacity (default 256)
  --cache-bytes BYTES     byte cap on the shared plan LRU, e.g. 256MiB
                          (default: unbounded; entries evict LRU-first)
  --max-sessions N        live sessions kept in the registry (default 64)
  --max-budget BYTES      largest budget a request may name (default 64GiB)
  --max-graph-nodes N     largest accepted graph (default 4096)
  --max-train-steps N     largest training request (default 50)
  --threads N             planner worker-pool width (default: REPRO_THREADS)

PROTOCOL: one JSON object per line; commands
  ping | graph_upload | plan | train | stats | shutdown
(see the serve module docs / README 'Serving' for fields and examples)";

/// `repro serve` entry point: parse flags, bind, print the bound
/// address, serve until SIGINT or a `shutdown` command.
pub fn cmd_serve(args: &[String]) -> Result<()> {
    let mut cfg = ServeConfig::default();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--help" || a == "-h" {
            println!("{SERVE_USAGE}");
            return Ok(());
        }
        let mut val = || it.next().ok_or_else(|| anyhow!("{a} needs a value"));
        match a.as_str() {
            "--addr" => cfg.addr = val()?.clone(),
            "--max-connections" => cfg.max_connections = parse_num(a, val()?)?,
            "--max-inflight" => cfg.max_inflight = parse_num(a, val()?)?,
            "--max-request-bytes" => cfg.max_request_bytes = parse_num(a, val()?)?,
            "--read-timeout-ms" => cfg.read_timeout = Duration::from_millis(parse_num(a, val()?)?),
            "--cache-capacity" => cfg.cache_capacity = parse_num(a, val()?)?,
            "--cache-bytes" => cfg.cache_bytes = Some(crate::parse_bytes(val()?)?),
            "--max-sessions" => cfg.max_sessions = parse_num(a, val()?)?,
            "--max-budget" => cfg.router.max_budget_bytes = crate::parse_bytes(val()?)?,
            "--max-graph-nodes" => cfg.router.max_graph_nodes = parse_num(a, val()?)?,
            "--max-train-steps" => cfg.router.max_train_steps = parse_num(a, val()?)?,
            "--threads" => crate::util::pool::set_global_threads(parse_num(a, val()?)?),
            other => bail!("unknown serve flag '{other}' (try 'repro serve --help')"),
        }
    }
    sigint::install();
    let server = Server::bind(cfg)?;
    // One parseable line on stdout so scripts (and the CI smoke job) can
    // learn the bound port before connecting.
    println!("repro serve listening on {}", server.local_addr());
    std::io::stdout().flush().ok();
    server.run()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_binds_port_zero_and_shuts_down_cleanly() {
        let cfg = ServeConfig { addr: "127.0.0.1:0".to_string(), ..ServeConfig::default() };
        let server = Server::bind(cfg).unwrap();
        let handle = server.handle();
        assert_ne!(handle.addr().port(), 0, "port 0 must resolve to a real port");
        assert!(!handle.is_shutdown());
        let t = std::thread::spawn(move || server.run());
        handle.shutdown();
        assert!(handle.is_shutdown());
        t.join().unwrap().unwrap();
    }

    #[test]
    fn zero_caps_are_rejected() {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 0,
            ..ServeConfig::default()
        };
        assert!(Server::bind(cfg).is_err());
    }

    #[test]
    fn serve_flags_parse_and_unknown_flags_error() {
        let bad = ["--warp".to_string()];
        let err = cmd_serve(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown serve flag"), "{err}");
        let missing = ["--addr".to_string()];
        assert!(cmd_serve(&missing).is_err(), "--addr without a value must error");
        let badnum = ["--max-inflight".to_string(), "chonk".to_string()];
        assert!(cmd_serve(&badnum).is_err());
    }
}
