//! The serve request router: one newline-delimited JSON request in, one
//! JSON reply out — **always**.
//!
//! Every reply is an object with `"ok": true` plus command-specific
//! fields, or `"ok": false` with a structured
//! `{"error": {"code": …, "msg": …}}`. The router never panics outward:
//! requests are parsed by the hardened [`Json::parse`] (depth-limited,
//! positioned errors), every handler returns typed rejections, and the
//! dispatch is wrapped in `catch_unwind` as a last line of defense, so a
//! bug in a handler degrades to an `"internal"` error reply instead of a
//! dead connection.
//!
//! Commands (the `"cmd"` field):
//!
//! | command        | fields                                              |
//! |----------------|-----------------------------------------------------|
//! | `ping`         | —                                                   |
//! | `graph_upload` | `graph` (the [`Graph::to_json`] object)             |
//! | `plan`         | `fingerprint` \| `network` (+`batch`), `planner`, `objective`, `sim`, `budget` \| `budget_frac` |
//! | `train`        | `network`, `batch`, `width`, `steps`, `mode`, `sim`, `budget` \| `budget_frac`, `lr` |
//! | `stats`        | —                                                   |
//! | `shutdown`     | —                                                   |
//!
//! The router multiplexes every client onto one [`SessionRegistry`]
//! (fingerprint-keyed sessions over one shared plan cache), which is
//! what makes the daemon an amortizer: two clients uploading isomorphic
//! relabelings of a graph plan against the same session, and the second
//! identical request is a cache hit whoever sent the first.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::cli::dag_loss_summary;
use crate::coordinator::report::session_json;
use crate::coordinator::train::train_zoo_model_in;
use crate::exec::TrainConfig;
use crate::graph::{Graph, GraphFingerprint};
use crate::models::zoo;
use crate::planner::{BudgetSpec, Objective, PlanRequest, PlannerId};
use crate::session::{PlanSession, SessionRegistry};
use crate::sim::SimMode;
use crate::util::json::Json;
use crate::{fmt_bytes, parse_bytes};

use super::stats::ServeMetrics;

/// Per-request resource caps the router enforces before doing any work —
/// one hostile request must not be able to occupy the daemon with an
/// enormous graph, budget, or training run.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Largest absolute activation budget a request may name.
    pub max_budget_bytes: u64,
    /// Largest graph (in nodes) accepted for upload or zoo construction.
    pub max_graph_nodes: u32,
    /// Largest `batch` accepted for zoo construction / training.
    pub max_batch: u64,
    /// Largest per-node `width` accepted for training.
    pub max_train_width: usize,
    /// Largest `steps` accepted for one training request.
    pub max_train_steps: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            max_budget_bytes: 64 << 30,
            max_graph_nodes: 4096,
            max_batch: 4096,
            max_train_width: 256,
            max_train_steps: 50,
        }
    }
}

/// One routed request's outcome.
pub struct Routed {
    /// The JSON reply to write back (always exactly one object).
    pub reply: Json,
    /// The request asked the daemon to shut down.
    pub shutdown: bool,
    /// The reply is an `"ok": false` error.
    pub is_error: bool,
}

/// A typed rejection: becomes the `{"code", "msg"}` of an error reply.
struct Reject {
    code: &'static str,
    msg: String,
}

fn reject(code: &'static str, msg: impl std::fmt::Display) -> Reject {
    Reject { code, msg: msg.to_string() }
}

/// Build an `"ok": false` reply with a structured error object.
pub fn error_reply(code: &str, msg: &str) -> Json {
    Json::obj()
        .set("ok", false.into())
        .set("error", Json::obj().set("code", code.into()).set("msg", msg.into()))
}

fn ok_reply(cmd: &str) -> Json {
    Json::obj().set("ok", true.into()).set("reply", cmd.into())
}

/// The daemon's request dispatcher. Owns the cross-client
/// [`SessionRegistry`] and a handle to the shared [`ServeMetrics`];
/// thread-safe (`&self` everywhere), shared across connection threads
/// via `Arc`.
pub struct Router {
    registry: SessionRegistry,
    metrics: Arc<ServeMetrics>,
    cfg: RouterConfig,
    started: Instant,
}

impl Router {
    pub fn new(registry: SessionRegistry, metrics: Arc<ServeMetrics>, cfg: RouterConfig) -> Router {
        Router { registry, metrics, cfg, started: Instant::now() }
    }

    /// The registry this router serves from (tests inspect it).
    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    /// Route one request line to a reply. Total: every input — hostile
    /// bytes included — produces exactly one JSON reply object.
    pub fn route_line(&self, line: &str) -> Routed {
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| self.dispatch(line)));
        let (reply, shutdown, is_error) = match outcome {
            Ok(Ok((reply, shutdown))) => (reply, shutdown, false),
            Ok(Err(r)) => (error_reply(r.code, &r.msg), false, true),
            Err(_) => (error_reply("internal", "request handler panicked"), false, true),
        };
        Routed { reply, shutdown, is_error }
    }

    fn dispatch(&self, line: &str) -> Result<(Json, bool), Reject> {
        let req = Json::parse(line).map_err(|e| reject("bad-json", e))?;
        let cmd = req
            .get("cmd")
            .as_str()
            .ok_or_else(|| reject("bad-request", "missing string field 'cmd'"))?;
        match cmd {
            "ping" => Ok((ok_reply("pong"), false)),
            "graph_upload" => self.graph_upload(&req).map(|j| (j, false)),
            "plan" => self.plan(&req).map(|j| (j, false)),
            "train" => self.train(&req).map(|j| (j, false)),
            "stats" => Ok((self.stats(), false)),
            "shutdown" => Ok((ok_reply("shutting down"), true)),
            other => Err(reject(
                "unknown-cmd",
                format!("unknown command '{other}' (ping|graph_upload|plan|train|stats|shutdown)"),
            )),
        }
    }

    // ---- graph_upload ---------------------------------------------------

    fn graph_upload(&self, req: &Json) -> Result<Json, Reject> {
        let gj = req.get("graph");
        if gj == &Json::Null {
            return Err(reject("bad-request", "graph_upload needs a 'graph' object"));
        }
        let g = Graph::from_json_value(gj).map_err(|e| reject("bad-graph", e))?;
        if g.len() == 0 {
            return Err(reject("bad-graph", "graph has no nodes"));
        }
        if g.len() > self.cfg.max_graph_nodes {
            return Err(reject(
                "graph-too-large",
                format!("{} nodes exceeds this server's cap {}", g.len(), self.cfg.max_graph_nodes),
            ));
        }
        let (name, nodes, total_mem) = (g.name.clone(), g.len(), g.total_mem());
        let (session, reused) = self.registry.get_or_insert(g);
        Ok(ok_reply("graph_upload")
            .set("fingerprint", session.fingerprint().to_string().into())
            .set("name", name.into())
            .set("nodes", nodes.into())
            .set("total_mem", total_mem.into())
            .set("reused", reused.into()))
    }

    // ---- plan -----------------------------------------------------------

    fn plan(&self, req: &Json) -> Result<Json, Reject> {
        let session = self.resolve_session(req)?;
        let planner = match req.get("planner").as_str() {
            None => PlannerId::ApproxDp,
            Some(s) => PlannerId::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let objective = parse_objective(req.get("objective").as_str().unwrap_or("tc"))?;
        let sim_mode = match req.get("sim").as_str() {
            None => SimMode::Liveness,
            Some(s) => SimMode::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let budget = self.budget_spec(req)?;
        let r = PlanRequest { planner, budget, objective, sim_mode };
        let (cp, cache_hit) = session.plan_tracked(&r).map_err(|e| reject("plan-failed", e))?;
        let mut reply = ok_reply("plan")
            .set("fingerprint", cp.fingerprint.to_string().into())
            .set("planner", cp.plan.kind.label().into())
            .set("objective", objective.label().into())
            .set("sim", sim_mode.label().into())
            .set("budget_bytes", cp.plan.budget.into())
            .set("k_segments", (cp.plan.chain.k() as u64).into())
            .set("overhead", cp.plan.overhead.into())
            .set("predicted_peak", cp.program.predicted_peak().into())
            .set("measured_peak", cp.report.peak_bytes.into())
            .set("peak_total", cp.report.peak_total.into())
            .set("cache_hit", cache_hit.into());
        if let Some(info) = &cp.plan.decomposition {
            reply = reply.set(
                "decomposition",
                Json::obj()
                    .set("components", info.components.into())
                    .set("cut_vertices", info.cut_vertices.into())
                    .set("cache_hits", info.cache_hits.into()),
            );
        }
        Ok(reply)
    }

    /// A `plan` request addresses its graph by `fingerprint` (from a
    /// prior `graph_upload` — possibly another client's: fingerprints
    /// are relabeling-invariant) or by zoo `network` name (+ `batch`).
    fn resolve_session(&self, req: &Json) -> Result<Arc<PlanSession>, Reject> {
        if let Some(h) = req.get("fingerprint").as_str() {
            let fp = u64::from_str_radix(h.trim(), 16).map_err(|_| {
                reject("bad-request", format!("bad fingerprint '{h}' (expected hex digits)"))
            })?;
            return self.registry.get(GraphFingerprint(fp)).ok_or_else(|| {
                reject(
                    "unknown-fingerprint",
                    format!("no session registered for fingerprint {h} (graph_upload it first)"),
                )
            });
        }
        if let Some(name) = req.get("network").as_str() {
            let e = zoo::find(name)
                .ok_or_else(|| reject("unknown-network", format!("unknown zoo network '{name}'")))?;
            let batch = match req.get("batch") {
                Json::Null => e.batch,
                b => b
                    .as_u64()
                    .filter(|&b| b >= 1)
                    .ok_or_else(|| reject("bad-request", "'batch' must be a positive integer"))?,
            };
            if batch > self.cfg.max_batch {
                return Err(reject(
                    "request-cap",
                    format!("batch {batch} exceeds this server's cap {}", self.cfg.max_batch),
                ));
            }
            let g = e.build_batch(batch);
            if g.len() > self.cfg.max_graph_nodes {
                return Err(reject(
                    "graph-too-large",
                    format!(
                        "{} nodes exceeds this server's cap {}",
                        g.len(),
                        self.cfg.max_graph_nodes
                    ),
                ));
            }
            return Ok(self.registry.get_or_insert(g).0);
        }
        Err(reject("bad-request", "plan needs 'fingerprint' (from graph_upload) or 'network'"))
    }

    /// `budget` (string like `"512KiB"`, or an integer byte count) /
    /// `budget_frac` → [`BudgetSpec`], capped at the server's limit.
    fn budget_spec(&self, req: &Json) -> Result<BudgetSpec, Reject> {
        let b = req.get("budget");
        let spec = match b {
            Json::Null => match req.get("budget_frac") {
                Json::Null => BudgetSpec::MinFeasible,
                f => match f.as_f64() {
                    Some(f) if f.is_finite() && (0.0..=1.0).contains(&f) => BudgetSpec::Frac(f),
                    _ => {
                        return Err(reject(
                            "bad-request",
                            "'budget_frac' must be a number in [0, 1]",
                        ))
                    }
                },
            },
            Json::Str(s) => {
                BudgetSpec::Bytes(parse_bytes(s).map_err(|e| reject("bad-request", e))?)
            }
            Json::Num(_) => BudgetSpec::Bytes(b.as_u64().ok_or_else(|| {
                reject("bad-request", "numeric 'budget' must be a non-negative integer byte count")
            })?),
            _ => {
                return Err(reject(
                    "bad-request",
                    "'budget' must be a string (\"512KiB\") or a byte count",
                ))
            }
        };
        if let BudgetSpec::Bytes(bytes) = spec {
            if bytes > self.cfg.max_budget_bytes {
                return Err(reject(
                    "budget-cap",
                    format!(
                        "requested budget {} exceeds this server's cap {}",
                        fmt_bytes(bytes),
                        fmt_bytes(self.cfg.max_budget_bytes)
                    ),
                ));
            }
        }
        Ok(spec)
    }

    // ---- train ----------------------------------------------------------

    fn train(&self, req: &Json) -> Result<Json, Reject> {
        let name = req
            .get("network")
            .as_str()
            .ok_or_else(|| reject("bad-request", "train needs 'network' (a zoo name)"))?;
        if zoo::find(name).is_none() {
            return Err(reject("unknown-network", format!("unknown zoo network '{name}'")));
        }
        let batch = opt_usize(req, "batch", 2)?;
        let width = opt_usize(req, "width", 8)?;
        let steps = opt_usize(req, "steps", 2)?;
        if batch as u64 > self.cfg.max_batch
            || width > self.cfg.max_train_width
            || steps > self.cfg.max_train_steps
        {
            return Err(reject(
                "request-cap",
                format!(
                    "train request exceeds this server's caps \
                     (batch ≤ {}, width ≤ {}, steps ≤ {})",
                    self.cfg.max_batch, self.cfg.max_train_width, self.cfg.max_train_steps
                ),
            ));
        }
        let lr = match req.get("lr") {
            Json::Null => 0.05_f32,
            v => match v.as_f64() {
                Some(f) if f.is_finite() && f > 0.0 && f <= 10.0 => f as f32,
                _ => return Err(reject("bad-request", "'lr' must be a number in (0, 10]")),
            },
        };
        let objectives: Vec<Objective> = match req.get("mode").as_str().unwrap_or("tc") {
            "all" => vec![Objective::MinOverhead, Objective::MaxOverhead],
            m => vec![parse_objective(m)?],
        };
        let sim = match req.get("sim").as_str() {
            None => SimMode::Liveness,
            Some(s) => SimMode::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let budget = self.budget_spec(req)?;
        let cfg = TrainConfig { layers: 0, steps, lr, seed: 7, log_every: 0 };
        let cmp = train_zoo_model_in(
            Some(&self.registry),
            name,
            batch,
            width,
            &cfg,
            budget,
            &objectives,
            sim,
            true,
        )
        .map_err(|e| reject("train-failed", e))?;
        let runs: Vec<Json> = cmp
            .runs
            .iter()
            .map(|r| {
                Json::obj()
                    .set("objective", r.objective.label().into())
                    .set("k_segments", (r.k as u64).into())
                    .set("overhead", r.overhead.into())
                    .set("budget_bytes", r.budget.into())
                    .set("peak", r.report.observed_peak.into())
                    .set("grads_match", r.grads_match.into())
                    .set("peak_matches_sim", r.peak_matches_sim.into())
                    .set("losses_identical", r.losses_identical.into())
                    .set("cache_hit", r.cache_hit.into())
                    .set("loss", dag_loss_summary(&r.report).into())
            })
            .collect();
        Ok(ok_reply("train")
            .set("model", cmp.model.as_str().into())
            .set("fingerprint", cmp.fingerprint.to_string().into())
            .set("nodes", cmp.nodes.into())
            .set("sim", cmp.mode.label().into())
            .set("steps", (steps as u64).into())
            .set("vanilla_peak", cmp.vanilla.observed_peak.into())
            .set("vanilla_loss", dag_loss_summary(&cmp.vanilla).into())
            .set("all_verified", cmp.all_verified().into())
            .set("runs", Json::Arr(runs)))
    }

    // ---- stats ----------------------------------------------------------

    fn stats(&self) -> Json {
        let cs = self.registry.cache().stats();
        let comp = self.registry.component_cache().stats();
        let agg = self.registry.aggregate_stats();
        let m = &*self.metrics;
        let latency = match m.latency.percentiles() {
            None => Json::Null,
            Some(p) => Json::obj()
                .set("count", p.count.into())
                .set("p50_us", p.p50_us.into())
                .set("p90_us", p.p90_us.into())
                .set("p99_us", p.p99_us.into())
                .set("max_us", p.max_us.into()),
        };
        ok_reply("stats")
            .set("uptime_ms", (self.started.elapsed().as_millis() as u64).into())
            .set("requests", m.requests.load(Ordering::Relaxed).into())
            .set("errors", m.errors.load(Ordering::Relaxed).into())
            .set("rejected", m.rejected.load(Ordering::Relaxed).into())
            .set("inflight", (m.inflight.load(Ordering::SeqCst) as u64).into())
            .set("connections", (m.connections.load(Ordering::SeqCst) as u64).into())
            .set("connections_total", m.connections_total.load(Ordering::Relaxed).into())
            .set("sessions", (self.registry.len() as u64).into())
            .set(
                "cache",
                Json::obj()
                    .set("hits", cs.hits.into())
                    .set("misses", cs.misses.into())
                    .set("evictions", cs.evictions.into())
                    .set("entries", cs.entries.into())
                    .set("bytes", cs.bytes.into())
                    .set("hit_rate", cs.hit_rate().into()),
            )
            .set(
                "component_cache",
                Json::obj()
                    .set("entries", comp.entries.into())
                    .set("hits", comp.hits.into())
                    .set("misses", comp.misses.into()),
            )
            .set("session_totals", session_json(&agg))
            .set("latency_us", latency)
    }
}

fn parse_objective(s: &str) -> Result<Objective, Reject> {
    match s {
        "tc" => Ok(Objective::MinOverhead),
        "mc" => Ok(Objective::MaxOverhead),
        o => Err(reject("bad-request", format!("bad objective '{o}' (tc|mc)"))),
    }
}

/// Optional positive-integer field with a default.
fn opt_usize(req: &Json, key: &str, default: usize) -> Result<usize, Reject> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_u64()
            .filter(|&n| n >= 1)
            .and_then(|n| usize::try_from(n).ok())
            .ok_or_else(|| reject("bad-request", format!("'{key}' must be a positive integer"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{PlanCache, SessionRegistry};
    use crate::testutil::{diamond, diamond_relabeled};

    fn router() -> Router {
        Router::new(
            SessionRegistry::new(8, PlanCache::shared(64)),
            Arc::new(ServeMetrics::new()),
            RouterConfig::default(),
        )
    }

    fn ok(r: &Routed) -> &Json {
        assert!(!r.is_error, "expected ok reply, got {}", r.reply.to_string());
        assert_eq!(r.reply.get("ok").as_bool(), Some(true));
        &r.reply
    }

    fn err_code(r: &Routed) -> String {
        assert!(r.is_error, "expected error reply, got {}", r.reply.to_string());
        assert_eq!(r.reply.get("ok").as_bool(), Some(false));
        r.reply.get("error").get("code").as_str().unwrap_or_default().to_string()
    }

    #[test]
    fn ping_pongs_and_unknown_cmds_error() {
        let rt = router();
        let r = rt.route_line(r#"{"cmd":"ping"}"#);
        assert_eq!(ok(&r).get("reply").as_str(), Some("pong"));
        assert!(!r.shutdown);
        assert_eq!(err_code(&rt.route_line(r#"{"cmd":"warp"}"#)), "unknown-cmd");
        assert_eq!(err_code(&rt.route_line(r#"{"nope":1}"#)), "bad-request");
        assert_eq!(err_code(&rt.route_line("not json")), "bad-json");
        assert_eq!(err_code(&rt.route_line(&"[".repeat(100_000))), "bad-json");
    }

    #[test]
    fn upload_plan_roundtrip_shares_sessions_across_relabelings() {
        let rt = router();
        let up = |g: &crate::graph::Graph| {
            let line = Json::obj()
                .set("cmd", "graph_upload".into())
                .set("graph", Json::parse(&g.to_json()).unwrap())
                .to_string();
            rt.route_line(&line)
        };
        let a = up(&diamond());
        let fp = ok(&a).get("fingerprint").as_str().unwrap().to_string();
        assert_eq!(a.reply.get("reused").as_bool(), Some(false));
        // The isomorphic relabeling lands on the same session.
        let b = up(&diamond_relabeled());
        assert_eq!(ok(&b).get("fingerprint").as_str(), Some(fp.as_str()));
        assert_eq!(b.reply.get("reused").as_bool(), Some(true));
        assert_eq!(rt.registry().len(), 1);

        // Plan by fingerprint: first is a miss, repeat is a cache hit.
        let plan_line = format!(r#"{{"cmd":"plan","fingerprint":"{fp}","planner":"exact"}}"#);
        let p1 = rt.route_line(&plan_line);
        assert_eq!(ok(&p1).get("cache_hit").as_bool(), Some(false));
        assert!(p1.reply.get("k_segments").as_u64().unwrap() >= 1);
        let p2 = rt.route_line(&plan_line);
        assert_eq!(ok(&p2).get("cache_hit").as_bool(), Some(true));
        assert_eq!(p1.reply.get("budget_bytes").as_u64(), p2.reply.get("budget_bytes").as_u64());
    }

    #[test]
    fn plan_rejections_are_structured() {
        let rt = router();
        for (line, code) in [
            (r#"{"cmd":"plan"}"#.to_string(), "bad-request"),
            (r#"{"cmd":"plan","fingerprint":"zzzz"}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","fingerprint":"00ddba11deadbeef"}"#.into(), "unknown-fingerprint"),
            (r#"{"cmd":"plan","network":"nosuchnet"}"#.into(), "unknown-network"),
            (r#"{"cmd":"plan","network":"unet","budget":"12parsecs"}"#.into(), "bad-request"),
            (
                r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#.into(),
                "bad-request",
            ),
            (r#"{"cmd":"plan","network":"unet","budget":"1B"}"#.into(), "plan-failed"),
            (r#"{"cmd":"plan","network":"unet","budget":"65GiB"}"#.into(), "budget-cap"),
            (r#"{"cmd":"plan","network":"unet","budget_frac":7}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","network":"unet","batch":0}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","network":"unet","batch":99999999}"#.into(), "request-cap"),
            (r#"{"cmd":"plan","network":"unet","objective":"zz"}"#.into(), "bad-request"),
        ] {
            assert_eq!(err_code(&rt.route_line(&line)), code, "{line}");
        }
    }

    #[test]
    fn zoo_plan_and_stats_shapes() {
        let rt = router();
        let p = rt.route_line(r#"{"cmd":"plan","network":"unet","objective":"mc"}"#);
        let reply = ok(&p);
        assert_eq!(reply.get("objective").as_str(), Some("mc"));
        assert!(reply.get("measured_peak").as_u64().unwrap() > 0);
        assert_eq!(reply.get("decomposition"), &Json::Null, "whole-graph plans carry none");

        // A decomposed plan reports its per-component shape.
        let d = rt.route_line(r#"{"cmd":"plan","network":"unet","planner":"decomposed"}"#);
        let dreply = ok(&d);
        assert_eq!(dreply.get("planner").as_str(), Some("Decomposed"));
        let info = dreply.get("decomposition");
        assert!(info.get("components").as_u64().unwrap() >= 1);
        assert!(info.get("cache_hits").as_u64().is_some());

        let s = rt.route_line(r#"{"cmd":"stats"}"#);
        let reply = ok(&s);
        assert_eq!(reply.get("sessions").as_u64(), Some(1));
        let cache = reply.get("cache");
        assert_eq!(cache.get("misses").as_u64(), Some(2));
        assert_eq!(cache.get("entries").as_u64(), Some(2));
        assert!(cache.get("bytes").as_u64().unwrap() > 0);
        assert!(cache.get("hit_rate").as_f64().is_some());
        let comp = reply.get("component_cache");
        assert!(comp.get("entries").as_u64().unwrap() >= 1);
        let totals = reply.get("session_totals");
        assert!(totals.get("components").as_u64().unwrap() >= 1);
        assert!(totals.get("component_cache_hits").as_u64().is_some());
        // The router itself records no latency (the connection loop
        // does), so the ring is empty here.
        assert_eq!(reply.get("latency_us"), &Json::Null);
        assert_eq!(reply.get("requests").as_u64(), Some(0));
    }

    #[test]
    fn shutdown_is_flagged() {
        let rt = router();
        let r = rt.route_line(r#"{"cmd":"shutdown"}"#);
        assert!(ok(&r).get("ok").as_bool().unwrap());
        assert!(r.shutdown);
    }
}
