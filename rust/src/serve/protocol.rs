//! The serve request router: one newline-delimited JSON request in, one
//! JSON reply out — **always**.
//!
//! Every reply is an object with `"ok": true` plus command-specific
//! fields, or `"ok": false` with a structured
//! `{"error": {"code": …, "msg": …}}`. The router never panics outward:
//! requests are validated by the lazy scanner (same hardened grammar as
//! [`Json::parse`]: depth-limited, positioned errors), every handler
//! returns typed rejections, and the dispatch is wrapped in
//! `catch_unwind` as a last line of defense, so a bug in a handler
//! degrades to an `"internal"` error reply instead of a dead connection.
//!
//! ## The fast path
//!
//! [`Router::route_line`] never builds a request tree unless it has to.
//! [`scan_fields`] validates the whole line and extracts the top-level
//! protocol fields (`cmd`, `id`, the `plan` addressing/knob fields)
//! without allocating; `ping`, `stats`, `shutdown`, malformed input and
//! — crucially — every `plan` request are answered straight from the
//! scan. Only the full-body commands (`graph_upload`, `train`) fall
//! back to [`Json::parse`], and since the scanner accepts exactly what
//! the tree parser accepts, that fallback cannot change the error
//! surface.
//!
//! On the reply side, `plan` responses are [`ReplyBody::Raw`]: the
//! per-request envelope (`ok`, `reply`, `id`, `cache_hit`) is written
//! by [`RawJson`] and the plan summary is spliced in byte-for-byte from
//! [`CompiledPlan::summary_bytes`] — serialized once at compile time,
//! reused verbatim on every cache hit. Cache-hit raw replies bump
//! [`ServeMetrics::fast_path_hits`] so the zero-copy path is
//! observable. [`Router::route_line_eager`] preserves the previous
//! tree-parse/tree-serialize pipeline for benchmarks and differential
//! tests.
//!
//! Commands (the `"cmd"` field):
//!
//! | command        | fields                                              |
//! |----------------|-----------------------------------------------------|
//! | `ping`         | —                                                   |
//! | `graph_upload` | `graph` (the [`Graph::to_json`] object)             |
//! | `plan`         | `fingerprint` \| `network` (+`batch`), `planner`, `objective`, `sim`, `budget` \| `budget_frac` |
//! | `train`        | `network`, `batch`, `width`, `steps`, `mode`, `sim`, `budget` \| `budget_frac`, `lr` |
//! | `stats`        | —                                                   |
//! | `shutdown`     | —                                                   |
//!
//! Every command additionally accepts an optional `id` (string or
//! number), echoed back verbatim on the reply — including error
//! replies, whenever the request was well-formed enough to carry one.
//!
//! The router multiplexes every client onto one [`SessionRegistry`]
//! (fingerprint-keyed sessions over one shared plan cache), which is
//! what makes the daemon an amortizer: two clients uploading isomorphic
//! relabelings of a graph plan against the same session, and the second
//! identical request is a cache hit whoever sent the first.

use std::borrow::Cow;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::cli::dag_loss_summary;
use crate::coordinator::report::session_json;
use crate::coordinator::train::train_zoo_model_in;
use crate::exec::TrainConfig;
use crate::graph::{Graph, GraphFingerprint};
use crate::models::zoo;
use crate::planner::{BudgetSpec, Objective, PlanRequest, PlannerId};
use crate::session::{CompiledPlan, PlanSession, SessionRegistry};
use crate::sim::SimMode;
use crate::util::json::{Json, RawJson};
use crate::util::json_lazy::{scan_fields, LazyValue};
use crate::{fmt_bytes, parse_bytes};

use super::stats::ServeMetrics;

/// Per-request resource caps the router enforces before doing any work —
/// one hostile request must not be able to occupy the daemon with an
/// enormous graph, budget, or training run.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Largest absolute activation budget a request may name.
    pub max_budget_bytes: u64,
    /// Largest graph (in nodes) accepted for upload or zoo construction.
    pub max_graph_nodes: u32,
    /// Largest `batch` accepted for zoo construction / training.
    pub max_batch: u64,
    /// Largest per-node `width` accepted for training.
    pub max_train_width: usize,
    /// Largest `steps` accepted for one training request.
    pub max_train_steps: usize,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            max_budget_bytes: 64 << 30,
            max_graph_nodes: 4096,
            max_batch: 4096,
            max_train_width: 256,
            max_train_steps: 50,
        }
    }
}

/// One reply, in whichever representation the handler produced it.
///
/// `Tree` replies are built field-by-field ([`Json::obj`]) and
/// serialized on write; `Raw` replies are already-serialized lines
/// (the zero-copy `plan` path: envelope via [`RawJson`], summary
/// spliced from [`CompiledPlan::summary_bytes`]). The connection loop
/// appends either into its reusable output buffer without an extra
/// allocation.
pub enum ReplyBody {
    Tree(Json),
    Raw(String),
}

impl ReplyBody {
    /// Materialize the reply as a tree (tests and stats introspection;
    /// `Raw` lines always parse — they were produced by this module).
    pub fn to_json(&self) -> Json {
        match self {
            ReplyBody::Tree(j) => j.clone(),
            ReplyBody::Raw(s) => Json::parse(s).unwrap_or(Json::Null),
        }
    }

    /// Append the compact serialized reply (no trailing newline) to an
    /// existing buffer — the connection loop's reuse point.
    pub fn write_line(&self, out: &mut String) {
        match self {
            ReplyBody::Tree(j) => j.write_compact_into(out),
            ReplyBody::Raw(s) => out.push_str(s),
        }
    }
}

/// One routed request's outcome.
pub struct Routed {
    /// The reply to write back (always exactly one JSON object).
    pub reply: ReplyBody,
    /// The request asked the daemon to shut down.
    pub shutdown: bool,
    /// The reply is an `"ok": false` error.
    pub is_error: bool,
}

impl Routed {
    /// The reply as a tree (tests; the hot path never calls this).
    pub fn reply_json(&self) -> Json {
        self.reply.to_json()
    }
}

/// A typed rejection: becomes the `{"code", "msg"}` of an error reply.
struct Reject {
    code: &'static str,
    msg: String,
}

fn reject(code: &'static str, msg: impl std::fmt::Display) -> Reject {
    Reject { code, msg: msg.to_string() }
}

/// Build an `"ok": false` reply with a structured error object.
pub fn error_reply(code: &str, msg: &str) -> Json {
    Json::obj()
        .set("ok", false.into())
        .set("error", Json::obj().set("code", code.into()).set("msg", msg.into()))
}

fn ok_reply(cmd: &str) -> Json {
    Json::obj().set("ok", true.into()).set("reply", cmd.into())
}

/// The top-level fields the lazy scan extracts from every request line:
/// dispatch (`cmd`), the reply envelope (`id`), and the full `plan`
/// request surface — so a `plan` never needs the tree parser.
const SCAN_KEYS: [&str; 10] = [
    "cmd",
    "id",
    "fingerprint",
    "network",
    "batch",
    "planner",
    "objective",
    "sim",
    "budget",
    "budget_frac",
];
const F_CMD: usize = 0;
const F_ID: usize = 1;
const F_FINGERPRINT: usize = 2;
const F_NETWORK: usize = 3;
const F_BATCH: usize = 4;
const F_PLANNER: usize = 5;
const F_OBJECTIVE: usize = 6;
const F_SIM: usize = 7;
const F_BUDGET: usize = 8;
const F_BUDGET_FRAC: usize = 9;

/// One request field, abstracted over where it came from — a scanned
/// [`LazyValue`] or an eager [`Json`] tree — so the `plan` handlers are
/// written once and shared by both paths. `Null` means *absent or
/// literal null*, exactly like [`Json::get`]'s sentinel; `Container`
/// only needs to exist as a variant (every `plan` field that may be a
/// container is an error case).
enum Field<'a> {
    Null,
    Bool(bool),
    Num(f64),
    Str(Cow<'a, str>),
    Container,
}

impl<'a> Field<'a> {
    fn from_lazy(v: &Option<LazyValue<'a>>) -> Field<'a> {
        match v {
            None | Some(LazyValue::Null) => Field::Null,
            Some(LazyValue::Bool(b)) => Field::Bool(*b),
            Some(LazyValue::Num(n)) => Field::Num(*n),
            Some(LazyValue::Str(s)) => Field::Str(s.clone()),
            Some(LazyValue::Container(_)) => Field::Container,
        }
    }

    fn from_json(v: &'a Json) -> Field<'a> {
        match v {
            Json::Null => Field::Null,
            Json::Bool(b) => Field::Bool(*b),
            Json::Num(n) => Field::Num(*n),
            Json::Str(s) => Field::Str(Cow::Borrowed(s)),
            Json::Arr(_) | Json::Obj(_) => Field::Container,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Field::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            Field::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Mirror of [`Json::as_u64`].
    fn as_u64(&self) -> Option<u64> {
        match self {
            Field::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 2f64.powi(53) => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    fn is_null(&self) -> bool {
        matches!(self, Field::Null)
    }
}

/// The `plan` request surface, extracted once from either path.
struct PlanFields<'a> {
    fingerprint: Field<'a>,
    network: Field<'a>,
    batch: Field<'a>,
    planner: Field<'a>,
    objective: Field<'a>,
    sim: Field<'a>,
    budget: Field<'a>,
    budget_frac: Field<'a>,
}

impl<'a> PlanFields<'a> {
    fn from_scan(fields: &[Option<LazyValue<'a>>; SCAN_KEYS.len()]) -> PlanFields<'a> {
        PlanFields {
            fingerprint: Field::from_lazy(&fields[F_FINGERPRINT]),
            network: Field::from_lazy(&fields[F_NETWORK]),
            batch: Field::from_lazy(&fields[F_BATCH]),
            planner: Field::from_lazy(&fields[F_PLANNER]),
            objective: Field::from_lazy(&fields[F_OBJECTIVE]),
            sim: Field::from_lazy(&fields[F_SIM]),
            budget: Field::from_lazy(&fields[F_BUDGET]),
            budget_frac: Field::from_lazy(&fields[F_BUDGET_FRAC]),
        }
    }

    fn from_req(req: &'a Json) -> PlanFields<'a> {
        PlanFields {
            fingerprint: Field::from_json(req.get("fingerprint")),
            network: Field::from_json(req.get("network")),
            batch: Field::from_json(req.get("batch")),
            planner: Field::from_json(req.get("planner")),
            objective: Field::from_json(req.get("objective")),
            sim: Field::from_json(req.get("sim")),
            budget: Field::from_json(req.get("budget")),
            budget_frac: Field::from_json(req.get("budget_frac")),
        }
    }
}

/// The request's correlation `id`, owned for the reply: echoed back
/// when it is a string or number, treated as absent otherwise.
fn request_id(v: &Option<LazyValue<'_>>) -> Option<Json> {
    match v {
        Some(LazyValue::Str(s)) => Some(Json::Str(s.clone().into_owned())),
        Some(LazyValue::Num(n)) => Some(Json::Num(*n)),
        _ => None,
    }
}

fn request_id_json(req: &Json) -> Option<Json> {
    match req.get("id") {
        Json::Str(s) => Some(Json::Str(s.clone())),
        Json::Num(n) => Some(Json::Num(*n)),
        _ => None,
    }
}

fn attach_id(reply: Json, id: Option<&Json>) -> Json {
    match id {
        Some(id) => reply.set("id", id.clone()),
        None => reply,
    }
}

/// The daemon's request dispatcher. Owns the cross-client
/// [`SessionRegistry`] and a handle to the shared [`ServeMetrics`];
/// thread-safe (`&self` everywhere), shared across connection threads
/// via `Arc`.
pub struct Router {
    registry: SessionRegistry,
    metrics: Arc<ServeMetrics>,
    cfg: RouterConfig,
    started: Instant,
}

impl Router {
    pub fn new(registry: SessionRegistry, metrics: Arc<ServeMetrics>, cfg: RouterConfig) -> Router {
        Router { registry, metrics, cfg, started: Instant::now() }
    }

    /// The registry this router serves from (tests inspect it).
    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    /// Route one request line to a reply. Total: every input — hostile
    /// bytes included — produces exactly one JSON reply object. This is
    /// the lazy fast path; see the module docs for what avoids parsing.
    pub fn route_line(&self, line: &str) -> Routed {
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| self.dispatch(line)));
        match outcome {
            Ok(routed) => routed,
            Err(_) => Routed {
                reply: ReplyBody::Tree(error_reply("internal", "request handler panicked")),
                shutdown: false,
                is_error: true,
            },
        }
    }

    /// The pre-lazy pipeline: full tree parse in, tree reply out.
    /// Behaviorally identical to [`Router::route_line`] (same accepted
    /// inputs, same reply fields); kept for benchmarks (the honest
    /// "before" measurement) and the differential tests that hold the
    /// two paths to agreement.
    pub fn route_line_eager(&self, line: &str) -> Routed {
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| self.dispatch_eager(line)));
        match outcome {
            Ok(routed) => routed,
            Err(_) => Routed {
                reply: ReplyBody::Tree(error_reply("internal", "request handler panicked")),
                shutdown: false,
                is_error: true,
            },
        }
    }

    fn dispatch(&self, line: &str) -> Routed {
        let fields = match scan_fields(line, &SCAN_KEYS) {
            Ok(f) => f,
            // Malformed input carries no trustworthy id to echo.
            Err(e) => return error_routed("bad-json", &e.to_string(), None),
        };
        let id = request_id(&fields[F_ID]);
        let cmd = match fields[F_CMD].as_ref().and_then(|v| v.as_str()) {
            Some(c) => c,
            None => {
                return error_routed("bad-request", "missing string field 'cmd'", id.as_ref())
            }
        };
        let res: Result<(ReplyBody, bool), Reject> = match cmd {
            "ping" => Ok((ReplyBody::Tree(ok_reply("pong")), false)),
            "stats" => Ok((ReplyBody::Tree(self.stats()), false)),
            "shutdown" => Ok((ReplyBody::Tree(ok_reply("shutting down")), true)),
            "plan" => {
                let f = PlanFields::from_scan(&fields);
                self.plan_fast(&f, id.as_ref()).map(|b| (b, false))
            }
            // Full-body commands fall back to the tree parser. The scan
            // already validated the document, so this cannot introduce
            // new parse failures.
            "graph_upload" => Json::parse(line)
                .map_err(|e| reject("bad-json", e))
                .and_then(|req| self.graph_upload(&req))
                .map(|j| (ReplyBody::Tree(j), false)),
            "train" => Json::parse(line)
                .map_err(|e| reject("bad-json", e))
                .and_then(|req| self.train(&req))
                .map(|j| (ReplyBody::Tree(j), false)),
            other => Err(reject(
                "unknown-cmd",
                format!("unknown command '{other}' (ping|graph_upload|plan|train|stats|shutdown)"),
            )),
        };
        finish_routed(res, id.as_ref())
    }

    fn dispatch_eager(&self, line: &str) -> Routed {
        let req = match Json::parse(line) {
            Ok(r) => r,
            Err(e) => return error_routed("bad-json", &e.to_string(), None),
        };
        let id = request_id_json(&req);
        let cmd = match req.get("cmd").as_str() {
            Some(c) => c,
            None => {
                return error_routed("bad-request", "missing string field 'cmd'", id.as_ref())
            }
        };
        let res: Result<(ReplyBody, bool), Reject> = match cmd {
            "ping" => Ok((ReplyBody::Tree(ok_reply("pong")), false)),
            "stats" => Ok((ReplyBody::Tree(self.stats()), false)),
            "shutdown" => Ok((ReplyBody::Tree(ok_reply("shutting down")), true)),
            "plan" => self.plan_eager(&req).map(|j| (ReplyBody::Tree(j), false)),
            "graph_upload" => self.graph_upload(&req).map(|j| (ReplyBody::Tree(j), false)),
            "train" => self.train(&req).map(|j| (ReplyBody::Tree(j), false)),
            other => Err(reject(
                "unknown-cmd",
                format!("unknown command '{other}' (ping|graph_upload|plan|train|stats|shutdown)"),
            )),
        };
        finish_routed(res, id.as_ref())
    }

    // ---- graph_upload ---------------------------------------------------

    fn graph_upload(&self, req: &Json) -> Result<Json, Reject> {
        let gj = req.get("graph");
        if gj == &Json::Null {
            return Err(reject("bad-request", "graph_upload needs a 'graph' object"));
        }
        let g = Graph::from_json_value(gj).map_err(|e| reject("bad-graph", e))?;
        if g.len() == 0 {
            return Err(reject("bad-graph", "graph has no nodes"));
        }
        if g.len() > self.cfg.max_graph_nodes {
            return Err(reject(
                "graph-too-large",
                format!("{} nodes exceeds this server's cap {}", g.len(), self.cfg.max_graph_nodes),
            ));
        }
        let (name, nodes, total_mem) = (g.name.clone(), g.len(), g.total_mem());
        let (session, reused) = self.registry.get_or_insert(g);
        Ok(ok_reply("graph_upload")
            .set("fingerprint", session.fingerprint().to_string().into())
            .set("name", name.into())
            .set("nodes", nodes.into())
            .set("total_mem", total_mem.into())
            .set("reused", reused.into()))
    }

    // ---- plan -----------------------------------------------------------

    /// Resolve and compile (or cache-hit) one `plan` request — the
    /// logic shared by the fast and eager reply builders.
    fn plan_common(&self, f: &PlanFields<'_>) -> Result<(Arc<CompiledPlan>, bool), Reject> {
        let session = self.resolve_session(f)?;
        let planner = match f.planner.as_str() {
            None => PlannerId::ApproxDp,
            Some(s) => PlannerId::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let objective = parse_objective(f.objective.as_str().unwrap_or("tc"))?;
        let sim_mode = match f.sim.as_str() {
            None => SimMode::Liveness,
            Some(s) => SimMode::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let budget = self.budget_spec(f)?;
        let r = PlanRequest { planner, budget, objective, sim_mode };
        session.plan_tracked(&r).map_err(|e| {
            let msg = e.to_string();
            // The static schedule auditor rejected the compiled plan:
            // surface it as its own error code (and counter) so clients
            // and operators can tell a broken schedule from an
            // infeasible request.
            if msg.starts_with(crate::analysis::AUDIT_FAILED_PREFIX) {
                self.metrics.audit_failed.fetch_add(1, Ordering::Relaxed);
                reject("audit-failed", msg)
            } else {
                reject("plan-failed", msg)
            }
        })
    }

    /// The zero-copy `plan` reply: envelope written by [`RawJson`], the
    /// summary spliced verbatim from the plan's pre-serialized bytes.
    fn plan_fast(&self, f: &PlanFields<'_>, id: Option<&Json>) -> Result<ReplyBody, Reject> {
        let (cp, cache_hit) = self.plan_common(f)?;
        if cache_hit {
            self.metrics.fast_path_hits.fetch_add(1, Ordering::Relaxed);
        }
        let mut w = RawJson::with_capacity(cp.summary_bytes.len() + 64);
        w.field_bool("ok", true);
        w.field_str("reply", "plan");
        if let Some(id) = id {
            w.field("id", id);
        }
        w.field_bool("cache_hit", cache_hit);
        w.splice_bytes(&cp.summary_bytes);
        Ok(ReplyBody::Raw(w.finish()))
    }

    /// The tree-built `plan` reply (the pre-lazy pipeline): same fields
    /// as [`Router::plan_fast`], rebuilt and re-serialized per request.
    fn plan_eager(&self, req: &Json) -> Result<Json, Reject> {
        let f = PlanFields::from_req(req);
        let (cp, cache_hit) = self.plan_common(&f)?;
        let mut reply = ok_reply("plan").set("cache_hit", cache_hit.into());
        if let Json::Obj(fields) = cp.summary_json() {
            for (k, v) in fields {
                reply = reply.set(&k, v);
            }
        }
        Ok(reply)
    }

    /// A `plan` request addresses its graph by `fingerprint` (from a
    /// prior `graph_upload` — possibly another client's: fingerprints
    /// are relabeling-invariant) or by zoo `network` name (+`batch`).
    fn resolve_session(&self, f: &PlanFields<'_>) -> Result<Arc<PlanSession>, Reject> {
        if let Some(h) = f.fingerprint.as_str() {
            let fp = u64::from_str_radix(h.trim(), 16).map_err(|_| {
                reject("bad-request", format!("bad fingerprint '{h}' (expected hex digits)"))
            })?;
            return self.registry.get(GraphFingerprint(fp)).ok_or_else(|| {
                reject(
                    "unknown-fingerprint",
                    format!("no session registered for fingerprint {h} (graph_upload it first)"),
                )
            });
        }
        if let Some(name) = f.network.as_str() {
            let e = zoo::find(name)
                .ok_or_else(|| reject("unknown-network", format!("unknown zoo network '{name}'")))?;
            let batch = if f.batch.is_null() {
                e.batch
            } else {
                f.batch
                    .as_u64()
                    .filter(|&b| b >= 1)
                    .ok_or_else(|| reject("bad-request", "'batch' must be a positive integer"))?
            };
            if batch > self.cfg.max_batch {
                return Err(reject(
                    "request-cap",
                    format!("batch {batch} exceeds this server's cap {}", self.cfg.max_batch),
                ));
            }
            let g = e.build_batch(batch);
            if g.len() > self.cfg.max_graph_nodes {
                return Err(reject(
                    "graph-too-large",
                    format!(
                        "{} nodes exceeds this server's cap {}",
                        g.len(),
                        self.cfg.max_graph_nodes
                    ),
                ));
            }
            return Ok(self.registry.get_or_insert(g).0);
        }
        Err(reject("bad-request", "plan needs 'fingerprint' (from graph_upload) or 'network'"))
    }

    /// `budget` (string like `"512KiB"`, or an integer byte count) /
    /// `budget_frac` → [`BudgetSpec`], capped at the server's limit.
    fn budget_spec(&self, f: &PlanFields<'_>) -> Result<BudgetSpec, Reject> {
        let spec = match &f.budget {
            Field::Null => match &f.budget_frac {
                Field::Null => BudgetSpec::MinFeasible,
                v => match v.as_f64() {
                    Some(x) if x.is_finite() && (0.0..=1.0).contains(&x) => BudgetSpec::Frac(x),
                    _ => {
                        return Err(reject(
                            "bad-request",
                            "'budget_frac' must be a number in [0, 1]",
                        ))
                    }
                },
            },
            Field::Str(s) => {
                BudgetSpec::Bytes(parse_bytes(s).map_err(|e| reject("bad-request", e))?)
            }
            Field::Num(_) => BudgetSpec::Bytes(f.budget.as_u64().ok_or_else(|| {
                reject("bad-request", "numeric 'budget' must be a non-negative integer byte count")
            })?),
            _ => {
                return Err(reject(
                    "bad-request",
                    "'budget' must be a string (\"512KiB\") or a byte count",
                ))
            }
        };
        if let BudgetSpec::Bytes(bytes) = spec {
            if bytes > self.cfg.max_budget_bytes {
                return Err(reject(
                    "budget-cap",
                    format!(
                        "requested budget {} exceeds this server's cap {}",
                        fmt_bytes(bytes),
                        fmt_bytes(self.cfg.max_budget_bytes)
                    ),
                ));
            }
        }
        Ok(spec)
    }

    // ---- train ----------------------------------------------------------

    fn train(&self, req: &Json) -> Result<Json, Reject> {
        let name = req
            .get("network")
            .as_str()
            .ok_or_else(|| reject("bad-request", "train needs 'network' (a zoo name)"))?;
        if zoo::find(name).is_none() {
            return Err(reject("unknown-network", format!("unknown zoo network '{name}'")));
        }
        let batch = opt_usize(req, "batch", 2)?;
        let width = opt_usize(req, "width", 8)?;
        let steps = opt_usize(req, "steps", 2)?;
        if batch as u64 > self.cfg.max_batch
            || width > self.cfg.max_train_width
            || steps > self.cfg.max_train_steps
        {
            return Err(reject(
                "request-cap",
                format!(
                    "train request exceeds this server's caps \
                     (batch ≤ {}, width ≤ {}, steps ≤ {})",
                    self.cfg.max_batch, self.cfg.max_train_width, self.cfg.max_train_steps
                ),
            ));
        }
        let lr = match req.get("lr") {
            Json::Null => 0.05_f32,
            v => match v.as_f64() {
                Some(f) if f.is_finite() && f > 0.0 && f <= 10.0 => f as f32,
                _ => return Err(reject("bad-request", "'lr' must be a number in (0, 10]")),
            },
        };
        let objectives: Vec<Objective> = match req.get("mode").as_str().unwrap_or("tc") {
            "all" => vec![Objective::MinOverhead, Objective::MaxOverhead],
            m => vec![parse_objective(m)?],
        };
        let sim = match req.get("sim").as_str() {
            None => SimMode::Liveness,
            Some(s) => SimMode::parse(s).map_err(|e| reject("bad-request", e))?,
        };
        let f = PlanFields::from_req(req);
        let budget = self.budget_spec(&f)?;
        let cfg = TrainConfig { layers: 0, steps, lr, seed: 7, log_every: 0 };
        let cmp = train_zoo_model_in(
            Some(&self.registry),
            name,
            batch,
            width,
            &cfg,
            budget,
            &objectives,
            sim,
            true,
        )
        .map_err(|e| reject("train-failed", e))?;
        let runs: Vec<Json> = cmp
            .runs
            .iter()
            .map(|r| {
                Json::obj()
                    .set("objective", r.objective.label().into())
                    .set("k_segments", (r.k as u64).into())
                    .set("overhead", r.overhead.into())
                    .set("budget_bytes", r.budget.into())
                    .set("peak", r.report.observed_peak.into())
                    .set("grads_match", r.grads_match.into())
                    .set("peak_matches_sim", r.peak_matches_sim.into())
                    .set("losses_identical", r.losses_identical.into())
                    .set("cache_hit", r.cache_hit.into())
                    .set("loss", dag_loss_summary(&r.report).into())
            })
            .collect();
        Ok(ok_reply("train")
            .set("model", cmp.model.as_str().into())
            .set("fingerprint", cmp.fingerprint.to_string().into())
            .set("nodes", cmp.nodes.into())
            .set("sim", cmp.mode.label().into())
            .set("steps", (steps as u64).into())
            .set("vanilla_peak", cmp.vanilla.observed_peak.into())
            .set("vanilla_loss", dag_loss_summary(&cmp.vanilla).into())
            .set("all_verified", cmp.all_verified().into())
            .set("runs", Json::Arr(runs)))
    }

    // ---- stats ----------------------------------------------------------

    fn stats(&self) -> Json {
        let cs = self.registry.cache().stats();
        let comp = self.registry.component_cache().stats();
        let agg = self.registry.aggregate_stats();
        let m = &*self.metrics;
        let latency = match m.latency.percentiles() {
            None => Json::Null,
            Some(p) => Json::obj()
                .set("count", p.count.into())
                .set("p50_us", p.p50_us.into())
                .set("p90_us", p.p90_us.into())
                .set("p99_us", p.p99_us.into())
                .set("max_us", p.max_us.into()),
        };
        ok_reply("stats")
            .set("uptime_ms", (self.started.elapsed().as_millis() as u64).into())
            .set("requests", m.requests.load(Ordering::Relaxed).into())
            .set("errors", m.errors.load(Ordering::Relaxed).into())
            .set("rejected", m.rejected.load(Ordering::Relaxed).into())
            .set("bytes_in", m.bytes_in.load(Ordering::Relaxed).into())
            .set("bytes_out", m.bytes_out.load(Ordering::Relaxed).into())
            .set("fast_path_hits", m.fast_path_hits.load(Ordering::Relaxed).into())
            .set("audit_failed", m.audit_failed.load(Ordering::Relaxed).into())
            .set("inflight", (m.inflight.load(Ordering::SeqCst) as u64).into())
            .set("connections", (m.connections.load(Ordering::SeqCst) as u64).into())
            .set("connections_total", m.connections_total.load(Ordering::Relaxed).into())
            .set("sessions", (self.registry.len() as u64).into())
            .set(
                "cache",
                Json::obj()
                    .set("hits", cs.hits.into())
                    .set("misses", cs.misses.into())
                    .set("evictions", cs.evictions.into())
                    .set("entries", cs.entries.into())
                    .set("bytes", cs.bytes.into())
                    .set("hit_rate", cs.hit_rate().into()),
            )
            .set(
                "component_cache",
                Json::obj()
                    .set("entries", comp.entries.into())
                    .set("hits", comp.hits.into())
                    .set("misses", comp.misses.into()),
            )
            .set("session_totals", session_json(&agg))
            .set("latency_us", latency)
    }
}

fn error_routed(code: &'static str, msg: &str, id: Option<&Json>) -> Routed {
    Routed {
        reply: ReplyBody::Tree(attach_id(error_reply(code, msg), id)),
        shutdown: false,
        is_error: true,
    }
}

fn finish_routed(res: Result<(ReplyBody, bool), Reject>, id: Option<&Json>) -> Routed {
    match res {
        Ok((body, shutdown)) => {
            let reply = match body {
                // Raw replies already spliced their id.
                ReplyBody::Tree(t) => ReplyBody::Tree(attach_id(t, id)),
                raw => raw,
            };
            Routed { reply, shutdown, is_error: false }
        }
        Err(r) => error_routed(r.code, &r.msg, id),
    }
}

fn parse_objective(s: &str) -> Result<Objective, Reject> {
    match s {
        "tc" => Ok(Objective::MinOverhead),
        "mc" => Ok(Objective::MaxOverhead),
        o => Err(reject("bad-request", format!("bad objective '{o}' (tc|mc)"))),
    }
}

/// Optional positive-integer field with a default.
fn opt_usize(req: &Json, key: &str, default: usize) -> Result<usize, Reject> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_u64()
            .filter(|&n| n >= 1)
            .and_then(|n| usize::try_from(n).ok())
            .ok_or_else(|| reject("bad-request", format!("'{key}' must be a positive integer"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{PlanCache, SessionRegistry};
    use crate::testutil::{diamond, diamond_relabeled};

    fn router() -> Router {
        Router::new(
            SessionRegistry::new(8, PlanCache::shared(64)),
            Arc::new(ServeMetrics::new()),
            RouterConfig::default(),
        )
    }

    fn ok(r: &Routed) -> Json {
        let j = r.reply_json();
        assert!(!r.is_error, "expected ok reply, got {}", j.to_string());
        assert_eq!(j.get("ok").as_bool(), Some(true));
        j
    }

    fn err_code(r: &Routed) -> String {
        let j = r.reply_json();
        assert!(r.is_error, "expected error reply, got {}", j.to_string());
        assert_eq!(j.get("ok").as_bool(), Some(false));
        j.get("error").get("code").as_str().unwrap_or_default().to_string()
    }

    #[test]
    fn ping_pongs_and_unknown_cmds_error() {
        let rt = router();
        let r = rt.route_line(r#"{"cmd":"ping"}"#);
        assert_eq!(ok(&r).get("reply").as_str(), Some("pong"));
        assert!(!r.shutdown);
        assert_eq!(err_code(&rt.route_line(r#"{"cmd":"warp"}"#)), "unknown-cmd");
        assert_eq!(err_code(&rt.route_line(r#"{"nope":1}"#)), "bad-request");
        assert_eq!(err_code(&rt.route_line("not json")), "bad-json");
        assert_eq!(err_code(&rt.route_line(&"[".repeat(100_000))), "bad-json");
    }

    #[test]
    fn upload_plan_roundtrip_shares_sessions_across_relabelings() {
        let rt = router();
        let up = |g: &crate::graph::Graph| {
            let line = Json::obj()
                .set("cmd", "graph_upload".into())
                .set("graph", Json::parse(&g.to_json()).unwrap())
                .to_string();
            rt.route_line(&line)
        };
        let a = up(&diamond());
        let fp = ok(&a).get("fingerprint").as_str().unwrap().to_string();
        assert_eq!(ok(&a).get("reused").as_bool(), Some(false));
        // The isomorphic relabeling lands on the same session.
        let b = up(&diamond_relabeled());
        assert_eq!(ok(&b).get("fingerprint").as_str(), Some(fp.as_str()));
        assert_eq!(ok(&b).get("reused").as_bool(), Some(true));
        assert_eq!(rt.registry().len(), 1);

        // Plan by fingerprint: first is a miss, repeat is a cache hit.
        let plan_line = format!(r#"{{"cmd":"plan","fingerprint":"{fp}","planner":"exact"}}"#);
        let p1 = rt.route_line(&plan_line);
        assert_eq!(ok(&p1).get("cache_hit").as_bool(), Some(false));
        assert!(ok(&p1).get("k_segments").as_u64().unwrap() >= 1);
        let p2 = rt.route_line(&plan_line);
        assert_eq!(ok(&p2).get("cache_hit").as_bool(), Some(true));
        assert!(matches!(p2.reply, ReplyBody::Raw(_)), "plan replies are pre-serialized");
        assert_eq!(ok(&p1).get("budget_bytes").as_u64(), ok(&p2).get("budget_bytes").as_u64());
    }

    #[test]
    fn plan_rejections_are_structured() {
        let rt = router();
        for (line, code) in [
            (r#"{"cmd":"plan"}"#.to_string(), "bad-request"),
            (r#"{"cmd":"plan","fingerprint":"zzzz"}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","fingerprint":"00ddba11deadbeef"}"#.into(), "unknown-fingerprint"),
            (r#"{"cmd":"plan","network":"nosuchnet"}"#.into(), "unknown-network"),
            (r#"{"cmd":"plan","network":"unet","budget":"12parsecs"}"#.into(), "bad-request"),
            (
                r#"{"cmd":"plan","network":"unet","budget":"99999999999999GiB"}"#.into(),
                "bad-request",
            ),
            (r#"{"cmd":"plan","network":"unet","budget":"1B"}"#.into(), "plan-failed"),
            (r#"{"cmd":"plan","network":"unet","budget":"65GiB"}"#.into(), "budget-cap"),
            (r#"{"cmd":"plan","network":"unet","budget_frac":7}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","network":"unet","budget":[1]}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","network":"unet","batch":0}"#.into(), "bad-request"),
            (r#"{"cmd":"plan","network":"unet","batch":99999999}"#.into(), "request-cap"),
            (r#"{"cmd":"plan","network":"unet","objective":"zz"}"#.into(), "bad-request"),
        ] {
            assert_eq!(err_code(&rt.route_line(&line)), code, "lazy {line}");
            assert_eq!(err_code(&rt.route_line_eager(&line)), code, "eager {line}");
        }
    }

    #[test]
    fn zoo_plan_and_stats_shapes() {
        let rt = router();
        let p = rt.route_line(r#"{"cmd":"plan","network":"unet","objective":"mc"}"#);
        let reply = ok(&p);
        assert_eq!(reply.get("objective").as_str(), Some("mc"));
        assert!(reply.get("measured_peak").as_u64().unwrap() > 0);
        assert_eq!(reply.get("decomposition"), &Json::Null, "whole-graph plans carry none");

        // A decomposed plan reports its per-component shape.
        let d = rt.route_line(r#"{"cmd":"plan","network":"unet","planner":"decomposed"}"#);
        let dreply = ok(&d);
        assert_eq!(dreply.get("planner").as_str(), Some("Decomposed"));
        let info = dreply.get("decomposition");
        assert!(info.get("components").as_u64().unwrap() >= 1);
        assert!(info.get("cache_hits").as_u64().is_some());

        let s = rt.route_line(r#"{"cmd":"stats"}"#);
        let reply = ok(&s);
        assert_eq!(reply.get("sessions").as_u64(), Some(1));
        let cache = reply.get("cache");
        assert_eq!(cache.get("misses").as_u64(), Some(2));
        assert_eq!(cache.get("entries").as_u64(), Some(2));
        assert!(cache.get("bytes").as_u64().unwrap() > 0);
        assert!(cache.get("hit_rate").as_f64().is_some());
        let comp = reply.get("component_cache");
        assert!(comp.get("entries").as_u64().unwrap() >= 1);
        let totals = reply.get("session_totals");
        assert!(totals.get("components").as_u64().unwrap() >= 1);
        assert!(totals.get("component_cache_hits").as_u64().is_some());
        // The router saw no daemon traffic (the connection loop owns
        // the counters), so the I/O and latency figures are all zero.
        assert_eq!(reply.get("latency_us"), &Json::Null);
        assert_eq!(reply.get("requests").as_u64(), Some(0));
        assert_eq!(reply.get("bytes_in").as_u64(), Some(0));
        assert_eq!(reply.get("bytes_out").as_u64(), Some(0));
        // Both plans above were compile misses, not fast-path hits.
        assert_eq!(reply.get("fast_path_hits").as_u64(), Some(0));
    }

    #[test]
    fn shutdown_is_flagged() {
        let rt = router();
        let r = rt.route_line(r#"{"cmd":"shutdown"}"#);
        assert!(ok(&r).get("ok").as_bool().unwrap());
        assert!(r.shutdown);
    }

    #[test]
    fn lazy_and_eager_paths_agree_reply_for_reply() {
        // Two fresh routers (so cache state matches call-for-call): every
        // line must produce the same reply tree through both pipelines.
        let lazy = router();
        let eager = router();
        for line in [
            r#"{"cmd":"ping"}"#,
            r#"{"cmd":"ping","id":"c-1"}"#,
            r#"{"cmd":"plan","network":"unet"}"#,
            r#"{"cmd":"plan","network":"unet"}"#, // repeat: cache hit both sides
            r#"{"cmd":"plan","network":"unet","planner":"decomposed","id":7}"#,
            r#"{"cmd":"plan","network":"unet","budget_frac":0.5,"objective":"mc"}"#,
            r#"{"cmd":"plan","network":"unet","budget":"1GiB","sim":"strict"}"#,
            r#"{"cmd":"plan"}"#,
            r#"{"cmd":"plan","id":"oops","network":"nosuchnet"}"#,
            r#"{"cmd":"warp","id":3}"#,
            r#"{"nope":1}"#,
            "not json",
        ] {
            let a = lazy.route_line(line);
            let b = eager.route_line_eager(line);
            assert_eq!(a.reply_json(), b.reply_json(), "{line}");
            assert_eq!(a.is_error, b.is_error, "{line}");
        }
    }

    #[test]
    fn request_ids_echo_on_every_reply_shape() {
        let rt = router();
        // Tree ok reply.
        let r = rt.route_line(r#"{"cmd":"ping","id":"abc"}"#);
        assert_eq!(ok(&r).get("id").as_str(), Some("abc"));
        // Raw plan reply (spliced envelope), both miss and hit.
        let m = rt.route_line(r#"{"cmd":"plan","network":"unet","id":41}"#);
        assert_eq!(ok(&m).get("id").as_u64(), Some(41));
        let h = rt.route_line(r#"{"cmd":"plan","network":"unet","id":42}"#);
        assert_eq!(ok(&h).get("id").as_u64(), Some(42));
        assert_eq!(ok(&h).get("cache_hit").as_bool(), Some(true));
        // Error reply.
        let e = rt.route_line(r#"{"cmd":"warp","id":"x"}"#);
        assert_eq!(err_code(&e), "unknown-cmd");
        assert_eq!(e.reply_json().get("id").as_str(), Some("x"));
        // Non-scalar ids are treated as absent, not echoed.
        let n = rt.route_line(r#"{"cmd":"ping","id":[1]}"#);
        assert_eq!(ok(&n).get("id"), &Json::Null);
    }

    #[test]
    fn fast_path_hits_count_raw_cache_hits() {
        let metrics = Arc::new(ServeMetrics::new());
        let rt = Router::new(
            SessionRegistry::new(8, PlanCache::shared(64)),
            metrics.clone(),
            RouterConfig::default(),
        );
        let line = r#"{"cmd":"plan","network":"unet"}"#;
        let miss = rt.route_line(line);
        assert_eq!(ok(&miss).get("cache_hit").as_bool(), Some(false));
        assert_eq!(metrics.fast_path_hits.load(Ordering::Relaxed), 0, "misses don't count");
        for _ in 0..3 {
            let hit = rt.route_line(line);
            assert_eq!(ok(&hit).get("cache_hit").as_bool(), Some(true));
        }
        assert_eq!(metrics.fast_path_hits.load(Ordering::Relaxed), 3);
        // The eager pipeline serves the same hits without the counter.
        let eager_hit = rt.route_line_eager(line);
        assert_eq!(ok(&eager_hit).get("cache_hit").as_bool(), Some(true));
        assert_eq!(metrics.fast_path_hits.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn warm_raw_replies_are_byte_identical() {
        let rt = router();
        let line = r#"{"cmd":"plan","network":"unet"}"#;
        let first = rt.route_line(line); // miss: compiles + pre-serializes
        let raw = |r: &Routed| match &r.reply {
            ReplyBody::Raw(s) => s.clone(),
            ReplyBody::Tree(_) => panic!("plan replies are raw"),
        };
        let hit1 = raw(&rt.route_line(line));
        let hit2 = raw(&rt.route_line(line));
        assert_eq!(hit1, hit2, "identical requests serve identical bytes");
        assert_ne!(raw(&first), hit1, "only cache_hit differs");
        // With an id, the reply is the hit plus the spliced id field.
        let with_id = raw(&rt.route_line(r#"{"cmd":"plan","network":"unet","id":"z"}"#));
        assert_eq!(with_id.replace(r#""id":"z","#, ""), hit1);
    }
}
