//! Serve-side observability: a fixed-capacity latency ring buffer and
//! the daemon's atomic counters/gauges.
//!
//! The ring keeps the last [`LATENCY_RING_CAPACITY`] request latencies
//! (as whole microseconds) and answers nearest-rank percentiles over a
//! sorted snapshot — O(capacity log capacity) per `stats` request, which
//! is the cold path; recording on the hot path is one mutex-guarded
//! slot write, no allocation after construction.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Poison-tolerant lock: the ring's samples stay coherent across an
/// unwound holder, so recover the guard instead of cascading panics
/// through every connection thread.
fn lock(m: &Mutex<Ring>) -> MutexGuard<'_, Ring> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Number of most-recent request latencies the ring retains.
pub const LATENCY_RING_CAPACITY: usize = 4096;

struct Ring {
    buf: Vec<u64>,
    cap: usize,
    /// Next slot to overwrite once the buffer is full.
    next: usize,
}

/// Fixed-capacity ring of request latencies in microseconds.
pub struct LatencyRing {
    ring: Mutex<Ring>,
}

/// Nearest-rank percentiles over the ring's current window.
#[derive(Clone, Copy, Debug)]
pub struct LatencyPercentiles {
    /// Samples in the window (≤ ring capacity).
    pub count: usize,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl LatencyRing {
    /// A ring holding the last `capacity` samples (≥ 1).
    pub fn new(capacity: usize) -> LatencyRing {
        assert!(capacity >= 1, "latency ring capacity must be positive");
        LatencyRing {
            ring: Mutex::new(Ring { buf: Vec::with_capacity(capacity), cap: capacity, next: 0 }),
        }
    }

    /// Record one request latency (saturating to whole microseconds).
    pub fn record(&self, latency: Duration) {
        let us = u64::try_from(latency.as_micros()).unwrap_or(u64::MAX);
        let mut r = lock(&self.ring);
        if r.buf.len() < r.cap {
            r.buf.push(us);
        } else {
            let slot = r.next;
            r.buf[slot] = us;
            r.next = (slot + 1) % r.cap;
        }
    }

    /// Nearest-rank p50/p90/p99/max over the current window, or `None`
    /// when no requests have been recorded yet.
    pub fn percentiles(&self) -> Option<LatencyPercentiles> {
        let mut sorted = lock(&self.ring).buf.clone();
        if sorted.is_empty() {
            return None;
        }
        sorted.sort_unstable();
        let nearest_rank = |p: f64| -> u64 {
            // ceil(p·n) as a 1-based rank, clamped into the window.
            let rank = (p * sorted.len() as f64).ceil() as usize;
            sorted[rank.clamp(1, sorted.len()) - 1]
        };
        Some(LatencyPercentiles {
            count: sorted.len(),
            p50_us: nearest_rank(0.50),
            p90_us: nearest_rank(0.90),
            p99_us: nearest_rank(0.99),
            max_us: sorted.last().copied().unwrap_or(0),
        })
    }
}

/// The daemon's shared counters: request totals, admission-control
/// rejections, live gauges, and the latency ring. All lock-free except
/// the ring; shared by every connection thread via `Arc`.
pub struct ServeMetrics {
    /// Requests routed (including ones answered with an error reply).
    pub requests: AtomicU64,
    /// Requests answered with an `"ok": false` reply.
    pub errors: AtomicU64,
    /// Requests (or connections) refused by admission control.
    pub rejected: AtomicU64,
    /// Request bytes read off client sockets (including framing and
    /// lines later rejected).
    pub bytes_in: AtomicU64,
    /// Reply bytes written back to clients (including the newline).
    pub bytes_out: AtomicU64,
    /// `plan` cache hits answered by splicing the pre-serialized
    /// summary bytes — the zero-copy fast path's observability hook.
    pub fast_path_hits: AtomicU64,
    /// `plan` requests rejected because the static schedule auditor
    /// ([`crate::analysis`]) found the compiled plan defective — the
    /// `audit-failed` error code's counter.
    pub audit_failed: AtomicU64,
    /// Requests currently being processed.
    pub inflight: AtomicUsize,
    /// Currently open connections.
    pub connections: AtomicUsize,
    /// Connections accepted over the daemon's lifetime.
    pub connections_total: AtomicU64,
    /// Recent request latencies.
    pub latency: LatencyRing,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            bytes_in: AtomicU64::new(0),
            bytes_out: AtomicU64::new(0),
            fast_path_hits: AtomicU64::new(0),
            audit_failed: AtomicU64::new(0),
            inflight: AtomicUsize::new(0),
            connections: AtomicUsize::new(0),
            connections_total: AtomicU64::new(0),
            latency: LatencyRing::new(LATENCY_RING_CAPACITY),
        }
    }

    /// Count one routed request and its latency.
    pub fn record(&self, latency: Duration, is_error: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        if is_error {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        self.latency.record(latency);
    }

    /// Reserve an in-flight slot if fewer than `max` requests are
    /// currently processing — the admission-control gate. Pair every
    /// successful call with [`ServeMetrics::release`].
    pub fn try_admit(&self, max: usize) -> bool {
        self.inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                if v < max {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Release a slot reserved by [`ServeMetrics::try_admit`].
    pub fn release(&self) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(n: u64) -> Duration {
        Duration::from_micros(n)
    }

    #[test]
    fn percentiles_empty_then_filled() {
        let ring = LatencyRing::new(8);
        assert!(ring.percentiles().is_none());
        for n in [10, 20, 30, 40] {
            ring.record(us(n));
        }
        let p = ring.percentiles().unwrap();
        assert_eq!(p.count, 4);
        assert_eq!(p.p50_us, 20, "nearest rank: ceil(0.5·4)=2nd of [10,20,30,40]");
        assert_eq!(p.p90_us, 40);
        assert_eq!(p.p99_us, 40);
        assert_eq!(p.max_us, 40);
    }

    #[test]
    fn ring_wraps_keeping_the_most_recent_window() {
        let ring = LatencyRing::new(4);
        for n in 1..=10u64 {
            ring.record(us(n));
        }
        let p = ring.percentiles().unwrap();
        // Window is the last 4 samples: 7, 8, 9, 10.
        assert_eq!(p.count, 4);
        assert_eq!(p.p50_us, 8);
        assert_eq!(p.max_us, 10);
    }

    #[test]
    fn record_from_many_threads() {
        let m = ServeMetrics::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..100 {
                        m.record(us(i), i % 10 == 0);
                    }
                });
            }
        });
        assert_eq!(m.requests.load(Ordering::Relaxed), 400);
        assert_eq!(m.errors.load(Ordering::Relaxed), 40);
        assert_eq!(m.latency.percentiles().unwrap().count, 400);
    }

    #[test]
    fn admission_caps_inflight() {
        let m = ServeMetrics::new();
        assert!(m.try_admit(2));
        assert!(m.try_admit(2));
        assert!(!m.try_admit(2), "third admission must be refused");
        m.release();
        assert!(m.try_admit(2), "released slot is reusable");
        assert_eq!(m.inflight.load(Ordering::SeqCst), 2);
    }
}
