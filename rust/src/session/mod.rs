//! The plan-serving engine: [`PlanSession`] + the compiled-plan cache.
//!
//! The paper's DP is a one-shot offline solve; a serving system answers
//! *many* planning/training requests against the same graph, so the
//! expensive artifacts must be amortized, not recomputed per request:
//!
//! - the **lower-set families** (exact enumeration / `L^Pruned`) and
//!   their [`DpContext`]s are built lazily, once per family, and shared
//!   across every request that needs them;
//! - the **minimal feasible budget** `B*` per family is memoized, so
//!   [`BudgetSpec::resolve`] never re-runs the minimax DP;
//! - the **vanilla program** per [`SimMode`] is compiled once;
//! - every answered request is a [`CompiledPlan`] — plan + [`SimReport`]
//!   + the mode-rewritten [`Trace`] + a ready-to-run [`OpProgram`] —
//!   held in an LRU [`PlanCache`] keyed by `(graph fingerprint,
//!   request)` and handed out as `Arc`, so a repeated [`PlanRequest`]
//!   is a pointer clone.
//!
//! The cache key uses [`Graph::fingerprint`], which is invariant under
//! node relabeling and renaming: a shared cache (see
//! [`PlanSession::with_cache`]) serves repeated re-traces of the same
//! model across sessions. **Caveat:** a cached plan's node ids are
//! those of the session that *compiled* it. Share a cache only across
//! sessions whose frontends emit a stable node numbering (re-traces of
//! the same model normally do); if your frontend renumbers nodes
//! between traces, keep the default per-session cache — executing a
//! program against a permuted labeling would break the
//! observed-equals-predicted accounting.
//!
//! The decomposed planner adds a second amortization level: its
//! per-component plans live in a [`ComponentCache`] keyed by *subgraph*
//! fingerprint, so two different graphs sharing a tower (or one graph
//! re-planned after editing a single branch) rebuild only the components
//! that actually changed. Sessions own a private component cache by
//! default; [`SessionRegistry`] hands every session one shared cache.
//!
//! [`SessionStats`] (`hits` / `misses` / `families_built` /
//! `components` / `component_cache_hits`) is the observable evidence of
//! the amortization, reported by `repro train --stats` and the JSON
//! reports next to the allocator pool counters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::analysis::{audit_plan, AuditReport, PlanAudit};
use crate::anyhow::{bail, Result};
use crate::exec::{OpProgram, Step};
use crate::fmt_bytes;
use crate::graph::{
    articulation_points, enumerate_lower_sets, pruned_lower_sets, EnumerationLimit, Graph,
    GraphFingerprint, NodeSet,
};
use crate::planner::{
    planner_for, BudgetSpec, ComponentCache, DpContext, Family, Plan, PlanContext,
    PlanRequest, PlannerId, PlannerKind,
};
use crate::sim::{
    apply_liveness, canonical_trace, measure, vanilla_trace, Event, SimMode, SimOptions,
    SimReport, Trace,
};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

/// Mutex acquisition that survives a poisoned lock: a thread that
/// panicked while holding a cache or session mutex must not cascade
/// into every other connection sharing it — the guarded state is plain
/// counter/map bookkeeping that stays coherent across an unwound
/// holder, so recovering the guard is strictly better than poisoning
/// the whole daemon.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Default capacity of a session's private [`PlanCache`].
pub const DEFAULT_CACHE_CAPACITY: usize = 128;

/// Default capacity (entries) of a session's private [`ComponentCache`].
pub const DEFAULT_COMPONENT_CACHE_CAPACITY: usize = 256;

/// Counters describing how much work a session amortized.
#[derive(Clone, Copy, Default, PartialEq, Eq, Debug)]
pub struct SessionStats {
    /// Requests served straight from the compiled-plan cache.
    pub hits: u64,
    /// Requests that had to be planned and compiled.
    pub misses: u64,
    /// Lower-set families (and their DP contexts) actually constructed —
    /// at most one per [`Family`] per session, however many requests ran.
    pub families_built: u64,
    /// Per-component subproblems the decomposed planner stitched across
    /// this session's cache misses (0 unless `--planner decomposed` ran).
    pub components: u64,
    /// Of those components, how many were served from the
    /// [`ComponentCache`] instead of being solved from scratch.
    pub component_cache_hits: u64,
}

/// Wall-clock the session spent on planner work — kept *separate* from
/// [`SessionStats`] so the stats stay comparable across runs and thread
/// counts (the determinism suite asserts `SessionStats` equality;
/// timings are inherently run-dependent). Reported by `--stats`.
#[derive(Clone, Copy, Default, Debug)]
pub struct SessionTiming {
    /// Time spent enumerating lower-set families and building their
    /// [`DpContext`]s (the worker-pool-sharded per-member precompute).
    pub family_build: Duration,
    /// Total time spent answering cache misses end to end (plan + DP
    /// solve + simulate + program compile; includes `family_build` work
    /// triggered by a first miss).
    pub compile: Duration,
}

/// Everything a served plan request produces, compiled once and shared.
#[derive(Clone, Debug)]
pub struct CompiledPlan {
    /// The request this plan answers.
    pub request: PlanRequest,
    /// Fingerprint of the graph it was compiled against.
    pub fingerprint: GraphFingerprint,
    /// The canonical strategy plus analytic (Eq. 1 / Eq. 2) costs.
    pub plan: Plan,
    /// Simulator measurement under the request's [`SimMode`]
    /// (`peak_bytes` = activations only, `peak_total` adds parameters).
    pub report: SimReport,
    /// Strict-mode (no-liveness, Table 2) activation peak of the same
    /// plan — the ablation ceiling the liveness peak must stay under.
    pub peak_strict: u64,
    /// The mode-rewritten event trace the program was compiled from.
    pub trace: Trace,
    /// Ready-to-run executable program for [`crate::exec::DagTrainer`].
    pub program: OpProgram,
    /// Static schedule audit ([`crate::analysis::audit_plan`]) of the
    /// compiled trace + chain, run once at compile time and cached with
    /// the plan. Plans with audit *errors* never get this far — compile
    /// fails with [`crate::analysis::AUDIT_FAILED_PREFIX`] — so a cached
    /// report carries at most warnings (and none under `--deny-audit`).
    pub audit: AuditReport,
    /// Pre-serialized reply summary: the fields of
    /// [`CompiledPlan::summary_json`] as a compact `"key":value,…`
    /// fragment (outer braces stripped). Serialized **once** here at
    /// compile time so the serve daemon's cache hits splice stored
    /// bytes into their reply envelope instead of rebuilding and
    /// re-serializing the summary tree per request. Counted by
    /// [`CompiledPlan::approx_bytes`].
    pub summary_bytes: Arc<[u8]>,
}

impl CompiledPlan {
    /// Approximate resident size of this compiled plan in bytes — the
    /// accounting unit of the cache's `--cache-bytes` cap. Counts the
    /// bulk owned storage (chain bitsets, trace events, program steps);
    /// deliberately ignores small fixed-size headers, so it is an
    /// estimate, not an allocator-exact figure. Deterministic for a
    /// given plan, which is all the eviction policy needs.
    pub fn approx_bytes(&self) -> u64 {
        let header = std::mem::size_of::<CompiledPlan>() as u64;
        let chain: u64 = self
            .plan
            .chain
            .lower_sets()
            .iter()
            .map(|s| (s.words().len() * std::mem::size_of::<u64>()) as u64)
            .sum();
        let events = (self.trace.events.len() * std::mem::size_of::<Event>()) as u64;
        let steps = (self.program.steps.len() * std::mem::size_of::<Step>()) as u64;
        let summary = self.summary_bytes.len() as u64;
        header + chain + events + steps + summary + self.audit.approx_bytes() as u64
    }

    /// The canonical machine-readable summary of this plan — the exact
    /// field set the serve daemon's `plan` reply carries (minus the
    /// per-request envelope: `ok`/`reply`/`id`/`cache_hit`), and the
    /// core `repro plan --json` builds its richer document on.
    pub fn summary_json(&self) -> Json {
        let mut j = Json::obj()
            .set("fingerprint", self.fingerprint.to_string().into())
            .set("planner", self.plan.kind.label().into())
            .set("objective", self.request.objective.label().into())
            .set("sim", self.request.sim_mode.label().into())
            .set("budget_bytes", self.plan.budget.into())
            .set("k_segments", (self.plan.chain.k() as u64).into())
            .set("overhead", self.plan.overhead.into())
            .set("predicted_peak", self.program.predicted_peak().into())
            .set("measured_peak", self.report.peak_bytes.into())
            .set("peak_total", self.report.peak_total.into())
            .set("audit", self.audit.verdict().into());
        if let Some(info) = &self.plan.decomposition {
            j = j.set(
                "decomposition",
                Json::obj()
                    .set("components", info.components.into())
                    .set("cut_vertices", info.cut_vertices.into())
                    .set("cache_hits", info.cache_hits.into()),
            );
        }
        j
    }

    /// Serialize [`CompiledPlan::summary_json`] once into the braceless
    /// fragment stored as [`CompiledPlan::summary_bytes`].
    fn summary_fragment(&self) -> Arc<[u8]> {
        let s = self.summary_json().to_string();
        // A compact object is always "{…}"; keep just the field list.
        Arc::from(s[1..s.len() - 1].as_bytes())
    }
}

struct CacheEntry {
    value: Arc<CompiledPlan>,
    last_used: u64,
    /// Memoized [`CompiledPlan::approx_bytes`] (so eviction can subtract
    /// without re-walking the plan).
    bytes: u64,
}

struct CacheInner {
    map: HashMap<(GraphFingerprint, PlanRequest), CacheEntry>,
    tick: u64,
    bytes: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Cache-level counters, aggregated across every session sharing the
/// cache — the serving daemon's hit-rate source (session-level
/// [`SessionStats`] only see one session's traffic).
#[derive(Clone, Copy, Default, PartialEq, Eq, Debug)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that found nothing (the caller compiled and inserted).
    pub misses: u64,
    /// Entries evicted by the LRU policy (entry-count or byte cap).
    pub evictions: u64,
    /// Live entries at snapshot time.
    pub entries: usize,
    /// Approximate resident bytes of the live entries
    /// (Σ [`CompiledPlan::approx_bytes`]).
    pub bytes: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A bounded LRU cache of compiled plans, keyed by
/// `(graph fingerprint, request)`. Sessions own a private one by
/// default; share one across sessions with [`PlanSession::with_cache`]
/// to serve repeated requests for the same (or isomorphic) graph from
/// different entry points.
///
/// Bounded two ways: by entry count (`capacity`) and, optionally, by
/// approximate resident bytes (`max_bytes`, the `--cache-bytes` flag) —
/// compiled plans for large graphs carry their whole trace and program,
/// so an entry-count cap alone lets a few thousand-node plans dwarf a
/// hundred toy ones. Both caps evict least-recently-used first.
pub struct PlanCache {
    capacity: usize,
    max_bytes: Option<u64>,
    inner: Mutex<CacheInner>,
}

impl PlanCache {
    /// A cache holding at most `capacity` compiled plans (≥ 1), with no
    /// byte cap.
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache::with_bytes(capacity, None)
    }

    /// A cache bounded by `capacity` entries *and* (when `Some`) by
    /// `max_bytes` approximate resident bytes. A single entry larger
    /// than the byte cap is still admitted (alone) — refusing it would
    /// make large graphs uncacheable rather than merely lonely.
    pub fn with_bytes(capacity: usize, max_bytes: Option<u64>) -> PlanCache {
        assert!(capacity >= 1, "cache capacity must be positive");
        PlanCache {
            capacity,
            max_bytes,
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                tick: 0,
                bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// Shared handle with the given capacity (no byte cap).
    pub fn shared(capacity: usize) -> Arc<PlanCache> {
        Arc::new(PlanCache::new(capacity))
    }

    /// Shared handle bounded by entries and (optionally) bytes.
    pub fn shared_with_bytes(capacity: usize, max_bytes: Option<u64>) -> Arc<PlanCache> {
        Arc::new(PlanCache::with_bytes(capacity, max_bytes))
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        lock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the cache-level counters (see [`CacheStats`]).
    pub fn stats(&self) -> CacheStats {
        let inner = lock(&self.inner);
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            entries: inner.map.len(),
            bytes: inner.bytes,
        }
    }

    fn get(&self, key: &(GraphFingerprint, PlanRequest)) -> Option<Arc<CompiledPlan>> {
        let mut inner = lock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let hit = inner.map.get_mut(key).map(|e| {
            e.last_used = tick;
            e.value.clone()
        });
        if hit.is_some() {
            inner.hits += 1;
        } else {
            inner.misses += 1;
        }
        hit
    }

    /// Insert-if-absent: when two concurrent compilations race on the
    /// same key, the first insert wins and the loser is handed the
    /// canonical `Arc` — identical requests always end up sharing one
    /// compiled plan.
    fn insert(
        &self,
        key: (GraphFingerprint, PlanRequest),
        value: Arc<CompiledPlan>,
    ) -> Arc<CompiledPlan> {
        let mut inner = lock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(existing) = inner.map.get_mut(&key) {
            existing.last_used = tick;
            return existing.value.clone();
        }
        let bytes = value.approx_bytes();
        // Evict least-recently-used entries (linear scan: the cache is
        // small and insertion is the cold path by construction) until
        // both the entry cap and the byte cap admit the newcomer. The
        // byte loop stops at an empty map, so an oversized single entry
        // is admitted alone rather than rejected.
        while inner.map.len() >= self.capacity
            || (!inner.map.is_empty()
                && self.max_bytes.is_some_and(|cap| inner.bytes + bytes > cap))
        {
            let Some(evict) =
                inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k)
            else {
                break;
            };
            if let Some(e) = inner.map.remove(&evict) {
                inner.bytes -= e.bytes;
                inner.evictions += 1;
            }
        }
        inner.map.insert(key, CacheEntry { value: value.clone(), last_used: tick, bytes });
        inner.bytes += bytes;
        value
    }
}

struct FamilySlot {
    ctx: Arc<DpContext>,
    /// Whether exact enumeration succeeded (false = degraded to pruned).
    exact: bool,
    /// Memoized minimal feasible budget.
    min_budget: Option<u64>,
}

#[derive(Default)]
struct Inner {
    exact: Option<FamilySlot>,
    approx: Option<FamilySlot>,
    vanilla: HashMap<SimMode, Arc<OpProgram>>,
    /// Lazily computed articulation set of the skeleton, shared by the
    /// Chen budget sweep and the decomposed planner (one Tarjan pass per
    /// session, however many requests need it).
    arts: Option<Arc<NodeSet>>,
    stats: SessionStats,
    timing: SessionTiming,
}

/// A long-lived planning session over one graph: owns the graph, its
/// fingerprint, the lazily built per-family artifacts, and a compiled-
/// plan cache. See the module docs for what gets amortized.
///
/// Thread-safe (`&self` everywhere, internal mutexes), so future
/// parallel-planning work can share one session across workers.
pub struct PlanSession {
    graph: Arc<Graph>,
    fingerprint: GraphFingerprint,
    limit: EnumerationLimit,
    cache: Arc<PlanCache>,
    components: Arc<ComponentCache>,
    pool: Arc<WorkerPool>,
    /// `--deny-audit`: escalate audit warnings to compile failures.
    deny_audit: AtomicBool,
    inner: Mutex<Inner>,
}

impl PlanSession {
    /// A session with the default enumeration limit and a private cache.
    pub fn new(graph: Graph) -> PlanSession {
        PlanSession::with_limit(graph, EnumerationLimit::default())
    }

    /// A session with a custom enumeration cap for the exact family.
    pub fn with_limit(graph: Graph, limit: EnumerationLimit) -> PlanSession {
        PlanSession::with_cache(graph, limit, PlanCache::shared(DEFAULT_CACHE_CAPACITY))
    }

    /// A session backed by a shared [`PlanCache`] — the cross-request
    /// serving configuration (cache keys carry the graph fingerprint, so
    /// sessions over different graphs coexist in one cache). Planner
    /// work runs on the process-wide [`crate::util::pool::global`] pool.
    pub fn with_cache(
        graph: Graph,
        limit: EnumerationLimit,
        cache: Arc<PlanCache>,
    ) -> PlanSession {
        PlanSession::with_pool(graph, limit, cache, crate::util::pool::global())
    }

    /// A session with an explicit worker pool (the fully spelled-out
    /// constructor — used by the thread-count determinism tests, which
    /// need two in-process sessions with *different* parallelism).
    /// Plans are bit-identical at any thread count; only timings differ.
    pub fn with_pool(
        graph: Graph,
        limit: EnumerationLimit,
        cache: Arc<PlanCache>,
        pool: Arc<WorkerPool>,
    ) -> PlanSession {
        let fingerprint = graph.fingerprint();
        PlanSession {
            graph: Arc::new(graph),
            fingerprint,
            limit,
            cache,
            components: Arc::new(ComponentCache::new(DEFAULT_COMPONENT_CACHE_CAPACITY)),
            pool,
            deny_audit: AtomicBool::new(false),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Escalate static-audit warnings to hard compile failures (the
    /// `--deny-audit` flag). Audit *errors* always fail compilation;
    /// this additionally blocks warning-severity findings.
    pub fn set_deny_audit(&self, deny: bool) {
        self.deny_audit.store(deny, Ordering::Relaxed);
    }

    /// Whether audit warnings are currently escalated to errors.
    pub fn deny_audit(&self) -> bool {
        self.deny_audit.load(Ordering::Relaxed)
    }

    /// Replace the session's private [`ComponentCache`] with a shared
    /// one (builder-style, applied at construction). Component-cache
    /// keys carry the *subgraph* fingerprint, so sessions over different
    /// graphs that share a tower reuse each other's per-component plans
    /// — [`SessionRegistry`] wires every session it creates this way.
    pub fn share_components(mut self, components: Arc<ComponentCache>) -> PlanSession {
        self.components = components;
        self
    }

    /// The graph this session plans.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Shared handle to the graph (for executors that outlive borrows).
    pub fn shared_graph(&self) -> Arc<Graph> {
        self.graph.clone()
    }

    /// The graph's structural fingerprint (the cache-key component).
    pub fn fingerprint(&self) -> GraphFingerprint {
        self.fingerprint
    }

    /// The cache this session serves from.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// The per-component plan cache the decomposed planner writes into.
    pub fn component_cache(&self) -> &Arc<ComponentCache> {
        &self.components
    }

    /// The articulation points of the graph's undirected skeleton, as a
    /// set — computed once (Tarjan) and cached; the Chen sweep and the
    /// decomposed planner both plan against it.
    pub fn articulation_set(&self) -> Arc<NodeSet> {
        let mut inner = lock(&self.inner);
        if let Some(a) = &inner.arts {
            return a.clone();
        }
        let mut s = NodeSet::empty(self.graph.len());
        for v in articulation_points(&self.graph) {
            s.insert(v);
        }
        let arts = Arc::new(s);
        inner.arts = Some(arts.clone());
        arts
    }

    /// Snapshot of the amortization counters.
    pub fn stats(&self) -> SessionStats {
        lock(&self.inner).stats
    }

    /// Snapshot of the planner wall-clock spent so far (`--stats`).
    pub fn timing(&self) -> SessionTiming {
        lock(&self.inner).timing
    }

    /// The worker pool planner work runs on.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// The lazily built DP context for `family` (and whether it really
    /// is the exact lattice). Constructed at most once per family.
    pub fn family_context(&self, family: Family) -> (Arc<DpContext>, bool) {
        let mut inner = lock(&self.inner);
        let Inner { exact, approx, stats, timing, .. } = &mut *inner;
        let slot = match family {
            Family::Exact => exact,
            Family::Approx => approx,
        };
        if slot.is_none() {
            let t0 = Instant::now();
            let (ctx, is_exact) = match family {
                Family::Exact => match enumerate_lower_sets(&self.graph, self.limit) {
                    Some(fam) => (
                        DpContext::from_shared_with(self.graph.clone(), fam, &self.pool),
                        true,
                    ),
                    None => (
                        DpContext::from_shared_with(
                            self.graph.clone(),
                            pruned_lower_sets(&self.graph),
                            &self.pool,
                        ),
                        false,
                    ),
                },
                Family::Approx => (
                    DpContext::from_shared_with(
                        self.graph.clone(),
                        pruned_lower_sets(&self.graph),
                        &self.pool,
                    ),
                    false,
                ),
            };
            stats.families_built += 1;
            timing.family_build += t0.elapsed();
            *slot = Some(FamilySlot { ctx: Arc::new(ctx), exact: is_exact, min_budget: None });
        }
        match slot.as_ref() {
            Some(s) => (s.ctx.clone(), s.exact),
            // Filled on the miss path directly above.
            None => unreachable!("family slot populated before read"),
        }
    }

    /// The minimal feasible budget `B*` for `family`, computed once and
    /// memoized — the deduplicated home of every former
    /// `min_feasible_budget` call site.
    pub fn min_feasible_budget(&self, family: Family) -> u64 {
        let (ctx, _) = self.family_context(family);
        {
            let inner = lock(&self.inner);
            let slot = match family {
                Family::Exact => inner.exact.as_ref(),
                Family::Approx => inner.approx.as_ref(),
            };
            if let Some(b) = slot.and_then(|s| s.min_budget) {
                return b;
            }
        }
        let b = ctx.min_feasible_budget();
        let mut inner = lock(&self.inner);
        let slot = match family {
            Family::Exact => inner.exact.as_mut(),
            Family::Approx => inner.approx.as_mut(),
        };
        if let Some(s) = slot {
            s.min_budget = Some(b);
        }
        b
    }

    /// The vanilla (no-recomputation) program under `mode`, compiled
    /// once per mode and shared — the baseline every comparison run
    /// reuses instead of recompiling per CLI mode.
    pub fn vanilla_program(&self, mode: SimMode) -> Result<Arc<OpProgram>> {
        if let Some(p) = lock(&self.inner).vanilla.get(&mode) {
            return Ok(p.clone());
        }
        let prog =
            Arc::new(OpProgram::from_trace(&self.graph, &vanilla_trace(&self.graph), mode)?);
        lock(&self.inner).vanilla.insert(mode, prog.clone());
        Ok(prog)
    }

    /// Answer a planning request: served from the cache when the same
    /// `(fingerprint, request)` was compiled before, otherwise planned,
    /// simulated, compiled, cached and returned. Identical requests
    /// return the *same* `Arc` — bit-identical plans by construction.
    pub fn plan(&self, req: &PlanRequest) -> Result<Arc<CompiledPlan>> {
        self.plan_tracked(req).map(|(plan, _)| plan)
    }

    /// [`PlanSession::plan`], also reporting whether the answer came
    /// from the cache — the race-free hit signal the serving layer puts
    /// in its replies (comparing counters before/after is racy when many
    /// connections share one session).
    pub fn plan_tracked(&self, req: &PlanRequest) -> Result<(Arc<CompiledPlan>, bool)> {
        let key = (self.fingerprint, *req);
        if let Some(hit) = self.cache.get(&key) {
            lock(&self.inner).stats.hits += 1;
            return Ok((hit, true));
        }
        lock(&self.inner).stats.misses += 1;
        let t0 = Instant::now();
        let compiled = Arc::new(self.compile(req)?);
        lock(&self.inner).timing.compile += t0.elapsed();
        Ok((self.cache.insert(key, compiled), false))
    }

    fn compile(&self, req: &PlanRequest) -> Result<CompiledPlan> {
        let g = &*self.graph;
        let (dp, exact_family, budget) = match req.planner.family() {
            Some(family) => {
                let (ctx, exact) = self.family_context(family);
                let budget = req.budget.resolve(self, family)?;
                (Some(ctx), exact, budget)
            }
            None => (None, false, 0),
        };
        let arts = match req.planner {
            PlannerId::Chen | PlannerId::Decomposed => Some(self.articulation_set()),
            _ => None,
        };
        let plan = planner_for(req.planner).plan(
            req,
            &PlanContext {
                graph: g,
                dp: dp.as_deref(),
                exact_family,
                budget,
                pool: Some(&self.pool),
                components: Some(&self.components),
                arts: arts.as_deref(),
            },
        )?;
        if let Some(info) = &plan.decomposition {
            let mut inner = lock(&self.inner);
            inner.stats.components += info.components as u64;
            inner.stats.component_cache_hits += info.cache_hits as u64;
        }
        // One trace drives everything downstream: the simulator report,
        // the strict-ablation peak, and the executable program all view
        // the same event stream, so "observed == predicted" stays an
        // equality between two views of one schedule.
        let raw = canonical_trace(g, &plan.chain);
        let report = measure(g, &raw, SimOptions { mode: req.sim_mode, include_params: true });
        let peak_strict =
            measure(g, &raw, SimOptions { mode: SimMode::Strict, include_params: false })
                .peak_bytes;
        let trace = match req.sim_mode {
            SimMode::Liveness => apply_liveness(&raw),
            SimMode::Strict => raw,
        };
        let program = OpProgram::compile(g, &trace)?;
        debug_assert_eq!(
            program.predicted_peak(),
            report.peak_bytes,
            "program and simulator must agree on the peak"
        );
        // Static schedule audit (see [`crate::analysis`]): verify the
        // exact event stream the program was compiled from before the
        // plan is cached or served. Chen's `plan.budget` is the winning
        // *per-segment* sweep budget (and vanilla has none), so the
        // global budget-fit rule only applies to the DP planners.
        let budget_bound = match plan.kind {
            PlannerKind::Chen | PlannerKind::Vanilla => None,
            _ if plan.budget > 0 => Some(plan.budget),
            _ => None,
        };
        let audit = audit_plan(&PlanAudit {
            graph: g,
            chain: &plan.chain,
            trace: &trace,
            mode: req.sim_mode,
            budget: budget_bound,
            predicted_peak: Some(report.peak_bytes),
            program_peak: Some(program.predicted_peak()),
        });
        audit.gate(self.deny_audit())?;
        let mut cp = CompiledPlan {
            request: *req,
            fingerprint: self.fingerprint,
            plan,
            report,
            peak_strict,
            trace,
            program,
            audit,
            summary_bytes: Arc::from(&b""[..]),
        };
        // Serialize the reply summary exactly once per compilation; every
        // cache hit after this splices these bytes verbatim.
        cp.summary_bytes = cp.summary_fragment();
        Ok(cp)
    }
}

struct RegistryEntry {
    session: Arc<PlanSession>,
    last_used: u64,
}

struct RegistryInner {
    map: HashMap<GraphFingerprint, RegistryEntry>,
    tick: u64,
}

/// A bounded, fingerprint-keyed registry of live [`PlanSession`]s that
/// all serve from **one shared** [`PlanCache`] — the cross-client
/// serving surface behind `repro serve`.
///
/// Keying by [`Graph::fingerprint`] means two clients uploading
/// isomorphic relabelings of the same network land on the *same*
/// session (the second upload reports `reused`), so the expensive
/// amortized artifacts — lower-set families, DP contexts, memoized
/// `B*`, compiled plans — are built once per structure, not once per
/// client. When the registry is full the least-recently-used session is
/// dropped (its compiled plans stay in the shared cache until the cache
/// itself evicts them).
pub struct SessionRegistry {
    capacity: usize,
    limit: EnumerationLimit,
    cache: Arc<PlanCache>,
    components: Arc<ComponentCache>,
    inner: Mutex<RegistryInner>,
}

impl SessionRegistry {
    /// A registry holding at most `capacity` live sessions (≥ 1), all
    /// sharing `cache`.
    pub fn new(capacity: usize, cache: Arc<PlanCache>) -> SessionRegistry {
        SessionRegistry::with_limit(capacity, cache, EnumerationLimit::default())
    }

    /// [`SessionRegistry::new`] with a custom exact-enumeration cap for
    /// the sessions it creates.
    pub fn with_limit(
        capacity: usize,
        cache: Arc<PlanCache>,
        limit: EnumerationLimit,
    ) -> SessionRegistry {
        assert!(capacity >= 1, "registry capacity must be positive");
        SessionRegistry {
            capacity,
            limit,
            cache,
            components: Arc::new(ComponentCache::new(DEFAULT_COMPONENT_CACHE_CAPACITY)),
            inner: Mutex::new(RegistryInner { map: HashMap::new(), tick: 0 }),
        }
    }

    /// The shared compiled-plan cache every registered session serves
    /// from.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// The shared per-component plan cache every registered session's
    /// decomposed planner writes into — keyed by subgraph fingerprint,
    /// so distinct clients' models that share a tower share its plan.
    pub fn component_cache(&self) -> &Arc<ComponentCache> {
        &self.components
    }

    /// Number of live sessions.
    pub fn len(&self) -> usize {
        lock(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The session for `fingerprint`, if one is registered (bumps its
    /// LRU recency).
    pub fn get(&self, fingerprint: GraphFingerprint) -> Option<Arc<PlanSession>> {
        let mut inner = lock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.get_mut(&fingerprint).map(|e| {
            e.last_used = tick;
            e.session.clone()
        })
    }

    /// The session for `graph`'s fingerprint, creating (and registering)
    /// one when absent. Returns `(session, reused)` — `reused` is true
    /// when an isomorphic graph was already registered, in which case
    /// `graph` is dropped and the existing session (with its amortized
    /// artifacts) answers. Evicts the least-recently-used session past
    /// capacity.
    pub fn get_or_insert(&self, graph: Graph) -> (Arc<PlanSession>, bool) {
        let fingerprint = graph.fingerprint();
        let mut inner = lock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&fingerprint) {
            e.last_used = tick;
            return (e.session.clone(), true);
        }
        if inner.map.len() >= self.capacity {
            if let Some(evict) =
                inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k)
            {
                inner.map.remove(&evict);
            }
        }
        let session = Arc::new(
            PlanSession::with_cache(graph, self.limit, self.cache.clone())
                .share_components(self.components.clone()),
        );
        inner.map.insert(
            fingerprint,
            RegistryEntry { session: session.clone(), last_used: tick },
        );
        (session, false)
    }

    /// Sum of the per-session amortization counters across every *live*
    /// session (evicted sessions take their counters with them; the
    /// shared cache's [`PlanCache::stats`] is the durable aggregate).
    pub fn aggregate_stats(&self) -> SessionStats {
        let inner = lock(&self.inner);
        let mut total = SessionStats::default();
        for e in inner.map.values() {
            let s = e.session.stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.families_built += s.families_built;
            total.components += s.components;
            total.component_cache_hits += s.component_cache_hits;
        }
        total
    }

    /// Fingerprints of the live sessions (unordered).
    pub fn fingerprints(&self) -> Vec<GraphFingerprint> {
        lock(&self.inner).map.keys().copied().collect()
    }
}

impl BudgetSpec {
    /// Resolve the spec against a session, which memoizes the minimal
    /// feasible budget per family — infeasible absolute budgets report
    /// the graph's `min_feasible_budget` instead of a bare failure.
    pub fn resolve(self, session: &PlanSession, family: Family) -> Result<u64> {
        let g = session.graph();
        let min_b = session.min_feasible_budget(family);
        match self {
            BudgetSpec::MinFeasible => Ok(min_b),
            BudgetSpec::Frac(f) => Ok(((g.total_mem() as f64 * f) as u64).max(min_b)),
            BudgetSpec::Bytes(b) if b < min_b => bail!(
                "budget {} infeasible for {}: min_feasible_budget = {}",
                fmt_bytes(b),
                g.name,
                fmt_bytes(min_b)
            ),
            BudgetSpec::Bytes(b) => Ok(b),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{Objective, PlannerId};
    use crate::testutil::{chain_graph, diamond, diamond_relabeled, diamond_with_skip};

    fn req() -> PlanRequest {
        PlanRequest::new(PlannerId::ExactDp, Objective::MinOverhead)
    }

    fn session_on(graph: Graph, cache: &Arc<PlanCache>) -> Arc<PlanSession> {
        Arc::new(PlanSession::with_cache(graph, EnumerationLimit::default(), cache.clone()))
    }

    #[test]
    fn identical_requests_share_one_compilation() {
        let s = PlanSession::new(diamond());
        let a = s.plan(&req()).unwrap();
        let b = s.plan(&req()).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(
            s.stats(),
            SessionStats { hits: 1, misses: 1, families_built: 1, ..SessionStats::default() }
        );
    }

    #[test]
    fn byte_cap_evicts_by_resident_size() {
        // A 1-byte cap forces every insert to evict whatever else lives
        // in the cache (oversized entries are admitted alone), while the
        // entry cap alone would have kept all three.
        let cache = PlanCache::shared_with_bytes(8, Some(1));
        let s = session_on(diamond(), &cache);
        let min_b = s.min_feasible_budget(Family::Exact);
        for delta in 0..3u64 {
            let r = PlanRequest { budget: BudgetSpec::Bytes(min_b + delta), ..req() };
            let p = s.plan(&r).unwrap();
            assert!(p.approx_bytes() > 0);
            assert_eq!(cache.len(), 1, "byte cap admits at most one oversized entry");
        }
        let cs = cache.stats();
        assert_eq!(cs.entries, 1);
        assert_eq!(cs.evictions, 2);
        assert!(cs.bytes > 1, "the lone survivor's bytes are accounted");

        // A generous byte cap changes nothing relative to entry-only LRU.
        let roomy = PlanCache::shared_with_bytes(8, Some(1 << 30));
        let s2 = session_on(diamond(), &roomy);
        let mut expect_bytes = 0;
        for delta in 0..3u64 {
            let r = PlanRequest { budget: BudgetSpec::Bytes(min_b + delta), ..req() };
            expect_bytes += s2.plan(&r).unwrap().approx_bytes();
        }
        let cs2 = roomy.stats();
        assert_eq!(cs2.entries, 3);
        assert_eq!(cs2.evictions, 0);
        assert_eq!(cs2.bytes, expect_bytes, "stats.bytes = Σ approx_bytes of live entries");
    }

    #[test]
    fn summary_bytes_count_toward_residency_without_reordering_eviction() {
        // Regression for the pre-serialized reply summary: it is real
        // resident memory, so `approx_bytes` must count it — but adding
        // it must not change which entry the byte/entry caps evict.
        let cache = PlanCache::shared_with_bytes(2, Some(1 << 30));
        let s = session_on(diamond(), &cache);
        let min_b = s.min_feasible_budget(Family::Exact);
        let plan_at = |delta: u64| {
            let r = PlanRequest { budget: BudgetSpec::Bytes(min_b + delta), ..req() };
            s.plan(&r).unwrap()
        };

        let p0 = plan_at(0);
        assert!(!p0.summary_bytes.is_empty(), "summary serialized at compile time");
        assert!(
            p0.approx_bytes() > p0.summary_bytes.len() as u64,
            "approx_bytes counts the summary on top of the plan storage"
        );
        // The stored fragment is the braceless body of `summary_json`:
        // re-wrapping it must reproduce the tree exactly.
        let wrapped = format!("{{{}}}", std::str::from_utf8(&p0.summary_bytes).unwrap());
        assert_eq!(Json::parse(&wrapped).unwrap(), p0.summary_json());

        let p1 = plan_at(1);
        assert_eq!(cache.stats().bytes, p0.approx_bytes() + p1.approx_bytes());

        // Third insert against the 2-entry cap: the least-recently-used
        // entry (delta 0) goes, exactly as before summaries existed.
        let _p2 = plan_at(2);
        assert_eq!(cache.stats().entries, 2);
        assert_eq!(cache.stats().evictions, 1);
        let again1 = plan_at(1);
        assert!(Arc::ptr_eq(&p1, &again1), "delta-1 entry survived the eviction");
        let again0 = plan_at(0);
        assert!(!Arc::ptr_eq(&p0, &again0), "delta-0 was the LRU victim: recompiled");

        // The oversized-single-entry rule still holds with the summary
        // included: a cap below one entry's size admits it alone.
        let tiny = PlanCache::shared_with_bytes(8, Some(p0.approx_bytes() - 1));
        let s2 = session_on(diamond(), &tiny);
        let q0 = s2.plan(&PlanRequest { budget: BudgetSpec::Bytes(min_b), ..req() }).unwrap();
        assert!(q0.approx_bytes() >= p0.approx_bytes(), "same plan, same resident size");
        assert_eq!(tiny.len(), 1, "oversized entry admitted alone");
        s2.plan(&PlanRequest { budget: BudgetSpec::Bytes(min_b + 1), ..req() }).unwrap();
        assert_eq!(tiny.len(), 1, "next insert evicts the oversized resident");
        assert_eq!(tiny.stats().evictions, 1);
    }

    #[test]
    fn component_cache_shared_across_sessions_reuses_towers() {
        // Two different graphs — uniform chains of 40 and 48 nodes —
        // decompose into 32-node units whose leading tower is
        // structurally identical. With a shared ComponentCache the
        // second session reuses the first's solved tower.
        let comp = Arc::new(ComponentCache::new(64));
        let mk = |n: usize| {
            PlanSession::with_cache(
                chain_graph(&vec![8u64; n]),
                EnumerationLimit::default(),
                PlanCache::shared(DEFAULT_CACHE_CAPACITY),
            )
            .share_components(comp.clone())
        };
        let (a, b) = (mk(40), mk(48));
        let r = PlanRequest::new(PlannerId::Decomposed, Objective::MinOverhead);
        let pa = a.plan(&r).unwrap();
        assert_eq!(a.stats().components, 2, "40 nodes coalesce into [32, 8]");
        assert_eq!(a.stats().component_cache_hits, 0, "cold cache");
        let pb = b.plan(&r).unwrap();
        assert!(pb.plan.decomposition.is_some());
        assert_eq!(b.stats().components, 2, "48 nodes coalesce into [32, 16]");
        assert_eq!(b.stats().component_cache_hits, 1, "the 32-node tower is shared");
        let cs = comp.stats();
        assert_eq!(cs.entries, 3, "32-, 8- and 16-node units");
        assert_eq!((cs.hits, cs.misses), (1, 3));
        // A repeated request is a compiled-plan cache hit: no new
        // component work, counters unchanged.
        let pa2 = a.plan(&r).unwrap();
        assert!(Arc::ptr_eq(&pa, &pa2));
        assert_eq!(a.stats().components, 2);
        assert_eq!(comp.stats().hits, 1);
    }

    #[test]
    fn vanilla_program_compiled_once_per_mode() {
        let s = PlanSession::new(diamond());
        let a = s.vanilla_program(SimMode::Liveness).unwrap();
        let b = s.vanilla_program(SimMode::Liveness).unwrap();
        let c = s.vanilla_program(SimMode::Strict).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(s.stats().families_built, 0, "vanilla needs no family");
    }

    #[test]
    fn budget_resolution_memoizes_b_star() {
        let s = PlanSession::new(diamond());
        let b1 = BudgetSpec::MinFeasible.resolve(&s, Family::Exact).unwrap();
        let b2 = BudgetSpec::MinFeasible.resolve(&s, Family::Exact).unwrap();
        assert_eq!(b1, b2);
        assert_eq!(s.stats().families_built, 1);
        // An absolute budget below B* names the minimum.
        let err = BudgetSpec::Bytes(1).resolve(&s, Family::Exact).unwrap_err().to_string();
        assert!(err.contains("infeasible"), "{err}");
        assert!(err.contains("min_feasible_budget"), "{err}");
        // A fraction clamps up to feasibility.
        assert!(BudgetSpec::Frac(0.0).resolve(&s, Family::Exact).unwrap() >= b1);
    }

    #[test]
    fn cache_capacity_and_counters_survive_concurrent_hammering() {
        // Many connections hammering one small shared cache through two
        // isomorphic sessions: the capacity bound, the LRU accounting
        // and the hit/miss counters must all stay coherent.
        const THREADS: u64 = 8;
        const OPS: u64 = 32;
        const CAPACITY: usize = 4;
        let cache = PlanCache::shared(CAPACITY);
        let s1 = session_on(diamond(), &cache);
        let s2 = session_on(diamond_relabeled(), &cache);
        assert_eq!(s1.fingerprint(), s2.fingerprint(), "isomorphic by construction");
        let min_b = s1.min_feasible_budget(Family::Exact);
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let (s1, s2) = (s1.clone(), s2.clone());
                scope.spawn(move || {
                    for i in 0..OPS {
                        // 8 distinct budgets → 8 distinct keys cycling
                        // through a 4-entry cache, from both sessions.
                        let delta = (t + i) % 8;
                        let r = PlanRequest {
                            budget: BudgetSpec::Bytes(min_b + delta),
                            ..req()
                        };
                        let s = if (t + i) % 2 == 0 { &s1 } else { &s2 };
                        let plan = s.plan(&r).expect("planning never fails here");
                        assert_eq!(plan.plan.budget, min_b + delta, "no cross-key mixups");
                        assert!(cache.len() <= CAPACITY, "capacity bound violated");
                    }
                });
            }
        });
        let cs = cache.stats();
        assert!(cache.len() <= CAPACITY);
        assert_eq!(cs.entries, cache.len());
        assert_eq!(cs.hits + cs.misses, THREADS * OPS, "every lookup counted exactly once");
        assert!(cs.hits > 0, "repeated keys must hit");
        assert!(cs.evictions > 0, "8 keys through a 4-entry cache must evict");
        // The per-session counters add up to the cache's view.
        let agg_hits = s1.stats().hits + s2.stats().hits;
        let agg_misses = s1.stats().misses + s2.stats().misses;
        assert_eq!(agg_hits, cs.hits);
        assert_eq!(agg_misses, cs.misses);
    }

    #[test]
    fn racing_identical_requests_converge_on_one_canonical_plan() {
        // The double-insert race: several threads miss on the same key
        // before any of them inserts. Insert-if-absent must hand every
        // loser the winner's Arc — one compiled plan, however many
        // concurrent compilations raced past `get`.
        let cache = PlanCache::shared(64);
        let sessions: Vec<Arc<PlanSession>> = (0..4)
            .map(|i| {
                session_on(
                    if i % 2 == 0 { diamond() } else { diamond_relabeled() },
                    &cache,
                )
            })
            .collect();
        let plans: Vec<Arc<CompiledPlan>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|t: usize| {
                    let s = sessions[t % sessions.len()].clone();
                    scope.spawn(move || s.plan(&req()).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for p in &plans[1..] {
            assert!(
                Arc::ptr_eq(&plans[0], p),
                "all racers must share the canonical compiled plan"
            );
        }
        assert_eq!(cache.len(), 1, "one key, one entry — no double insert");
    }

    #[test]
    fn registry_shares_sessions_across_isomorphic_uploads() {
        let reg = SessionRegistry::new(2, PlanCache::shared(16));
        let (a, reused_a) = reg.get_or_insert(diamond());
        assert!(!reused_a);
        let (b, reused_b) = reg.get_or_insert(diamond_relabeled());
        assert!(reused_b, "isomorphic relabeling must land on the existing session");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        assert!(reg.get(a.fingerprint()).is_some());
        assert!(reg.get(GraphFingerprint(0xdead_beef)).is_none());

        // Distinct structures get their own sessions; the registry stays
        // bounded by evicting the least-recently-used one.
        let (_c, reused_c) = reg.get_or_insert(diamond_with_skip());
        assert!(!reused_c);
        assert_eq!(reg.len(), 2);
        let (_d, _) = reg.get_or_insert(chain_graph(&[5, 5, 5]));
        assert_eq!(reg.len(), 2, "capacity bound");

        // All registered sessions serve from the one shared cache, and
        // aggregate_stats sums their counters.
        let (d, _) = reg.get_or_insert(chain_graph(&[5, 5, 5]));
        d.plan(&PlanRequest::new(PlannerId::ApproxDp, Objective::MinOverhead)).unwrap();
        d.plan(&PlanRequest::new(PlannerId::ApproxDp, Objective::MinOverhead)).unwrap();
        let agg = reg.aggregate_stats();
        assert!(agg.hits >= 1, "{agg:?}");
        assert!(agg.misses >= 1, "{agg:?}");
        assert!(reg.cache().stats().hits >= 1);
        assert!(!reg.fingerprints().is_empty());
    }

    #[test]
    fn sessions_agree_bitwise_across_thread_counts() {
        let mk = |threads| {
            PlanSession::with_pool(
                diamond(),
                EnumerationLimit::default(),
                PlanCache::shared(DEFAULT_CACHE_CAPACITY),
                Arc::new(WorkerPool::with_threads(threads)),
            )
        };
        let (s1, s4) = (mk(1), mk(4));
        for r in [
            PlanRequest::new(PlannerId::ExactDp, Objective::MinOverhead),
            PlanRequest::new(PlannerId::ExactDp, Objective::MaxOverhead),
            PlanRequest::new(PlannerId::ApproxDp, Objective::MinOverhead),
        ] {
            let (a, b) = (s1.plan(&r).unwrap(), s4.plan(&r).unwrap());
            assert_eq!(a.plan.chain.lower_sets(), b.plan.chain.lower_sets(), "{r:?}");
            assert_eq!(a.plan.overhead, b.plan.overhead, "{r:?}");
            assert_eq!(a.report.peak_bytes, b.report.peak_bytes, "{r:?}");
        }
        assert_eq!(s1.stats(), s4.stats(), "amortization counters are thread-count invariant");
        // Timing is collected (run-dependent, so only sanity-checked):
        // three misses were compiled, so some wall-clock accrued.
        assert!(s1.timing().compile > Duration::ZERO);
        assert!(s1.timing().family_build > Duration::ZERO);
    }
}
