//! Drop-in stand-in for the `anyhow` crate.
//!
//! The build environment is offline — no crates.io — so the ergonomic
//! error handling the codebase was written against (`anyhow::Result`,
//! `anyhow!`, `bail!`, `.context(..)`) is provided by this self-contained
//! module instead. The surface mirrors the subset of `anyhow` the repo
//! uses; swapping the real crate back in is a one-line import change per
//! file.
//!
//! Design notes:
//!
//! - [`Error`] is a flat message string with contexts prepended
//!   (`"outer: inner"`), matching how `anyhow` renders with `{:#}`.
//! - A blanket `From<E: std::error::Error>` powers `?` on std errors
//!   (io, parse, [`crate::util::json::JsonError`], …). `Error` itself
//!   deliberately does **not** implement `std::error::Error`, exactly like
//!   `anyhow::Error`, so the blanket impl does not overlap the reflexive
//!   `From<T> for T`.
//! - The macros are `#[macro_export]` under hidden names and re-exported
//!   here, so `use crate::anyhow::{anyhow, bail}` (in-crate) and
//!   `use recompute::anyhow::{anyhow, bail}` (tests/examples/benches)
//!   both work.

use std::fmt;

/// A flat, context-prefixed error message.
pub struct Error {
    msg: String,
}

/// `Result` defaulting its error type to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer: `"{context}: {self}"`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        // Render the source chain inline so nothing is lost.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// Context-attaching extension for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format an [`Error`] — `anyhow!("bad value {v}")`.
#[doc(hidden)]
#[macro_export]
macro_rules! __recompute_anyhow {
    ($($t:tt)*) => {
        $crate::anyhow::Error::msg(::std::format!($($t)*))
    };
}

/// Early-return with a formatted [`Error`] — `bail!("unknown flag {f}")`.
#[doc(hidden)]
#[macro_export]
macro_rules! __recompute_bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::__recompute_anyhow!($($t)*))
    };
}

pub use crate::__recompute_anyhow as anyhow;
pub use crate::__recompute_bail as bail;

#[cfg(test)]
mod tests {
    use super::{anyhow, bail, Context, Error, Result};

    fn parse_two(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // From<ParseIntError>
        if v != 2 {
            bail!("expected 2, got {v}");
        }
        Ok(v)
    }

    #[test]
    fn question_mark_and_bail() {
        assert_eq!(parse_two("2").unwrap(), 2);
        assert!(parse_two("x").unwrap_err().to_string().contains("invalid digit"));
        assert_eq!(parse_two("3").unwrap_err().to_string(), "expected 2, got 3");
    }

    #[test]
    fn context_layers_prepend() {
        let e: Result<()> = Err(anyhow!("inner"));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let e2 = Err::<(), Error>(e).with_context(|| format!("layer {}", 3)).unwrap_err();
        assert_eq!(e2.to_string(), "layer 3: outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing value").unwrap_err().to_string(), "missing value");
        assert_eq!(Some(7u32).context("unused").unwrap(), 7);
    }

    #[test]
    fn std_errors_convert() {
        let e: Error = "x".parse::<u32>().unwrap_err().into();
        assert!(e.to_string().contains("invalid digit"));
        // Debug and Display agree (flat message, no struct noise).
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }
}
