//! Shared helpers for unit/property tests (compiled only under `cfg(test)`).

use crate::graph::{Graph, GraphBuilder, NodeId, OpKind};
use crate::util::rng::Pcg32;

/// Random weakly-connected DAG with random costs — the workhorse of the
/// property tests (planner-vs-oracle, trace safety, simulator invariants).
pub fn random_dag(rng: &mut Pcg32, n: u32) -> Graph {
    let mut b = GraphBuilder::new("rand", 1);
    let mut ids: Vec<NodeId> = Vec::new();
    for w in 0..n {
        let mut inputs = Vec::new();
        if w > 0 {
            inputs.push(ids[rng.below(w) as usize]);
            if rng.chance(0.35) {
                inputs.push(ids[rng.below(w) as usize]);
            }
            inputs.sort();
            inputs.dedup();
        }
        ids.push(b.add_raw(
            format!("n{w}"),
            OpKind::Other,
            rng.range(1, 12) as u64,
            rng.range(1, 6) as u64,
            &inputs,
        ));
    }
    b.build()
}

/// A simple chain graph with the given memories and unit times.
pub fn chain_graph(mems: &[u64]) -> Graph {
    let mut b = GraphBuilder::new("chain", 1);
    let mut prev: Option<NodeId> = None;
    for (i, &m) in mems.iter().enumerate() {
        let inputs: Vec<NodeId> = prev.into_iter().collect();
        prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, m, 1, &inputs));
    }
    b.build()
}
