//! Shared fixtures for unit, integration and property tests.
//!
//! Compiled unconditionally (not `cfg(test)`) so the integration suites
//! under `rust/tests/` and downstream harnesses can drive the same seeded
//! graph generators as the in-crate property tests. Not part of the
//! stable library surface — test support only.

use crate::graph::{Graph, GraphBuilder, Node, NodeId, OpKind};
use crate::util::rng::Pcg32;

/// Random weakly-connected DAG with random costs — the workhorse of the
/// property tests (planner-vs-oracle, trace safety, simulator invariants,
/// executor-vs-vanilla bit-exactness).
pub fn random_dag(rng: &mut Pcg32, n: u32) -> Graph {
    let mut b = GraphBuilder::new("rand", 1);
    let mut ids: Vec<NodeId> = Vec::new();
    for w in 0..n {
        let mut inputs = Vec::new();
        if w > 0 {
            inputs.push(ids[rng.below(w) as usize]);
            if rng.chance(0.35) {
                inputs.push(ids[rng.below(w) as usize]);
            }
            inputs.sort();
            inputs.dedup();
        }
        ids.push(b.add_raw(
            format!("n{w}"),
            OpKind::Other,
            rng.range(1, 12) as u64,
            rng.range(1, 6) as u64,
            &inputs,
        ));
    }
    b.build()
}

/// A simple chain graph with the given memories and unit times.
pub fn chain_graph(mems: &[u64]) -> Graph {
    let mut b = GraphBuilder::new("chain", 1);
    let mut prev: Option<NodeId> = None;
    for (i, &m) in mems.iter().enumerate() {
        let inputs: Vec<NodeId> = prev.into_iter().collect();
        prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, m, 1, &inputs));
    }
    b.build()
}

/// The diamond's edge list `0 → {1, 2} → 3`, shared by the fixture
/// variants below.
pub const DIAMOND_EDGES: [(NodeId, NodeId); 4] = [
    (NodeId(0), NodeId(1)),
    (NodeId(0), NodeId(2)),
    (NodeId(1), NodeId(3)),
    (NodeId(2), NodeId(3)),
];

/// The diamond / fan-in fixture `0 → {1, 2} → 3` with `M_v = 10·(v+1)`
/// and unit times — the smallest graph exercising both fan-out (node 0
/// read twice) and fan-in (node 3 merges two branches). Shared by the
/// graph/planner unit tests and the executor integration suite.
pub fn diamond() -> Graph {
    let nodes = (0..4)
        .map(|i| Node {
            name: format!("n{i}"),
            op: OpKind::Other,
            mem: 10 * (i + 1) as u64,
            time: 1,
            shape: vec![],
            param_bytes: 0,
        })
        .collect();
    Graph::new("diamond", nodes, &DIAMOND_EDGES)
}

/// Diamond topology with explicit per-node memory costs. Names (`m{i}`)
/// deliberately differ from [`diamond`]'s `n{i}`, so fingerprint tests
/// can also assert name insensitivity.
pub fn diamond_with_mems(mems: [u64; 4]) -> Graph {
    let nodes = mems
        .iter()
        .enumerate()
        .map(|(i, &m)| Node {
            name: format!("m{i}"),
            op: OpKind::Other,
            mem: m,
            time: 1,
            shape: vec![],
            param_bytes: 0,
        })
        .collect();
    Graph::new("diamond", nodes, &DIAMOND_EDGES)
}

/// An isomorphic relabeling of [`diamond`]: the two branch nodes are
/// stored in the opposite index order (node 1 carries `M = 30`, node 2
/// carries `M = 20`) and everything is renamed — the same graph up to
/// node numbering. Fingerprints must collide with [`diamond`]'s.
pub fn diamond_relabeled() -> Graph {
    diamond_with_mems([10, 30, 20, 40])
}

/// The diamond plus a skip edge `0 → 3` — one structural edit away from
/// [`diamond`], so fingerprints must differ.
pub fn diamond_with_skip() -> Graph {
    let mut edges = DIAMOND_EDGES.to_vec();
    edges.push((NodeId(0), NodeId(3)));
    let nodes = (0..4)
        .map(|i| Node {
            name: format!("n{i}"),
            op: OpKind::Other,
            mem: 10 * (i + 1) as u64,
            time: 1,
            shape: vec![],
            param_bytes: 0,
        })
        .collect();
    Graph::new("diamond+skip", nodes, &edges)
}
