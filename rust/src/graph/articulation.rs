//! Articulation points of the undirected skeleton of the DAG.
//!
//! Chen's algorithm (Appendix B of the paper) defines its candidate stage
//! split points `C` as the nodes whose removal disconnects the computation
//! graph — i.e. the articulation points of the underlying undirected graph.
//! Classic Hopcroft–Tarjan low-link DFS, implemented iteratively so deep
//! chains (ResNet152: 516 nodes) do not overflow the stack.

use super::{Graph, NodeId};

/// Articulation points of `g`'s undirected skeleton, in ascending id order.
pub fn articulation_points(g: &Graph) -> Vec<NodeId> {
    let n = g.len() as usize;
    if n == 0 {
        return Vec::new();
    }
    // Undirected adjacency.
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (v, _) in g.nodes() {
        for &w in g.succs(v) {
            adj[v.0 as usize].push(w.0);
            adj[w.0 as usize].push(v.0);
        }
    }

    let mut disc = vec![u32::MAX; n]; // discovery times
    let mut low = vec![u32::MAX; n];
    let mut is_art = vec![false; n];
    let mut timer: u32 = 0;

    // Iterative DFS. Frame: (node, parent, next-neighbor-index).
    for root in 0..n {
        if disc[root] != u32::MAX {
            continue;
        }
        let mut root_children = 0usize;
        let mut stack: Vec<(usize, usize, usize)> = vec![(root, usize::MAX, 0)];
        disc[root] = timer;
        low[root] = timer;
        timer += 1;
        while let Some(&mut (v, parent, ref mut idx)) = stack.last_mut() {
            if *idx < adj[v].len() {
                let w = adj[v][*idx] as usize;
                *idx += 1;
                if disc[w] == u32::MAX {
                    if v == root {
                        root_children += 1;
                    }
                    disc[w] = timer;
                    low[w] = timer;
                    timer += 1;
                    stack.push((w, v, 0));
                } else if w != parent {
                    low[v] = low[v].min(disc[w]);
                }
            } else {
                stack.pop();
                if let Some(&mut (p, _, _)) = stack.last_mut() {
                    low[p] = low[p].min(low[v]);
                    if p != root && low[v] >= disc[p] {
                        is_art[p] = true;
                    }
                }
            }
        }
        if root_children > 1 {
            is_art[root] = true;
        }
    }

    (0..n as u32).map(NodeId).filter(|v| is_art[v.0 as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::super::{Graph, Node, OpKind};
    use super::*;

    fn mk(n: u32, edges: &[(u32, u32)]) -> Graph {
        let nodes = (0..n)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 1,
                time: 1,
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        let e: Vec<_> = edges.iter().map(|&(a, b)| (NodeId(a), NodeId(b))).collect();
        Graph::new("t", nodes, &e)
    }

    #[test]
    fn chain_interior_nodes_are_articulation_points() {
        let g = mk(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let pts = articulation_points(&g);
        assert_eq!(pts, vec![NodeId(1), NodeId(2), NodeId(3)]);
    }

    #[test]
    fn diamond_has_only_endpoints_as_cuts() {
        // 0→{1,2}→3 plus tails: t0→0, 3→t1. The diamond interior is
        // biconnected; only 0 and 3 (and none of 1,2) separate the tails.
        let g = mk(6, &[(4, 0), (0, 1), (0, 2), (1, 3), (2, 3), (3, 5)]);
        let pts = articulation_points(&g);
        assert_eq!(pts, vec![NodeId(0), NodeId(3)]);
    }

    #[test]
    fn residual_block_skip_kills_interior_cuts() {
        // 0→1→2→3 with skip 0→3 (a residual block): 1 and 2 are on a cycle
        // in the skeleton, so only nothing separates — no articulation
        // points except none.
        let g = mk(4, &[(0, 1), (1, 2), (2, 3), (0, 3)]);
        assert!(articulation_points(&g).is_empty());
    }

    #[test]
    fn two_blocks_share_a_cut() {
        // Residual block 0..3 then residual block 3..6: node 3 is the only cut.
        let g = mk(7, &[(0, 1), (1, 2), (2, 3), (0, 3), (3, 4), (4, 5), (5, 6), (3, 6)]);
        assert_eq!(articulation_points(&g), vec![NodeId(3)]);
    }

    #[test]
    fn empty_and_singleton() {
        let g = mk(1, &[]);
        assert!(articulation_points(&g).is_empty());
    }

    /// Brute-force cross-check: v is an articulation point iff removing it
    /// increases the number of connected components of the skeleton.
    #[test]
    fn matches_bruteforce_on_random_dags() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(7);
        for _ in 0..30 {
            let n = rng.range(3, 14);
            let mut edges = Vec::new();
            for w in 1..n {
                // Ensure weak connectivity: each node gets ≥1 predecessor.
                let v = rng.below(w);
                edges.push((v, w));
                if rng.chance(0.4) {
                    let v2 = rng.below(w);
                    if v2 != v {
                        edges.push((v2, w));
                    }
                }
            }
            let g = mk(n, &edges);
            let fast: Vec<u32> = articulation_points(&g).iter().map(|v| v.0).collect();
            let slow: Vec<u32> = (0..n).filter(|&v| is_cut_bruteforce(&g, v)).collect();
            assert_eq!(fast, slow, "n={n} edges={edges:?}");
        }
    }

    fn is_cut_bruteforce(g: &Graph, cut: u32) -> bool {
        let n = g.len();
        let mut adj = vec![Vec::new(); n as usize];
        for (v, _) in g.nodes() {
            for &w in g.succs(v) {
                adj[v.0 as usize].push(w.0);
                adj[w.0 as usize].push(v.0);
            }
        }
        let comps = |skip: Option<u32>| -> usize {
            let mut seen = vec![false; n as usize];
            let mut count = 0;
            for s in 0..n {
                if Some(s) == skip || seen[s as usize] {
                    continue;
                }
                count += 1;
                let mut stack = vec![s];
                seen[s as usize] = true;
                while let Some(u) = stack.pop() {
                    for &w in &adj[u as usize] {
                        if Some(w) != skip && !seen[w as usize] {
                            seen[w as usize] = true;
                            stack.push(w);
                        }
                    }
                }
            }
            count
        };
        comps(Some(cut)) > comps(None)
    }
}
