//! Biconnected decomposition of the computation graph (PR 8 tentpole).
//!
//! Feng & Huang (*Optimal Gradient Checkpoint Search for Arbitrary
//! Computation Graphs*) observe that dividing a network at separators
//! makes optimal checkpoint search tractable: the exact DP's lower-set
//! family is (near-)additive across pieces that only communicate through
//! a single vertex, so planning per piece and stitching at the cuts
//! costs the sum — not the product — of the per-piece family sizes.
//!
//! Two layers live here:
//!
//! 1. [`block_cut_tree`]: the classic biconnected components ("blocks")
//!    of the undirected skeleton plus its articulation points — the
//!    textbook block–cut tree, via an iterative edge-stack
//!    Hopcroft–Tarjan DFS (deep chains must not overflow the stack).
//! 2. [`decompose`]: the planning-grade refinement. Not every
//!    articulation point is a sound *stitch* point for lower-set chains:
//!    a merge node fed by two otherwise-independent branches cuts the
//!    skeleton, but no serial ordering of the two branch blocks keeps
//!    every chain prefix a lower set. The articulation points that *are*
//!    sound are the **gates** — cut vertices `s` whose ancestor closure
//!    `L^s` has boundary exactly `{s}`, i.e. every edge from the past to
//!    the future passes through `s`. Gates are totally ordered by
//!    closure inclusion, so they slice `V` into consecutive components
//!    `C_i = L^{s_i} \ L^{s_{i-1}}` whose only cross-edges leave the
//!    trailing gate of each slice. Any concatenation of per-component
//!    lower-set chains (each shifted by the prefix) is then a valid
//!    global chain.

use super::{articulation_points, Graph, Node, NodeId, NodeSet};

/// Block–cut tree of the undirected skeleton: the biconnected components
/// ("blocks") and the articulation points ("cuts") joining them.
#[derive(Clone, Debug)]
pub struct BlockCutTree {
    /// Biconnected components; every skeleton edge lies in exactly one
    /// block, and blocks overlap only at cut vertices. Isolated nodes
    /// form singleton blocks. Sorted by smallest member id.
    pub blocks: Vec<NodeSet>,
    /// Articulation points of the skeleton, ascending.
    pub cuts: Vec<NodeId>,
}

impl BlockCutTree {
    /// Blocks (by index into [`BlockCutTree::blocks`]) containing `v`.
    pub fn blocks_of(&self, v: NodeId) -> Vec<usize> {
        (0..self.blocks.len()).filter(|&i| self.blocks[i].contains(v)).collect()
    }
}

/// Compute the block–cut tree of `g`'s undirected skeleton.
///
/// Iterative Hopcroft–Tarjan with an explicit edge stack: when a DFS
/// subtree rooted at `w` cannot reach above its tree parent `v`
/// (`low[w] >= disc[v]`), the edges accumulated since `(v, w)` form one
/// biconnected block.
pub fn block_cut_tree(g: &Graph) -> BlockCutTree {
    let n = g.len() as usize;
    let cuts = articulation_points(g);
    let mut blocks: Vec<NodeSet> = Vec::new();
    if n == 0 {
        return BlockCutTree { blocks, cuts };
    }

    // Undirected adjacency, neighbor ids ascending for determinism.
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (v, _) in g.nodes() {
        for &w in g.succs(v) {
            adj[v.0 as usize].push(w.0);
            adj[w.0 as usize].push(v.0);
        }
    }
    for a in &mut adj {
        a.sort_unstable();
    }

    let mut disc = vec![u32::MAX; n];
    let mut low = vec![u32::MAX; n];
    let mut timer: u32 = 0;
    let mut estack: Vec<(u32, u32)> = Vec::new();

    for root in 0..n {
        if disc[root] != u32::MAX {
            continue;
        }
        if adj[root].is_empty() {
            // Isolated vertex: its own (degenerate) block.
            disc[root] = timer;
            timer += 1;
            blocks.push(NodeSet::from_iter(g.len(), [NodeId(root as u32)]));
            continue;
        }
        // Frame: (node, parent, next-neighbor-index).
        let mut stack: Vec<(usize, usize, usize)> = vec![(root, usize::MAX, 0)];
        disc[root] = timer;
        low[root] = timer;
        timer += 1;
        while let Some(&mut (v, parent, ref mut idx)) = stack.last_mut() {
            if *idx < adj[v].len() {
                let w = adj[v][*idx] as usize;
                *idx += 1;
                if disc[w] == u32::MAX {
                    estack.push((v as u32, w as u32));
                    disc[w] = timer;
                    low[w] = timer;
                    timer += 1;
                    stack.push((w, v, 0));
                } else if w != parent && disc[w] < disc[v] {
                    // Back edge (the mirror direction was not yet pushed).
                    estack.push((v as u32, w as u32));
                    low[v] = low[v].min(disc[w]);
                }
            } else {
                stack.pop();
                if let Some(&mut (p, _, _)) = stack.last_mut() {
                    low[p] = low[p].min(low[v]);
                    if low[v] >= disc[p] {
                        // (p, v) closes a block: pop through it.
                        let mut b = NodeSet::empty(g.len());
                        while let Some((a, c)) = estack.pop() {
                            b.insert(NodeId(a));
                            b.insert(NodeId(c));
                            if (a, c) == (p as u32, v as u32) {
                                break;
                            }
                        }
                        blocks.push(b);
                    }
                }
            }
        }
    }
    blocks.sort_by_key(|b| (b.iter().next().map(|v| v.0).unwrap_or(u32::MAX), b.len()));
    BlockCutTree { blocks, cuts }
}

/// A serial split of `g` at its gate vertices — see the module docs for
/// why gates (not arbitrary articulation points) are the sound stitch
/// points for lower-set chains.
#[derive(Clone, Debug)]
pub struct Decomposition {
    /// Gate vertices `s_1, …, s_{m-1}` in closure-nesting (= topological)
    /// order; `gates[i]` is the last checkpointed vertex of
    /// `components[i]` and the only producer feeding `components[i+1]`.
    pub gates: Vec<NodeId>,
    /// The slices `C_i = L^{s_i} \ L^{s_{i-1}}`; a partition of `V` with
    /// `gates[i] ∈ components[i]`. Always non-empty (one component
    /// covering `V` when the graph has no gates).
    pub components: Vec<NodeSet>,
}

impl Decomposition {
    /// Number of components.
    pub fn len(&self) -> usize {
        self.components.len()
    }

    /// True when the decomposition is the trivial single slice.
    pub fn is_empty(&self) -> bool {
        self.gates.is_empty()
    }
}

/// Split `g` at its gates. `arts` must be the skeleton's articulation
/// points (from [`articulation_points`] or a cached copy) — gates are
/// screened from them: `s` qualifies iff `∂(L^s) = {s}`, i.e. the
/// ancestor closure of `s` touches the future only through `s` itself.
pub fn decompose(g: &Graph, arts: &[NodeId]) -> Decomposition {
    let n = g.len();
    // Candidate gates with their closures.
    let mut cands: Vec<(NodeSet, NodeId)> = Vec::new();
    for &v in arts {
        let l = g.ancestors_closure(v);
        let b = g.boundary(&l);
        if b.len() == 1 && b.contains(v) {
            cands.push((l, v));
        }
    }
    // Nesting order: closures of gates are totally ordered by inclusion
    // *within a weakly-connected graph*; for safety (disconnected
    // skeletons) keep a maximal chain greedily, sorted by closure size.
    cands.sort_by(|a, b| a.0.len().cmp(&b.0.len()).then_with(|| a.1 .0.cmp(&b.1 .0)));
    let mut gates: Vec<NodeId> = Vec::new();
    let mut closures: Vec<NodeSet> = Vec::new();
    for (l, v) in cands {
        if l.len() == n {
            continue; // a gate must have a non-empty future
        }
        match closures.last() {
            Some(prev) if !(prev.is_strict_subset(&l)) => continue,
            _ => {}
        }
        closures.push(l);
        gates.push(v);
    }
    // Components: successive closure differences plus the tail.
    let mut components: Vec<NodeSet> = Vec::new();
    let mut prev = NodeSet::empty(n);
    for l in &closures {
        components.push(l.difference(&prev));
        prev = l.clone();
    }
    components.push(prev.complement());
    debug_assert!(components.iter().all(|c| !c.is_empty()));
    Decomposition { gates, components }
}

/// Extract the sub-DAG induced by `set`, relabeling members to dense
/// local ids in ascending original-id order. Returns the subgraph and
/// the local→global id map. Edges with an endpoint outside `set` are
/// dropped (for gate components these are exactly the edges through the
/// bounding gates).
pub fn induced_subgraph(g: &Graph, set: &NodeSet) -> (Graph, Vec<NodeId>) {
    let map: Vec<NodeId> = set.iter().collect();
    let mut local = vec![u32::MAX; g.len() as usize];
    for (i, v) in map.iter().enumerate() {
        local[v.0 as usize] = i as u32;
    }
    let nodes: Vec<Node> = map.iter().map(|&v| g.node(v).clone()).collect();
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    for &v in &map {
        for &w in g.succs(v) {
            if set.contains(w) {
                edges.push((NodeId(local[v.0 as usize]), NodeId(local[w.0 as usize])));
            }
        }
    }
    let name = format!("{}[{}+{}]", g.name, map.first().map(|v| v.0).unwrap_or(0), map.len());
    (Graph::new(name, nodes, &edges), map)
}

#[cfg(test)]
mod tests {
    use super::super::{Node, OpKind};
    use super::*;

    fn mk(n: u32, edges: &[(u32, u32)]) -> Graph {
        let nodes = (0..n)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 10 + u64::from(i % 3),
                time: 1 + u64::from(i % 2),
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        let e: Vec<_> = edges.iter().map(|&(a, b)| (NodeId(a), NodeId(b))).collect();
        Graph::new("t", nodes, &e)
    }

    /// Brute-force blocks: maximal edge groups under the "same simple
    /// cycle or shared edge chain" relation, via the standard definition:
    /// two edges are in one block iff they lie on a common simple cycle.
    /// For the small fixtures here we instead check the defining
    /// properties rather than reimplement the partition.
    #[test]
    fn chain_blocks_are_edges_and_interior_cuts() {
        let g = mk(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let t = block_cut_tree(&g);
        assert_eq!(t.blocks.len(), 4, "a chain's blocks are its edges");
        assert_eq!(t.cuts, vec![NodeId(1), NodeId(2), NodeId(3)]);
        for b in &t.blocks {
            assert_eq!(b.len(), 2);
        }
    }

    #[test]
    fn diamond_is_one_block() {
        let g = mk(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let t = block_cut_tree(&g);
        assert_eq!(t.blocks.len(), 1);
        assert_eq!(t.blocks[0].len(), 4);
        assert!(t.cuts.is_empty());
    }

    #[test]
    fn residual_stack_blocks_meet_at_cuts() {
        // Two diamonds sharing node 3: 0→{1,2}→3→{4,5}→6.
        let g = mk(7, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (3, 5), (4, 6), (5, 6)]);
        let t = block_cut_tree(&g);
        assert_eq!(t.cuts, vec![NodeId(3)]);
        assert_eq!(t.blocks.len(), 2);
        // Every edge is covered exactly once and blocks overlap only at 3.
        let inter = t.blocks[0].intersection(&t.blocks[1]);
        assert_eq!(inter.iter().collect::<Vec<_>>(), vec![NodeId(3)]);
        assert_eq!(t.blocks_of(NodeId(3)), vec![0, 1]);
        assert_eq!(t.blocks_of(NodeId(0)).len(), 1);
    }

    #[test]
    fn blocks_partition_edges_on_random_dags() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(0xb10c);
        for _ in 0..20 {
            let n = rng.range(3, 14);
            let g = crate::testutil::random_dag(&mut rng, n);
            let t = block_cut_tree(&g);
            // Each directed edge lies in exactly one block.
            for (v, _) in g.nodes() {
                for &w in g.succs(v) {
                    let covering = t
                        .blocks
                        .iter()
                        .filter(|b| b.contains(v) && b.contains(w))
                        .count();
                    assert_eq!(covering, 1, "edge {}→{} in {covering} blocks", v.0, w.0);
                }
            }
            // Nodes in ≥ 2 blocks are exactly the articulation points
            // (plus nothing else), on connected skeletons.
            for (v, _) in g.nodes() {
                let k = t.blocks_of(v).len();
                if k >= 2 {
                    assert!(t.cuts.contains(&v), "node {} in {k} blocks must be a cut", v.0);
                }
            }
        }
    }

    #[test]
    fn chain_decomposes_at_every_interior_node() {
        let g = mk(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let arts = articulation_points(&g);
        let d = decompose(&g, &arts);
        assert_eq!(d.gates, vec![NodeId(1), NodeId(2), NodeId(3)]);
        assert_eq!(d.len(), 4);
        // Node 0 is a skeleton leaf, not a cut, so the first slice is {0, 1}.
        assert_eq!(d.components[0].iter().collect::<Vec<_>>(), vec![NodeId(0), NodeId(1)]);
    }

    #[test]
    fn merge_of_independent_branches_is_not_a_gate() {
        // Two source chains merging: 0→1→4, 2→3→4, 4→5. Node 4 cuts the
        // skeleton but L^1 = {0,1} has boundary {1} — node 1 IS a gate
        // for its own branch; however 1's closure does not contain the
        // other branch, so after keeping the maximal nested chain only
        // one branch's gates survive, and stitching stays valid.
        let g = mk(6, &[(0, 1), (1, 4), (2, 3), (3, 4), (4, 5)]);
        let arts = articulation_points(&g);
        let d = decompose(&g, &arts);
        // 4 is a gate (boundary of L^4 = {0..4} is {4}); 1 and 3 are
        // mutually incomparable so at most one of them survives.
        assert!(d.gates.contains(&NodeId(4)));
        // Every prefix union of components must be a lower set.
        let mut prefix = NodeSet::empty(g.len());
        for c in &d.components {
            prefix.union_with(c);
            assert!(g.is_lower_set(&prefix));
        }
    }

    #[test]
    fn decomposition_partitions_and_prefixes_are_lower_sets() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(0xdec0);
        for _ in 0..25 {
            let n = rng.range(3, 16);
            let g = crate::testutil::random_dag(&mut rng, n);
            let arts = articulation_points(&g);
            let d = decompose(&g, &arts);
            assert_eq!(d.components.len(), d.gates.len() + 1);
            let mut union = NodeSet::empty(g.len());
            for (i, c) in d.components.iter().enumerate() {
                assert!(!c.is_empty());
                assert!(union.is_disjoint(c), "components must partition V");
                union.union_with(c);
                assert!(g.is_lower_set(&union), "prefix {i} must be a lower set");
                if i < d.gates.len() {
                    // The trailing gate is the only node feeding the future.
                    let b = g.boundary(&union);
                    assert_eq!(b.len(), 1);
                    assert!(b.contains(d.gates[i]));
                    assert!(c.contains(d.gates[i]));
                }
            }
            assert_eq!(union.len(), g.len());
        }
    }

    #[test]
    fn induced_subgraph_roundtrips_nodes_and_edges() {
        let g = mk(7, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (3, 5), (4, 6), (5, 6)]);
        let set = NodeSet::from_iter(7, [NodeId(3), NodeId(4), NodeId(5), NodeId(6)]);
        let (sub, map) = induced_subgraph(&g, &set);
        assert_eq!(sub.len(), 4);
        assert_eq!(map, vec![NodeId(3), NodeId(4), NodeId(5), NodeId(6)]);
        assert_eq!(sub.edge_count(), 4); // 3→4, 3→5, 4→6, 5→6
        for (i, &v) in map.iter().enumerate() {
            assert_eq!(sub.node(NodeId(i as u32)).mem, g.node(v).mem);
            assert_eq!(sub.node(NodeId(i as u32)).name, g.node(v).name);
        }
        // Local sources are the nodes whose only preds were outside.
        assert_eq!(sub.sources(), vec![NodeId(0)]);
    }
}
