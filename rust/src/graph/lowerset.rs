//! Lower-set (order-ideal) machinery.
//!
//! The exact DP (§4.2) searches over the full family `L_G` of lower sets;
//! the approximate DP (§4.3) over the pruned family
//! `L^Pruned = {L^v | v ∈ V}` of reachability closures. Both are produced
//! here. `#L_G` can be exponential, so enumeration takes a limit and
//! reports overflow instead of OOM-ing — the exact planner then falls back
//! to the approximate family, which matches the paper's practical guidance.

use std::collections::HashSet;

use super::{Graph, NodeId, NodeSet};

/// Cap on the number of lower sets the exhaustive enumeration will produce.
#[derive(Clone, Copy, Debug)]
pub struct EnumerationLimit {
    /// Maximum number of distinct lower sets (including ∅ and V).
    pub max_ideals: usize,
}

impl Default for EnumerationLimit {
    fn default() -> Self {
        // GoogLeNet-class graphs stay in the tens of thousands; this cap
        // keeps the exact DP tractable while letting every zoo network
        // that the paper ran ExactDP on complete.
        EnumerationLimit { max_ideals: 2_000_000 }
    }
}

/// Enumerate **all** lower sets of `g`, or `None` if there are more than
/// `limit.max_ideals`.
///
/// BFS over the ideal lattice: from ideal `L`, every `v ∉ L` whose
/// predecessors are all in `L` yields the successor ideal `L ∪ {v}`.
/// Every ideal is reachable from ∅ this way (peel maximal elements).
/// Results are returned sorted by cardinality then lexicographic word
/// order, which is the iteration order the exact DP wants ("ascending set
/// size", Algorithm 1 line 3).
pub fn enumerate_lower_sets(g: &Graph, limit: EnumerationLimit) -> Option<Vec<NodeSet>> {
    let n = g.len();
    let empty = NodeSet::empty(n);
    let mut seen: HashSet<NodeSet> = HashSet::new();
    seen.insert(empty.clone());
    let mut frontier = vec![empty];
    let mut all: Vec<NodeSet> = Vec::new();
    while let Some(l) = frontier.pop() {
        all.push(l.clone());
        if all.len() > limit.max_ideals {
            return None;
        }
        // Addable nodes: v ∉ L with preds(v) ⊆ L.
        for v in addable(g, &l).iter() {
            let mut next = l.clone();
            next.insert(v);
            if seen.insert(next.clone()) {
                frontier.push(next);
            }
        }
    }
    all.sort_by(|a, b| a.len().cmp(&b.len()).then_with(|| a.words().cmp(b.words())));
    Some(all)
}

/// Nodes that can be appended to the ideal `l` (minimal elements of `V\L`).
pub fn addable(g: &Graph, l: &NodeSet) -> NodeSet {
    let mut out = NodeSet::empty(g.len());
    for v in l.complement().iter() {
        if g.pred_mask(v).is_subset(l) {
            out.insert(v);
        }
    }
    out
}

/// The paper's pruned family `L^Pruned = {L^v | v ∈ V} ∪ {∅}`, where
/// `L^v = {w | v is reachable from w}` (ancestors of `v`, inclusive).
///
/// `#L^Pruned ≤ #V + 1`; duplicates (distinct `v` with identical closures)
/// are collapsed. `V` itself is always included: for a single-sink graph it
/// equals `L^sink`; for multi-sink graphs we add it explicitly so the DP
/// can terminate at `opt[V, ·]`.
pub fn pruned_lower_sets(g: &Graph) -> Vec<NodeSet> {
    let n = g.len();
    let mut seen: HashSet<NodeSet> = HashSet::new();
    seen.insert(NodeSet::empty(n));
    for v in 0..n {
        seen.insert(g.ancestors_closure(NodeId(v)));
    }
    seen.insert(NodeSet::full(n));
    let mut all: Vec<NodeSet> = seen.into_iter().collect();
    all.sort_by(|a, b| a.len().cmp(&b.len()).then_with(|| a.words().cmp(b.words())));
    all
}

#[cfg(test)]
mod tests {
    use super::super::{Graph, Node, OpKind};
    use super::*;

    fn mk(n: u32, edges: &[(u32, u32)]) -> Graph {
        let nodes = (0..n)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 1,
                time: 1,
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        let e: Vec<_> = edges.iter().map(|&(a, b)| (NodeId(a), NodeId(b))).collect();
        Graph::new("t", nodes, &e)
    }

    #[test]
    fn chain_has_n_plus_one_ideals() {
        let g = mk(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let ideals = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap();
        assert_eq!(ideals.len(), 6); // ∅ plus 5 prefixes
        for l in &ideals {
            assert!(g.is_lower_set(l));
        }
    }

    #[test]
    fn antichain_has_2_pow_n_ideals() {
        let g = mk(4, &[]); // 4 isolated nodes
        let ideals = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap();
        assert_eq!(ideals.len(), 16);
    }

    #[test]
    fn respects_limit() {
        let g = mk(10, &[]); // 2^10 ideals
        assert!(enumerate_lower_sets(&g, EnumerationLimit { max_ideals: 100 }).is_none());
    }

    #[test]
    fn sorted_by_size() {
        let g = mk(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let ideals = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap();
        for w in ideals.windows(2) {
            assert!(w[0].len() <= w[1].len());
        }
        assert!(ideals.first().unwrap().is_empty());
        assert_eq!(ideals.last().unwrap().len(), 4);
    }

    #[test]
    fn paper_cardinality_bounds() {
        // #V ≤ #L_G ≤ 2^#V for any graph with at least one node (§2 counts
        // non-empty lower sets; with ∅ included the lower bound still holds).
        for (n, edges) in [
            (5u32, vec![(0u32, 1u32), (1, 2), (2, 3), (3, 4)]),
            (4, vec![(0, 1), (0, 2), (1, 3), (2, 3)]),
            (6, vec![(0, 1), (1, 2), (0, 3), (3, 4), (2, 5), (4, 5)]),
        ] {
            let g = mk(n, &edges);
            let count = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap().len();
            assert!(count >= n as usize);
            assert!(count <= 1 << n);
        }
    }

    #[test]
    fn pruned_family_members_are_lower_sets() {
        let g = mk(6, &[(0, 1), (1, 2), (0, 3), (3, 4), (2, 5), (4, 5)]);
        let pruned = pruned_lower_sets(&g);
        assert!(pruned.len() <= 6 + 2);
        for l in &pruned {
            assert!(g.is_lower_set(l));
        }
        assert!(pruned.iter().any(|l| l.is_empty()));
        assert!(pruned.iter().any(|l| l.len() == 6));
        // Pruned ⊆ full family.
        let all = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap();
        for l in &pruned {
            assert!(all.contains(l));
        }
    }

    #[test]
    fn addable_matches_definition() {
        let g = mk(4, &[(0, 1), (0, 2), (1, 3), (2, 3)]);
        let l = NodeSet::from_iter(4, [NodeId(0)]);
        assert_eq!(addable(&g, &l), NodeSet::from_iter(4, [NodeId(1), NodeId(2)]));
        let l2 = NodeSet::empty(4);
        assert_eq!(addable(&g, &l2), NodeSet::from_iter(4, [NodeId(0)]));
    }
}
