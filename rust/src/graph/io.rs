//! JSON (de)serialization of graphs.
//!
//! A stable interchange format so plans can be computed for graphs produced
//! elsewhere (e.g. exported from a tracing frontend) and so the CLI can
//! load user-supplied graphs: `repro plan --graph mynet.json --budget 2.5`.
//!
//! Format:
//! ```json
//! {
//!   "name": "resnet50",
//!   "nodes": [{"name":"conv1","op":"conv","mem":123,"time":10,
//!              "shape":[64,112,112],"param_bytes":37632}, …],
//!   "edges": [[0,1],[1,2], …]
//! }
//! ```

use crate::anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::{Graph, Node, NodeId, OpKind};

impl Graph {
    /// Serialize to pretty JSON.
    pub fn to_json(&self) -> String {
        let nodes: Vec<Json> = self
            .nodes()
            .map(|(_, n)| {
                Json::obj()
                    .set("name", n.name.as_str().into())
                    .set("op", n.op.as_str().into())
                    .set("mem", n.mem.into())
                    .set("time", n.time.into())
                    .set("shape", n.shape.iter().map(|&d| Json::from(d)).collect::<Vec<_>>().into())
                    .set("param_bytes", n.param_bytes.into())
            })
            .collect();
        let edges: Vec<Json> = self
            .nodes()
            .flat_map(|(v, _)| {
                self.succs(v)
                    .iter()
                    .map(move |w| Json::Arr(vec![v.0.into(), w.0.into()]))
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str().into())
            .set("nodes", Json::Arr(nodes))
            .set("edges", Json::Arr(edges))
            .to_string_pretty()
    }

    /// Parse from JSON produced by [`Graph::to_json`] (or hand-written).
    pub fn from_json(s: &str) -> Result<Graph> {
        let v = Json::parse(s).context("parsing graph JSON")?;
        Graph::from_json_value(&v)
    }

    /// Build from an already-parsed [`Json`] value — the entry point for
    /// callers that embed a graph inside a larger message (the serve
    /// router's `graph_upload` command) and must not re-serialize just to
    /// re-parse.
    pub fn from_json_value(v: &Json) -> Result<Graph> {
        let name = v.get("name").as_str().unwrap_or("unnamed").to_string();
        let nodes_json = v.get("nodes").as_arr().context("graph JSON: missing 'nodes' array")?;
        let mut nodes = Vec::with_capacity(nodes_json.len());
        for (i, nj) in nodes_json.iter().enumerate() {
            let shape = nj
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_u64().map(|x| x as u32))
                .collect::<Option<Vec<u32>>>()
                .with_context(|| format!("node {i}: bad shape"))?;
            nodes.push(Node {
                name: nj
                    .get("name")
                    .as_str()
                    .map(str::to_string)
                    .unwrap_or_else(|| format!("n{i}")),
                op: OpKind::from_str(nj.get("op").as_str().unwrap_or("other")),
                mem: nj.get("mem").as_u64().with_context(|| format!("node {i}: missing mem"))?,
                time: nj
                    .get("time")
                    .as_u64()
                    .with_context(|| format!("node {i}: missing time"))?,
                shape,
                param_bytes: nj.get("param_bytes").as_u64().unwrap_or(0),
            });
        }
        let n = nodes.len() as u32;
        let edges_json = v.get("edges").as_arr().context("graph JSON: missing 'edges' array")?;
        let mut edges = Vec::with_capacity(edges_json.len());
        for (i, ej) in edges_json.iter().enumerate() {
            let pair = ej.as_arr().with_context(|| format!("edge {i}: not a pair"))?;
            if pair.len() != 2 {
                bail!("edge {i}: expected [from,to]");
            }
            let a = pair[0].as_u64().with_context(|| format!("edge {i}: bad endpoint"))? as u32;
            let b = pair[1].as_u64().with_context(|| format!("edge {i}: bad endpoint"))? as u32;
            if a >= n || b >= n {
                bail!("edge ({a},{b}) out of range (graph has {n} nodes)");
            }
            if a == b {
                bail!("self-loop at node {a}");
            }
            edges.push((NodeId(a), NodeId(b)));
        }
        // Cycle check before Graph::new's panic path, to return Err instead.
        let mut indeg = vec![0u32; n as usize];
        let mut succs = vec![Vec::new(); n as usize];
        for &(a, b) in &edges {
            indeg[b.0 as usize] += 1;
            succs[a.0 as usize].push(b);
        }
        let mut ready: Vec<NodeId> =
            (0..n).map(NodeId).filter(|v| indeg[v.0 as usize] == 0).collect();
        let mut seen = 0u32;
        while let Some(v) = ready.pop() {
            seen += 1;
            for &w in &succs[v.0 as usize] {
                indeg[w.0 as usize] -= 1;
                if indeg[w.0 as usize] == 0 {
                    ready.push(w);
                }
            }
        }
        if seen != n {
            bail!("graph JSON contains a cycle");
        }
        Ok(Graph::new(name, nodes, &edges))
    }

    /// Load from a file path.
    pub fn from_json_file(path: &std::path::Path) -> Result<Graph> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading graph file {}", path.display()))?;
        Graph::from_json(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{GraphBuilder, OpKind};
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = GraphBuilder::new("rt", 4);
        let a = b.add("a", OpKind::Conv, &[16, 8, 8], &[]);
        let c = b.add("c", OpKind::Activation, &[16, 8, 8], &[a]);
        let _ = b.add("d", OpKind::Add, &[16, 8, 8], &[a, c]);
        let g = b.build();
        let g2 = Graph::from_json(&g.to_json()).unwrap();
        assert_eq!(g2.len(), g.len());
        assert_eq!(g2.edge_count(), g.edge_count());
        assert_eq!(g2.total_mem(), g.total_mem());
        assert_eq!(g2.topo_order(), g.topo_order());
        assert_eq!(g2.name, "rt");
        assert_eq!(g2.node(a).op, OpKind::Conv);
        assert_eq!(g2.node(a).shape, vec![16, 8, 8]);
    }

    #[test]
    fn rejects_cycle() {
        let json = r#"{
            "name": "bad", "edges": [[0,1],[1,0]],
            "nodes": [
                {"name":"a","op":"other","mem":1,"time":1},
                {"name":"b","op":"other","mem":1,"time":1}
            ]
        }"#;
        assert!(Graph::from_json(json).unwrap_err().to_string().contains("cycle"));
    }

    #[test]
    fn rejects_out_of_range_edge() {
        let json = r#"{
            "name": "bad", "edges": [[0,5]],
            "nodes": [{"name":"a","op":"other","mem":1,"time":1}]
        }"#;
        assert!(Graph::from_json(json).unwrap_err().to_string().contains("out of range"));
    }

    #[test]
    fn op_kind_roundtrip() {
        for op in [
            OpKind::Conv,
            OpKind::Dense,
            OpKind::BatchNorm,
            OpKind::Activation,
            OpKind::Pool,
            OpKind::Add,
            OpKind::Concat,
            OpKind::Upsample,
            OpKind::Dropout,
            OpKind::Softmax,
            OpKind::Other,
        ] {
            assert_eq!(OpKind::from_str(op.as_str()), op);
        }
    }
}
