//! Incremental graph construction DSL.
//!
//! The network zoo ([`crate::models`]) builds each architecture by chaining
//! `add_node` calls; the builder tracks shapes and computes `M_v` from the
//! fp32 tensor volume at a given batch size, and `T_v` from the op kind
//! (conv/dense = 10, everything else = 1, per §3 of the paper).

use super::{Graph, Node, NodeId, OpKind};

/// Bytes per element (the paper's experiments are fp32).
pub const BYTES_PER_ELEM: u64 = 4;

/// Mutable graph-under-construction.
pub struct GraphBuilder {
    name: String,
    batch: u64,
    nodes: Vec<Node>,
    edges: Vec<(NodeId, NodeId)>,
}

impl GraphBuilder {
    /// New builder. `batch` scales every node's activation memory.
    pub fn new(name: impl Into<String>, batch: u64) -> Self {
        GraphBuilder { name: name.into(), batch, nodes: Vec::new(), edges: Vec::new() }
    }

    pub fn batch(&self) -> u64 {
        self.batch
    }

    pub fn len(&self) -> u32 {
        self.nodes.len() as u32
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Output shape of a previously added node.
    pub fn shape(&self, v: NodeId) -> &[u32] {
        &self.nodes[v.0 as usize].shape
    }

    /// Add a node whose output tensor has `shape` (excluding batch), wired
    /// from `inputs`. Memory is `batch · Π shape · 4` bytes; time is the op
    /// default. Returns the new node's id.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        op: OpKind,
        shape: &[u32],
        inputs: &[NodeId],
    ) -> NodeId {
        self.add_with(name, op, shape, inputs, 0)
    }

    /// Like [`Self::add`], with explicit parameter bytes (conv/dense/bn
    /// weights owned by the node).
    pub fn add_with(
        &mut self,
        name: impl Into<String>,
        op: OpKind,
        shape: &[u32],
        inputs: &[NodeId],
        param_bytes: u64,
    ) -> NodeId {
        let elems: u64 = shape.iter().map(|&d| d as u64).product::<u64>().max(1);
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node {
            name: name.into(),
            op,
            mem: self.batch * elems * BYTES_PER_ELEM,
            time: op.default_time_cost(),
            shape: shape.to_vec(),
            param_bytes,
        });
        for &src in inputs {
            assert!(src.0 < id.0, "inputs must precede the node (got {} -> {})", src.0, id.0);
            self.edges.push((src, id));
        }
        id
    }

    /// Add a node with explicit memory/time costs (for synthetic graphs and
    /// tests that want exact numbers rather than shape-derived ones).
    pub fn add_raw(
        &mut self,
        name: impl Into<String>,
        op: OpKind,
        mem: u64,
        time: u64,
        inputs: &[NodeId],
    ) -> NodeId {
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node {
            name: name.into(),
            op,
            mem,
            time,
            shape: vec![],
            param_bytes: 0,
        });
        for &src in inputs {
            self.edges.push((src, id));
        }
        id
    }

    /// Finalize into an immutable [`Graph`]. Panics on cycles (impossible
    /// if only `add*` was used, since inputs must precede nodes).
    pub fn build(self) -> Graph {
        Graph::new(self.name, self.nodes, &self.edges)
    }
}

/// Convolution output spatial size for input `hw`, kernel `k`, stride `s`,
/// padding `p`, dilation `d`.
pub fn conv_out(hw: u32, k: u32, s: u32, p: u32, d: u32) -> u32 {
    let eff = d * (k - 1) + 1;
    (hw + 2 * p - eff) / s + 1
}

/// Conv parameter bytes: `cout·cin·k·k + cout` (weights + bias), fp32.
pub fn conv_params(cin: u32, cout: u32, k: u32) -> u64 {
    (cout as u64 * cin as u64 * (k as u64) * (k as u64) + cout as u64) * BYTES_PER_ELEM
}

/// Dense parameter bytes: `in·out + out`, fp32.
pub fn dense_params(din: u64, dout: u64) -> u64 {
    (din * dout + dout) * BYTES_PER_ELEM
}

/// BatchNorm parameter bytes: 4 vectors of length `c` (γ, β, μ, σ²).
pub fn bn_params(c: u32) -> u64 {
    4 * c as u64 * BYTES_PER_ELEM
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_derived_memory() {
        let mut b = GraphBuilder::new("t", 8);
        let x = b.add("conv", OpKind::Conv, &[64, 56, 56], &[]);
        let g = b.build();
        assert_eq!(g.node(x).mem, 8 * 64 * 56 * 56 * 4);
        assert_eq!(g.node(x).time, 10, "conv costs 10");
    }

    #[test]
    fn non_conv_costs_one() {
        let mut b = GraphBuilder::new("t", 1);
        let c = b.add("c", OpKind::Conv, &[1], &[]);
        let r = b.add("r", OpKind::Activation, &[1], &[c]);
        let p = b.add("p", OpKind::Pool, &[1], &[r]);
        let d = b.add("d", OpKind::Dense, &[1], &[p]);
        let g = b.build();
        assert_eq!(g.node(c).time, 10);
        assert_eq!(g.node(r).time, 1);
        assert_eq!(g.node(p).time, 1);
        assert_eq!(g.node(d).time, 10);
    }

    #[test]
    fn wiring() {
        let mut b = GraphBuilder::new("t", 1);
        let a = b.add("a", OpKind::Conv, &[4], &[]);
        let c = b.add("c", OpKind::Activation, &[4], &[a]);
        let d = b.add("d", OpKind::Add, &[4], &[a, c]);
        let g = b.build();
        assert_eq!(g.preds(d), &[a, c]);
        assert_eq!(g.succs(a), &[c, d]);
        assert_eq!(g.topo_order(), &[a, c, d]);
    }

    #[test]
    fn conv_arith() {
        assert_eq!(conv_out(224, 7, 2, 3, 1), 112); // ResNet stem
        assert_eq!(conv_out(56, 3, 1, 1, 1), 56); // 3x3 same
        assert_eq!(conv_out(56, 1, 1, 0, 1), 56); // 1x1
        assert_eq!(conv_out(112, 3, 2, 1, 1), 56); // stride-2 3x3
        assert_eq!(conv_out(56, 3, 1, 2, 2), 56); // dilated same (PSPNet)
        assert_eq!(conv_params(3, 64, 7), (64 * 3 * 49 + 64) * 4);
    }
}
