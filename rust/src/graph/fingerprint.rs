//! Structural graph fingerprinting.
//!
//! [`Graph::fingerprint`] computes a stable 64-bit hash of a graph's
//! *structure and costs* — topology, per-node `(op, M_v, T_v, params)` —
//! while deliberately ignoring node *labels* (names and storage order).
//! Two isomorphic relabelings of the same network therefore collide,
//! which is exactly what the compiled-plan cache wants: the plan for a
//! graph does not depend on how its nodes happen to be numbered, so a
//! cache keyed by `(fingerprint, request)` can serve a re-traced model
//! whose frontend emitted the nodes in a different order.
//!
//! The hash is a Weisfeiler–Lehman-style color refinement: each node
//! starts from a hash of its local costs, then absorbs the sorted
//! multisets of its predecessors' and successors' hashes for
//! `O(log #V)` rounds, and the fingerprint combines the sorted multiset
//! of final node hashes with the node and edge counts. Sorting at every
//! aggregation point is what makes the result invariant under node
//! permutation. Like any hash it is not an isomorphism *test* — distinct
//! graphs can collide — but the mixing is 64-bit splitmix, so accidental
//! collisions are vanishingly unlikely in practice.

use super::{Graph, NodeSet};

/// Stable structural hash of a [`Graph`] — the cache key component of
/// [`crate::session::PlanSession`].
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct GraphFingerprint(pub u64);

impl std::fmt::Display for GraphFingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// splitmix64 finalizer — full-avalanche 64-bit mixing.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Order-dependent combine (used only over pre-sorted sequences).
fn mix(h: u64, v: u64) -> u64 {
    splitmix(h ^ splitmix(v))
}

impl Graph {
    /// Stable structural fingerprint (see module docs). Deterministic
    /// across runs and processes; invariant under node relabeling and
    /// renaming; sensitive to any edge or cost change.
    pub fn fingerprint(&self) -> GraphFingerprint {
        let n = self.len() as usize;
        if n == 0 {
            return GraphFingerprint(splitmix(0));
        }
        // Round 0: local costs only. Names are labels, not structure.
        let mut h: Vec<u64> = self
            .nodes()
            .map(|(_, node)| {
                let mut x = splitmix(0xc0f1);
                for b in node.op.as_str().bytes() {
                    x = mix(x, b as u64);
                }
                x = mix(x, node.mem);
                x = mix(x, node.time);
                x = mix(x, node.param_bytes);
                x
            })
            .collect();
        // WL refinement: enough rounds to propagate colors across the
        // graph's diameter for typical DAG shapes.
        let rounds = 2 + (usize::BITS - n.leading_zeros()) as usize;
        let mut next = vec![0u64; n];
        let mut neigh: Vec<u64> = Vec::new();
        for _ in 0..rounds {
            for (v, _) in self.nodes() {
                let mut x = mix(h[v.0 as usize], 0x1);
                neigh.clear();
                neigh.extend(self.preds(v).iter().map(|p| h[p.0 as usize]));
                neigh.sort_unstable();
                for &p in &neigh {
                    x = mix(x, p);
                }
                x = mix(x, 0x2);
                neigh.clear();
                neigh.extend(self.succs(v).iter().map(|s| h[s.0 as usize]));
                neigh.sort_unstable();
                for &s in &neigh {
                    x = mix(x, s);
                }
                next[v.0 as usize] = x;
            }
            std::mem::swap(&mut h, &mut next);
        }
        h.sort_unstable();
        let mut out = mix(splitmix(n as u64), self.edge_count() as u64);
        for x in h {
            out = mix(out, x);
        }
        GraphFingerprint(out)
    }

    /// Fingerprint of the sub-DAG induced by `set`, without materializing
    /// it: the same WL refinement restricted to members, with neighbor
    /// multisets intersected with `set` and the node/edge counts taken
    /// within the set. Guaranteed equal to
    /// `induced_subgraph(self, set).0.fingerprint()` — the per-component
    /// plan cache of the decomposed planner keys on this, so editing one
    /// branch of a model invalidates only that branch's components.
    pub fn subgraph_fingerprint(&self, set: &NodeSet) -> GraphFingerprint {
        let members: Vec<_> = set.iter().collect();
        let n = members.len();
        if n == 0 {
            return GraphFingerprint(splitmix(0));
        }
        let cap = self.len() as usize;
        let mut h: Vec<u64> = vec![0; cap];
        let mut internal_edges = 0usize;
        for &v in &members {
            let node = self.node(v);
            let mut x = splitmix(0xc0f1);
            for b in node.op.as_str().bytes() {
                x = mix(x, b as u64);
            }
            x = mix(x, node.mem);
            x = mix(x, node.time);
            x = mix(x, node.param_bytes);
            h[v.0 as usize] = x;
            internal_edges += self.succs(v).iter().filter(|s| set.contains(**s)).count();
        }
        let rounds = 2 + (usize::BITS - n.leading_zeros()) as usize;
        let mut next = vec![0u64; cap];
        let mut neigh: Vec<u64> = Vec::new();
        for _ in 0..rounds {
            for &v in &members {
                let mut x = mix(h[v.0 as usize], 0x1);
                neigh.clear();
                neigh.extend(
                    self.preds(v).iter().filter(|p| set.contains(**p)).map(|p| h[p.0 as usize]),
                );
                neigh.sort_unstable();
                for &p in &neigh {
                    x = mix(x, p);
                }
                x = mix(x, 0x2);
                neigh.clear();
                neigh.extend(
                    self.succs(v).iter().filter(|s| set.contains(**s)).map(|s| h[s.0 as usize]),
                );
                neigh.sort_unstable();
                for &s in &neigh {
                    x = mix(x, s);
                }
                next[v.0 as usize] = x;
            }
            std::mem::swap(&mut h, &mut next);
        }
        let mut finals: Vec<u64> = members.iter().map(|v| h[v.0 as usize]).collect();
        finals.sort_unstable();
        let mut out = mix(splitmix(n as u64), internal_edges as u64);
        for x in finals {
            out = mix(out, x);
        }
        GraphFingerprint(out)
    }
}

#[cfg(test)]
mod tests {
    use crate::testutil::{diamond, diamond_relabeled, diamond_with_mems, diamond_with_skip};

    #[test]
    fn deterministic_and_name_insensitive() {
        let a = diamond();
        assert_eq!(a.fingerprint(), a.fingerprint());
        // diamond_with_mems names its nodes differently (m{i} vs n{i}).
        assert_eq!(
            a.fingerprint(),
            diamond_with_mems([10, 20, 30, 40]).fingerprint(),
            "names must not matter"
        );
    }

    #[test]
    fn relabeling_collides_edge_addition_does_not() {
        let base = diamond();
        assert_eq!(base.fingerprint(), diamond_relabeled().fingerprint());
        assert_ne!(base.fingerprint(), diamond_with_skip().fingerprint());
    }

    #[test]
    fn cost_changes_change_the_fingerprint() {
        assert_ne!(
            diamond().fingerprint(),
            diamond_with_mems([10, 20, 31, 40]).fingerprint()
        );
    }

    #[test]
    fn subgraph_fingerprint_equals_materialized_induced_graph() {
        use crate::graph::{induced_subgraph, NodeSet};
        use crate::testutil::random_dag;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(0x5f9);
        for _ in 0..12 {
            let n = rng.range(3, 12);
            let g = random_dag(&mut rng, n);
            // Random member subset (keep at least one node).
            let mut set = NodeSet::empty(g.len());
            for (v, _) in g.nodes() {
                if rng.next_u64() % 3 != 0 {
                    set.insert(v);
                }
            }
            if set.is_empty() {
                set.insert(crate::graph::NodeId(0));
            }
            let (sub, _) = induced_subgraph(&g, &set);
            assert_eq!(g.subgraph_fingerprint(&set), sub.fingerprint());
        }
    }

    #[test]
    fn subgraph_fingerprint_full_set_matches_whole_graph() {
        use crate::graph::NodeSet;
        let g = diamond();
        assert_eq!(g.subgraph_fingerprint(&NodeSet::full(g.len())), g.fingerprint());
    }
}
