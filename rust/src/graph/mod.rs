//! Computation-graph substrate.
//!
//! The paper models a network as a DAG `G = (V, E)` over *intermediate*
//! variables (inputs and parameters excluded), with a forward-compute cost
//! `T_v > 0` and a memory cost `M_v > 0` per node. Everything the planners
//! need — neighborhoods `δ±(S)`, lower sets `L ≺ V`, boundaries `∂(L)`,
//! reachability closures, lower-set enumeration, articulation points — is
//! implemented here on top of [`NodeSet`] bitsets.

mod articulation;
pub mod builder;
mod decompose;
mod fingerprint;
mod io;
mod lowerset;
mod nodeset;
mod topo;

pub use articulation::articulation_points;
pub use builder::GraphBuilder;
pub use decompose::{block_cut_tree, decompose, induced_subgraph, BlockCutTree, Decomposition};
pub use fingerprint::GraphFingerprint;
pub use lowerset::{addable, enumerate_lower_sets, pruned_lower_sets, EnumerationLimit};
pub use nodeset::NodeSet;
pub use topo::{is_acyclic, topological_order};

/// Index of a node in its [`Graph`]. Dense, `0..graph.len()`.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct NodeId(pub u32);

/// Operator kind, used for cost assignment and for the execution engine's
/// artifact dispatch. The planner itself only reads `time`/`mem`.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OpKind {
    /// Convolution (the paper assigns these `T_v = 10`).
    Conv,
    /// Fully-connected / matmul (treated as conv-weight compute, `T_v = 10`).
    Dense,
    /// Batch normalization.
    BatchNorm,
    /// Elementwise activation (ReLU/GELU/…).
    Activation,
    /// Pooling (max/avg).
    Pool,
    /// Elementwise add (residual join).
    Add,
    /// Channel concatenation (DenseNet/U-Net/GoogLeNet joins).
    Concat,
    /// Upsampling / transposed conv.
    Upsample,
    /// Dropout.
    Dropout,
    /// Softmax / loss head.
    Softmax,
    /// Anything else.
    Other,
}

impl OpKind {
    /// The paper's relative forward-compute cost: conv-like nodes are 10,
    /// everything else 1 (§3, last paragraph).
    pub fn default_time_cost(self) -> u64 {
        match self {
            OpKind::Conv | OpKind::Dense => 10,
            _ => 1,
        }
    }
}

impl OpKind {
    /// Stable string name used in the JSON interchange format.
    pub fn as_str(self) -> &'static str {
        match self {
            OpKind::Conv => "conv",
            OpKind::Dense => "dense",
            OpKind::BatchNorm => "batch_norm",
            OpKind::Activation => "activation",
            OpKind::Pool => "pool",
            OpKind::Add => "add",
            OpKind::Concat => "concat",
            OpKind::Upsample => "upsample",
            OpKind::Dropout => "dropout",
            OpKind::Softmax => "softmax",
            OpKind::Other => "other",
        }
    }

    /// Inverse of [`OpKind::as_str`]; unknown names map to `Other`.
    pub fn from_str(s: &str) -> OpKind {
        match s {
            "conv" => OpKind::Conv,
            "dense" => OpKind::Dense,
            "batch_norm" => OpKind::BatchNorm,
            "activation" => OpKind::Activation,
            "pool" => OpKind::Pool,
            "add" => OpKind::Add,
            "concat" => OpKind::Concat,
            "upsample" => OpKind::Upsample,
            "dropout" => OpKind::Dropout,
            "softmax" => OpKind::Softmax,
            _ => OpKind::Other,
        }
    }
}

/// One intermediate variable of the network.
#[derive(Clone, Debug)]
pub struct Node {
    /// Human-readable name (`conv2_3/bn`, `layer4/add`, …).
    pub name: String,
    /// Operator kind.
    pub op: OpKind,
    /// Memory cost `M_v` in bytes of the node's output.
    pub mem: u64,
    /// Forward compute cost `T_v` (relative units).
    pub time: u64,
    /// Output tensor shape excluding batch (for diagnostics / the executor).
    pub shape: Vec<u32>,
    /// Bytes of trainable parameters owned by this node (conv/dense/bn
    /// weights). Not part of `M_v`; reported separately like the paper's
    /// Table 1 which *includes* parameter memory in the totals.
    pub param_bytes: u64,
}

/// Immutable computation DAG with per-node costs and bitset adjacency.
///
/// Edges `(v, w)` mean "`v` is directly required to compute `w`".
#[derive(Clone, Debug)]
pub struct Graph {
    nodes: Vec<Node>,
    preds: Vec<Vec<NodeId>>,
    succs: Vec<Vec<NodeId>>,
    pred_mask: Vec<NodeSet>,
    succ_mask: Vec<NodeSet>,
    topo: Vec<NodeId>,
    /// Optional model-level name for reports.
    pub name: String,
}

impl Graph {
    /// Construct from nodes and an edge list. Panics if the edge list has
    /// out-of-range endpoints or the graph is cyclic — graphs here are
    /// always built by [`GraphBuilder`] or deserialized from trusted JSON.
    pub fn new(name: impl Into<String>, nodes: Vec<Node>, edges: &[(NodeId, NodeId)]) -> Self {
        let n = nodes.len() as u32;
        let mut preds = vec![Vec::new(); n as usize];
        let mut succs = vec![Vec::new(); n as usize];
        let mut pred_mask = vec![NodeSet::empty(n); n as usize];
        let mut succ_mask = vec![NodeSet::empty(n); n as usize];
        for &(v, w) in edges {
            assert!(v.0 < n && w.0 < n, "edge ({},{}) out of range", v.0, w.0);
            assert_ne!(v, w, "self loop at {}", v.0);
            if !pred_mask[w.0 as usize].contains(v) {
                preds[w.0 as usize].push(v);
                succs[v.0 as usize].push(w);
                pred_mask[w.0 as usize].insert(v);
                succ_mask[v.0 as usize].insert(w);
            }
        }
        let mut g = Graph {
            nodes,
            preds,
            succs,
            pred_mask,
            succ_mask,
            topo: Vec::new(),
            name: name.into(),
        };
        g.topo = topological_order(&g).expect("graph must be acyclic");
        g
    }

    /// Number of nodes `#V`.
    #[inline]
    pub fn len(&self) -> u32 {
        self.nodes.len() as u32
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    #[inline]
    pub fn node(&self, v: NodeId) -> &Node {
        &self.nodes[v.0 as usize]
    }

    #[inline]
    pub fn nodes(&self) -> impl Iterator<Item = (NodeId, &Node)> {
        self.nodes.iter().enumerate().map(|(i, n)| (NodeId(i as u32), n))
    }

    #[inline]
    pub fn preds(&self, v: NodeId) -> &[NodeId] {
        &self.preds[v.0 as usize]
    }

    #[inline]
    pub fn succs(&self, v: NodeId) -> &[NodeId] {
        &self.succs[v.0 as usize]
    }

    #[inline]
    pub fn pred_mask(&self, v: NodeId) -> &NodeSet {
        &self.pred_mask[v.0 as usize]
    }

    #[inline]
    pub fn succ_mask(&self, v: NodeId) -> &NodeSet {
        &self.succ_mask[v.0 as usize]
    }

    /// A cached topological order of all nodes.
    #[inline]
    pub fn topo_order(&self) -> &[NodeId] {
        &self.topo
    }

    /// Total edge count.
    pub fn edge_count(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    /// `M(S) = Σ_{v∈S} M_v` in bytes.
    pub fn mem_of(&self, s: &NodeSet) -> u64 {
        s.iter().map(|v| self.node(v).mem).sum()
    }

    /// `T(S) = Σ_{v∈S} T_v`.
    pub fn time_of(&self, s: &NodeSet) -> u64 {
        s.iter().map(|v| self.node(v).time).sum()
    }

    /// `T(V)` — one full forward pass.
    pub fn total_time(&self) -> u64 {
        self.nodes.iter().map(|n| n.time).sum()
    }

    /// `M(V)` in bytes.
    pub fn total_mem(&self) -> u64 {
        self.nodes.iter().map(|n| n.mem).sum()
    }

    /// Total parameter bytes (weights), reported alongside activations.
    pub fn total_param_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.param_bytes).sum()
    }

    /// `δ+(S)`: nodes with an incoming edge from `S` (may intersect `S`).
    pub fn delta_plus(&self, s: &NodeSet) -> NodeSet {
        let mut out = NodeSet::empty(self.len());
        for v in s.iter() {
            out.union_with(&self.succ_mask[v.0 as usize]);
        }
        out
    }

    /// `δ−(S)`: nodes with an outgoing edge into `S` (may intersect `S`).
    pub fn delta_minus(&self, s: &NodeSet) -> NodeSet {
        let mut out = NodeSet::empty(self.len());
        for v in s.iter() {
            out.union_with(&self.pred_mask[v.0 as usize]);
        }
        out
    }

    /// Is `L` a lower set, i.e. no edge from `V \ L` into `L`
    /// (equivalently `δ−(L) ⊆ L`)?
    pub fn is_lower_set(&self, l: &NodeSet) -> bool {
        l.iter().all(|v| self.pred_mask[v.0 as usize].is_subset(l))
    }

    /// Boundary `∂(L) = δ−(V\L) ∩ L`: members of `L` with a successor
    /// outside `L`. (Only meaningful when `L` is a lower set, but defined
    /// for any set.)
    pub fn boundary(&self, l: &NodeSet) -> NodeSet {
        let mut out = NodeSet::empty(self.len());
        for v in l.iter() {
            if !self.succ_mask[v.0 as usize].is_subset(l) {
                out.insert(v);
            }
        }
        out
    }

    /// `δ+(L) \ L` — the forward frontier outside `L` (term (iii) of Eq. 2).
    pub fn frontier(&self, l: &NodeSet) -> NodeSet {
        let mut f = self.delta_plus(l);
        f.subtract(l);
        f
    }

    /// `δ−(δ+(L)) \ L` — co-inputs of the frontier (term (iv) of Eq. 2).
    pub fn frontier_coinputs(&self, l: &NodeSet) -> NodeSet {
        let mut c = self.delta_minus(&self.delta_plus(l));
        c.subtract(l);
        c
    }

    /// All nodes from which `v` is reachable, *including* `v` — the paper's
    /// `L^v = {w | v reachable from w}`, always a lower set.
    pub fn ancestors_closure(&self, v: NodeId) -> NodeSet {
        let mut seen = NodeSet::empty(self.len());
        let mut stack = vec![v];
        seen.insert(v);
        while let Some(u) = stack.pop() {
            for &p in self.preds(u) {
                if !seen.contains(p) {
                    seen.insert(p);
                    stack.push(p);
                }
            }
        }
        seen
    }

    /// All nodes reachable from `v`, including `v`.
    pub fn descendants_closure(&self, v: NodeId) -> NodeSet {
        let mut seen = NodeSet::empty(self.len());
        let mut stack = vec![v];
        seen.insert(v);
        while let Some(u) = stack.pop() {
            for &s in self.succs(u) {
                if !seen.contains(s) {
                    seen.insert(s);
                    stack.push(s);
                }
            }
        }
        seen
    }

    /// Source nodes (no predecessors among intermediates).
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len()).map(NodeId).filter(|&v| self.preds(v).is_empty()).collect()
    }

    /// Sink nodes (no successors).
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len()).map(NodeId).filter(|&v| self.succs(v).is_empty()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::diamond;

    #[test]
    fn basic_shape() {
        let g = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.sources(), vec![NodeId(0)]);
        assert_eq!(g.sinks(), vec![NodeId(3)]);
        assert_eq!(g.total_mem(), 10 + 20 + 30 + 40);
        assert_eq!(g.total_time(), 4);
    }

    #[test]
    fn delta_and_boundary() {
        let g = diamond();
        let l = NodeSet::from_iter(4, [NodeId(0), NodeId(1)]);
        assert!(g.is_lower_set(&l));
        // δ+({0,1}) = {1,2,3}
        let dp = g.delta_plus(&l);
        assert_eq!(dp, NodeSet::from_iter(4, [NodeId(1), NodeId(2), NodeId(3)]));
        // frontier = {2,3}
        assert_eq!(g.frontier(&l), NodeSet::from_iter(4, [NodeId(2), NodeId(3)]));
        // ∂({0,1}): 0 has succ 2 outside, 1 has succ 3 outside ⇒ both.
        assert_eq!(g.boundary(&l), l);
        // {1} is not a lower set (pred 0 missing).
        let not_l = NodeSet::from_iter(4, [NodeId(1)]);
        assert!(!g.is_lower_set(&not_l));
    }

    #[test]
    fn frontier_coinputs_matches_paper_term() {
        let g = diamond();
        let l = NodeSet::from_iter(4, [NodeId(0), NodeId(1)]);
        // δ+(L) = {1,2,3}; δ−({1,2,3}) = {0,1,2}; minus L = {2}.
        assert_eq!(g.frontier_coinputs(&l), NodeSet::from_iter(4, [NodeId(2)]));
    }

    #[test]
    fn closures() {
        let g = diamond();
        assert_eq!(
            g.ancestors_closure(NodeId(3)),
            NodeSet::full(4),
            "everything reaches the sink"
        );
        assert_eq!(
            g.ancestors_closure(NodeId(1)),
            NodeSet::from_iter(4, [NodeId(0), NodeId(1)])
        );
        assert_eq!(
            g.descendants_closure(NodeId(1)),
            NodeSet::from_iter(4, [NodeId(1), NodeId(3)])
        );
        assert!(g.is_lower_set(&g.ancestors_closure(NodeId(2))));
    }

    #[test]
    fn lower_set_count_bounds() {
        // #V ≤ #L_G ≤ 2^#V (§2). For the diamond: ∅,{0},{0,1},{0,2},{0,1,2},V = 6.
        let g = diamond();
        let ideals =
            enumerate_lower_sets(&g, EnumerationLimit::default()).expect("small graph");
        assert_eq!(ideals.len(), 6);
        for l in &ideals {
            assert!(g.is_lower_set(l));
        }
    }

    #[test]
    #[should_panic(expected = "acyclic")]
    fn rejects_cycles() {
        let nodes = (0..2)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 1,
                time: 1,
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        Graph::new("cyc", nodes, &[(NodeId(0), NodeId(1)), (NodeId(1), NodeId(0))]);
    }

    #[test]
    fn duplicate_edges_collapse() {
        let nodes = (0..2)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 1,
                time: 1,
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        let g = Graph::new("dup", nodes, &[(NodeId(0), NodeId(1)), (NodeId(0), NodeId(1))]);
        assert_eq!(g.edge_count(), 1);
    }
}
