//! Topological ordering and acyclicity checking (Kahn's algorithm).

use super::{Graph, NodeId};

/// Kahn's algorithm. Returns `None` if the graph has a cycle.
///
/// Ties are broken by node id, so the order is deterministic — the
/// simulator and the executor both rely on a stable order for reproducible
/// traces.
pub fn topological_order(g: &Graph) -> Option<Vec<NodeId>> {
    let n = g.len() as usize;
    let mut indeg: Vec<u32> = (0..n).map(|v| g.preds(NodeId(v as u32)).len() as u32).collect();
    // Binary-heap-free deterministic variant: scan a sorted ready list.
    let mut ready: Vec<NodeId> =
        (0..n as u32).map(NodeId).filter(|&v| indeg[v.0 as usize] == 0).collect();
    ready.sort_unstable_by(|a, b| b.cmp(a)); // pop smallest from the back
    let mut order = Vec::with_capacity(n);
    while let Some(v) = ready.pop() {
        order.push(v);
        let mut newly = Vec::new();
        for &w in g.succs(v) {
            indeg[w.0 as usize] -= 1;
            if indeg[w.0 as usize] == 0 {
                newly.push(w);
            }
        }
        // Keep `ready` sorted descending so pop() yields the smallest id.
        for w in newly {
            let pos = ready.partition_point(|x| x.0 > w.0);
            ready.insert(pos, w);
        }
    }
    if order.len() == n {
        Some(order)
    } else {
        None
    }
}

/// Convenience predicate.
pub fn is_acyclic(g: &Graph) -> bool {
    topological_order(g).is_some()
}

#[cfg(test)]
mod tests {
    use super::super::{Graph, Node, NodeId, NodeSet, OpKind};

    fn mk(n: u32, edges: &[(u32, u32)]) -> Graph {
        let nodes = (0..n)
            .map(|i| Node {
                name: format!("n{i}"),
                op: OpKind::Other,
                mem: 1,
                time: 1,
                shape: vec![],
                param_bytes: 0,
            })
            .collect();
        let e: Vec<_> = edges.iter().map(|&(a, b)| (NodeId(a), NodeId(b))).collect();
        Graph::new("t", nodes, &e)
    }

    #[test]
    fn chain_order() {
        let g = mk(4, &[(0, 1), (1, 2), (2, 3)]);
        assert_eq!(g.topo_order(), &[NodeId(0), NodeId(1), NodeId(2), NodeId(3)]);
    }

    #[test]
    fn order_respects_edges_and_is_deterministic() {
        let g = mk(6, &[(0, 2), (1, 2), (2, 3), (2, 4), (3, 5), (4, 5)]);
        let order = g.topo_order();
        let pos: Vec<usize> =
            (0..6).map(|v| order.iter().position(|&x| x.0 == v).unwrap()).collect();
        for (v, n) in g.nodes() {
            for &w in g.succs(v) {
                assert!(pos[v.0 as usize] < pos[w.0 as usize], "{:?}", n.name);
            }
        }
        // Deterministic: same graph twice gives same order.
        let g2 = mk(6, &[(0, 2), (1, 2), (2, 3), (2, 4), (3, 5), (4, 5)]);
        assert_eq!(g.topo_order(), g2.topo_order());
        // Smallest-id tiebreak: 0 before 1, 3 before 4.
        assert!(pos[0] < pos[1]);
        assert!(pos[3] < pos[4]);
    }

    #[test]
    fn every_topo_prefix_is_a_lower_set() {
        let g = mk(7, &[(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (2, 5), (5, 6), (4, 6)]);
        let mut prefix = NodeSet::empty(7);
        for &v in g.topo_order() {
            prefix.insert(v);
            assert!(g.is_lower_set(&prefix));
        }
    }
}
