//! Fixed-width bitset over graph nodes.
//!
//! All set algebra in the planners (δ±, boundaries, lower-set transitions)
//! runs on these bitsets; for the network zoo (`#V ≤ 1024`) every operation
//! is a handful of word-wise instructions. Width is fixed per graph, so two
//! sets from the same graph always have the same number of words.

use std::fmt;
use std::hash::{Hash, Hasher};

use super::NodeId;

/// A set of nodes of one particular [`super::Graph`], stored as a bitset.
///
/// Invariant: `words.len() == words_for(capacity)` and bits at positions
/// `>= capacity` are always zero (operations re-normalize the tail word).
#[derive(Clone, PartialEq, Eq)]
pub struct NodeSet {
    words: Vec<u64>,
    capacity: u32,
}

#[inline]
fn words_for(capacity: u32) -> usize {
    ((capacity as usize) + 63) / 64
}

impl NodeSet {
    /// The empty set over a universe of `capacity` nodes.
    pub fn empty(capacity: u32) -> Self {
        NodeSet { words: vec![0; words_for(capacity)], capacity }
    }

    /// The full set `{0, …, capacity-1}`.
    pub fn full(capacity: u32) -> Self {
        let mut s = NodeSet { words: vec![!0u64; words_for(capacity)], capacity };
        s.normalize();
        s
    }

    /// Build a set from an iterator of node ids.
    pub fn from_iter<I: IntoIterator<Item = NodeId>>(capacity: u32, iter: I) -> Self {
        let mut s = Self::empty(capacity);
        for v in iter {
            s.insert(v);
        }
        s
    }

    /// Number of nodes in the universe (not in the set).
    #[inline]
    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Zero out any bits beyond `capacity`.
    #[inline]
    fn normalize(&mut self) {
        let rem = (self.capacity as usize) % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    #[inline]
    pub fn insert(&mut self, v: NodeId) {
        debug_assert!(v.0 < self.capacity);
        self.words[(v.0 / 64) as usize] |= 1u64 << (v.0 % 64);
    }

    #[inline]
    pub fn remove(&mut self, v: NodeId) {
        debug_assert!(v.0 < self.capacity);
        self.words[(v.0 / 64) as usize] &= !(1u64 << (v.0 % 64));
    }

    #[inline]
    pub fn contains(&self, v: NodeId) -> bool {
        debug_assert!(v.0 < self.capacity);
        self.words[(v.0 / 64) as usize] & (1u64 << (v.0 % 64)) != 0
    }

    /// Cardinality.
    #[inline]
    pub fn len(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// `self ⊆ other`.
    #[inline]
    pub fn is_subset(&self, other: &NodeSet) -> bool {
        debug_assert_eq!(self.capacity, other.capacity);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }

    /// `self ⊊ other`.
    #[inline]
    pub fn is_strict_subset(&self, other: &NodeSet) -> bool {
        self.is_subset(other) && self != other
    }

    /// `self ∩ other == ∅`.
    #[inline]
    pub fn is_disjoint(&self, other: &NodeSet) -> bool {
        debug_assert_eq!(self.capacity, other.capacity);
        self.words.iter().zip(&other.words).all(|(a, b)| a & b == 0)
    }

    /// In-place union.
    #[inline]
    pub fn union_with(&mut self, other: &NodeSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// In-place intersection.
    #[inline]
    pub fn intersect_with(&mut self, other: &NodeSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// In-place difference (`self \ other`).
    #[inline]
    pub fn subtract(&mut self, other: &NodeSet) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// `self ∪ other` as a new set.
    #[inline]
    pub fn union(&self, other: &NodeSet) -> NodeSet {
        let mut s = self.clone();
        s.union_with(other);
        s
    }

    /// `self ∩ other` as a new set.
    #[inline]
    pub fn intersection(&self, other: &NodeSet) -> NodeSet {
        let mut s = self.clone();
        s.intersect_with(other);
        s
    }

    /// `self \ other` as a new set.
    #[inline]
    pub fn difference(&self, other: &NodeSet) -> NodeSet {
        let mut s = self.clone();
        s.subtract(other);
        s
    }

    /// Complement within the universe.
    #[inline]
    pub fn complement(&self) -> NodeSet {
        let mut s = NodeSet {
            words: self.words.iter().map(|w| !w).collect(),
            capacity: self.capacity,
        };
        s.normalize();
        s
    }

    /// Iterate over members in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = NodeId> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut w = w;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let bit = w.trailing_zeros();
                    w &= w - 1;
                    Some(NodeId(wi as u32 * 64 + bit))
                }
            })
        })
    }

    /// Raw words — used by the ideal interner for hashing.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

impl Hash for NodeSet {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.words.hash(state);
    }
}

impl fmt::Debug for NodeSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, v) in self.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{}", v.0)?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(v: &[u32]) -> Vec<NodeId> {
        v.iter().map(|&i| NodeId(i)).collect()
    }

    #[test]
    fn empty_and_full() {
        let e = NodeSet::empty(70);
        assert!(e.is_empty());
        assert_eq!(e.len(), 0);
        let f = NodeSet::full(70);
        assert_eq!(f.len(), 70);
        assert!(e.is_subset(&f));
        assert!(!f.is_subset(&e));
        assert_eq!(f.complement(), e);
        assert_eq!(e.complement(), f);
    }

    #[test]
    fn insert_remove_contains() {
        let mut s = NodeSet::empty(130);
        for i in [0u32, 63, 64, 65, 127, 128, 129] {
            assert!(!s.contains(NodeId(i)));
            s.insert(NodeId(i));
            assert!(s.contains(NodeId(i)));
        }
        assert_eq!(s.len(), 7);
        s.remove(NodeId(64));
        assert!(!s.contains(NodeId(64)));
        assert_eq!(s.len(), 6);
    }

    #[test]
    fn set_algebra() {
        let a = NodeSet::from_iter(10, ids(&[1, 2, 3, 4]));
        let b = NodeSet::from_iter(10, ids(&[3, 4, 5, 6]));
        assert_eq!(a.union(&b), NodeSet::from_iter(10, ids(&[1, 2, 3, 4, 5, 6])));
        assert_eq!(a.intersection(&b), NodeSet::from_iter(10, ids(&[3, 4])));
        assert_eq!(a.difference(&b), NodeSet::from_iter(10, ids(&[1, 2])));
        assert!(!a.is_disjoint(&b));
        assert!(a.difference(&b).is_disjoint(&b));
    }

    #[test]
    fn subset_relations() {
        let a = NodeSet::from_iter(10, ids(&[1, 2]));
        let b = NodeSet::from_iter(10, ids(&[1, 2, 3]));
        assert!(a.is_subset(&b));
        assert!(a.is_strict_subset(&b));
        assert!(b.is_subset(&b));
        assert!(!b.is_strict_subset(&b));
        assert!(!b.is_subset(&a));
    }

    #[test]
    fn iter_ascending() {
        let s = NodeSet::from_iter(200, ids(&[199, 0, 64, 100]));
        let got: Vec<u32> = s.iter().map(|v| v.0).collect();
        assert_eq!(got, vec![0, 64, 100, 199]);
    }

    #[test]
    fn complement_normalizes_tail() {
        // capacity not a multiple of 64: complement must not set ghost bits.
        let s = NodeSet::empty(65);
        let c = s.complement();
        assert_eq!(c.len(), 65);
        assert_eq!(c.words()[1], 1); // only bit 64 set in the tail word
    }
}
