//! Chen et al. (2016) √n checkpointing — the paper's baseline.
//!
//! "Training deep nets with sublinear memory cost" divides the network
//! into segments, caches segment boundaries during the forward pass, and
//! recomputes each segment during backward. The NeurIPS-2019 paper's
//! Appendix B pins down the two under-specified pieces for general graphs,
//! which we follow exactly:
//!
//! - topological order obtained by DFS on the computation graph;
//! - candidate stage splitting points `C` = the *articulation points* of
//!   the (undirected skeleton of the) computation graph — the nodes whose
//!   removal disconnects it.
//!
//! Given a per-segment budget `b`, Chen's "memory planning with budget"
//! packs nodes into the current segment until its temporary size exceeds
//! `b`, then cuts at the next candidate point. The overall algorithm
//! sweeps `b` (Chen uses a grid/doubling search) and keeps the plan with
//! the lowest total memory. Every topological prefix is a lower set, so
//! each Chen plan is a [`LowerSetChain`] and is evaluated by the very same
//! simulator as ours — exactly how the paper compares against it.

use crate::anyhow::{anyhow, Result};

use crate::graph::{articulation_points, Graph, NodeSet};

use super::strategy::LowerSetChain;

/// A Chen plan: the chain plus the per-segment budget that produced it.
pub struct ChenPlan {
    pub chain: LowerSetChain,
    /// The per-segment temporary-memory budget `b` that won the sweep.
    pub segment_budget: u64,
}

/// Build the segmentation for a fixed per-segment budget `b`.
///
/// Walks the topological order accumulating the running segment's memory;
/// once it exceeds `b` the segment is closed at the next articulation
/// point (splitting elsewhere would sever a skip connection — Chen's
/// heuristic only cuts where the graph is 1-connected).
///
/// Computes the articulation set on every call; when sweeping budgets
/// (or when the session already has the set cached), use
/// [`chen_segmentation_with`] instead.
pub fn chen_segmentation(g: &Graph, b: u64) -> LowerSetChain {
    let arts: NodeSet = {
        let mut s = NodeSet::empty(g.len());
        for v in articulation_points(g) {
            s.insert(v);
        }
        s
    };
    chen_segmentation_with(g, &arts, b)
}

/// [`chen_segmentation`] with a precomputed articulation set — the shared
/// decomposition of the skeleton. The budget sweep in [`chen_plan_with`]
/// and the session-cached set both route through here so the Tarjan pass
/// runs once per graph, not once per candidate budget.
pub fn chen_segmentation_with(g: &Graph, arts: &NodeSet, b: u64) -> LowerSetChain {
    let topo = g.topo_order();
    let mut chain: Vec<NodeSet> = Vec::new();
    let mut cur = NodeSet::empty(g.len()); // cumulative lower set
    let mut seg_mem = 0u64;
    let mut want_cut = false;
    for (idx, &v) in topo.iter().enumerate() {
        cur.insert(v);
        seg_mem += g.node(v).mem;
        if seg_mem > b {
            want_cut = true;
        }
        let last = idx + 1 == topo.len();
        // Cut at articulation points once over budget (and always at the end).
        if last || (want_cut && arts.contains(v)) {
            chain.push(cur.clone());
            seg_mem = 0;
            want_cut = false;
        }
    }
    LowerSetChain::new_unchecked(g, chain)
}

/// Sweep per-segment budgets and return the plan minimizing the measured
/// peak (per `score`, typically the liveness-aware simulator). The sweep
/// is geometric from the largest single node to `M(V)`, which covers the
/// √n sweet spot Chen's analysis targets.
///
/// Computes the articulation set once up front and hands it to
/// [`chen_plan_with`]; callers that already hold the set (the session,
/// the decomposed planner) should call that directly.
pub fn chen_plan<F>(g: &Graph, score: F) -> Result<ChenPlan>
where
    F: FnMut(&LowerSetChain) -> u64,
{
    let arts: NodeSet = {
        let mut s = NodeSet::empty(g.len());
        for v in articulation_points(g) {
            s.insert(v);
        }
        s
    };
    chen_plan_with(g, &arts, score)
}

/// [`chen_plan`] with a precomputed articulation set. The sweep tries
/// ~`log₁.₃(M(V))` budgets; sharing one Tarjan pass across all of them
/// (and with whatever else the session runs) is the point of the split.
pub fn chen_plan_with<F>(g: &Graph, arts: &NodeSet, mut score: F) -> Result<ChenPlan>
where
    F: FnMut(&LowerSetChain) -> u64,
{
    let max_node = g.nodes().map(|(_, n)| n.mem).max().unwrap_or(1);
    let total = g.total_mem();
    if total == 0 {
        return Err(anyhow!("empty graph"));
    }
    let mut budgets: Vec<u64> = Vec::new();
    let mut b = max_node.max(1);
    while b < total {
        budgets.push(b);
        // 1.3× geometric steps: fine enough to find the knee, coarse
        // enough to keep the sweep cheap.
        b = (b as f64 * 1.3) as u64 + 1;
    }
    budgets.push(total);
    let mut best: Option<(u64, u64, LowerSetChain)> = None;
    for b in budgets {
        let chain = chen_segmentation_with(g, arts, b);
        let peak = score(&chain);
        if best.as_ref().map(|(p, _, _)| peak < *p).unwrap_or(true) {
            best = Some((peak, b, chain));
        }
    }
    let (_, segment_budget, chain) = best.unwrap();
    Ok(ChenPlan { chain, segment_budget })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, NodeId, OpKind};

    fn chain_graph(n: u32, mem: u64) -> Graph {
        let mut b = GraphBuilder::new("chain", 1);
        let mut prev: Option<NodeId> = None;
        for i in 0..n {
            let inputs: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, mem, 1, &inputs));
        }
        b.build()
    }

    #[test]
    fn segmentation_is_valid_chain() {
        let g = chain_graph(16, 10);
        for b in [10u64, 40, 80, 160] {
            let c = chen_segmentation(&g, b);
            assert_eq!(c.lower_sets().last().unwrap().len(), 16);
            for l in c.lower_sets() {
                assert!(g.is_lower_set(l));
            }
        }
    }

    #[test]
    fn sqrt_n_segments_on_uniform_chain() {
        // 16 nodes of mem 10, budget 40 ⇒ segments of 4-5 nodes ⇒ 4 cuts.
        let g = chain_graph(16, 10);
        let c = chen_segmentation(&g, 40);
        assert!(c.k() >= 3 && c.k() <= 5, "k={}", c.k());
    }

    #[test]
    fn skip_connections_prevent_cuts() {
        // Residual-style graph: skips 0→3, 3→6 guard the interiors; only
        // nodes 3 and 6 are articulation points... build 0→1→2→3→4→5→6 with
        // skips 0→3 and 3→6: cuts can only happen at 3 and 6.
        let mut b = GraphBuilder::new("res", 1);
        let mut ids = Vec::new();
        for i in 0..7u32 {
            let mut inputs: Vec<NodeId> = Vec::new();
            if i > 0 {
                inputs.push(ids[(i - 1) as usize]);
            }
            if i == 3 {
                inputs.push(ids[0]);
            }
            if i == 6 {
                inputs.push(ids[3]);
            }
            ids.push(b.add_raw(format!("n{i}"), OpKind::Other, 10, 1, &inputs));
        }
        let g = b.build();
        // Tiny budget: wants to cut everywhere but may only cut at 3.
        let c = chen_segmentation(&g, 10);
        assert_eq!(c.k(), 2, "one interior cut at node 3 plus the final segment");
        assert_eq!(c.lower_sets()[0].len(), 4); // {0,1,2,3}
    }

    #[test]
    fn with_variants_match_recomputing_ones() {
        let g = chain_graph(20, 10);
        let arts: NodeSet = {
            let mut s = NodeSet::empty(g.len());
            for v in articulation_points(&g) {
                s.insert(v);
            }
            s
        };
        for b in [10u64, 50, 120] {
            assert_eq!(
                chen_segmentation(&g, b).lower_sets(),
                chen_segmentation_with(&g, &arts, b).lower_sets()
            );
        }
        let a = chen_plan(&g, |c| c.peak_mem(&g)).unwrap();
        let w = chen_plan_with(&g, &arts, |c| c.peak_mem(&g)).unwrap();
        assert_eq!(a.segment_budget, w.segment_budget);
        assert_eq!(a.chain.lower_sets(), w.chain.lower_sets());
    }

    #[test]
    fn sweep_picks_minimum() {
        let g = chain_graph(25, 10);
        let plan = chen_plan(&g, |c| c.peak_mem(&g)).unwrap();
        // The Eq.2 peak of the chosen plan must beat both extremes.
        let coarse = chen_segmentation(&g, g.total_mem());
        let fine = chen_segmentation(&g, 10);
        let best = plan.chain.peak_mem(&g);
        assert!(best <= coarse.peak_mem(&g));
        assert!(best <= fine.peak_mem(&g));
    }
}
