//! The dynamic-programming core (Algorithm 1 of the paper).
//!
//! Shared by the exact DP (§4.2, family = all lower sets) and the
//! approximate DP (§4.3, family = `L^Pruned`). The DP table is the
//! paper's sparse `opt[L, t] = m` with `optarg[L, t] = (L_prev, t_prev)`:
//! per lower set, a Pareto front sorted by accumulated overhead `t`
//! holding the minimal cache memory `m = M(U_i)` reaching that `(L, t)`.
//!
//! Entries are Pareto-pruned in the direction of the objective:
//!
//! - **MinOverhead** (time-centric): keep `m` strictly decreasing in `t`
//!   — the paper's "skip `opt[L,t']` when `t < t'` and
//!   `opt[L,t] < opt[L,t']`";
//! - **MaxOverhead** (memory-centric, §4.4): larger `t` is *desirable*,
//!   so keep `m` strictly increasing in `t` (mirror front).
//!
//! Both prunings are sound because every downstream feasibility check is
//! monotone in `m` and the final selection is monotone in `t`.
//!
//! [`DpContext::min_feasible_budget`] avoids the naive binary search over
//! budgets: a single **minimax DP** pass computes, per lower set, the
//! Pareto front of `(cache m, best achievable max-peak)` and reads the
//! minimal feasible `B*` off the final front directly.

use std::sync::Arc;

use crate::graph::{Graph, NodeSet};
use crate::util::pool::WorkerPool;

use super::strategy::LowerSetChain;
use super::Objective;

/// Precomputed per-family quantities reused across DP runs.
///
/// The context *owns* a shared handle to its graph (no borrowed
/// lifetime), so it can be cached and handed out by
/// [`crate::session::PlanSession`] across requests.
pub struct DpContext {
    g: Arc<Graph>,
    /// The lower-set family, sorted by cardinality ascending; `family[0]`
    /// must be ∅ and the last element `V`.
    pub family: Vec<NodeSet>,
    /// `M(δ+(L)\L) + M(δ−(δ+(L))\L)` per family member (Eq. 2 iii+iv).
    extra_mem: Vec<u64>,
    /// For each family index, the index of the first member with strictly
    /// larger cardinality (start of possible transition targets).
    next_size_start: Vec<usize>,
    /// Per-ideal prefix sums `M(L)` / `T(L)` — turn the per-transition
    /// segment sums into O(1) differences (perf §opt-1).
    mem_cum: Vec<u64>,
    time_cum: Vec<u64>,
    /// Boundary node lists (boundaries are narrow — tens of nodes — so the
    /// per-transition `∂(L')\L` sums scan these instead of full bitsets).
    boundary_nodes: Vec<Vec<u32>>,
    /// Per-node cost lookups.
    node_mem: Vec<u64>,
    node_time: Vec<u64>,
}

/// One DP front entry: `opt[L, t] = m` plus the `optarg` predecessor.
#[derive(Clone, Copy, Debug)]
struct Cell {
    t: u32,
    m: u64,
    prev: u32,
    prev_t: u32,
}

/// Solution of one DP run.
pub struct DpSolution {
    pub chain: LowerSetChain,
    pub overhead: u64,
}

impl DpContext {
    /// Build a context from a borrowed graph (clones it into a shared
    /// handle — cheap next to family enumeration). `family` must contain
    /// ∅ and `V`; it is re-sorted by cardinality here.
    pub fn new(g: &Graph, family: Vec<NodeSet>) -> Self {
        Self::from_shared(Arc::new(g.clone()), family)
    }

    /// Build a context sharing an existing graph handle (the session's
    /// zero-copy path). Runs the per-member precompute on the process-wide
    /// [`crate::util::pool::global`] worker pool.
    pub fn from_shared(g: Arc<Graph>, family: Vec<NodeSet>) -> Self {
        Self::from_shared_with(g, family, &crate::util::pool::global())
    }

    /// [`Self::from_shared`] with an explicit worker pool.
    ///
    /// The per-member quantities (boundary, Eq. 2 extra memory, `M(L)` /
    /// `T(L)` prefix values) are independent across family members, so
    /// they shard across the pool; [`WorkerPool::map`] returns them in
    /// family order, making the built context — and every plan derived
    /// from it — bit-identical at any thread count.
    pub fn from_shared_with(g: Arc<Graph>, mut family: Vec<NodeSet>, pool: &WorkerPool) -> Self {
        family.sort_by(|a, b| a.len().cmp(&b.len()).then_with(|| a.words().cmp(b.words())));
        family.dedup();
        assert!(family.first().map(|l| l.is_empty()).unwrap_or(false), "family must contain ∅");
        assert_eq!(family.last().map(|l| l.len()), Some(g.len()), "family must contain V");
        let per_member: Vec<(Vec<u32>, u64, u64, u64)> = pool.map(family.len(), |i| {
            let l = &family[i];
            let boundary: Vec<u32> = g.boundary(l).iter().map(|v| v.0).collect();
            let extra = g.mem_of(&g.frontier(l)) + g.mem_of(&g.frontier_coinputs(l));
            (boundary, extra, g.mem_of(l), g.time_of(l))
        });
        let mut boundary_nodes: Vec<Vec<u32>> = Vec::with_capacity(per_member.len());
        let mut extra_mem: Vec<u64> = Vec::with_capacity(per_member.len());
        let mut mem_cum: Vec<u64> = Vec::with_capacity(per_member.len());
        let mut time_cum: Vec<u64> = Vec::with_capacity(per_member.len());
        for (b, e, m, t) in per_member {
            boundary_nodes.push(b);
            extra_mem.push(e);
            mem_cum.push(m);
            time_cum.push(t);
        }
        let sizes: Vec<u32> = family.iter().map(|l| l.len()).collect();
        let next_size_start: Vec<usize> =
            sizes.iter().map(|&s| sizes.partition_point(|&x| x <= s)).collect();
        let node_mem: Vec<u64> = (0..g.len()).map(|v| g.node(crate::graph::NodeId(v)).mem).collect();
        let node_time: Vec<u64> =
            (0..g.len()).map(|v| g.node(crate::graph::NodeId(v)).time).collect();
        DpContext {
            g,
            family,
            extra_mem,
            next_size_start,
            mem_cum,
            time_cum,
            boundary_nodes,
            node_mem,
            node_time,
        }
    }

    /// Number of family members.
    pub fn family_len(&self) -> usize {
        self.family.len()
    }

    /// The graph this context was built for.
    pub fn graph(&self) -> &Graph {
        &self.g
    }

    /// Shared handle to the graph.
    pub fn shared_graph(&self) -> Arc<Graph> {
        self.g.clone()
    }

    /// Per-transition Eq. 2 terms for `L = family[j] → L' = family[j2]`.
    /// Returns `(seg_mem2, t_add, m_add)`.
    ///
    /// Perf §opt-1: all three terms reduce to prefix-sum differences plus
    /// a scan of the *boundary* `∂(L')` (narrow — tens of nodes) instead
    /// of three full-bitset iterations:
    ///   `M(V')            = M(L') − M(L)`
    ///   `T(V' \ ∂(L'))    = T(L') − T(L) − T(∂(L') \ L)`
    ///   `M(∂(L') \ L)`    = boundary scan
    /// (`∂(L') ∩ V' = ∂(L') \ L` because `∂(L') ⊆ L'`.)
    #[inline]
    fn transition_terms(&self, j: usize, j2: usize) -> (u64, u64, u64) {
        let seg_mem2 = 2 * (self.mem_cum[j2] - self.mem_cum[j]);
        let l1 = &self.family[j];
        let mut bsum_m = 0u64;
        let mut bsum_t = 0u64;
        for &v in &self.boundary_nodes[j2] {
            if !l1.contains(crate::graph::NodeId(v)) {
                bsum_m += self.node_mem[v as usize];
                bsum_t += self.node_time[v as usize];
            }
        }
        let t_add = self.time_cum[j2] - self.time_cum[j] - bsum_t;
        let m_add = bsum_m;
        (seg_mem2, t_add, m_add)
    }

    /// Run Algorithm 1 under memory budget `budget` and extract the best
    /// chain for `objective`. Returns `None` if no canonical strategy over
    /// this family satisfies the budget.
    ///
    /// Perf §opt-2: a transition `L → L'` maps the *whole* source front by
    /// a uniform shift `(t + t_add, m + m_add)` after a feasibility filter
    /// that is monotone in `m`; target-front update is therefore a single
    /// Pareto **merge** of two sorted vectors — O(|a|+|b|), allocation-free
    /// with a reused scratch buffer — instead of per-entry tree inserts.
    pub fn solve(&self, budget: u64, objective: Objective) -> Option<DpSolution> {
        let n = self.family.len();
        let mut fronts: Vec<Vec<Cell>> = vec![Vec::new(); n];
        fronts[0].push(Cell { t: 0, m: 0, prev: u32::MAX, prev_t: 0 });
        let mut scratch: Vec<Cell> = Vec::new();
        let mut shifted: Vec<Cell> = Vec::new();

        for j in 0..n {
            if fronts[j].is_empty() {
                continue;
            }
            let (head, tail) = fronts.split_at_mut(j + 1);
            let src = &head[j];
            for j2 in self.next_size_start[j]..n {
                if !self.family[j].is_strict_subset(&self.family[j2]) {
                    continue;
                }
                let (seg_mem2, t_add, m_add) = self.transition_terms(j, j2);
                let extra = self.extra_mem[j2];
                let cap = budget.saturating_sub(seg_mem2 + extra);
                if seg_mem2 + extra > budget {
                    continue;
                }
                // Feasible + shifted copy of the source front. Fronts are
                // sorted by t ascending in both objectives; the filter
                // m <= cap keeps a contiguous run (m monotone in t).
                shifted.clear();
                for c in src.iter() {
                    if c.m <= cap {
                        shifted.push(Cell {
                            t: c.t + t_add as u32,
                            m: c.m + m_add,
                            prev: j as u32,
                            prev_t: c.t,
                        });
                    }
                }
                if shifted.is_empty() {
                    continue;
                }
                let dst = &mut tail[j2 - j - 1];
                pareto_merge(dst, &shifted, &mut scratch, objective);
            }
        }

        let final_front = &fronts[n - 1];
        let best = match objective {
            Objective::MinOverhead => final_front.first()?,
            Objective::MaxOverhead => final_front.last()?,
        };
        let t_star = best.t;

        // Backtrack via optarg.
        let mut chain_rev = Vec::new();
        let mut j = n - 1;
        let mut t = t_star;
        loop {
            chain_rev.push(self.family[j].clone());
            let cell = fronts[j]
                .iter()
                .find(|c| c.t == t)
                .expect("optarg chain broken");
            if cell.prev == u32::MAX {
                break;
            }
            j = cell.prev as usize;
            t = cell.prev_t;
            if self.family[j].is_empty() {
                break;
            }
        }
        chain_rev.reverse();
        let chain = LowerSetChain::new_unchecked(&self.g, chain_rev);
        debug_assert_eq!(chain.overhead(&self.g), t_star as u64, "DP t matches Eq. 1");
        Some(DpSolution { chain, overhead: t_star as u64 })
    }

    /// Solve the DP at every budget in `budgets`, sharded across the
    /// worker pool — the budget↔overhead *frontier* of §3.
    ///
    /// Each budget row is an independent [`Self::solve`] run over the
    /// shared (read-only) context, so the sweep is embarrassingly
    /// parallel; results come back in `budgets` order and each row is the
    /// very `DpSolution` the serial call would produce, at any thread
    /// count.
    pub fn solve_frontier(
        &self,
        budgets: &[u64],
        objective: Objective,
        pool: &WorkerPool,
    ) -> Vec<Option<DpSolution>> {
        pool.map(budgets.len(), |i| self.solve(budgets[i], objective))
    }

    /// Smallest budget for which `solve` succeeds.
    ///
    /// One **minimax DP** pass instead of the paper's binary search: per
    /// lower set, keep the Pareto front of `(m, p)` where `p` is the best
    /// achievable maximum segment peak among chains reaching that state
    /// with cache memory `m`. `B* = min p` over the final front. (§5.1
    /// determined the same quantity by binary search; the one-pass version
    /// is validated against the search in the planner tests, and measured
    /// ~50× faster.)
    ///
    /// Perf §opt-2 applies here too: a transition maps a front entry to
    /// `(m + m_add, max(p, m + c))`. Along a front (m asc, p desc) the
    /// image is a p-decreasing prefix followed by m-dominated entries, so
    /// the shifted front is the prefix plus the crossover point — then one
    /// O(n) Pareto merge into the target.
    pub fn min_feasible_budget(&self) -> u64 {
        let n = self.family.len();
        // Front per ideal: Vec<(m, p)>, m ascending ⇒ p strictly decreasing.
        let mut fronts: Vec<Vec<(u64, u64)>> = vec![Vec::new(); n];
        fronts[0].push((0, 0));
        let mut shifted: Vec<(u64, u64)> = Vec::new();
        let mut scratch: Vec<(u64, u64)> = Vec::new();
        for j in 0..n {
            if fronts[j].is_empty() {
                continue;
            }
            let (head, tail) = fronts.split_at_mut(j + 1);
            let src = &head[j];
            for j2 in self.next_size_start[j]..n {
                if !self.family[j].is_strict_subset(&self.family[j2]) {
                    continue;
                }
                let (seg_mem2, _t_add, m_add) = self.transition_terms(j, j2);
                let c = seg_mem2 + self.extra_mem[j2];
                shifted.clear();
                for &(m, p) in src.iter() {
                    let p2 = p.max(m + c);
                    shifted.push((m + m_add, p2));
                    if p <= m + c {
                        // Every later entry has both larger m and larger
                        // peak — dominated by this crossover point.
                        break;
                    }
                }
                let dst = &mut tail[j2 - j - 1];
                minimax_merge(dst, &shifted, &mut scratch);
            }
        }
        fronts[n - 1].iter().map(|&(_, p)| p).min().expect("one-segment chain always exists")
    }

    /// Reference implementation of the minimal budget by binary search
    /// (the paper's §5.1 method) — the serial **cross-check oracle** for
    /// the fast paths: the one-pass minimax DP validates against it in
    /// the unit tests, the planner-scaling bench times both in release,
    /// and the threaded-planner determinism suite re-derives `B*`
    /// through it before sweeping the parallel frontier.
    pub fn min_feasible_budget_by_search(&self) -> u64 {
        let mut hi = 2 * self.g.total_mem() + self.extra_mem.iter().copied().max().unwrap_or(0);
        let mut lo = 0u64;
        debug_assert!(self.solve(hi, Objective::MinOverhead).is_some());
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.solve(mid, Objective::MinOverhead).is_some() {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        hi
    }
}

/// Merge the Pareto front `add` into `dst` (both sorted by `t` asc),
/// keeping only non-dominated cells for the objective:
///
/// - MinOverhead: `m` strictly decreasing in `t` (smaller t, smaller m win);
/// - MaxOverhead: `m` strictly increasing in `t` (larger t, smaller m win).
fn pareto_merge(dst: &mut Vec<Cell>, add: &[Cell], scratch: &mut Vec<Cell>, obj: Objective) {
    if dst.is_empty() {
        dst.extend_from_slice(add);
        return;
    }
    scratch.clear();
    let (mut i, mut k) = (0usize, 0usize);
    match obj {
        Objective::MinOverhead => {
            // Sweep t ascending; keep a cell iff its m is strictly below
            // every m seen so far (any earlier-t cell with m <= dominates).
            let mut best_m = u64::MAX;
            while i < dst.len() || k < add.len() {
                let take_dst = match (dst.get(i), add.get(k)) {
                    (Some(a), Some(b)) => (a.t, a.m) <= (b.t, b.m),
                    (Some(_), None) => true,
                    _ => false,
                };
                let c = if take_dst {
                    i += 1;
                    dst[i - 1]
                } else {
                    k += 1;
                    add[k - 1]
                };
                if c.m < best_m {
                    best_m = c.m;
                    scratch.push(c);
                }
            }
        }
        Objective::MaxOverhead => {
            // Sweep t descending; keep a cell iff its m is strictly below
            // every m seen so far (any later-t cell with m <= dominates).
            let mut best_m = u64::MAX;
            let (mut i2, mut k2) = (dst.len(), add.len());
            while i2 > 0 || k2 > 0 {
                let take_dst = match (
                    i2.checked_sub(1).map(|x| &dst[x]),
                    k2.checked_sub(1).map(|x| &add[x]),
                ) {
                    (Some(a), Some(b)) => (a.t, u64::MAX - a.m) >= (b.t, u64::MAX - b.m),
                    (Some(_), None) => true,
                    _ => false,
                };
                let c = if take_dst {
                    i2 -= 1;
                    dst[i2]
                } else {
                    k2 -= 1;
                    add[k2]
                };
                if c.m < best_m {
                    best_m = c.m;
                    scratch.push(c);
                }
            }
            scratch.reverse();
        }
    }
    let _ = (i, k);
    std::mem::swap(dst, scratch);
}

/// Merge minimax fronts (both sorted m asc, p strictly desc), keeping the
/// Pareto-optimal subset: an entry survives iff its `p` is strictly below
/// every `p` of entries with smaller-or-equal `m`.
fn minimax_merge(dst: &mut Vec<(u64, u64)>, add: &[(u64, u64)], scratch: &mut Vec<(u64, u64)>) {
    if dst.is_empty() {
        dst.extend_from_slice(add);
        return;
    }
    scratch.clear();
    let (mut i, mut k) = (0usize, 0usize);
    let mut best_p = u64::MAX;
    while i < dst.len() || k < add.len() {
        let take_dst = match (dst.get(i), add.get(k)) {
            (Some(a), Some(b)) => *a <= *b,
            (Some(_), None) => true,
            _ => false,
        };
        let e = if take_dst {
            i += 1;
            dst[i - 1]
        } else {
            k += 1;
            add[k - 1]
        };
        if e.1 < best_p {
            best_p = e.1;
            scratch.push(e);
        }
    }
    std::mem::swap(dst, scratch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{enumerate_lower_sets, EnumerationLimit, Graph, GraphBuilder, NodeId, OpKind};

    fn chain_graph(mems: &[u64], times: &[u64]) -> Graph {
        let mut b = GraphBuilder::new("chain", 1);
        let mut prev: Option<NodeId> = None;
        for (i, (&m, &t)) in mems.iter().zip(times).enumerate() {
            let inputs: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, m, t, &inputs));
        }
        b.build()
    }

    fn full_ctx(g: &Graph) -> DpContext {
        let fam = enumerate_lower_sets(g, EnumerationLimit::default()).unwrap();
        DpContext::new(g, fam)
    }

    #[test]
    fn generous_budget_gives_zero_overhead_chain() {
        let g = chain_graph(&[1, 1, 1, 1], &[1, 1, 1, 1]);
        let ctx = full_ctx(&g);
        let sol = ctx.solve(1 << 40, Objective::MinOverhead).unwrap();
        // Only the sink cannot be cached (∂ never contains it).
        assert_eq!(sol.overhead, 1);
    }

    #[test]
    fn tight_budget_forces_recomputation() {
        let g = chain_graph(&[10, 10, 10, 10], &[1, 1, 1, 1]);
        let ctx = full_ctx(&g);
        let generous = ctx.solve(1 << 40, Objective::MinOverhead).unwrap();
        let min_b = ctx.min_feasible_budget();
        let tight = ctx.solve(min_b, Objective::MinOverhead).unwrap();
        assert!(tight.overhead >= generous.overhead);
        assert!(ctx.solve(min_b - 1, Objective::MinOverhead).is_none());
    }

    #[test]
    fn minimax_budget_matches_binary_search() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(90);
        for _ in 0..30 {
            let n = rng.range(3, 11);
            let g = crate::testutil::random_dag(&mut rng, n);
            let ctx = full_ctx(&g);
            assert_eq!(
                ctx.min_feasible_budget(),
                ctx.min_feasible_budget_by_search(),
                "graph {}",
                g.to_json()
            );
        }
    }

    #[test]
    fn mc_overhead_geq_tc_overhead() {
        let g = chain_graph(&[5, 3, 8, 2, 7, 4], &[2, 1, 3, 1, 2, 1]);
        let ctx = full_ctx(&g);
        let b = ctx.min_feasible_budget();
        let tc = ctx.solve(b, Objective::MinOverhead).unwrap();
        let mc = ctx.solve(b, Objective::MaxOverhead).unwrap();
        assert!(mc.overhead >= tc.overhead);
        // MC overhead is bounded by one forward pass (§4.4).
        assert!(mc.overhead <= g.total_time());
    }

    #[test]
    fn chain_eq2_within_budget() {
        let g = chain_graph(&[4, 7, 2, 9, 5], &[1, 1, 1, 1, 1]);
        let ctx = full_ctx(&g);
        for budget in [10u64, 14, 20, 30, 44] {
            if let Some(sol) = ctx.solve(budget, Objective::MinOverhead) {
                assert!(
                    sol.chain.peak_mem(&g) <= budget,
                    "budget {budget}: peak {}",
                    sol.chain.peak_mem(&g),
                );
                assert_eq!(sol.chain.overhead(&g), sol.overhead);
            }
        }
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let g = chain_graph(&[10, 10], &[1, 1]);
        let ctx = full_ctx(&g);
        assert!(ctx.solve(1, Objective::MinOverhead).is_none());
    }

    #[test]
    fn branching_graph_solves() {
        let mut b = GraphBuilder::new("d", 1);
        let a = b.add_raw("a", OpKind::Other, 2, 1, &[]);
        let x = b.add_raw("x", OpKind::Other, 9, 2, &[a]);
        let y = b.add_raw("y", OpKind::Other, 3, 1, &[a]);
        let _z = b.add_raw("z", OpKind::Other, 4, 1, &[x, y]);
        let g = b.build();
        let ctx = full_ctx(&g);
        let min_b = ctx.min_feasible_budget();
        let sol = ctx.solve(min_b, Objective::MinOverhead).unwrap();
        assert!(sol.chain.peak_mem(&g) <= min_b);
        for l in sol.chain.lower_sets() {
            assert!(g.is_lower_set(l));
        }
    }

    #[test]
    fn pareto_merge_invariants() {
        let mk = |t, m| Cell { t, m, prev: 0, prev_t: 0 };
        // MinOverhead: result must have m strictly decreasing in t.
        let mut dst = vec![mk(3, 20), mk(5, 10)];
        let mut scratch = Vec::new();
        pareto_merge(&mut dst, &[mk(4, 8), mk(6, 12), mk(7, 5)], &mut scratch,
            Objective::MinOverhead);
        let ts: Vec<(u32, u64)> = dst.iter().map(|c| (c.t, c.m)).collect();
        for w in ts.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 > w[1].1, "{ts:?}");
        }
        assert!(ts.contains(&(3, 20)) && ts.contains(&(4, 8)) && ts.contains(&(7, 5)));
        assert!(!ts.contains(&(5, 10)) && !ts.contains(&(6, 12)), "{ts:?}");

        // MaxOverhead: m strictly increasing in t.
        let mut dst = vec![mk(5, 10)];
        pareto_merge(&mut dst, &[mk(3, 2), mk(6, 3), mk(7, 5)], &mut scratch,
            Objective::MaxOverhead);
        let ts: Vec<(u32, u64)> = dst.iter().map(|c| (c.t, c.m)).collect();
        for w in ts.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1, "{ts:?}");
        }
        assert!(ts.contains(&(7, 5)) && ts.contains(&(3, 2)) && ts.contains(&(6, 3)));
        assert!(!ts.contains(&(5, 10)), "{ts:?}");
    }

    #[test]
    fn parallel_context_build_is_bit_identical_to_serial() {
        use crate::util::pool::WorkerPool;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seeded(0x715);
        let serial = WorkerPool::with_threads(1);
        let four = WorkerPool::with_threads(4);
        for _ in 0..12 {
            let n = rng.range(3, 11);
            let g = Arc::new(crate::testutil::random_dag(&mut rng, n));
            let fam = enumerate_lower_sets(&g, EnumerationLimit::default()).unwrap();
            let c1 = DpContext::from_shared_with(g.clone(), fam.clone(), &serial);
            let c4 = DpContext::from_shared_with(g.clone(), fam, &four);
            assert_eq!(c1.family, c4.family);
            assert_eq!(c1.extra_mem, c4.extra_mem);
            assert_eq!(c1.boundary_nodes, c4.boundary_nodes);
            assert_eq!(c1.mem_cum, c4.mem_cum);
            assert_eq!(c1.time_cum, c4.time_cum);
            assert_eq!(c1.min_feasible_budget(), c4.min_feasible_budget());
        }
    }

    #[test]
    fn frontier_rows_match_serial_solves_at_any_thread_count() {
        use crate::util::pool::WorkerPool;
        let g = chain_graph(&[4, 7, 2, 9, 5, 3, 8, 6], &[2, 1, 3, 1, 2, 1, 2, 1]);
        let ctx = full_ctx(&g);
        // Anchor the sweep at the oracle's B* — the binary-search
        // reference cross-checks the minimax DP on the same context the
        // frontier runs over.
        let b_star = ctx.min_feasible_budget_by_search();
        assert_eq!(b_star, ctx.min_feasible_budget());
        let budgets: Vec<u64> = (0..16).map(|i| b_star.saturating_sub(2) + i * 3).collect();
        for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
            let serial: Vec<Option<(Vec<NodeSet>, u64)>> = budgets
                .iter()
                .map(|&b| {
                    ctx.solve(b, obj).map(|s| (s.chain.lower_sets().to_vec(), s.overhead))
                })
                .collect();
            for t in [1usize, 4] {
                let pool = WorkerPool::with_threads(t);
                let rows = ctx.solve_frontier(&budgets, obj, &pool);
                let got: Vec<Option<(Vec<NodeSet>, u64)>> = rows
                    .into_iter()
                    .map(|r| r.map(|s| (s.chain.lower_sets().to_vec(), s.overhead)))
                    .collect();
                assert_eq!(serial, got, "threads={t} obj={obj:?}");
            }
        }
    }
}
