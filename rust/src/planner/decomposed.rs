//! Divide-and-conquer planning (the decomposition layer).
//!
//! The exact DP's lower-set family explodes with graph width, capping
//! exact-quality plans at a few hundred nodes. Feng & Huang (*Optimal
//! Gradient Checkpoint Search for Arbitrary Computation Graphs*) observe
//! that dividing a network at separators keeps optimal search tractable:
//! pieces that communicate through a single vertex can be planned
//! independently and stitched, for the *sum* — not the product — of the
//! per-piece family sizes.
//!
//! [`DecomposedPlanner`] implements that idea on top of the gate
//! decomposition of [`crate::graph::decompose`]:
//!
//! 1. split `V` at its **gates** (articulation points whose ancestor
//!    closure has boundary exactly `{gate}` — the sound stitch points
//!    for lower-set chains), then coalesce consecutive slices into units
//!    of at least [`COMPONENT_NODE_TARGET`] nodes so a plain chain does
//!    not shatter into singletons;
//! 2. solve every unit through the degradation ladder — exact DP while
//!    its lower-set family fits under [`COMPONENT_IDEAL_CAP`], else
//!    approx DP over `L^Pruned`, else (beyond [`COMPONENT_CHEN_CAP`]
//!    nodes) Chen's √n sweep — sharded across the worker pool, since
//!    units are embarrassingly parallel;
//! 3. stitch the local chains at the gates: each local lower set, mapped
//!    to global ids and unioned with the prefix of earlier units, is a
//!    global lower set, so the concatenation is a valid global chain.
//!    The stitched chain is re-validated by the checked
//!    [`LowerSetChain::new`] and its reported overhead / peak are the
//!    *exact* Eq. 1 / Eq. 2 values of the global chain — no
//!    compositional approximation leaks into the reports.
//!
//! Budget accounting charges each gate's checkpoint bytes exactly once:
//! under an absolute budget the units are solved in topological order
//! and unit `i` plans under `B − carryᵢ`, where `carryᵢ` is the memory
//! of everything units `< i` decided to cache (their cache sets plus
//! their gates). Because the local Eq. 2 cannot see cross-unit frontier
//! terms, the stitched chain's true global peak is checked against `B`
//! at the end and the planner fails honestly if it overflows.
//!
//! Per-unit plans are cached in a [`ComponentCache`] keyed by the unit's
//! [`Graph::subgraph_fingerprint`], so a session editing one branch of a
//! model re-plans only the components that changed.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::anyhow::{anyhow, bail, Result};
use crate::fmt_bytes;
use crate::graph::{
    articulation_points, decompose, enumerate_lower_sets, induced_subgraph, pruned_lower_sets,
    Decomposition, EnumerationLimit, Graph, GraphFingerprint, NodeId, NodeSet,
};
use crate::util::pool::WorkerPool;

use super::dp::DpContext;
use super::strategy::LowerSetChain;
use super::{
    chen_plan, BudgetSpec, Objective, Plan, PlanContext, PlanRequest, Planner, PlannerId,
    PlannerKind,
};

/// Coalescing threshold: consecutive gate slices merge until a unit
/// holds at least this many nodes. On a plain chain *every* interior
/// node is a gate, and stitching at all of them would force caching
/// every cut vertex; coalescing keeps the per-gate checkpoint cost
/// amortized. A fixed constant — never derived from the thread count —
/// so plans are bit-identical at any parallelism.
pub const COMPONENT_NODE_TARGET: u32 = 32;

/// Per-unit lower-set enumeration cap for the exact rung of the ladder.
/// Units whose family overflows it degrade to the approximate family.
pub const COMPONENT_IDEAL_CAP: usize = 65_536;

/// Units larger than this skip the DP ladder entirely and take the Chen
/// √n rung (building even the pruned family would be quadratic).
pub const COMPONENT_CHEN_CAP: u32 = 2_048;

/// Tunable knobs of the decomposed planner. Production uses
/// [`DecomposeCfg::default`]; unit tests shrink the caps to force every
/// ladder rung on small fixtures.
#[derive(Clone, Copy, Debug)]
struct DecomposeCfg {
    node_target: u32,
    ideal_cap: usize,
    chen_cap: u32,
}

impl Default for DecomposeCfg {
    fn default() -> DecomposeCfg {
        DecomposeCfg {
            node_target: COMPONENT_NODE_TARGET,
            ideal_cap: COMPONENT_IDEAL_CAP,
            chen_cap: COMPONENT_CHEN_CAP,
        }
    }
}

/// Per-component statistics of a decomposed plan, surfaced through
/// [`Plan::decomposition`](super::Plan), the CLI report and the session
/// stats.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct DecompositionInfo {
    /// Number of coalesced components the graph was split into.
    pub components: u32,
    /// Gate (cut) vertices used as stitch points (`components − 1`).
    pub cut_vertices: u32,
    /// Node count per component, in topological order.
    pub sizes: Vec<u32>,
    /// Lower-set family size per component (0 for the Chen rung, which
    /// builds no family).
    pub family_sizes: Vec<usize>,
    /// Ladder rung each component was solved on.
    pub kinds: Vec<PlannerKind>,
    /// Components whose plan was reused — from the [`ComponentCache`]
    /// or from an identical component earlier in the same graph.
    pub cache_hits: u32,
}

/// A solved component: its local lower-set chain plus provenance.
#[derive(Debug)]
pub(crate) struct ComponentPlan {
    /// Cumulative lower sets in the component's local id space.
    sets: Vec<NodeSet>,
    kind: PlannerKind,
    family_len: usize,
}

/// Cache key: the component's structural fingerprint plus what was asked
/// of it — objective, local budget (`None` = minimal feasible), and
/// whether fractional "clamp up to feasible" semantics applied.
type Key = (GraphFingerprint, Objective, Option<u64>, bool);

struct CacheEntry {
    plan: Arc<ComponentPlan>,
    last_used: u64,
}

struct CacheInner {
    map: HashMap<Key, CacheEntry>,
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU cache of solved component plans, keyed by the component's
/// [`Graph::subgraph_fingerprint`] plus the objective and local budget.
/// [`crate::session::PlanSession`] owns one alongside its compiled-plan
/// cache, so sessions serving many model variants re-plan only the
/// components that actually changed.
pub struct ComponentCache {
    capacity: usize,
    inner: Mutex<CacheInner>,
}

/// Counters of a [`ComponentCache`].
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct ComponentCacheStats {
    /// Live entries.
    pub entries: usize,
    /// Component plans reused instead of solved (includes reuse between
    /// identical components of a single graph).
    pub hits: u64,
    /// Components that had to be solved.
    pub misses: u64,
}

impl ComponentCache {
    /// Create a cache holding at most `capacity` component plans
    /// (clamped to ≥ 1).
    pub fn new(capacity: usize) -> ComponentCache {
        ComponentCache {
            capacity: capacity.max(1),
            inner: Mutex::new(CacheInner { map: HashMap::new(), tick: 0, hits: 0, misses: 0 }),
        }
    }

    /// Current counters.
    pub fn stats(&self) -> ComponentCacheStats {
        let inner = self.inner.lock().expect("component cache lock");
        ComponentCacheStats { entries: inner.map.len(), hits: inner.hits, misses: inner.misses }
    }

    /// Fetch an entry, refreshing its LRU stamp. Does not touch the
    /// hit/miss counters — the planner validates the entry against the
    /// concrete component first and reports the outcome via
    /// [`ComponentCache::record`].
    fn lookup(&self, key: &Key) -> Option<Arc<ComponentPlan>> {
        let mut inner = self.inner.lock().expect("component cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.get_mut(key).map(|e| {
            e.last_used = tick;
            Arc::clone(&e.plan)
        })
    }

    /// Insert a solved plan, evicting least-recently-used entries down
    /// to capacity.
    fn insert(&self, key: Key, plan: Arc<ComponentPlan>) {
        let mut inner = self.inner.lock().expect("component cache lock");
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, CacheEntry { plan, last_used: tick });
        while inner.map.len() > self.capacity {
            // Ticks are unique, so the victim is deterministic.
            match inner.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| *k) {
                Some(victim) => {
                    inner.map.remove(&victim);
                }
                None => break,
            }
        }
    }

    /// Fold one planning call's hit/miss counts into the cache stats.
    fn record(&self, hits: u64, misses: u64) {
        let mut inner = self.inner.lock().expect("component cache lock");
        inner.hits += hits;
        inner.misses += misses;
    }
}

/// One coalesced slice of the decomposition.
struct Unit {
    nodes: NodeSet,
    /// The trailing gate joining this unit to the next (`None` on the
    /// last unit).
    gate: Option<NodeId>,
}

/// Merge consecutive gate slices into units of at least `target` nodes.
/// A unit can only close at a gate boundary, so each unit but the last
/// carries the gate of its last merged slice.
fn coalesce(d: &Decomposition, target: u32) -> Vec<Unit> {
    let mut units: Vec<Unit> = Vec::new();
    let mut acc: Option<NodeSet> = None;
    for (i, c) in d.components.iter().enumerate() {
        match acc.as_mut() {
            Some(a) => a.union_with(c),
            None => acc = Some(c.clone()),
        }
        if i < d.gates.len() && acc.as_ref().map(|a| a.len() >= target).unwrap_or(false) {
            let nodes = acc.take().expect("accumulator set");
            units.push(Unit { nodes, gate: Some(d.gates[i]) });
        }
    }
    if let Some(a) = acc {
        units.push(Unit { nodes: a, gate: None });
    }
    units
}

/// A cached (or duplicate) chain is only reusable when it is a valid
/// chain of *this* component's labeling — guards against fingerprint
/// collisions and isomorphic-but-relabeled twins.
fn chain_fits(sub: &Graph, sets: &[NodeSet]) -> bool {
    sets.last().map(|l| l.capacity() == sub.len()).unwrap_or(false)
        && LowerSetChain::new(sub, sets.to_vec()).is_ok()
}

/// Solve one component through the degradation ladder: exact DP while
/// the family fits under `cfg.ideal_cap`, else approx DP over
/// `L^Pruned`, else (beyond `cfg.chen_cap` nodes) Chen's √n sweep
/// (which resolves its own per-segment budget and ignores `budget`; the
/// stitched chain's global budget check still applies).
///
/// `budget = None` plans at the component's minimal feasible budget;
/// `Some(b)` caps it, clamping up to feasible when `clamp` is set
/// (fractional-budget semantics) and failing otherwise.
fn plan_component(
    sub: &Graph,
    pool: &WorkerPool,
    objective: Objective,
    budget: Option<u64>,
    clamp: bool,
    cfg: DecomposeCfg,
) -> Result<ComponentPlan> {
    if sub.len() > cfg.chen_cap {
        let p = chen_plan(sub, |c| c.peak_mem(sub))?;
        return Ok(ComponentPlan {
            sets: p.chain.lower_sets().to_vec(),
            kind: PlannerKind::Chen,
            family_len: 0,
        });
    }
    let limit = EnumerationLimit { max_ideals: cfg.ideal_cap };
    let (family, kind) = match enumerate_lower_sets(sub, limit) {
        Some(f) => (f, PlannerKind::ExactDp),
        None => (pruned_lower_sets(sub), PlannerKind::ApproxDp),
    };
    let dp = DpContext::from_shared_with(Arc::new(sub.clone()), family, pool);
    let family_len = dp.family_len();
    let b = match budget {
        None => dp.min_feasible_budget(),
        Some(b) => {
            let min = dp.min_feasible_budget();
            if b >= min {
                b
            } else if clamp {
                min
            } else {
                bail!(
                    "budget {} infeasible for {}: min feasible {}",
                    fmt_bytes(b),
                    sub.name,
                    fmt_bytes(min)
                );
            }
        }
    };
    let sol = dp.solve(b, objective).ok_or_else(|| {
        anyhow!("solve at budget {} for {} must succeed", fmt_bytes(b), sub.name)
    })?;
    Ok(ComponentPlan { sets: sol.chain.lower_sets().to_vec(), kind, family_len })
}

/// Shared state of one decomposed planning call.
struct Solver<'a> {
    g: &'a Graph,
    units: &'a [Unit],
    preps: &'a [(Graph, Vec<NodeId>, GraphFingerprint)],
    objective: Objective,
    cache: Option<&'a ComponentCache>,
    pool: &'a WorkerPool,
    cfg: DecomposeCfg,
}

/// Per-unit plans plus this call's reuse accounting.
struct Solved {
    plans: Vec<Arc<ComponentPlan>>,
    hits: u64,
    misses: u64,
}

impl Solver<'_> {
    /// Minimal-feasible-budget path: every unit plans at its own local
    /// `B*`, independently — fully parallel across the pool. Cache
    /// probes and intra-graph deduplication run sequentially *before*
    /// the parallel solve so hit/miss accounting (and therefore the
    /// session stats) never depends on the thread count.
    fn min_feasible(&self) -> Result<Solved> {
        let n = self.units.len();
        let mut plans: Vec<Option<Arc<ComponentPlan>>> = vec![None; n];
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut rep_for_key: HashMap<Key, usize> = HashMap::new();
        let mut to_solve: Vec<usize> = Vec::new();
        let mut followers: Vec<(usize, usize)> = Vec::new();
        for i in 0..n {
            let key = (self.preps[i].2, self.objective, None, false);
            if let Some(cc) = self.cache {
                if let Some(p) = cc.lookup(&key) {
                    if chain_fits(&self.preps[i].0, &p.sets) {
                        plans[i] = Some(p);
                        hits += 1;
                        continue;
                    }
                }
            }
            match rep_for_key.get(&key) {
                Some(&rep) => followers.push((i, rep)),
                None => {
                    rep_for_key.insert(key, i);
                    to_solve.push(i);
                }
            }
        }
        // Solve the unique misses in parallel; results come back in
        // index order, so everything downstream stays deterministic.
        let solved: Vec<Result<ComponentPlan>> = self.pool.map(to_solve.len(), |k| {
            plan_component(
                &self.preps[to_solve[k]].0,
                self.pool,
                self.objective,
                None,
                false,
                self.cfg,
            )
        });
        for (k, r) in solved.into_iter().enumerate() {
            let i = to_solve[k];
            let plan = Arc::new(r?);
            if let Some(cc) = self.cache {
                cc.insert((self.preps[i].2, self.objective, None, false), Arc::clone(&plan));
            }
            plans[i] = Some(plan);
            misses += 1;
        }
        // Duplicates reuse their representative's plan when it fits
        // their labeling; isomorphic-but-relabeled twins solve solo.
        for (i, rep) in followers {
            let p = plans[rep].as_ref().expect("representative solved").clone();
            if chain_fits(&self.preps[i].0, &p.sets) {
                plans[i] = Some(p);
                hits += 1;
            } else {
                plans[i] = Some(Arc::new(plan_component(
                    &self.preps[i].0,
                    self.pool,
                    self.objective,
                    None,
                    false,
                    self.cfg,
                )?));
                misses += 1;
            }
        }
        let plans = plans.into_iter().map(|p| p.expect("every unit resolved")).collect();
        Ok(Solved { plans, hits, misses })
    }

    /// Absolute-budget path: units solve in topological order, each
    /// under `budget − carry`, where `carry` is the checkpoint memory
    /// committed by earlier units — their cache sets plus their gates,
    /// each charged exactly once. Sequential across units (the carry is
    /// a data dependency); each unit's DP still shards its own family
    /// precompute across the pool.
    fn budgeted(&self, budget: u64, clamp: bool) -> Result<Solved> {
        let n = self.units.len();
        let mut plans: Vec<Arc<ComponentPlan>> = Vec::with_capacity(n);
        let mut hits = 0u64;
        let mut misses = 0u64;
        let mut carry = 0u64;
        for i in 0..n {
            let sub = &self.preps[i].0;
            let local_b = budget.saturating_sub(carry);
            let key = (self.preps[i].2, self.objective, Some(local_b), clamp);
            let mut plan: Option<Arc<ComponentPlan>> = None;
            if let Some(cc) = self.cache {
                if let Some(p) = cc.lookup(&key) {
                    if chain_fits(sub, &p.sets) {
                        hits += 1;
                        plan = Some(p);
                    }
                }
            }
            let plan = match plan {
                Some(p) => p,
                None => {
                    let obj = self.objective;
                    let solved = plan_component(sub, self.pool, obj, Some(local_b), clamp, self.cfg)
                        .map_err(|e| {
                            e.context(format!(
                                "component {} of {} (budget {} after {} checkpointed upstream)",
                                i,
                                self.g.name,
                                fmt_bytes(local_b),
                                fmt_bytes(carry),
                            ))
                        })?;
                    misses += 1;
                    let p = Arc::new(solved);
                    if let Some(cc) = self.cache {
                        cc.insert(key, Arc::clone(&p));
                    }
                    p
                }
            };
            // Advance the carry: this unit's cache set (its local U_k)
            // plus the gate joining it to the next unit.
            let mut u = NodeSet::empty(sub.len());
            for l in &plan.sets {
                u.union_with(&sub.boundary(l));
            }
            carry += sub.mem_of(&u);
            if let Some(gate) = self.units[i].gate {
                carry += self.g.node(gate).mem;
            }
            plans.push(plan);
        }
        Ok(Solved { plans, hits, misses })
    }
}

/// The decomposition planner behind [`PlannerId::Decomposed`] — see the
/// module docs for the algorithm. Registered in
/// [`super::planner_for`]; [`crate::session::PlanSession`] supplies the
/// worker pool, the cached articulation set and the [`ComponentCache`]
/// through [`PlanContext`].
pub struct DecomposedPlanner;

impl Planner for DecomposedPlanner {
    fn id(&self) -> PlannerId {
        PlannerId::Decomposed
    }

    fn plan(&self, req: &PlanRequest, ctx: &PlanContext<'_>) -> Result<Plan> {
        plan_decomposed(req, ctx, DecomposeCfg::default())
    }
}

fn plan_decomposed(req: &PlanRequest, ctx: &PlanContext<'_>, cfg: DecomposeCfg) -> Result<Plan> {
    let g = ctx.graph;
    if g.len() == 0 {
        bail!("empty graph");
    }
    let arts: Vec<NodeId> = match ctx.arts {
        Some(set) => set.iter().collect(),
        None => articulation_points(g),
    };
    let units = coalesce(&decompose(g, &arts), cfg.node_target);

    let global_pool;
    let pool: &WorkerPool = match ctx.pool {
        Some(p) => p,
        None => {
            global_pool = crate::util::pool::global();
            &global_pool
        }
    };

    // Materialize subgraphs + fingerprints in parallel (index order).
    let preps: Vec<(Graph, Vec<NodeId>, GraphFingerprint)> = pool.map(units.len(), |i| {
        let (sub, map) = induced_subgraph(g, &units[i].nodes);
        let fp = g.subgraph_fingerprint(&units[i].nodes);
        (sub, map, fp)
    });

    let (global_budget, clamp) = match req.budget {
        BudgetSpec::MinFeasible => (None, false),
        BudgetSpec::Bytes(b) => (Some(b), false),
        BudgetSpec::Frac(f) => (Some((g.total_mem() as f64 * f) as u64), true),
    };

    let solver = Solver {
        g,
        units: &units,
        preps: &preps,
        objective: req.objective,
        cache: ctx.components,
        pool,
        cfg,
    };
    let solved = match global_budget {
        None => solver.min_feasible()?,
        Some(b) => solver.budgeted(b, clamp)?,
    };
    if let Some(cc) = ctx.components {
        cc.record(solved.hits, solved.misses);
    }

    // Stitch: each local lower set, mapped to global ids and unioned
    // with the prefix of earlier units, extends the global chain.
    let mut global_sets: Vec<NodeSet> = Vec::new();
    let mut prefix = NodeSet::empty(g.len());
    for (i, plan) in solved.plans.iter().enumerate() {
        let map = &preps[i].1;
        for l in &plan.sets {
            let mut s = prefix.clone();
            for v in l.iter() {
                s.insert(map[v.0 as usize]);
            }
            global_sets.push(s);
        }
        prefix = global_sets.last().expect("non-empty local chain").clone();
    }

    // Deliberate corruption hook: a graph named
    // [`crate::analysis::FAULT_INJECT_GRAPH`] gets one checkpoint node
    // dropped from every stitched set but the last, so integration
    // tests (and the serve acceptance gate) can watch the audit below
    // reject a defective stitch end to end. Real graphs never carry
    // this name.
    if g.name == crate::analysis::FAULT_INJECT_GRAPH && global_sets.len() >= 2 {
        // Bind the victim before mutating: the scrutinee of an `if let`
        // would keep the iterator's borrow alive across the loop body.
        let victim = global_sets[0].iter().next();
        if let Some(victim) = victim {
            let last = global_sets.len() - 1;
            for l in &mut global_sets[..last] {
                l.remove(victim);
            }
        }
    }

    // Rule-backed stitch audit: the same A009/A010 diagnostics the
    // compile-time auditor emits, run on the raw stitched sets *before*
    // the checked constructor — so a stitching defect reports which
    // invariant broke (and which backward read lost its checkpoint)
    // instead of a bare constructor error.
    let stitch_diags = crate::analysis::audit_chain(g, &global_sets);
    if let Some(first) = stitch_diags.first() {
        bail!(
            "{}: {} {}: {} (stitched chain of {}, {} finding(s))",
            crate::analysis::AUDIT_FAILED_PREFIX,
            first.rule.code(),
            first.rule.name(),
            first.message,
            g.name,
            stitch_diags.len()
        );
    }
    let chain = LowerSetChain::new(g, global_sets)?;
    let overhead = chain.overhead(g);
    let peak_eq2 = chain.peak_mem(g);
    let budget = match (global_budget, clamp) {
        (Some(b), false) => {
            if peak_eq2 > b {
                bail!(
                    "decomposed plan for {} exceeds budget {} ({} {}): stitched Eq. 2 peak {}",
                    g.name,
                    fmt_bytes(b),
                    crate::analysis::Rule::BudgetExceeded.code(),
                    crate::analysis::Rule::BudgetExceeded.name(),
                    fmt_bytes(peak_eq2)
                );
            }
            b
        }
        (Some(b), true) => b.max(peak_eq2),
        (None, _) => peak_eq2,
    };
    let info = DecompositionInfo {
        components: units.len() as u32,
        cut_vertices: (units.len() - 1) as u32,
        sizes: units.iter().map(|u| u.nodes.len()).collect(),
        family_sizes: solved.plans.iter().map(|p| p.family_len).collect(),
        kinds: solved.plans.iter().map(|p| p.kind).collect(),
        cache_hits: solved.hits as u32,
    };
    Ok(Plan {
        chain,
        kind: PlannerKind::Decomposed,
        objective: req.objective,
        budget,
        overhead,
        peak_eq2,
        decomposition: Some(info),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{exact_dp, planner_for};
    use crate::sim::SimMode;
    use crate::testutil::chain_graph;

    fn small_cfg() -> DecomposeCfg {
        DecomposeCfg { node_target: 3, ideal_cap: 4096, chen_cap: 2048 }
    }

    fn req(budget: BudgetSpec) -> PlanRequest {
        PlanRequest {
            planner: PlannerId::Decomposed,
            budget,
            objective: Objective::MinOverhead,
            sim_mode: SimMode::Liveness,
        }
    }

    #[test]
    fn decomposes_and_stitches_a_chain() {
        let g = chain_graph(&[10; 12]);
        let ctx = PlanContext::bare(&g, 0);
        let plan = plan_decomposed(&req(BudgetSpec::MinFeasible), &ctx, small_cfg()).unwrap();
        let info = plan.decomposition.as_ref().unwrap();
        assert!(info.components >= 3, "12-node chain at target 3 must split: {info:?}");
        assert_eq!(info.components, info.cut_vertices + 1);
        assert_eq!(info.sizes.iter().sum::<u32>(), 12);
        assert!(info.kinds.iter().all(|k| *k == PlannerKind::ExactDp), "{:?}", info.kinds);
        assert!(info.family_sizes.iter().all(|&s| s > 0));
        // The stitched chain revalidates and the reported metrics are
        // the exact Eq. 1 / Eq. 2 values of the global chain.
        let c = LowerSetChain::new(&g, plan.chain.lower_sets().to_vec()).unwrap();
        assert_eq!(plan.overhead, c.overhead(&g));
        assert_eq!(plan.peak_eq2, c.peak_mem(&g));
        assert_eq!(plan.budget, plan.peak_eq2);
        assert_eq!(plan.kind, PlannerKind::Decomposed);
    }

    #[test]
    fn matches_exact_overhead_on_chain_at_generous_budget() {
        let g = chain_graph(&[7, 3, 9, 4, 6, 8, 2, 5, 10, 4, 6, 3]);
        let b = g.total_mem() * 4;
        let ctx = PlanContext::bare(&g, 0);
        let plan = plan_decomposed(&req(BudgetSpec::Bytes(b)), &ctx, small_cfg()).unwrap();
        let exact = exact_dp(&g, b, Objective::MinOverhead).unwrap();
        assert_eq!(plan.overhead, exact.overhead, "generous budget: both reach the optimum");
        assert!(plan.peak_eq2 <= b);
        assert_eq!(plan.budget, b);
    }

    #[test]
    fn ladder_degrades_per_component() {
        let g = chain_graph(&[10; 12]);
        let ctx = PlanContext::bare(&g, 0);
        // A 2-ideal cap cannot hold any unit's family: approx rung.
        let approx = DecomposeCfg { node_target: 3, ideal_cap: 2, chen_cap: 2048 };
        let p = plan_decomposed(&req(BudgetSpec::MinFeasible), &ctx, approx).unwrap();
        let info = p.decomposition.unwrap();
        assert!(info.kinds.iter().all(|k| *k == PlannerKind::ApproxDp), "{:?}", info.kinds);
        // Units of 3 nodes overflow a 2-node Chen cap: Chen rung.
        let chen = DecomposeCfg { node_target: 3, ideal_cap: 4096, chen_cap: 2 };
        let p = plan_decomposed(&req(BudgetSpec::MinFeasible), &ctx, chen).unwrap();
        let info = p.decomposition.unwrap();
        assert!(info.kinds.iter().all(|k| *k == PlannerKind::Chen), "{:?}", info.kinds);
        assert!(info.family_sizes.iter().all(|&s| s == 0));
    }

    #[test]
    fn identical_components_dedupe_and_cache_across_calls() {
        let g = chain_graph(&[10; 9]);
        let cache = ComponentCache::new(16);
        let ctx = PlanContext { components: Some(&cache), ..PlanContext::bare(&g, 0) };
        let p1 = plan_decomposed(&req(BudgetSpec::MinFeasible), &ctx, small_cfg()).unwrap();
        let i1 = p1.decomposition.unwrap();
        assert_eq!(i1.components, 3);
        assert_eq!(i1.cache_hits, 2, "two duplicate components reuse the first solve");
        let p2 = plan_decomposed(&req(BudgetSpec::MinFeasible), &ctx, small_cfg()).unwrap();
        let i2 = p2.decomposition.unwrap();
        assert_eq!(i2.cache_hits, 3, "second call is served entirely from the cache");
        assert_eq!(p1.chain.lower_sets(), p2.chain.lower_sets());
        let s = cache.stats();
        assert_eq!(s.entries, 1, "three identical components share one entry");
        assert_eq!(s.hits, 5);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn cache_evicts_lru_beyond_capacity() {
        let cache = ComponentCache::new(2);
        let mk = |n: u32| {
            Arc::new(ComponentPlan {
                sets: vec![NodeSet::full(n)],
                kind: PlannerKind::ExactDp,
                family_len: 1,
            })
        };
        let key = |x: u64| (GraphFingerprint(x), Objective::MinOverhead, None, false);
        cache.insert(key(1), mk(1));
        cache.insert(key(2), mk(2));
        assert!(cache.lookup(&key(1)).is_some()); // touch 1 ⇒ 2 is LRU
        cache.insert(key(3), mk(3));
        assert!(cache.lookup(&key(2)).is_none(), "LRU entry evicted");
        assert!(cache.lookup(&key(1)).is_some());
        assert!(cache.lookup(&key(3)).is_some());
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn identical_plans_at_any_thread_count() {
        let g = chain_graph(&[5, 9, 3, 7, 11, 2, 8, 6, 4, 10, 7, 3, 9, 5]);
        let p1 = WorkerPool::with_threads(1);
        let p4 = WorkerPool::with_threads(4);
        for budget in
            [BudgetSpec::MinFeasible, BudgetSpec::Bytes(g.total_mem() * 3), BudgetSpec::Frac(0.5)]
        {
            let ctx1 = PlanContext { pool: Some(&p1), ..PlanContext::bare(&g, 0) };
            let ctx4 = PlanContext { pool: Some(&p4), ..PlanContext::bare(&g, 0) };
            let a = plan_decomposed(&req(budget), &ctx1, small_cfg()).unwrap();
            let b = plan_decomposed(&req(budget), &ctx4, small_cfg()).unwrap();
            assert_eq!(a.chain.lower_sets(), b.chain.lower_sets(), "{budget:?}");
            assert_eq!(a.overhead, b.overhead);
            assert_eq!(a.peak_eq2, b.peak_eq2);
            assert_eq!(a.decomposition, b.decomposition);
        }
    }

    #[test]
    fn infeasible_budget_names_the_component() {
        let g = chain_graph(&[10; 9]);
        let ctx = PlanContext::bare(&g, 0);
        let err =
            plan_decomposed(&req(BudgetSpec::Bytes(5)), &ctx, small_cfg()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("infeasible"), "{msg}");
        assert!(msg.contains("component"), "{msg}");
    }

    #[test]
    fn registered_behind_the_planner_trait() {
        let g = chain_graph(&[10; 40]);
        let p = planner_for(PlannerId::Decomposed);
        assert_eq!(p.id(), PlannerId::Decomposed);
        let plan = p.plan(&req(BudgetSpec::MinFeasible), &PlanContext::bare(&g, 0)).unwrap();
        assert_eq!(plan.kind, PlannerKind::Decomposed);
        let info = plan.decomposition.unwrap();
        assert_eq!(info.components, 2, "40 nodes at the default 32-node target split once");
        assert_eq!(info.sizes, vec![32, 8]);
    }
}
