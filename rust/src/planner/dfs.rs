//! Exhaustive depth-first search over lower-set sequences (§4.1).
//!
//! The rudimentary baseline: explores every increasing sequence of lower
//! sets and returns the optimum. Exponential — usable only on small graphs,
//! which is exactly its role here: it is the *oracle* that the DP planners
//! are property-tested against.

use crate::graph::{Graph, NodeSet};

use super::strategy::LowerSetChain;
use super::Objective;

/// Exhaustively find the optimal canonical strategy under `budget`.
/// Returns `None` if no sequence satisfies the budget.
///
/// Complexity is `O(#L_G^{#V})` in the worst case as the paper notes;
/// only call this on graphs with ≲ 12 nodes.
pub fn exhaustive_search(g: &Graph, budget: u64, objective: Objective) -> Option<LowerSetChain> {
    assert!(g.len() <= 20, "exhaustive search is an oracle for tiny graphs");
    let full = NodeSet::full(g.len());
    let mut best: Option<(u64, Vec<NodeSet>)> = None;
    let mut path: Vec<NodeSet> = Vec::new();
    dfs(g, budget, objective, &NodeSet::empty(g.len()), 0, 0, &full, &mut path, &mut best);
    best.map(|(_, chain)| LowerSetChain::new_unchecked(g, chain))
}

#[allow(clippy::too_many_arguments)]
fn dfs(
    g: &Graph,
    budget: u64,
    objective: Objective,
    l: &NodeSet,      // current lower set L_i
    t: u64,           // T({L_1 ≺ … ≺ L_i})
    m: u64,           // M(U_i)
    full: &NodeSet,
    path: &mut Vec<NodeSet>,
    best: &mut Option<(u64, Vec<NodeSet>)>,
) {
    if l == full {
        let better = match (&best, objective) {
            (None, _) => true,
            (Some((bt, _)), Objective::MinOverhead) => t < *bt,
            (Some((bt, _)), Objective::MaxOverhead) => t > *bt,
        };
        if better {
            *best = Some((t, path.clone()));
        }
        return;
    }
    // Enumerate all lower sets L' with L ⊊ L' by DFS over addable nodes.
    // Generate each strict superset exactly once via canonical subset
    // enumeration: collect all lower sets reachable by adding nodes.
    let supersets = strict_super_lower_sets(g, l);
    for l2 in supersets {
        // Eq. 2 terms for the prospective segment.
        let mut v_seg = l2.clone();
        v_seg.subtract(l);
        let peak = m
            + 2 * g.mem_of(&v_seg)
            + g.mem_of(&g.frontier(&l2))
            + g.mem_of(&g.frontier_coinputs(&l2));
        if peak > budget {
            continue;
        }
        let boundary = g.boundary(&l2);
        let mut recomputed = v_seg.clone();
        recomputed.subtract(&boundary);
        let t2 = t + g.time_of(&recomputed);
        let mut newly = boundary;
        newly.subtract(l);
        let m2 = m + g.mem_of(&newly);
        path.push(l2.clone());
        dfs(g, budget, objective, &l2, t2, m2, full, path, best);
        path.pop();
    }
}

/// All lower sets strictly containing `l`.
fn strict_super_lower_sets(g: &Graph, l: &NodeSet) -> Vec<NodeSet> {
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    let mut stack = vec![l.clone()];
    seen.insert(l.clone());
    while let Some(cur) = stack.pop() {
        for v in crate::graph::addable(g, &cur).iter() {
            let mut next = cur.clone();
            next.insert(v);
            if seen.insert(next.clone()) {
                out.push(next.clone());
                stack.push(next);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, NodeId, OpKind};

    fn chain_graph(mems: &[u64]) -> Graph {
        let mut b = GraphBuilder::new("chain", 1);
        let mut prev: Option<NodeId> = None;
        for (i, &m) in mems.iter().enumerate() {
            let inputs: Vec<NodeId> = prev.into_iter().collect();
            prev = Some(b.add_raw(format!("n{i}"), OpKind::Other, m, 1, &inputs));
        }
        b.build()
    }

    #[test]
    fn finds_zero_extra_overhead_at_large_budget() {
        let g = chain_graph(&[1, 1, 1, 1]);
        let c = exhaustive_search(&g, 1 << 30, Objective::MinOverhead).unwrap();
        assert_eq!(c.overhead(&g), 1); // sink only
    }

    #[test]
    fn respects_budget() {
        let g = chain_graph(&[10, 10, 10, 10]);
        for b in [25u64, 30, 40, 60, 100] {
            if let Some(c) = exhaustive_search(&g, b, Objective::MinOverhead) {
                assert!(c.peak_mem(&g) <= b);
            }
        }
        assert!(exhaustive_search(&g, 10, Objective::MinOverhead).is_none());
    }

    #[test]
    fn max_objective_not_less_than_min() {
        let g = chain_graph(&[3, 1, 4, 1, 5]);
        let b = 30;
        let tc = exhaustive_search(&g, b, Objective::MinOverhead).unwrap();
        let mc = exhaustive_search(&g, b, Objective::MaxOverhead).unwrap();
        assert!(mc.overhead(&g) >= tc.overhead(&g));
    }
}
