//! Recomputation planners — the paper's core contribution.
//!
//! Entry points:
//!
//! - [`exact_dp`] — §4.2, Algorithm 1 over **all** lower sets (optimal
//!   canonical strategy). Falls back to the approximate family when the
//!   lower-set lattice exceeds the enumeration cap.
//! - [`approx_dp`] — §4.3, Algorithm 1 over the pruned family
//!   `L^Pruned = {L^v}`, `O(T(V)·#V²)`.
//! - [`exhaustive_search`] — §4.1, the DFS oracle (tiny graphs/tests only).
//! - [`chen_plan`] — the Chen et al. (2016) √n baseline (Appendix B).
//! - [`Objective::MaxOverhead`] — §4.4 memory-centric strategies.
//! - [`min_feasible_budget`] — the binary search used throughout §5.
//!
//! All planners return a [`Plan`]: the lower-set chain plus its analytic
//! costs. *Measured* peak memory (with liveness analysis) comes from
//! [`crate::sim::simulate`] — the two are deliberately separate, mirroring
//! the paper (the DP optimizes Eq. 2; Table 1 reports simulator numbers).

mod chen;
mod dfs;
mod dp;
mod strategy;

pub use chen::{chen_plan, chen_segmentation, ChenPlan};
pub use dfs::exhaustive_search;
pub use dp::{DpContext, DpSolution};
pub use strategy::{singleton_chain, whole_graph_chain, LowerSetChain, SegmentCost};

use crate::anyhow::{anyhow, Result};

use crate::graph::{enumerate_lower_sets, pruned_lower_sets, EnumerationLimit, Graph};

/// Optimization direction for Algorithm 1's final selection (line 15).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Objective {
    /// Time-centric (§4.2/4.3): minimize recomputation overhead.
    MinOverhead,
    /// Memory-centric (§4.4): maximize overhead — coarse partitions that
    /// couple well with liveness analysis for the lowest peak memory.
    MaxOverhead,
}

/// Which algorithm produced a plan (for reports).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PlannerKind {
    ExactDp,
    ApproxDp,
    Chen,
    Exhaustive,
    Vanilla,
}

impl PlannerKind {
    pub fn label(self) -> &'static str {
        match self {
            PlannerKind::ExactDp => "ExactDP",
            PlannerKind::ApproxDp => "ApproxDP",
            PlannerKind::Chen => "Chen's",
            PlannerKind::Exhaustive => "Exhaustive",
            PlannerKind::Vanilla => "Vanilla",
        }
    }
}

/// A recomputation plan: the canonical strategy plus analytic costs.
pub struct Plan {
    pub chain: LowerSetChain,
    pub kind: PlannerKind,
    pub objective: Objective,
    /// The memory budget `B` the plan was solved under.
    pub budget: u64,
    /// Recomputation overhead (Eq. 1), in `T_v` units.
    pub overhead: u64,
    /// Analytic peak memory (Eq. 2), activations only, bytes.
    pub peak_eq2: u64,
}

impl Plan {
    fn from_solution(
        g: &Graph,
        sol: DpSolution,
        kind: PlannerKind,
        objective: Objective,
        budget: u64,
    ) -> Plan {
        let peak_eq2 = sol.chain.peak_mem(g);
        Plan { chain: sol.chain, kind, objective, budget, overhead: sol.overhead, peak_eq2 }
    }
}

/// Exact DP (§4.2) under memory budget `budget` (activation bytes).
///
/// Errors if the budget is infeasible. If the lower-set lattice is larger
/// than the enumeration cap, degrades to the approximate family (and says
/// so in the returned plan's `kind`).
pub fn exact_dp(g: &Graph, budget: u64, objective: Objective) -> Result<Plan> {
    let (ctx, exact) = exact_context(g);
    let kind = if exact { PlannerKind::ExactDp } else { PlannerKind::ApproxDp };
    let sol = ctx
        .solve(budget, objective)
        .ok_or_else(|| anyhow!("budget {budget} infeasible for {}", g.name))?;
    Ok(Plan::from_solution(g, sol, kind, objective, budget))
}

/// Approximate DP (§4.3) under memory budget `budget`.
pub fn approx_dp(g: &Graph, budget: u64, objective: Objective) -> Result<Plan> {
    let ctx = DpContext::new(g, pruned_lower_sets(g));
    let sol = ctx
        .solve(budget, objective)
        .ok_or_else(|| anyhow!("budget {budget} infeasible for {}", g.name))?;
    Ok(Plan::from_solution(g, sol, PlannerKind::ApproxDp, objective, budget))
}

/// Family selector for [`min_feasible_budget`] / [`plan_at_min_budget`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Family {
    Exact,
    Approx,
}

fn exact_context(g: &Graph) -> (DpContext<'_>, bool) {
    match enumerate_lower_sets(g, EnumerationLimit::default()) {
        Some(family) => (DpContext::new(g, family), true),
        None => (DpContext::new(g, pruned_lower_sets(g)), false),
    }
}

/// Build the (possibly expensive) DP context for a family once; reuse it
/// across budget searches and multiple solves.
pub fn build_context(g: &Graph, family: Family) -> DpContext<'_> {
    match family {
        Family::Exact => exact_context(g).0,
        Family::Approx => DpContext::new(g, pruned_lower_sets(g)),
    }
}

/// The minimal feasible budget `B*` for the given family (binary search,
/// §5.1).
pub fn min_feasible_budget(g: &Graph, family: Family) -> u64 {
    build_context(g, family).min_feasible_budget()
}

/// Solve at the minimal feasible budget — the configuration Table 1 uses
/// for both the TC and MC columns.
pub fn plan_at_min_budget(g: &Graph, family: Family, objective: Objective) -> Result<Plan> {
    let ctx = build_context(g, family);
    let b = ctx.min_feasible_budget();
    let kind = match family {
        Family::Exact => PlannerKind::ExactDp,
        Family::Approx => PlannerKind::ApproxDp,
    };
    let sol = ctx
        .solve(b, objective)
        .ok_or_else(|| anyhow!("solve at min budget {b} must succeed"))?;
    Ok(Plan::from_solution(g, sol, kind, objective, b))
}

/// Convenience: solve a prebuilt context into a [`Plan`].
pub fn plan_with_context(
    g: &Graph,
    ctx: &DpContext<'_>,
    kind: PlannerKind,
    budget: u64,
    objective: Objective,
) -> Result<Plan> {
    let sol =
        ctx.solve(budget, objective).ok_or_else(|| anyhow!("budget {budget} infeasible"))?;
    Ok(Plan::from_solution(g, sol, kind, objective, budget))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, NodeId, OpKind};
    use crate::util::rng::Pcg32;

    /// Random small DAG with random costs; always weakly connected.
    pub(crate) fn random_dag(rng: &mut Pcg32, n: u32) -> Graph {
        let mut b = GraphBuilder::new("rand", 1);
        let mut ids: Vec<NodeId> = Vec::new();
        for w in 0..n {
            let mut inputs = Vec::new();
            if w > 0 {
                inputs.push(ids[rng.below(w) as usize]);
                if rng.chance(0.35) {
                    inputs.push(ids[rng.below(w) as usize]);
                }
                inputs.sort();
                inputs.dedup();
            }
            ids.push(b.add_raw(
                format!("n{w}"),
                OpKind::Other,
                rng.range(1, 12) as u64,
                rng.range(1, 6) as u64,
                &inputs,
            ));
        }
        b.build()
    }

    #[test]
    fn exact_dp_matches_exhaustive_oracle() {
        let mut rng = Pcg32::seeded(42);
        let mut feasible_cases = 0;
        for case in 0..40 {
            let n = rng.range(4, 9);
            let g = random_dag(&mut rng, n);
            // Random budget between min node and 2·M(V).
            let budget = rng.range(
                g.nodes().map(|(_, n)| n.mem).max().unwrap() as u32,
                (2 * g.total_mem()) as u32 + 1,
            ) as u64;
            let oracle = exhaustive_search(&g, budget, Objective::MinOverhead);
            let dp = exact_dp(&g, budget, Objective::MinOverhead).ok();
            match (oracle, dp) {
                (None, None) => {}
                (Some(o), Some(d)) => {
                    feasible_cases += 1;
                    assert_eq!(
                        o.overhead(&g),
                        d.overhead,
                        "case {case}: oracle {} vs dp {}",
                        o.overhead(&g),
                        d.overhead
                    );
                    assert!(d.peak_eq2 <= budget);
                }
                (o, d) => panic!(
                    "case {case}: feasibility disagreement oracle={} dp={}",
                    o.is_some(),
                    d.is_some()
                ),
            }
        }
        assert!(feasible_cases >= 10, "want a healthy mix, got {feasible_cases}");
    }

    #[test]
    fn exact_dp_matches_oracle_for_max_objective() {
        let mut rng = Pcg32::seeded(43);
        for case in 0..25 {
            let n = rng.range(4, 8);
            let g = random_dag(&mut rng, n);
            let budget = 2 * g.total_mem();
            let oracle = exhaustive_search(&g, budget, Objective::MaxOverhead).unwrap();
            let dp = exact_dp(&g, budget, Objective::MaxOverhead).unwrap();
            assert_eq!(oracle.overhead(&g), dp.overhead, "case {case}");
        }
    }

    #[test]
    fn approx_never_beats_exact() {
        let mut rng = Pcg32::seeded(44);
        for _ in 0..25 {
            let n = rng.range(5, 10);
            let g = random_dag(&mut rng, n);
            let budget = g.total_mem() + g.nodes().map(|(_, n)| n.mem).max().unwrap();
            let exact = exact_dp(&g, budget, Objective::MinOverhead).ok();
            let approx = approx_dp(&g, budget, Objective::MinOverhead).ok();
            if let (Some(e), Some(a)) = (&exact, &approx) {
                assert!(
                    e.overhead <= a.overhead,
                    "exact searches a superset of the approx family"
                );
            }
            // If approx is feasible, exact must be too (superset family).
            if approx.is_some() {
                assert!(exact.is_some());
            }
        }
    }

    #[test]
    fn min_budget_exact_leq_approx() {
        let mut rng = Pcg32::seeded(45);
        for _ in 0..15 {
            let n = rng.range(5, 10);
            let g = random_dag(&mut rng, n);
            let be = min_feasible_budget(&g, Family::Exact);
            let ba = min_feasible_budget(&g, Family::Approx);
            assert!(be <= ba, "exact family ⊇ approx family ⇒ B*_exact ≤ B*_approx");
        }
    }

    #[test]
    fn plans_always_valid_chains() {
        let mut rng = Pcg32::seeded(46);
        for _ in 0..20 {
            let n = rng.range(4, 12);
            let g = random_dag(&mut rng, n);
            for family in [Family::Exact, Family::Approx] {
                for obj in [Objective::MinOverhead, Objective::MaxOverhead] {
                    let plan = plan_at_min_budget(&g, family, obj).unwrap();
                    // Re-validate through the checked constructor.
                    LowerSetChain::new(&g, plan.chain.lower_sets().to_vec()).unwrap();
                    assert!(plan.peak_eq2 <= plan.budget);
                }
            }
        }
    }

    #[test]
    fn mc_has_no_less_overhead_than_tc_at_same_budget() {
        let mut rng = Pcg32::seeded(47);
        for _ in 0..20 {
            let n = rng.range(4, 10);
            let g = random_dag(&mut rng, n);
            let ctx = build_context(&g, Family::Exact);
            let b = ctx.min_feasible_budget();
            let tc = ctx.solve(b, Objective::MinOverhead).unwrap();
            let mc = ctx.solve(b, Objective::MaxOverhead).unwrap();
            assert!(mc.overhead >= tc.overhead);
            assert!(mc.overhead <= g.total_time(), "§4.4: MC ≤ one forward pass");
        }
    }

    #[test]
    fn vanilla_like_chain_within_generous_budget() {
        let g = random_dag(&mut Pcg32::seeded(48), 8);
        let s = singleton_chain(&g);
        let w = whole_graph_chain(&g);
        assert!(s.overhead(&g) <= w.overhead(&g));
        assert_eq!(w.overhead(&g), g.total_time());
    }

    #[test]
    fn larger_budget_never_increases_tc_overhead() {
        let mut rng = Pcg32::seeded(49);
        for _ in 0..10 {
            let n = rng.range(5, 10);
            let g = random_dag(&mut rng, n);
            let ctx = build_context(&g, Family::Exact);
            let b0 = ctx.min_feasible_budget();
            let mut last = u64::MAX;
            for mult in [10u64, 12, 15, 20, 40] {
                let b = b0 * mult / 10;
                let sol = ctx.solve(b, Objective::MinOverhead).unwrap();
                assert!(sol.overhead <= last, "monotone in budget");
                last = sol.overhead;
            }
        }
    }

    #[test]
    fn chen_is_a_feasible_canonical_strategy() {
        let mut rng = Pcg32::seeded(50);
        for _ in 0..10 {
            let n = rng.range(6, 14);
            let g = random_dag(&mut rng, n);
            let plan = chen_plan(&g, |c| c.peak_mem(&g)).unwrap();
            LowerSetChain::new(&g, plan.chain.lower_sets().to_vec()).unwrap();
        }
    }
}
